//! Bench: Fig. 1a regenerator — pattern extraction + ranking on
//! Wiki-Vote with a 4×4 window, plus the partitioner hot path across
//! window sizes. Prints the figure once, then timing statistics.
//!
//! Run: `cargo bench --bench fig1_patterns`

use repro::graph::datasets::Dataset;
use repro::pattern::{extract::partition, rank::PatternRanking};
use repro::report::figures;
use repro::util::bench::{black_box, Bench};

fn main() {
    println!("{}", figures::fig1(None).unwrap());

    let g = Dataset::WikiVote.load().unwrap();
    let mut b = Bench::new();
    b.run("partition WV c=4", || black_box(partition(&g, 4, false)));
    b.run("partition WV c=8", || black_box(partition(&g, 8, false)));
    let part = partition(&g, 4, false);
    b.run("rank patterns WV c=4", || {
        black_box(PatternRanking::from_partitioned(&part))
    });
    b.run("fig1 end-to-end", || black_box(figures::fig1(None).unwrap()));
}
