//! Bench: Fig. 5 regenerator — BFS on Wiki-Vote with 4 static + 2
//! dynamic engines (4 crossbars each) and activity tracing on, which is
//! the worst-case scheduler overhead configuration.
//!
//! Run: `cargo bench --bench fig5_activity`

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::Bfs;
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::report::figures;
use repro::sched::executor::NativeExecutor;
use repro::util::bench::{black_box, Bench};

fn main() {
    println!("{}", figures::fig5(None).unwrap());

    let g = Dataset::WikiVote.load().unwrap();
    let acc = Accelerator::new(ArchConfig::fig5(), CostParams::default());
    let pre = acc.preprocess(&g, false).unwrap();
    let mut b = Bench::new();
    b.run("fig5 sim (traced, 6 engines)", || {
        black_box(acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap())
    });
    let acc_untraced = Accelerator::new(
        ArchConfig { trace_activity: false, ..ArchConfig::fig5() },
        CostParams::default(),
    );
    b.run("fig5 sim (untraced)", || {
        black_box(acc_untraced.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap())
    });
}
