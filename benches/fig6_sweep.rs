//! Bench: Fig. 6 regenerator — static-engine sweep (N ∈ 0..32) over
//! three datasets, normalized to N = 0.
//!
//! Run: `cargo bench --bench fig6_sweep`

use std::time::Duration;

use repro::accel::ArchConfig;
use repro::algo::Bfs;
use repro::cost::CostParams;
use repro::dse::static_engine_sweep;
use repro::graph::datasets::Dataset;
use repro::report::figures;
use repro::util::bench::{black_box, Bench};

fn main() {
    println!("{}", figures::fig6(None).unwrap());

    let g = Dataset::Gnutella.load().unwrap();
    let mut b = Bench::new().with_target(Duration::from_secs(5)).with_max_iters(10);
    b.run("static sweep PG (5 points)", || {
        black_box(
            static_engine_sweep(
                &g,
                &ArchConfig::default(),
                &CostParams::default(),
                &Bfs::new(0),
                &[0, 8, 16, 24, 31],
            )
            .unwrap(),
        )
    });
}
