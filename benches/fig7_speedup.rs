//! Bench: Fig. 7 regenerator — BFS speedup of all four designs
//! normalized to GraphR, across the six Table 2 datasets.
//!
//! Run: `cargo bench --bench fig7_speedup`

use std::time::Duration;

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::Bfs;
use repro::baselines;
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::report::figures;
use repro::sched::executor::NativeExecutor;
use repro::util::bench::{black_box, Bench};

fn main() {
    println!("{}", figures::fig7(None).unwrap());

    let g = Dataset::WikiVote.load().unwrap();
    let params = CostParams::default();
    let mut b = Bench::new().with_target(Duration::from_secs(4)).with_max_iters(15);
    let acc = Accelerator::new(ArchConfig::default(), params.clone());
    let pre = acc.preprocess(&g, false).unwrap();
    b.run("proposed sim WV", || {
        black_box(acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap())
    });
    b.run("baseline models WV (x3)", || {
        black_box(baselines::simulate_all(&g, 0, &params, 32))
    });
}
