//! Bench: hot-path microbenchmarks — the components the performance pass
//! (EXPERIMENTS.md §Perf) optimizes: plan compilation vs per-superstep
//! interpretation, scheduler dispatch throughput, native executor, PJRT
//! dispatch, partitioner, and the serving loop.
//!
//! Results are also written to `BENCH_hotpath.json` so the hot path is
//! tracked across PRs.
//!
//! Run: `make artifacts && cargo bench --bench hotpath`

use std::time::Duration;

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::traits::{StepKind, INF};
use repro::algo::{Bfs, PageRank};
use repro::cost::CostParams;
use repro::coordinator::{Service, ServiceConfig};
use repro::graph::datasets::Dataset;
use repro::pattern::extract::partition;
use repro::sched::executor::{NativeExecutor, StepExecutor};
use repro::sched::ExecutionPlan;
use repro::session::JobSpec;
use repro::util::bench::{black_box, Bench};
use repro::util::SplitMix64;

fn main() {
    let g = Dataset::WikiVote.load().unwrap();
    let arch = ArchConfig::default();
    let acc = Accelerator::new(arch.clone(), CostParams::default());
    let pre = acc.preprocess(&g, false).unwrap();
    let ops = pre.part.num_subgraphs() as u64;
    let mut b = Bench::new().with_target(Duration::from_secs(3)).with_max_iters(20);

    // Plan compilation: the one-time cost the ArtifactStore amortizes
    // across every run/serve/DSE caller of the same artifact key.
    b.run("plan build WV", || {
        black_box(ExecutionPlan::build(&pre.part, &pre.ct, &pre.st, &arch))
    });

    // Plan interpretation end to end (scheduler + native executor) — the
    // per-job cost once the plan is compiled, sequential vs lane-parallel
    // (results are bit-identical; only wall time may differ).
    let s = b
        .run("plan interpret: BFS WV threads=1", || {
            black_box(acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap())
        })
        .mean;
    let run = acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap();
    println!(
        "  -> {:.2} M subgraph-dispatches/s ({} ops per run, {:.1} µs/superstep over {})",
        run.counts.mvm_ops as f64 / s.as_secs_f64() / 1e6,
        run.counts.mvm_ops,
        s.as_secs_f64() * 1e6 / run.supersteps.max(1) as f64,
        run.supersteps,
    );

    let s4 = b
        .run("plan interpret: BFS WV threads=4", || {
            black_box(
                acc.run_threaded(&pre, &Bfs::new(0), &mut NativeExecutor, 4)
                    .unwrap(),
            )
        })
        .mean;
    println!("  -> {:.2}x vs threads=1", s.as_secs_f64() / s4.as_secs_f64());

    let sp = b
        .run("plan interpret: PageRank(5) WV threads=1", || {
            black_box(acc.run(&pre, &PageRank::new(0.85, 5), &mut NativeExecutor).unwrap())
        })
        .mean;
    let sp4 = b
        .run("plan interpret: PageRank(5) WV threads=4", || {
            black_box(
                acc.run_threaded(&pre, &PageRank::new(0.85, 5), &mut NativeExecutor, 4)
                    .unwrap(),
            )
        })
        .mean;
    println!("  -> {:.2}x vs threads=1", sp.as_secs_f64() / sp4.as_secs_f64());

    // Native executor alone on a big batch.
    let part = partition(&g, 4, false);
    let exec_plan = ExecutionPlan::from_partitioned(&part);
    let n = part.num_subgraphs().min(50_000);
    let sgs: Vec<u32> = (0..n as u32).collect();
    let mut rng = SplitMix64::new(7);
    let xs: Vec<f32> = (0..n * 4)
        .map(|_| if rng.next_bool(0.5) { INF } else { rng.next_f32() * 8.0 })
        .collect();
    let mut out = Vec::new();
    let st = b.run("native executor 50k subgraphs", || {
        NativeExecutor
            .execute(StepKind::Bfs, exec_plan.batch(&sgs), &xs, &mut out)
            .unwrap();
        black_box(out.len())
    });
    println!(
        "  -> {:.1} M subgraph-MVMs/s",
        n as f64 / st.mean.as_secs_f64() / 1e6
    );

    // Partitioner.
    b.run("partition WV c=4", || black_box(partition(&g, 4, false)));

    // PJRT dispatch path (needs `make artifacts` + `--features pjrt`).
    #[cfg(feature = "pjrt")]
    match repro::runtime::PjrtExecutor::from_default_dir() {
        Ok(mut pjrt) => {
            let n = 4096.min(part.num_subgraphs());
            let sgs: Vec<u32> = (0..n as u32).collect();
            let xs2 = &xs[..n * 4];
            let st = b.run("pjrt executor 4k subgraphs", || {
                pjrt.execute(StepKind::Bfs, exec_plan.batch(&sgs), xs2, &mut out)
                    .unwrap();
                black_box(out.len())
            });
            println!(
                "  -> {:.2} M subgraph-MVMs/s through PJRT",
                n as f64 / st.mean.as_secs_f64() / 1e6
            );
        }
        Err(e) => println!("(pjrt bench skipped: {e})"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt bench skipped: built without the `pjrt` feature)");

    // Serving loop throughput.
    let st = b.run("serving loop: 16 mixed jobs (Tiny)", || {
        let svc =
            Service::spawn(ServiceConfig { workers: 4, ..ServiceConfig::default() }).unwrap();
        let pending: Vec<_> = (0..16u32)
            .map(|i| {
                svc.submit(match i % 2 {
                    0 => JobSpec::new(Dataset::Tiny, "bfs").with_source(i),
                    _ => JobSpec::new(Dataset::Tiny, "wcc"),
                })
                .unwrap()
            })
            .collect();
        for p in pending {
            p.wait().unwrap();
        }
    });
    println!("  -> {:.0} jobs/s", 16.0 / st.mean.as_secs_f64());

    if let Err(e) = b.write_json("BENCH_hotpath.json") {
        eprintln!("(could not write BENCH_hotpath.json: {e})");
    } else {
        println!("wrote BENCH_hotpath.json ({} entries)", b.results().len());
    }
    let _ = ops;
}
