//! Bench: hot-path microbenchmarks — the components the performance pass
//! (EXPERIMENTS.md §Perf) optimizes: plan compilation vs per-superstep
//! interpretation (sequential vs the scoped-spawn baseline vs the
//! persistent worker pool at threads=1/4), scheduler dispatch throughput,
//! native executor, PJRT dispatch, partitioner, cold preprocess vs
//! on-disk artifact load (the `--artifact-dir` warm-start win), and the
//! serving loop.
//!
//! Results are written to `BENCH_hotpath.json` at the **repo root**
//! (anchored on `CARGO_MANIFEST_DIR`, not the invocation cwd) so the hot
//! path is tracked across PRs. The pooled-vs-scoped pair is the headline
//! number: same dispatch, same bit-identical result, no per-superstep
//! spawn/join tax.
//!
//! Run: `make artifacts && cargo bench --bench hotpath`
//! CI smoke: `BENCH_SMOKE=1 cargo bench --bench hotpath` (tiny dataset,
//! short target — keeps the harness compiling and running without
//! burning minutes).

use std::time::Duration;

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::traits::{StepKind, INF};
use repro::algo::{Bfs, PageRank};
use repro::cost::CostParams;
use repro::coordinator::{Service, ServiceConfig};
use repro::graph::datasets::Dataset;
use repro::graph::{DeltaBatch, EdgeDelta};
use repro::pattern::extract::{partition, partition_chunked};
use repro::sched::executor::{NativeExecutor, StepExecutor};
use repro::sched::{
    patch_preprocessed, run_parallel_pooled, run_parallel_scoped, ExecutionPlan, WorkerPool,
};
use repro::session::{ArtifactKey, DiskStore, JobSpec};
use repro::util::bench::{black_box, Bench};
use repro::util::SplitMix64;

fn main() {
    // Truthy check: `BENCH_SMOKE=0` or empty means a full run.
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let dataset = if smoke { Dataset::Tiny } else { Dataset::WikiVote };
    let g = dataset.load().unwrap();
    let edges = g.edges.len() as u64;
    let arch = ArchConfig::default();
    let params = CostParams::default();
    let acc = Accelerator::new(arch.clone(), params.clone());
    let pre = acc.preprocess(&g, false).unwrap();
    let ops = pre.part.num_subgraphs() as u64;
    let (target, max_iters) = if smoke {
        (Duration::from_millis(50), 3)
    } else {
        (Duration::from_secs(3), 20)
    };
    let mut b = Bench::new().with_target(target).with_max_iters(max_iters);

    // Plan compilation: the one-time cost the ArtifactStore amortizes
    // across every run/serve/DSE caller of the same artifact key.
    b.run("plan build", || {
        black_box(ExecutionPlan::build(&pre.part, &pre.ct, &pre.st, &arch))
    });

    // Plan interpretation end to end (scheduler + native executor) — the
    // per-job cost once the plan is compiled. Three mechanisms, one
    // bit-identical result: sequential interpreter, the scoped-spawn
    // baseline (spawn/join per superstep — what the pool replaced), and
    // the persistent pool (spawned once, reused across every iteration
    // below, exactly like a Session reuses it across jobs).
    let mut pool = WorkerPool::new(4);
    let bfs_run = acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap();
    let bfs_steps = bfs_run.supersteps as u64;

    let s = b
        .run("interpret: BFS threads=1", || {
            black_box(acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap())
        })
        .mean;
    // BFS relaxes each edge roughly once across the whole frontier-masked
    // run (unlike PageRank's full sweep per superstep), so one iteration's
    // edge work is ~`edges`, not edges × supersteps.
    b.annotate_throughput(edges, bfs_steps);
    println!(
        "  -> {:.2} M subgraph-dispatches/s ({} ops per run, {:.1} µs/superstep over {})",
        bfs_run.counts.mvm_ops as f64 / s.as_secs_f64() / 1e6,
        bfs_run.counts.mvm_ops,
        s.as_secs_f64() * 1e6 / bfs_run.supersteps.max(1) as f64,
        bfs_run.supersteps,
    );

    let s4s = b
        .run("interpret: BFS threads=4 scoped", || {
            black_box(
                run_parallel_scoped(&arch, &params, &pre.plan, &Bfs::new(0), &mut NativeExecutor, 4)
                    .unwrap(),
            )
        })
        .mean;
    b.annotate_throughput(edges, bfs_steps);
    let s4p = b
        .run("interpret: BFS threads=4 pooled", || {
            black_box(
                run_parallel_pooled(
                    &arch,
                    &params,
                    &pre.plan,
                    &Bfs::new(0),
                    &mut NativeExecutor,
                    &mut pool,
                )
                .unwrap(),
            )
        })
        .mean;
    b.annotate_throughput(edges, bfs_steps);
    println!(
        "  -> scoped {:.2}x, pooled {:.2}x vs threads=1 (pool wins {:.2}x over scoped)",
        s.as_secs_f64() / s4s.as_secs_f64(),
        s.as_secs_f64() / s4p.as_secs_f64(),
        s4s.as_secs_f64() / s4p.as_secs_f64(),
    );

    let pr = PageRank::new(0.85, 5);
    let sp = b
        .run("interpret: PageRank(5) threads=1", || {
            black_box(acc.run(&pre, &pr, &mut NativeExecutor).unwrap())
        })
        .mean;
    b.annotate_throughput(edges * 5, 5);
    let sp4s = b
        .run("interpret: PageRank(5) threads=4 scoped", || {
            black_box(
                run_parallel_scoped(&arch, &params, &pre.plan, &pr, &mut NativeExecutor, 4)
                    .unwrap(),
            )
        })
        .mean;
    b.annotate_throughput(edges * 5, 5);
    let sp4p = b
        .run("interpret: PageRank(5) threads=4 pooled", || {
            black_box(
                run_parallel_pooled(&arch, &params, &pre.plan, &pr, &mut NativeExecutor, &mut pool)
                    .unwrap(),
            )
        })
        .mean;
    b.annotate_throughput(edges * 5, 5);
    println!(
        "  -> scoped {:.2}x, pooled {:.2}x vs threads=1 (pool wins {:.2}x over scoped)",
        sp.as_secs_f64() / sp4s.as_secs_f64(),
        sp.as_secs_f64() / sp4p.as_secs_f64(),
        sp4s.as_secs_f64() / sp4p.as_secs_f64(),
    );

    // Sharded scale-out: the same workload decomposed across 4 simulated
    // accelerators running lockstep supersteps with cross-shard frontier
    // exchange (bit-identical result — tests/shard.rs). shards=1 routes
    // through the exchange entry point but delegates to the single-plan
    // path, so the pair isolates the exchange layer's own cost; the
    // pooled row is the serve-fleet shape (one persistent pool per
    // shard from the session free list).
    let sharded = acc.preprocess_sharded(&g, false, 4, None).unwrap();
    let refs: Vec<&_> = sharded.iter().collect();
    let one_shard = acc.preprocess_sharded(&g, false, 1, None).unwrap();
    let one_ref: Vec<&_> = one_shard.iter().collect();
    let sh1 = b
        .run("interpret: BFS shards=1 threads=4", || {
            black_box(acc.run_sharded(&one_ref, &Bfs::new(0), &mut NativeExecutor, 4).unwrap())
        })
        .mean;
    b.annotate_throughput(edges, bfs_steps);
    let sh4 = b
        .run("interpret: BFS shards=4 threads=4", || {
            black_box(acc.run_sharded(&refs, &Bfs::new(0), &mut NativeExecutor, 4).unwrap())
        })
        .mean;
    b.annotate_throughput(edges, bfs_steps);
    let mut shard_pools: Vec<WorkerPool> = (0..4).map(|_| WorkerPool::new(4)).collect();
    let sh4p = b
        .run("interpret: BFS shards=4 threads=4 pooled", || {
            black_box(
                acc.run_sharded_pooled(
                    &refs,
                    &Bfs::new(0),
                    &mut NativeExecutor,
                    &mut shard_pools,
                    4,
                )
                .unwrap(),
            )
        })
        .mean;
    b.annotate_throughput(edges, bfs_steps);
    println!(
        "  -> 4-shard exchange {:.2}x vs shards=1 (pooled {:.2}x; overhead is the scale-out tax one box pays to rehearse a fleet)",
        sh1.as_secs_f64() / sh4.as_secs_f64(),
        sh1.as_secs_f64() / sh4p.as_secs_f64(),
    );

    // Native executor alone on a big batch.
    let part = partition(&g, 4, false);
    let exec_plan = ExecutionPlan::from_partitioned(&part);
    let n = part.num_subgraphs().min(50_000);
    let sgs: Vec<u32> = (0..n as u32).collect();
    let mut rng = SplitMix64::new(7);
    let xs: Vec<f32> = (0..n * 4)
        .map(|_| if rng.next_bool(0.5) { INF } else { rng.next_f32() * 8.0 })
        .collect();
    let mut out = Vec::new();
    let st = b.run("native executor 50k subgraphs", || {
        NativeExecutor
            .execute(StepKind::Bfs, exec_plan.batch(&sgs), &xs, &mut out)
            .unwrap();
        black_box(out.len())
    });
    println!(
        "  -> {:.1} M subgraph-MVMs/s",
        n as f64 / st.mean.as_secs_f64() / 1e6
    );

    // Partitioner: monolithic vs the chunked build the parallel
    // preprocess path merges from (4096-edge chunks — the merge overhead
    // the determinism contract pays for, measured on one thread).
    b.run("partition c=4", || black_box(partition(&g, 4, false)));
    b.run("partition chunked c=4", || {
        black_box(partition_chunked(&g, 4, false, 4096))
    });

    // Warm-start: full cold preprocess (dataset already in memory:
    // partition + ranking + CT/ST + plan compile) vs deserializing the
    // persisted artifact from the on-disk cache — the cost a restarted
    // serve fleet pays per key with and without --artifact-dir.
    let art_dir = std::env::temp_dir().join(format!("repro-hotpath-art-{}", std::process::id()));
    let disk = DiskStore::open(&art_dir).unwrap();
    disk.clear();
    let art_key = ArtifactKey::new(dataset, 1.0, false, &arch);
    let sc = b
        .run("preprocess cold threads=1", || {
            black_box(acc.preprocess(&g, false).unwrap())
        })
        .mean;
    // Same compile fanned out over the persistent pool — the cold-miss
    // path a `--threads 4` session actually takes (bit-identical result,
    // see tests/preprocess_par.rs).
    let sc4 = b
        .run("preprocess cold threads=4", || {
            black_box(acc.preprocess_pooled(&g, false, &mut pool).unwrap())
        })
        .mean;
    println!(
        "  -> parallel cold preprocess {:.2}x vs threads=1",
        sc.as_secs_f64() / sc4.as_secs_f64(),
    );
    assert!(disk.save(&art_key, &pre).unwrap(), "bench dir must start cold");
    let sw = b
        .run("artifact disk load (warm start)", || {
            black_box(disk.load(&art_key, &arch).unwrap())
        })
        .mean;
    println!(
        "  -> warm start {:.2}x faster than cold preprocess ({} B on disk)",
        sc.as_secs_f64() / sw.as_secs_f64(),
        std::fs::metadata(disk.path_of(&art_key)).map(|m| m.len()).unwrap_or(0),
    );
    let _ = std::fs::remove_dir_all(&art_dir);

    // Streaming mutation: incremental plan patch vs cold recompile of
    // the mutated graph — the cost per churn event with and without the
    // delta path. Each iteration applies a full remove + re-add cycle of
    // one existing edge, so the patched artifact returns to its starting
    // state (bit-identical, asserted once below) and every iteration
    // patches the same dirty windows.
    let e = g.edges[0];
    let one = |d: EdgeDelta| DeltaBatch::new(g.num_vertices, vec![d]).unwrap();
    let remove = one(EdgeDelta::remove(e.src, e.dst));
    let readd = one(EdgeDelta::add_weighted(e.src, e.dst, e.weight));
    let mutated = remove.apply_to_coo(&g).unwrap();
    let mut p = pre.clone();
    let pstats = patch_preprocessed(&mut p, &remove, &arch).unwrap();
    patch_preprocessed(&mut p, &readd, &arch).unwrap();
    assert_eq!(p, pre, "churn cycle must restore the artifact bit for bit");
    let spatch = b
        .run("delta patch: 1-edge churn (remove + re-add)", || {
            patch_preprocessed(&mut p, &remove, &arch).unwrap();
            patch_preprocessed(&mut p, &readd, &arch).unwrap();
            black_box(p.plan.num_ops())
        })
        .mean;
    let scold = b
        .run("preprocess after delta (cold recompile)", || {
            black_box(acc.preprocess(&mutated, false).unwrap())
        })
        .mean;
    println!(
        "  -> patch {:.1}x faster than cold recompile per batch ({} dirty windows, {} plan ops)",
        scold.as_secs_f64() / (spatch.as_secs_f64() / 2.0),
        pstats.dirty_partitions,
        pstats.patched_ops,
    );

    // PJRT dispatch path (needs `make artifacts` + `--features pjrt`).
    #[cfg(feature = "pjrt")]
    match repro::runtime::PjrtExecutor::from_default_dir() {
        Ok(mut pjrt) => {
            let n = 4096.min(part.num_subgraphs());
            let sgs: Vec<u32> = (0..n as u32).collect();
            let xs2 = &xs[..n * 4];
            let st = b.run("pjrt executor 4k subgraphs", || {
                pjrt.execute(StepKind::Bfs, exec_plan.batch(&sgs), xs2, &mut out)
                    .unwrap();
                black_box(out.len())
            });
            println!(
                "  -> {:.2} M subgraph-MVMs/s through PJRT",
                n as f64 / st.mean.as_secs_f64() / 1e6
            );
        }
        Err(e) => println!("(pjrt bench skipped: {e})"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt bench skipped: built without the `pjrt` feature)");

    // Serving loop throughput (workers share the session's persistent
    // pool through the coordinator).
    let st = b.run("serving loop: 16 mixed jobs (Tiny)", || {
        let svc =
            Service::spawn(ServiceConfig { workers: 4, ..ServiceConfig::default() }).unwrap();
        let pending: Vec<_> = (0..16u32)
            .map(|i| {
                svc.submit(match i % 2 {
                    0 => JobSpec::new(Dataset::Tiny, "bfs").with_source(i),
                    _ => JobSpec::new(Dataset::Tiny, "wcc"),
                })
                .unwrap()
            })
            .collect();
        for p in pending {
            p.wait().unwrap();
        }
    });
    println!("  -> {:.0} jobs/s", 16.0 / st.mean.as_secs_f64());

    // Land the trajectory at the repo root regardless of invocation cwd —
    // but never from a smoke run: Tiny-scale timings under the real entry
    // names would silently corrupt the cross-PR trajectory. The smoke
    // still exercises the writer end to end against a throwaway path
    // (and fails loudly if it breaks).
    if smoke {
        let tmp = std::env::temp_dir().join("BENCH_hotpath.smoke.json");
        b.write_json(&tmp).expect("smoke write of bench JSON failed");
        println!(
            "(BENCH_SMOKE: wrote throwaway {} — repo trajectory untouched)",
            tmp.display()
        );
    } else {
        let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_hotpath.json");
        if let Err(e) = b.write_json(out_path) {
            eprintln!("(could not write {out_path}: {e})");
        } else {
            println!("wrote {out_path} ({} entries)", b.results().len());
        }
    }
    let _ = ops;
}
