//! Bench: §IV.D regenerator — lifetime analysis (128 engines, Wiki-Vote
//! hourly, E = 1e8).
//!
//! Run: `cargo bench --bench lifetime`

use std::time::Duration;

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::Bfs;
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::report::figures;
use repro::sched::executor::NativeExecutor;
use repro::util::bench::{black_box, Bench};

fn main() {
    println!("{}", figures::lifetime(None).unwrap());

    let g = Dataset::WikiVote.load().unwrap();
    let acc = Accelerator::new(ArchConfig::lifetime(), CostParams::default());
    let pre = acc.preprocess(&g, false).unwrap();
    let mut b = Bench::new().with_target(Duration::from_secs(4)).with_max_iters(15);
    b.run("lifetime config sim (128 engines)", || {
        black_box(acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap())
    });
}
