//! Bench: serving-tier load studies through `coordinator::loadgen` —
//! the three traffic shapes the production queue is built for:
//!
//! 1. **Closed-loop mixed burst** — the throughput ceiling: N virtual
//!    clients drive a 4-worker service as fast as completions allow.
//! 2. **Coalesce burst** — every job of an algorithm shares one
//!    `CoalesceKey` (`sources: 1`), so queued duplicates ride one
//!    execution; the `coalesced` count against `subgraph_ops` is the
//!    amortization win, the paper's thesis applied to the serve queue.
//! 3. **Open-loop overload** — arrivals at a fixed rate a single worker
//!    cannot sustain, with a per-job deadline: queue-wait percentiles
//!    grow and expired jobs are load-shed instead of executed.
//! 4. **Compatible burst, batched** — one algorithm over a wide source
//!    spread against a single batching worker (`max_batch`): queued
//!    batch-compatible jobs run as multi-source batches, paying the
//!    plan walk / crossbar replay / pool dispatch once per batch. The
//!    `batched` count against `completed` is the formation rate; every
//!    report stays bit-identical to its solo run.
//!
//! Results are written to `BENCH_serve.json` at the **repo root**
//! (anchored on `CARGO_MANIFEST_DIR`, not the invocation cwd) so serve
//! latency/throughput is tracked across PRs next to the hotpath
//! trajectory.
//!
//! Run: `cargo bench --bench serve`
//! CI smoke: `BENCH_SMOKE=1 cargo bench --bench serve` (tiny dataset,
//! few jobs, throwaway output path — keeps the harness compiling and
//! running without burning minutes).

use std::time::Duration;

use repro::coordinator::{loadgen, LoadMode, LoadgenConfig, Service, ServiceConfig};
use repro::graph::datasets::Dataset;

fn service(workers: usize, queue_depth: usize) -> Service {
    Service::spawn(ServiceConfig { workers, queue_depth, ..ServiceConfig::default() }).unwrap()
}

fn main() {
    // Truthy check: `BENCH_SMOKE=0` or empty means a full run.
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let dataset = if smoke { Dataset::Tiny } else { Dataset::WikiVote };
    let jobs = if smoke { 8 } else { 256 };
    let mut reports = Vec::new();

    // 1. Closed-loop throughput ceiling: 8 clients, 4 workers, wide
    // key space (little coalescing — this measures raw serve capacity).
    {
        let svc = service(4, 0);
        let cfg = LoadgenConfig {
            name: "closed-loop mixed".to_string(),
            dataset,
            jobs,
            mode: LoadMode::Closed { concurrency: 8 },
            sources: 64,
            ..LoadgenConfig::default()
        };
        let r = loadgen::run(&svc, &cfg).expect("closed-loop run");
        println!("{}\n", r.render());
        reports.push(r);
    }

    // 2. Coalesce burst: one source per algorithm — queued duplicates
    // share executions; `completed - subgraph-op-weighted executions`
    // is work the queue amortized away.
    {
        let svc = service(2, 0);
        let cfg = LoadgenConfig {
            name: "coalesce burst".to_string(),
            dataset,
            jobs,
            mode: LoadMode::Closed { concurrency: 8 },
            sources: 1,
            ..LoadgenConfig::default()
        };
        let r = loadgen::run(&svc, &cfg).expect("coalesce run");
        println!("{}\n", r.render());
        reports.push(r);
    }

    // 3. Open-loop overload + deadlines: arrivals outpace one worker,
    // queue-wait tails grow, expired jobs are shed unexecuted. The
    // queue stays unbounded so arrival pacing is never backpressured —
    // the open-loop contract.
    {
        let svc = service(1, 0);
        let cfg = LoadgenConfig {
            name: "open-loop overload".to_string(),
            dataset,
            jobs,
            mode: LoadMode::Open { rate_per_s: if smoke { 100_000.0 } else { 2_000.0 } },
            deadline: Some(Duration::from_millis(if smoke { 50 } else { 20 })),
            sources: 64,
            ..LoadgenConfig::default()
        };
        let r = loadgen::run(&svc, &cfg).expect("open-loop run");
        println!("{}\n", r.render());
        reports.push(r);
    }

    // 4. Compatible burst + batching: a deep closed loop over one
    // algorithm keeps batch-compatible work queued at the single
    // worker, whose execution lanes make the batched pipeline pass
    // eligible (`threads > 1`). Compare against scenario 2: coalescing
    // dedupes identical results, batching shares the walk across
    // *different* sources.
    {
        let svc = Service::spawn(ServiceConfig {
            workers: 1,
            parallelism: 4,
            max_batch: 8,
            queue_depth: 0,
            ..ServiceConfig::default()
        })
        .expect("batched service");
        let cfg = LoadgenConfig {
            name: "compatible burst batched".to_string(),
            dataset,
            jobs,
            mode: LoadMode::Closed { concurrency: 8 },
            algorithms: vec!["bfs".to_string()],
            sources: 64,
            ..LoadgenConfig::default()
        };
        let r = loadgen::run(&svc, &cfg).expect("batched run");
        println!("{}\n", r.render());
        reports.push(r);
    }

    // Land the trajectory at the repo root regardless of invocation cwd —
    // but never from a smoke run: Tiny-scale numbers under the real
    // scenario names would silently corrupt the cross-PR trajectory. The
    // smoke still exercises the writer end to end against a throwaway
    // path (and fails loudly if it breaks).
    if smoke {
        let tmp = std::env::temp_dir().join("BENCH_serve.smoke.json");
        loadgen::write_json(&tmp, &reports).expect("smoke write of serve JSON failed");
        println!(
            "(BENCH_SMOKE: wrote throwaway {} — repo trajectory untouched)",
            tmp.display()
        );
    } else {
        let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_serve.json");
        if let Err(e) = loadgen::write_json(out_path, &reports) {
            eprintln!("(could not write {out_path}: {e})");
        } else {
            println!("wrote {out_path} ({} scenarios)", reports.len());
        }
    }
}
