//! Bench: Table 4 regenerator — BFS energy of all four designs across
//! the six Table 2 datasets.
//!
//! Run: `cargo bench --bench table4_energy`

use std::time::Duration;

use repro::report::figures;
use repro::util::bench::{black_box, Bench};

fn main() {
    println!("{}", figures::table4(None).unwrap());

    let mut b = Bench::new().with_target(Duration::from_secs(4)).with_max_iters(5);
    // Small-scale end-to-end regeneration timing (full scale printed above).
    b.run("table4 end-to-end (5% scale)", || {
        black_box(figures::table4(Some(0.05)).unwrap())
    });
}
