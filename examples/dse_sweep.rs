//! Design-space exploration driver (paper Fig. 6 + conclusion):
//! sweeps static/dynamic engine splits, crossbar sizes, and replacement
//! policies on three datasets, and reports the best configuration the
//! DSE framework would pick for each.
//!
//! Run: `cargo run --release --example dse_sweep`

use anyhow::Result;

use repro::accel::ArchConfig;
use repro::algo::Bfs;
use repro::cost::CostParams;
use repro::dse::{crossbar_sweep, find_best_static_split, policy_sweep};
use repro::graph::datasets::Dataset;
use repro::report::Table;
use repro::util::fmt;

fn main() -> Result<()> {
    let params = CostParams::default();
    let datasets = [Dataset::WikiVote, Dataset::Epinions, Dataset::Gnutella];

    println!("== static/dynamic split (T = 32, 4x4 crossbars) ==");
    for d in datasets {
        let g = d.load()?;
        let (best, points) = find_best_static_split(
            &g,
            &ArchConfig::default(),
            &params,
            &Bfs::new(0),
            Some(&[0, 2, 4, 8, 12, 16, 20, 24, 28, 31]),
        )?;
        let mut t = Table::new(format!("{} ({})", d.spec().name, d.spec().short))
            .header(["N static", "speedup", "energy", "writes (bits)", "hit rate"]);
        for p in &points {
            t.row([
                p.x.to_string(),
                format!("{:.2}x", p.speedup),
                fmt::energy(p.energy_j),
                fmt::count(p.write_bits),
                format!("{:.1}%", p.static_hit_rate * 100.0),
            ]);
        }
        print!("{}", t.render());
        println!("→ best split for {}: N = {best}\n", d.spec().short);
    }

    println!("== crossbar-size ablation (Wiki-Vote) ==");
    let g = Dataset::WikiVote.load()?;
    let points = crossbar_sweep(&g, &ArchConfig::default(), &params, &Bfs::new(0), &[2, 4, 8])?;
    let mut t = Table::new("window/crossbar size C")
        .header(["C", "speedup vs C=2", "energy", "hit rate"]);
    for p in &points {
        t.row([
            p.x.to_string(),
            format!("{:.2}x", p.speedup),
            fmt::energy(p.energy_j),
            format!("{:.1}%", p.static_hit_rate * 100.0),
        ]);
    }
    print!("{}", t.render());

    println!("\n== replacement-policy ablation (Wiki-Vote, 16 dynamic engines) ==");
    let out = policy_sweep(&g, &ArchConfig::default(), &params, &Bfs::new(0))?;
    let mut t =
        Table::new("dynamic-engine replacement").header(["policy", "time vs LRU", "writes (bits)"]);
    let lru_time = out[0].1.exec_time_ns;
    for (kind, p) in &out {
        t.row([
            kind.name().to_string(),
            format!("{:.3}x", p.exec_time_ns / lru_time),
            fmt::count(p.write_bits),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
