//! END-TO-END DRIVER (DESIGN.md §6): the full three-layer stack on a
//! real-scale workload.
//!
//! 1. Generates the Wiki-Vote-scale benchmark (7 K vertices / ~104 K
//!    edges, seeded R-MAT matched to paper Table 2).
//! 2. Preprocesses it (Alg. 1): window partition → pattern ranking →
//!    static/dynamic assignment (paper default: 32 engines, 16 static,
//!    4×4 crossbars).
//! 3. Runs BFS through the production datapath: the rust scheduler
//!    (Alg. 2) dispatches every batch's edge-compute to the AOT-lowered
//!    HLO artifact executing on the PJRT CPU client — the kernel that
//!    actually computes vertex updates is the Pallas/JAX program lowered
//!    by `make artifacts`. Python is not running.
//! 4. Validates the resulting levels against the pure-CPU reference BFS
//!    and cross-checks PJRT vs the native mirror.
//! 5. Reports the paper's metrics (energy, modeled time, static hit
//!    rate, ReRAM writes, lifetime) plus host-side throughput.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end_bfs`
//! Recorded in EXPERIMENTS.md §End-to-end.

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "end_to_end_bfs drives the AOT/PJRT datapath; rebuild with \
         `--features pjrt` and run `make artifacts` first."
    );
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    pjrt_demo::run()
}

#[cfg(feature = "pjrt")]
mod pjrt_demo {
    use std::time::Instant;

    use anyhow::Result;

    use repro::accel::{Accelerator, ArchConfig};
    use repro::algo::{reference, traits::INF, Bfs, PageRank};
    use repro::cost::{lifetime_seconds, CostParams};
    use repro::graph::datasets::Dataset;
    use repro::graph::{Csr, GraphStats};
    use repro::runtime::PjrtExecutor;
    use repro::sched::executor::NativeExecutor;
    use repro::util::fmt;

    pub fn run() -> Result<()> {
        // --- 1. workload ---
        let dataset = Dataset::WikiVote;
        let g = dataset.load()?;
        let s = GraphStats::of(&g);
        println!(
            "workload: {} — {} vertices, {} edges, avg degree {:.1}, sparsity {:.3}%",
            dataset.spec().name,
            fmt::count(s.num_vertices as u64),
            fmt::count(s.num_edges as u64),
            s.avg_degree,
            s.sparsity_pct
        );

        // --- 2. preprocessing (Alg. 1) ---
        let params = CostParams::default();
        let acc = Accelerator::new(ArchConfig::default(), params.clone());
        let t0 = Instant::now();
        let pre = acc.preprocess(&g, false)?;
        println!(
            "preprocess: {} subgraphs, {} patterns, top-16 coverage {:.1}%, static coverage {:.1}% ({} ms)",
            fmt::count(pre.part.num_subgraphs() as u64),
            pre.ranking.num_patterns(),
            pre.ranking.coverage(16) * 100.0,
            pre.static_coverage() * 100.0,
            t0.elapsed().as_millis()
        );

        // --- 3. BFS through the AOT/PJRT datapath ---
        let mut pjrt = PjrtExecutor::from_default_dir()?;
        println!("datapath: PJRT ({})", pjrt.runtime.platform());
        let t1 = Instant::now();
        let report = acc.run(&pre, &Bfs::new(0), &mut pjrt)?;
        let wall = t1.elapsed();
        let run = report.run.as_ref().unwrap();
        println!(
            "bfs: {} supersteps, {} scheduler iterations, {} subgraph ops, {} PJRT dispatches, wall {:.2} s",
            report.supersteps,
            fmt::count(report.iterations),
            fmt::count(report.counts.mvm_ops),
            fmt::count(pjrt.runtime.dispatches),
            wall.as_secs_f64()
        );

        // --- 4. validation ---
        let csr = Csr::from_coo(&g);
        let want = reference::bfs_levels(&csr, 0);
        let mut worst = 0f32;
        let mut reached = 0usize;
        for (got, want) in run.values.iter().zip(&want) {
            if *got < INF || *want < INF {
                worst = worst.max((got - want).abs());
            }
            if *want < INF {
                reached += 1;
            }
        }
        println!(
            "validation vs CPU reference BFS: {} reachable vertices, max abs error {:.1e}",
            fmt::count(reached as u64),
            worst
        );
        anyhow::ensure!(worst < 1e-3, "PJRT datapath diverged from reference");

        // Cross-check PJRT vs native mirror on identical preprocessing.
        let native_report = acc.run(&pre, &Bfs::new(0), &mut NativeExecutor)?;
        let nr = native_report.run.as_ref().unwrap();
        anyhow::ensure!(
            nr.values == run.values,
            "native and PJRT executors disagree"
        );
        println!("cross-check: native mirror produces identical levels ✓");

        // --- 5. paper metrics ---
        println!("\n== modeled hardware metrics (Table 3 constants) ==");
        println!("energy:           {}", fmt::energy(report.energy_j()));
        println!("  reram read:     {}", fmt::energy(report.energy.reram_read_j));
        println!("  reram write:    {}", fmt::energy(report.energy.reram_write_j));
        println!("  sram buffers:   {}", fmt::energy(report.energy.sram_j));
        println!("  adc:            {}", fmt::energy(report.energy.adc_j));
        println!("  main memory:    {}", fmt::energy(report.energy.main_mem_j));
        println!("modeled time:     {}", fmt::time(report.exec_time_s()));
        println!("static hit rate:  {:.1}%", report.static_hit_rate * 100.0);
        println!("ReRAM write bits: {}", fmt::count(report.counts.write_bits));
        println!(
            "lifetime (hourly runs): {}",
            fmt::time(lifetime_seconds(params.endurance_cycles, report.max_cell_writes, 3600.0))
        );
        println!(
            "host throughput:  {:.0} subgraph ops/s through PJRT",
            report.counts.mvm_ops as f64 / wall.as_secs_f64()
        );

        // Bonus: PageRank over the same preprocessing, PJRT datapath.
        let t2 = Instant::now();
        let pr = acc.run(&pre, &PageRank::new(0.85, 10), &mut pjrt)?;
        let pr_run = pr.run.as_ref().unwrap();
        let want_pr = reference::pagerank(&csr, 0.85, 10);
        let worst_pr = pr_run
            .values
            .iter()
            .zip(&want_pr)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!(
            "\npagerank (10 iters): wall {:.2} s, max abs error vs reference {:.1e}",
            t2.elapsed().as_secs_f64(),
            worst_pr
        );
        anyhow::ensure!(worst_pr < 1e-4, "pagerank diverged");
        println!("END-TO-END OK");
        Ok(())
    }
}
