//! Lifetime study (paper §IV.D): wear accumulation and the E/w × T
//! model across designs and engine counts, plus a failure-injection run
//! with artificially tiny endurance to exercise engine retirement.
//!
//! Run: `cargo run --release --example lifetime_study`

use anyhow::Result;

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::Bfs;
use repro::baselines::{self};
use repro::cost::{lifetime_seconds, CostParams};
use repro::graph::datasets::Dataset;
use repro::report::Table;
use repro::sched::executor::NativeExecutor;
use repro::util::fmt;

fn main() -> Result<()> {
    let g = Dataset::WikiVote.load()?;
    let params = CostParams::default();
    let interval_s = 3600.0; // one execution per hour, as in the paper

    println!("== lifetime vs engine count (Wiki-Vote BFS, hourly) ==");
    let mut t = Table::new("")
        .header(["engines", "max cell writes/run", "lifetime (proposed)", "lifetime (SparseMEM)", "lifetime (GraphR)"]);
    for engines in [32u32, 64, 128] {
        let cfg = ArchConfig {
            total_engines: engines,
            static_engines: 16,
            ..ArchConfig::default()
        };
        let acc = Accelerator::new(cfg, params.clone());
        let ours = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor)?;
        let base = baselines::simulate_all(&g, 0, &params, engines);
        let by = |name: &str| {
            base.iter()
                .find(|r| r.design == name)
                .map(|r| r.max_cell_writes)
                .unwrap()
        };
        let lt = |w: u64| fmt::time(lifetime_seconds(params.endurance_cycles, w, interval_s));
        t.row([
            engines.to_string(),
            fmt::count(ours.max_cell_writes),
            lt(ours.max_cell_writes),
            lt(by("SparseMEM")),
            lt(by("GraphR")),
        ]);
    }
    print!("{}", t.render());

    // Failure injection: shrink endurance so dynamic crossbars retire
    // mid-run, and show the scheduler either survives on the remaining
    // slots or reports a clean exhaustion error.
    println!("\n== failure injection: endurance = 40 write cycles ==");
    let mut weak = CostParams::default();
    weak.endurance_cycles = 40.0;
    let cfg = ArchConfig { total_engines: 8, static_engines: 4, ..ArchConfig::default() };
    let acc = Accelerator::new(cfg, weak);
    match acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor) {
        Ok(r) => {
            let run = r.run.as_ref().unwrap();
            let retired = run
                .engines
                .iter()
                .filter(|e| !e.is_static && e.max_cell_writes >= 40)
                .count();
            println!(
                "survived with {} retired dynamic crossbar(s); max cell writes {}",
                retired, r.max_cell_writes
            );
        }
        Err(e) => println!("clean exhaustion: {e}"),
    }
    Ok(())
}
