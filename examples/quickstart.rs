//! Quickstart: the paper's Fig. 3 worked example end to end.
//!
//! Builds the 6-vertex graph from Fig. 3, preprocesses it with three
//! graph engines (two static + one dynamic, 2×2 crossbars), prints the
//! pattern ranking and the CT/ST tables, then runs BFS through the full
//! accelerator — with the AOT/PJRT datapath if `artifacts/` exists,
//! falling back to the native mirror otherwise.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::{reference, Bfs};
use repro::cost::CostParams;
use repro::graph::coo::{Coo, Edge};
use repro::graph::Csr;
use repro::report::Table;
use repro::sched::executor::NativeExecutor;
use repro::sched::StepExecutor;
use repro::util::fmt;

fn make_executor() -> Result<Box<dyn StepExecutor>> {
    #[cfg(feature = "pjrt")]
    {
        let artifacts = repro::runtime::default_artifact_dir();
        if artifacts.join("manifest.tsv").exists() {
            println!("datapath: AOT HLO artifact via PJRT ({})", artifacts.display());
            return Ok(Box::new(repro::runtime::PjrtExecutor::from_default_dir()?));
        }
    }
    println!(
        "datapath: native mirror (build with --features pjrt and run `make artifacts` for the PJRT path)"
    );
    Ok(Box::new(NativeExecutor))
}

fn main() -> Result<()> {
    // Fig. 3a: six vertices; windows chosen so patterns repeat.
    let g = Coo::from_edges(
        6,
        vec![
            Edge::new(0, 1), // S0: block (0,0) — pattern P0
            Edge::new(2, 3), // S4: block (1,1) — P0 again
            Edge::new(4, 5), // S8: block (2,2) — P0 again
            Edge::new(1, 2), // block (0,1) — P1
            Edge::new(3, 4), // block (1,2) — P1 again
            Edge::new(5, 0), // block (2,0) — P2
            Edge::new(0, 4), // block (0,2) — P3
        ],
    );

    // Fig. 3d: three graph engines — two static, one dynamic, 2×2 crossbars.
    let config = ArchConfig {
        crossbar_size: 2,
        total_engines: 3,
        static_engines: 2,
        crossbars_per_engine: 1,
        ..ArchConfig::default()
    };
    let acc = Accelerator::new(config, CostParams::default());
    let pre = acc.preprocess(&g, false)?;

    println!("== Fig. 3b/c: patterns ranked by frequency ==");
    let mut rank_t = Table::new("").header(["rank", "pattern bits", "occurrences"]);
    for (i, (p, c)) in pre.ranking.ranked.iter().enumerate() {
        rank_t.row([format!("P{i}"), format!("{p}"), c.to_string()]);
    }
    print!("{}", rank_t.render());

    println!("== Fig. 3e: configuration table (CT) ==");
    let mut ct_t = Table::new("").header(["pattern", "engine", "kind", "COO cells"]);
    for e in &pre.ct.entries {
        let (engine, kind) = match e.slots.first() {
            Some(s) => (format!("GE{}", s.engine), "static"),
            None => ("dynamic pool".to_string(), "dynamic"),
        };
        ct_t.row([
            format!("{}", e.pattern),
            engine,
            kind.to_string(),
            format!("{:?}", e.pattern.cells(2)),
        ]);
    }
    print!("{}", ct_t.render());

    println!("== Fig. 3e: subgraph table (ST, column-major) ==");
    let mut st_t = Table::new("").header(["group", "start (src,dst)", "pattern rank"]);
    for (gi, grp) in pre.st.iter_groups().enumerate() {
        for e in grp {
            st_t.row([
                format!("{gi}"),
                format!("(V{}, V{})", e.src_start, e.dst_start),
                format!("P{}", e.pattern_rank),
            ]);
        }
    }
    print!("{}", st_t.render());
    println!(
        "static coverage: {:.0}% of subgraph occurrences need no ReRAM write\n",
        pre.static_coverage() * 100.0
    );

    // Run BFS through the accelerator; prefer the AOT/PJRT datapath when
    // this binary has it and artifacts exist.
    let mut exec = make_executor()?;
    let report = acc.run(&pre, &Bfs::new(0), exec.as_mut())?;
    let run = report.run.as_ref().unwrap();
    println!("\n== BFS from V0 ==");
    println!("levels: {:?}", run.values);
    let want = reference::bfs_levels(&Csr::from_coo(&g), 0);
    assert_eq!(run.values, want, "accelerator BFS must match CPU reference");
    println!("matches CPU reference ✓");
    println!(
        "energy: {}   modeled time: {}   static hit rate: {:.0}%   ReRAM writes: {} bits",
        fmt::energy(report.energy_j()),
        fmt::time(report.exec_time_s()),
        report.static_hit_rate * 100.0,
        report.counts.write_bits
    );
    Ok(())
}
