//! Serving-loop demo: the L3 leader/worker coordinator under a mixed
//! job stream (BFS / PageRank / WCC / SSSP over two datasets), showing
//! queueing, preprocessing reuse, and the metrics surface.
//!
//! Run: `cargo run --release --example serving_loop`

use std::time::Instant;

use anyhow::Result;

use repro::coordinator::{Service, ServiceConfig};
use repro::graph::datasets::Dataset;
use repro::session::JobSpec;
use repro::util::fmt;

fn main() -> Result<()> {
    let svc = Service::spawn(ServiceConfig { workers: 4, ..ServiceConfig::default() })?;
    let t0 = Instant::now();

    // A burst of mixed jobs; Tiny and Gnutella alternate so the
    // preprocessing cache sees both hits and misses.
    let mut pending = Vec::new();
    for i in 0..24u32 {
        let dataset = if i % 2 == 0 { Dataset::Tiny } else { Dataset::Gnutella };
        let job = match i % 4 {
            0 => JobSpec::new(dataset, "bfs").with_source(i),
            1 => JobSpec::new(dataset, "pagerank").with_iterations(5),
            2 => JobSpec::new(dataset, "wcc"),
            _ => JobSpec::new(dataset, "sssp").with_source(i),
        };
        pending.push((i, svc.submit(job)?));
    }

    for (i, p) in pending {
        let r = p.wait()?;
        println!(
            "job {i:>2} [{:<8}] {:>8} µs  {:>10} subgraph ops  energy {}",
            r.report.algorithm,
            r.wall_time_us,
            fmt::count(r.report.counts.mvm_ops),
            fmt::energy(r.report.energy_j()),
        );
    }

    let s = svc.metrics.snapshot();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {} jobs in {:.2} s ({:.1} jobs/s): mean latency {:.0} µs, max {} µs, {} subgraph ops total ({:.2} M ops/s)",
        s.jobs_completed,
        wall,
        s.jobs_completed as f64 / wall,
        s.mean_latency_us,
        s.max_latency_us,
        fmt::count(s.subgraph_ops),
        s.subgraph_ops as f64 / wall / 1e6,
    );
    println!("  queue-wait {}", s.queue_wait.render());
    println!("  execution  {}", s.execution.render());
    for (algo, st) in &s.per_algorithm {
        println!(
            "  {algo:>9}: {} completed, queue depth {}, exec p99 {} µs",
            st.completed, st.queue_depth, st.execution.p99_us
        );
    }
    let cache = svc.session().artifacts().stats();
    println!(
        "artifact cache: {} preprocessing runs for {} jobs ({} hits)",
        cache.misses, s.jobs_completed, cache.hits
    );
    assert_eq!(s.jobs_failed, 0);
    Ok(())
}
