"""AOT lowering: JAX batch-step models -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Each artifact is one (algorithm, batch, crossbar-size) variant:

    artifacts/<name>_b<B>_c<C>.hlo.txt

plus ``artifacts/manifest.json`` describing shapes so the rust runtime can
discover and validate artifacts without hardcoding.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import STEP_NAMES, build_step

# (B, C) variants the rust coordinator may request. B is the engine batch
# (total graph engines T in the paper's Fig. 6 setups), C the crossbar size.
# NOTE: a (1024, 4) large-batch variant was measured 12x SLOWER end to
# end: the interpret-mode pallas grid lowers to a sequential loop whose
# cost scales with B, and padded tail batches waste compute. B = 128 is
# the sweet spot on the CPU PJRT client (EXPERIMENTS.md §Perf).
VARIANTS: list[tuple[int, int]] = [
    (32, 4),   # paper default: 32 engines, 4x4 crossbars
    (32, 8),   # 8x8 crossbar ablation
    (128, 4),  # lifetime config (§IV.D) + best PJRT dispatch batch
    (6, 2),    # Fig. 3 worked example (3 engines used; padded to 6)
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name: str, b: int, c: int) -> str:
    fn, example_args = build_step(name, b, c)
    lowered = jax.jit(fn).lower(*example_args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--steps", nargs="*", default=list(STEP_NAMES), help="subset of steps"
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest: dict = {"format": "hlo-text", "entries": []}
    for name in args.steps:
        for b, c in VARIANTS:
            text = lower_variant(name, b, c)
            fname = f"{name}_b{b}_c{c}.hlo.txt"
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(
                {
                    "step": name,
                    "batch": b,
                    "crossbar": c,
                    "file": fname,
                    # All steps take (B,C,C) f32 + (B,C) f32 -> 1-tuple (B,C) f32.
                    "inputs": [[b, c, c], [b, c]],
                    "output": [b, c],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV manifest is what the rust runtime parses (offline image vendors
    # no JSON crate); JSON kept for humans/tools.
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write("# step\tbatch\tcrossbar\tfile\n")
        for e in manifest["entries"]:
            f.write(f"{e['step']}\t{e['batch']}\t{e['crossbar']}\t{e['file']}\n")
    print(f"wrote {os.path.join(args.out, 'manifest.json')} + manifest.tsv "
          f"({len(manifest['entries'])} artifacts)")


if __name__ == "__main__":
    main()
