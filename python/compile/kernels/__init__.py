"""Layer-1 Pallas kernels (build-time only; never imported at runtime)."""

from .crossbar_mvm import (  # noqa: F401
    ADC_LEVELS,
    INF,
    matmul_mvm,
    matmul_mvm_adc,
    minplus_mvm,
)
