"""Layer-1 Pallas kernels: the ReRAM crossbar datapath.

A graph engine's crossbar performs an in-situ MVM: each bitline j computes
sum_i G[i,j] * V[i] in O(1) analog time (paper §II.A). We model a *batch*
of engines as one TPU-style kernel invocation: the grid iterates over the
engine batch, and each program instance owns one C x C crossbar tile in
VMEM plus its C-vector of wordline voltages.

Three datapath variants:

* ``matmul_mvm``   - the plain analog MAC (PageRank-style semiring).
* ``matmul_mvm_adc`` - same, followed by the 8-bit ADC quantization model
  (sample-and-hold -> shared SAR ADC, paper Fig. 4 / Table 3).
* ``minplus_mvm``  - tropical semiring out[j] = min_i (cost[i,j] + x[i])
  used by BFS/SSSP edge-compute. An analog crossbar does not natively
  min-reduce; the paper offloads non-MVM ops to the engine ALU. We keep
  the op inside the kernel so the whole edge-compute phase lowers into a
  single fused HLO (DESIGN.md §Hardware-Adaptation).

All kernels are lowered with ``interpret=True``: the CPU PJRT client that
the rust runtime embeds cannot execute Mosaic custom-calls. On a real TPU
the same BlockSpecs tile each engine batch into VMEM and feed the MXU.

Correctness oracle: ``ref.py`` (pure jnp), pinned by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sentinel for "no edge" in the tropical semiring. f32 has plenty of
# headroom: INF + INF stays finite and well above any real path length.
INF = 1.0e9

# 8-bit SAR ADC (Table 3): 256 levels across the bitline full-scale range.
ADC_LEVELS = 256


def _mvm_kernel(g_ref, x_ref, o_ref):
    """One engine: bitline MAC  o[j] = sum_i G[i,j] * x[i]."""
    g = g_ref[0]  # (C, C) crossbar conductances
    x = x_ref[0]  # (C,)  wordline voltages
    # x @ G contracts over wordlines i — one dot per bitline, exactly the
    # analog reduction the crossbar performs in a single cycle.
    o_ref[0] = x @ g


def _mvm_adc_kernel(fullscale, g_ref, x_ref, o_ref):
    """Bitline MAC followed by the S/H + 8-bit ADC quantization model."""
    g = g_ref[0]
    x = x_ref[0]
    acc = x @ g
    # ``fullscale`` is a plain python float (compile-time constant): pallas
    # kernels cannot capture traced array constants.
    lsb = float(fullscale) / (ADC_LEVELS - 1)
    code = jnp.clip(jnp.round(acc / lsb), 0.0, ADC_LEVELS - 1.0)
    o_ref[0] = code * lsb


def _minplus_kernel(cost_ref, x_ref, o_ref):
    """One engine: tropical MVM  o[j] = min_i (cost[i,j] + x[i]).

    ``cost[i,j]`` is the edge weight (1.0 for BFS) where an edge exists and
    INF elsewhere; ``x`` is the current vertex property of the C source
    vertices of the subgraph.
    """
    cost = cost_ref[0]  # (C, C)
    x = x_ref[0]  # (C,)
    cand = cost + x[:, None]
    o_ref[0] = jnp.min(cand, axis=0)


def _batched_call(kernel, b: int, c: int, n_mats: int):
    """Build a pallas_call whose grid iterates over the engine batch.

    ``n_mats`` matrix operands of shape (b, c, c) are followed by one
    vector operand of shape (b, c); output is (b, c).
    """
    mat_spec = pl.BlockSpec((1, c, c), lambda i: (i, 0, 0))
    vec_spec = pl.BlockSpec((1, c), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[mat_spec] * n_mats + [vec_spec],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )


@functools.partial(jax.jit, static_argnames=())
def matmul_mvm(patterns: jax.Array, x: jax.Array) -> jax.Array:
    """Batched crossbar MVM.  patterns: (B, C, C), x: (B, C) -> (B, C)."""
    b, c, _ = patterns.shape
    return _batched_call(_mvm_kernel, b, c, 1)(patterns, x)


def matmul_mvm_adc(patterns: jax.Array, x: jax.Array, fullscale: float) -> jax.Array:
    """Batched crossbar MVM with 8-bit ADC quantization on each bitline."""
    b, c, _ = patterns.shape
    kernel = functools.partial(_mvm_adc_kernel, float(fullscale))
    return _batched_call(kernel, b, c, 1)(patterns, x)


@functools.partial(jax.jit, static_argnames=())
def minplus_mvm(cost: jax.Array, x: jax.Array) -> jax.Array:
    """Batched tropical MVM.  cost: (B, C, C), x: (B, C) -> (B, C)."""
    b, c, _ = cost.shape
    return _batched_call(_minplus_kernel, b, c, 1)(cost, x)
