"""Pure-jnp oracles for the Pallas crossbar kernels.

These are the correctness ground truth: no pallas, no tiling, just the
mathematical definition of each datapath. pytest + hypothesis assert the
kernels in ``crossbar_mvm.py`` match these bit-for-bit-ish (allclose).
"""

from __future__ import annotations

import jax.numpy as jnp

from .crossbar_mvm import ADC_LEVELS


def matmul_mvm_ref(patterns: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """out[b, j] = sum_i patterns[b, i, j] * x[b, i]."""
    return jnp.einsum("bij,bi->bj", patterns, x)


def adc_quantize_ref(v: jnp.ndarray, fullscale: float) -> jnp.ndarray:
    """8-bit SAR ADC transfer function: clip + round to 256 levels."""
    lsb = fullscale / (ADC_LEVELS - 1)
    code = jnp.clip(jnp.round(v / lsb), 0.0, ADC_LEVELS - 1.0)
    return code * lsb


def matmul_mvm_adc_ref(patterns, x, fullscale: float) -> jnp.ndarray:
    return adc_quantize_ref(matmul_mvm_ref(patterns, x), fullscale)


def minplus_mvm_ref(cost: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """out[b, j] = min_i (cost[b, i, j] + x[b, i])."""
    return jnp.min(cost + x[:, :, None], axis=1)
