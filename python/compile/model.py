"""Layer-2 JAX model: per-algorithm batch steps over a batch of graph engines.

One *batch step* is what the rust scheduler (Alg. 2) offloads per
iteration: B engines, each holding a C x C crossbar (the subgraph pattern,
possibly weighted) and a C-vector of vertex data, produce B updated
C-vectors. The reduce across subgraphs that share destination vertices
(the "aggregate" of Alg. 2 line 17) happens back in the rust ALU model —
batches mix arbitrary subgraphs, so the cross-subgraph reduce cannot be a
fixed-shape XLA op.

Vertex programming model (paper §III.D, inherited from GraphR):

* ``edge compute``  - in-situ MVM on the crossbar  -> the L1 Pallas kernel.
* ``reduce/apply``  - per-engine part fused here (min along bitlines for
  BFS/SSSP already happens inside the tropical kernel; PageRank applies
  damping here); the cross-engine part stays in rust.

Everything here is shape-polymorphic python, lowered ONCE per (B, C) by
``aot.py`` to HLO text. Python never runs at request time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import INF, matmul_mvm, matmul_mvm_adc, minplus_mvm


def bfs_step(adj: jax.Array, x: jax.Array) -> tuple[jax.Array]:
    """BFS edge-compute for a batch of subgraphs.

    adj: (B, C, C) 0/1 pattern matrices (adj[b, i, j] = edge i -> j).
    x:   (B, C)    current level of each subgraph's C source vertices
                   (INF when unvisited / inactive).
    returns (B, C) candidate level for each destination vertex:
                   min_i over edges of (x[i] + 1).
    """
    cost = jnp.where(adj > 0, 1.0, INF).astype(jnp.float32)
    return (minplus_mvm(cost, x),)


def sssp_step(adjw: jax.Array, x: jax.Array) -> tuple[jax.Array]:
    """SSSP edge-compute: adjw holds positive edge weights, 0 = no edge.

    returns (B, C) candidate distances min_i (x[i] + w[i, j]).
    """
    cost = jnp.where(adjw > 0, adjw, INF).astype(jnp.float32)
    return (minplus_mvm(cost, x),)


def wcc_step(adj: jax.Array, x: jax.Array) -> tuple[jax.Array]:
    """WCC (min-label propagation) edge-compute: min-plus with zero edge
    cost, so each destination receives the minimum label among its sources.
    """
    cost = jnp.where(adj > 0, 0.0, INF).astype(jnp.float32)
    return (minplus_mvm(cost, x),)


def pagerank_step(adj: jax.Array, contrib: jax.Array) -> tuple[jax.Array]:
    """PageRank edge-compute: plain analog MAC along bitlines.

    contrib: (B, C) = rank[i] / outdeg[i] of the source vertices (the rust
    side pre-divides; the crossbar stores the 1-bit adjacency).
    returns (B, C) partial rank mass arriving at each destination vertex.
    """
    return (matmul_mvm(adj.astype(jnp.float32), contrib),)


def pagerank_step_adc(adj: jax.Array, contrib: jax.Array, *, c: int) -> tuple[jax.Array]:
    """PageRank edge-compute through the 8-bit ADC model.

    Full-scale = C (a bitline can at most sum C unit contributions); this
    is the fidelity-loss variant used by the ADC ablation bench.
    """
    return (matmul_mvm_adc(adj.astype(jnp.float32), contrib, float(c)),)


def mvm_step(patterns: jax.Array, x: jax.Array) -> tuple[jax.Array]:
    """Raw crossbar MVM — the quickstart / microbench artifact."""
    return (matmul_mvm(patterns, x),)


#: name -> (builder taking (B, C) -> (fn, example_args)) for aot.py.
def _specs(b: int, c: int):
    mat = jax.ShapeDtypeStruct((b, c, c), jnp.float32)
    vec = jax.ShapeDtypeStruct((b, c), jnp.float32)
    return mat, vec


def build_step(name: str, b: int, c: int):
    """Return (callable, example_args) for a named step at batch B, size C."""
    mat, vec = _specs(b, c)
    if name == "bfs":
        return bfs_step, (mat, vec)
    if name == "sssp":
        return sssp_step, (mat, vec)
    if name == "wcc":
        return wcc_step, (mat, vec)
    if name == "pagerank":
        return pagerank_step, (mat, vec)
    if name == "pagerank_adc":
        return (lambda adj, x: pagerank_step_adc(adj, x, c=c)), (mat, vec)
    if name == "mvm":
        return mvm_step, (mat, vec)
    raise ValueError(f"unknown step {name!r}")


STEP_NAMES = ("bfs", "sssp", "wcc", "pagerank", "pagerank_adc", "mvm")
