"""AOT lowering smoke tests: HLO text is produced and looks loadable."""

import json

import pytest

from compile.aot import VARIANTS, lower_variant, to_hlo_text
from compile.model import STEP_NAMES, build_step

import jax


def test_lower_bfs_small_produces_entry():
    text = lower_variant("bfs", 6, 2)
    assert "ENTRY" in text
    assert "HloModule" in text
    # return_tuple=True => root is a tuple of one f32[6,2]
    assert "f32[6,2]" in text


def test_lower_all_steps_at_example_variant():
    for name in STEP_NAMES:
        text = lower_variant(name, 6, 2)
        assert "ENTRY" in text, name


def test_variants_cover_paper_configs():
    assert (32, 4) in VARIANTS  # paper default
    assert (128, 4) in VARIANTS  # lifetime config
    assert (32, 8) in VARIANTS  # 8x8 ablation


def test_hlo_text_has_no_serialized_proto_markers():
    # Guard against regressing to .serialize() (binary) interchange.
    text = lower_variant("mvm", 6, 2)
    assert text.isprintable() or "\n" in text
    assert not text.startswith("\x08")


def test_manifest_roundtrip(tmp_path):
    # Run the writer end-to-end for one cheap step.
    import subprocess, sys, os

    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--steps", "mvm"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert len(manifest["entries"]) == len(VARIANTS)
    for e in manifest["entries"]:
        assert (out / e["file"]).exists()
        assert e["inputs"] == [[e["batch"], e["crossbar"], e["crossbar"]],
                               [e["batch"], e["crossbar"]]]
