"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

hypothesis sweeps batch size, crossbar size, and value ranges; every
kernel must match its ref.py oracle to f32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import INF, matmul_mvm, matmul_mvm_adc, minplus_mvm
from compile.kernels import ref

# Keep example counts modest: every pallas interpret trace is a fresh jit.
SETTINGS = dict(max_examples=25, deadline=None)


def rand(shape, seed, lo=-4.0, hi=4.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape), dtype=jnp.float32)


@given(b=st.integers(1, 8), c=st.integers(1, 8), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_matmul_mvm_matches_ref(b, c, seed):
    g = rand((b, c, c), seed)
    x = rand((b, c), seed + 1)
    got = matmul_mvm(g, x)
    want = ref.matmul_mvm_ref(g, x)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(b=st.integers(1, 6), c=st.integers(1, 8), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_minplus_mvm_matches_ref(b, c, seed):
    cost = rand((b, c, c), seed, lo=0.0, hi=10.0)
    x = rand((b, c), seed + 1, lo=0.0, hi=10.0)
    got = minplus_mvm(cost, x)
    want = ref.minplus_mvm_ref(cost, x)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(
    b=st.integers(1, 4),
    c=st.integers(1, 8),
    seed=st.integers(0, 2**31),
    fullscale=st.sampled_from([1.0, 4.0, 8.0, 16.0]),
)
@settings(**SETTINGS)
def test_matmul_mvm_adc_matches_ref(b, c, seed, fullscale):
    g = jnp.asarray(
        np.random.default_rng(seed).integers(0, 2, size=(b, c, c)), jnp.float32
    )
    x = rand((b, c), seed + 1, lo=0.0, hi=1.0)
    got = matmul_mvm_adc(g, x, fullscale)
    want = ref.matmul_mvm_adc_ref(g, x, fullscale)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_minplus_respects_inf_sentinel():
    # A crossbar with no edges must return >= INF everywhere (no update).
    cost = jnp.full((2, 4, 4), INF, jnp.float32)
    x = jnp.zeros((2, 4), jnp.float32)
    out = minplus_mvm(cost, x)
    assert bool(jnp.all(out >= INF))


def test_minplus_single_edge():
    # One edge 0 -> 2 with weight 1, source level 3 => dest candidate 4.
    cost = jnp.full((1, 4, 4), INF, jnp.float32)
    cost = cost.at[0, 0, 2].set(1.0)
    x = jnp.full((1, 4), INF, jnp.float32).at[0, 0].set(3.0)
    out = np.asarray(minplus_mvm(cost, x))
    assert out[0, 2] == pytest.approx(4.0)
    assert np.all(out[0, [0, 1, 3]] >= INF)


def test_matmul_is_transpose_contraction():
    # out[j] = sum_i G[i,j] x[i]  — i.e. x @ G, not G @ x.
    g = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4)
    x = jnp.asarray([[1.0, 0.0, 0.0, 0.0]])
    out = np.asarray(matmul_mvm(g, x))
    np.testing.assert_allclose(out[0], np.arange(4.0))  # row 0 of G


def test_adc_quantization_is_monotone_and_bounded():
    v = jnp.linspace(-1.0, 20.0, 64)
    q = np.asarray(ref.adc_quantize_ref(v, 16.0))
    assert np.all(np.diff(q) >= 0)
    assert q.min() >= 0.0 and q.max() <= 16.0


def test_adc_idempotent():
    v = rand((32,), 7, lo=0.0, hi=4.0)
    q1 = ref.adc_quantize_ref(v, 4.0)
    q2 = ref.adc_quantize_ref(q1, 4.0)
    np.testing.assert_allclose(q1, q2, rtol=0, atol=1e-6)


def test_kernels_are_jittable_at_paper_shapes():
    # The exact shapes aot.py lowers must trace cleanly.
    for b, c in [(32, 4), (32, 8), (128, 4)]:
        g = rand((b, c, c), b + c)
        x = rand((b, c), b * c)
        assert matmul_mvm(g, x).shape == (b, c)
        assert minplus_mvm(jnp.abs(g), jnp.abs(x)).shape == (b, c)
