"""Layer-2 batch-step semantics: handcrafted graph fragments."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import INF
from compile.model import (
    STEP_NAMES,
    bfs_step,
    build_step,
    pagerank_step,
    pagerank_step_adc,
    sssp_step,
)


def test_bfs_step_propagates_level_plus_one():
    # Subgraph: edges 0->1 and 0->3; source vertex 0 at level 2.
    adj = jnp.zeros((1, 4, 4), jnp.float32).at[0, 0, 1].set(1.0).at[0, 0, 3].set(1.0)
    x = jnp.full((1, 4), INF, jnp.float32).at[0, 0].set(2.0)
    (out,) = bfs_step(adj, x)
    out = np.asarray(out)
    assert out[0, 1] == pytest.approx(3.0)
    assert out[0, 3] == pytest.approx(3.0)
    assert np.all(out[0, [0, 2]] >= INF)


def test_bfs_step_unvisited_sources_never_update():
    adj = jnp.ones((1, 4, 4), jnp.float32)
    x = jnp.full((1, 4), INF, jnp.float32)
    (out,) = bfs_step(adj, x)
    assert bool(jnp.all(out >= INF))


def test_bfs_step_takes_min_over_sources():
    # Both 0->2 and 1->2 exist; levels 5 and 1 => dest candidate 2.
    adj = jnp.zeros((1, 4, 4), jnp.float32).at[0, 0, 2].set(1.0).at[0, 1, 2].set(1.0)
    x = jnp.full((1, 4), INF, jnp.float32).at[0, 0].set(5.0).at[0, 1].set(1.0)
    (out,) = bfs_step(adj, x)
    assert np.asarray(out)[0, 2] == pytest.approx(2.0)


def test_sssp_step_uses_edge_weights():
    adjw = jnp.zeros((1, 4, 4), jnp.float32).at[0, 0, 1].set(2.5).at[0, 2, 1].set(0.5)
    x = jnp.full((1, 4), INF, jnp.float32).at[0, 0].set(1.0).at[0, 2].set(4.0)
    (out,) = sssp_step(adjw, x)
    # min(1.0 + 2.5, 4.0 + 0.5) = 3.5
    assert np.asarray(out)[0, 1] == pytest.approx(3.5)


def test_sssp_zero_weight_means_no_edge():
    adjw = jnp.zeros((2, 4, 4), jnp.float32)
    x = jnp.zeros((2, 4), jnp.float32)
    (out,) = sssp_step(adjw, x)
    assert bool(jnp.all(out >= INF))


def test_pagerank_step_sums_contributions():
    adj = jnp.zeros((1, 4, 4), jnp.float32).at[0, 0, 3].set(1.0).at[0, 1, 3].set(1.0)
    contrib = jnp.asarray([[0.25, 0.5, 0.0, 0.0]])
    (out,) = pagerank_step(adj, contrib)
    assert np.asarray(out)[0, 3] == pytest.approx(0.75)
    assert np.asarray(out)[0, :3] == pytest.approx([0.0, 0.0, 0.0])


def test_pagerank_adc_close_to_exact():
    rng = np.random.default_rng(0)
    adj = jnp.asarray(rng.integers(0, 2, (8, 4, 4)), jnp.float32)
    contrib = jnp.asarray(rng.uniform(0, 0.25, (8, 4)), jnp.float32)
    (exact,) = pagerank_step(adj, contrib)
    (quant,) = pagerank_step_adc(adj, contrib, c=4)
    # 8-bit over full-scale 4 => lsb ~ 0.0157; error bounded by lsb/2.
    np.testing.assert_allclose(quant, exact, atol=4.0 / 255 / 2 + 1e-6)


def test_build_step_covers_all_names_and_shapes():
    for name in STEP_NAMES:
        fn, (mat, vec) = build_step(name, 6, 2)
        assert mat.shape == (6, 2, 2) and vec.shape == (6, 2)
        adj = jnp.zeros(mat.shape, jnp.float32)
        x = jnp.zeros(vec.shape, jnp.float32)
        (out,) = fn(adj, x)
        assert out.shape == (6, 2)


def test_build_step_rejects_unknown():
    with pytest.raises(ValueError):
        build_step("pagerankk", 4, 4)
