//! Per-iteration engine activity trace (paper Fig. 5): crossbar
//! read/write bit counts per engine per scheduler iteration, plus the
//! sliding-window 0–100 normalization the figure plots.

/// Flattened trace: iteration-major, engine-minor.
#[derive(Debug, Clone, Default)]
pub struct ActivityTrace {
    pub num_engines: usize,
    reads: Vec<u32>,
    writes: Vec<u32>,
}

impl ActivityTrace {
    pub fn new(num_engines: usize) -> Self {
        Self { num_engines, reads: Vec::new(), writes: Vec::new() }
    }

    /// Append one iteration's per-engine (read_bits, write_bits).
    pub fn push_iteration(&mut self, per_engine: impl Iterator<Item = (u32, u32)>) {
        let before = self.reads.len();
        for (r, w) in per_engine {
            self.reads.push(r);
            self.writes.push(w);
        }
        debug_assert_eq!(self.reads.len() - before, self.num_engines);
    }

    pub fn num_iterations(&self) -> usize {
        if self.num_engines == 0 {
            0
        } else {
            self.reads.len() / self.num_engines
        }
    }

    #[inline]
    pub fn read(&self, iter: usize, engine: usize) -> u32 {
        self.reads[iter * self.num_engines + engine]
    }

    #[inline]
    pub fn write(&self, iter: usize, engine: usize) -> u32 {
        self.writes[iter * self.num_engines + engine]
    }

    /// Fig. 5 series: aggregate over a sliding window of `window`
    /// iterations and normalize to 0–100 against the global max, per
    /// engine. Returns `(read_activity, write_activity)`, each
    /// `[engine][window_index]`.
    pub fn windowed_activity(&self, window: usize) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        assert!(window >= 1);
        let iters = self.num_iterations();
        let nw = iters.div_ceil(window).max(1);
        let mut reads = vec![vec![0f64; nw]; self.num_engines];
        let mut writes = vec![vec![0f64; nw]; self.num_engines];
        for it in 0..iters {
            for e in 0..self.num_engines {
                reads[e][it / window] += self.read(it, e) as f64;
                writes[e][it / window] += self.write(it, e) as f64;
            }
        }
        let norm = |m: &mut Vec<Vec<f64>>| {
            let max = m
                .iter()
                .flat_map(|row| row.iter().copied())
                .fold(0.0f64, f64::max);
            if max > 0.0 {
                for row in m.iter_mut() {
                    for v in row.iter_mut() {
                        *v = *v / max * 100.0;
                    }
                }
            }
        };
        norm(&mut reads);
        norm(&mut writes);
        (reads, writes)
    }

    /// Total (reads, writes) per engine across the whole run.
    pub fn totals(&self) -> Vec<(u64, u64)> {
        let mut out = vec![(0u64, 0u64); self.num_engines];
        for it in 0..self.num_iterations() {
            for e in 0..self.num_engines {
                out[e].0 += self.read(it, e) as u64;
                out[e].1 += self.write(it, e) as u64;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> ActivityTrace {
        let mut t = ActivityTrace::new(2);
        t.push_iteration([(10, 0), (0, 5)].into_iter());
        t.push_iteration([(20, 0), (0, 0)].into_iter());
        t.push_iteration([(30, 0), (10, 5)].into_iter());
        t.push_iteration([(0, 0), (0, 0)].into_iter());
        t
    }

    #[test]
    fn indexing() {
        let t = trace();
        assert_eq!(t.num_iterations(), 4);
        assert_eq!(t.read(0, 0), 10);
        assert_eq!(t.write(2, 1), 5);
    }

    #[test]
    fn windowed_normalizes_to_100() {
        let t = trace();
        let (r, w) = t.windowed_activity(2);
        assert_eq!(r[0].len(), 2);
        // Engine 0 reads: windows [30, 30] -> both 100.
        assert_eq!(r[0], vec![100.0, 100.0]);
        // Engine 1 reads: [0, 10] -> [0, 33.3].
        assert!(r[1][0] == 0.0 && (r[1][1] - 100.0 / 3.0).abs() < 1e-9);
        // Writes max is 5 per window.
        assert_eq!(w[1][0], 100.0);
    }

    #[test]
    fn totals_sum_all_iterations() {
        let t = trace();
        assert_eq!(t.totals(), vec![(60, 0), (10, 10)]);
    }

    #[test]
    fn empty_trace() {
        let t = ActivityTrace::new(3);
        assert_eq!(t.num_iterations(), 0);
        let (r, _) = t.windowed_activity(4);
        assert_eq!(r.len(), 3);
    }
}
