//! Architecture parameters (paper §III.A): crossbar size C, total graph
//! engines T, static engines N, crossbars per engine M — plus execution
//! order and the dynamic-engine replacement policy.

use crate::pattern::tables::{ExecOrder, StaticAssignment};

/// Dynamic-engine replacement policy selector (Alg. 2 `FindGE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// Least-recently-used slot (default).
    #[default]
    Lru,
    /// Round-robin over dynamic slots.
    RoundRobin,
    /// Least-frequently-used slot.
    Lfu,
    /// Uniform random slot (deterministic seed).
    Random,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Some(Self::Lru),
            "rr" | "round-robin" | "roundrobin" => Some(Self::RoundRobin),
            "lfu" => Some(Self::Lfu),
            "random" | "rand" => Some(Self::Random),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Lru => "lru",
            Self::RoundRobin => "round-robin",
            Self::Lfu => "lfu",
            Self::Random => "random",
        }
    }
}

/// Generic architecture model (Fig. 2): all four paper parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Crossbar size C (window size), 1..=8.
    pub crossbar_size: usize,
    /// Total number of graph engines T.
    pub total_engines: u32,
    /// Number of static graph engines N (≤ T).
    pub static_engines: u32,
    /// Crossbars per graph engine M.
    pub crossbars_per_engine: u32,
    /// Streaming-apply execution order (§III.C).
    pub order: ExecOrder,
    /// Dynamic-engine replacement policy.
    pub policy: PolicyKind,
    /// Static slot apportionment: `Balanced` (default) replicates hot
    /// patterns across engines proportionally to frequency ("balances
    /// pattern load among static engines", §III.B); `TopK` is the
    /// literal one-slot-per-pattern Alg. 1 (ablation).
    pub static_assignment: StaticAssignment,
    /// Extension (not in the paper): before reconfiguring, check whether
    /// a dynamic crossbar *already holds* the pattern and reuse it
    /// write-free. Alg. 2 reconfigures unconditionally ("…and then
    /// reconfigured with the corresponding pattern"), so this defaults to
    /// off; the ablation bench quantifies what reuse would buy.
    pub dynamic_reuse: bool,
    /// Record the per-iteration activity trace (Fig. 5) — adds memory
    /// proportional to iterations × engines, so off by default.
    pub trace_activity: bool,
}

impl Default for ArchConfig {
    /// Paper §IV.A defaults: 32 engines with 4×4 crossbars; 16 static
    /// (the Fig. 6 optimum); single crossbar per engine.
    fn default() -> Self {
        Self {
            crossbar_size: 4,
            total_engines: 32,
            static_engines: 16,
            crossbars_per_engine: 1,
            order: ExecOrder::ColumnMajor,
            policy: PolicyKind::Lru,
            static_assignment: StaticAssignment::Balanced,
            dynamic_reuse: false,
            trace_activity: false,
        }
    }
}

impl ArchConfig {
    /// Paper Fig. 5 configuration: 6 engines (4 static + 2 dynamic),
    /// 4 crossbars each, with tracing on.
    pub fn fig5() -> Self {
        Self {
            total_engines: 6,
            static_engines: 4,
            crossbars_per_engine: 4,
            trace_activity: true,
            ..Self::default()
        }
    }

    /// Paper §IV.D lifetime configuration: 128 engines.
    pub fn lifetime() -> Self {
        Self { total_engines: 128, static_engines: 16, ..Self::default() }
    }

    pub fn dynamic_engines(&self) -> u32 {
        self.total_engines - self.static_engines
    }

    /// Static pattern capacity N × M.
    pub fn static_capacity(&self) -> u32 {
        self.static_engines * self.crossbars_per_engine
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (1..=crate::pattern::pattern::MAX_C).contains(&self.crossbar_size),
            "crossbar size must be 1..=8, got {}",
            self.crossbar_size
        );
        anyhow::ensure!(self.total_engines >= 1, "need at least one engine");
        anyhow::ensure!(
            self.static_engines <= self.total_engines,
            "static engines ({}) exceed total ({})",
            self.static_engines,
            self.total_engines
        );
        anyhow::ensure!(self.crossbars_per_engine >= 1, "need at least one crossbar per engine");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_setup() {
        let c = ArchConfig::default();
        assert_eq!(c.crossbar_size, 4);
        assert_eq!(c.total_engines, 32);
        assert_eq!(c.static_engines, 16);
        assert_eq!(c.crossbars_per_engine, 1);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn fig5_config() {
        let c = ArchConfig::fig5();
        assert_eq!(c.total_engines, 6);
        assert_eq!(c.static_engines, 4);
        assert_eq!(c.crossbars_per_engine, 4);
        assert_eq!(c.static_capacity(), 16);
        assert!(c.trace_activity);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = ArchConfig::default();
        c.static_engines = 40;
        assert!(c.validate().is_err());
        c = ArchConfig::default();
        c.crossbar_size = 9;
        assert!(c.validate().is_err());
        c = ArchConfig::default();
        c.crossbars_per_engine = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(PolicyKind::parse("LRU"), Some(PolicyKind::Lru));
        assert_eq!(PolicyKind::parse("rr"), Some(PolicyKind::RoundRobin));
        assert_eq!(PolicyKind::parse("lfu"), Some(PolicyKind::Lfu));
        assert_eq!(PolicyKind::parse("random"), Some(PolicyKind::Random));
        assert_eq!(PolicyKind::parse("fifo"), None);
    }
}
