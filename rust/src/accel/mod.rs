//! Top-level accelerator: architecture configuration, the preprocessing +
//! simulation pipeline, and per-iteration activity tracing.

pub mod activity;
pub mod config;
pub mod simulator;

pub use activity::ActivityTrace;
pub use config::{ArchConfig, PolicyKind};
pub use simulator::{Accelerator, Preprocessed, PreprocessTiming, SimReport};
