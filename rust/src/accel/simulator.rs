//! Top-level accelerator: design flow of Fig. 2 — preprocess the input
//! graph against an architecture model, then execute vertex programs and
//! report energy/latency/lifetime.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::algo::traits::VertexProgram;
use crate::cost::{CostParams, EnergyBreakdown, EventCounts};
use crate::graph::Coo;
use crate::pattern::extract::{
    finalize_windows, merge_windows, partition, Partitioned, WindowMap,
};
use crate::pattern::rank::{merge_counts, PatternRanking};
use crate::pattern::tables::{ConfigTable, SubgraphTable};
use crate::pattern::Pattern;
use crate::sched::executor::StepExecutor;
use crate::sched::plan::ExecutionPlan;
use crate::sched::scheduler::RunResult;
use crate::sched::WorkerPool;

use super::config::ArchConfig;

/// Wall-clock of one cold preprocess, split by Alg.-1 phase — recorded
/// per compile by the session's `ArtifactStore`, aggregated into
/// min/mean/max by `coordinator::metrics`, and persisted in the artifact
/// envelope so `repro artifacts ls` surfaces warm-vs-cold regressions
/// across processes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PreprocessTiming {
    /// Phase ①: edge bucketing + window merge (`pattern::extract`).
    pub partition_ns: u64,
    /// Phase ②: pattern occurrence counting + ranking.
    pub rank_ns: u64,
    /// Phase ③a: config + subgraph table build.
    pub tables_ns: u64,
    /// Phase ③b: execution-plan section emission.
    pub plan_ns: u64,
    /// Worker threads the compile fanned out over (1 = sequential).
    pub threads: u32,
}

impl PreprocessTiming {
    pub fn total_ns(&self) -> u64 {
        self.partition_ns + self.rank_ns + self.tables_ns + self.plan_ns
    }
}

/// Split `xs` into at most `n` contiguous chunks (none empty) — the
/// deterministic shard shape of every parallel preprocess phase.
fn chunk_slices<T>(xs: &[T], n: usize) -> Vec<&[T]> {
    xs.chunks(xs.len().div_ceil(n.max(1)).max(1)).collect()
}

/// Output of the preprocessing stage (Alg. 1): everything the runtime
/// needs, resident in main memory — including the compiled
/// [`ExecutionPlan`], so the schedule itself is built exactly once per
/// `(graph, architecture)` and shared by every run against this artifact
/// (the session `ArtifactStore` caches `Preprocessed` whole).
#[derive(Debug, Clone, PartialEq)]
pub struct Preprocessed {
    pub part: Partitioned,
    pub ranking: PatternRanking,
    pub ct: ConfigTable,
    pub st: SubgraphTable,
    /// Compiled scheduling IR interpreted by `Scheduler::run`.
    pub plan: ExecutionPlan,
}

impl Preprocessed {
    /// Fraction of subgraph occurrences served by static engines.
    pub fn static_coverage(&self) -> f64 {
        self.ct.static_coverage()
    }
}

/// One simulated execution, summarized.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub design: String,
    pub algorithm: String,
    pub counts: EventCounts,
    pub energy: EnergyBreakdown,
    pub exec_time_ns: f64,
    pub supersteps: usize,
    pub iterations: u64,
    pub static_hit_rate: f64,
    /// Max per-cell writes on any runtime-writable crossbar (lifetime w).
    pub max_cell_writes: u64,
    pub run: Option<RunResult>,
}

impl SimReport {
    pub fn energy_j(&self) -> f64 {
        self.energy.total_j()
    }

    pub fn exec_time_s(&self) -> f64 {
        self.exec_time_ns * 1e-9
    }
}

/// The proposed accelerator (preprocessing + scheduler + cost model).
pub struct Accelerator {
    pub config: ArchConfig,
    pub params: CostParams,
}

impl Accelerator {
    pub fn new(config: ArchConfig, params: CostParams) -> Self {
        Self { config, params }
    }

    pub fn with_defaults() -> Self {
        Self::new(ArchConfig::default(), CostParams::default())
    }

    /// Alg. 1: partition, rank, build CT/ST, compile the execution plan.
    /// Sequential — the differential oracle for the parallel variants.
    pub fn preprocess(&self, graph: &Coo, weighted: bool) -> Result<Preprocessed> {
        Ok(self.preprocess_timed(graph, weighted, None)?.0)
    }

    /// [`preprocess`](Self::preprocess) fanned out over `threads` workers
    /// (`0` = one per hardware thread) on a transient pool; `<= 1` takes
    /// the sequential path verbatim. The result is whole-struct-equal to
    /// the sequential preprocess for every thread count. Repeated
    /// callers should hold a persistent pool and use
    /// [`preprocess_pooled`](Self::preprocess_pooled) instead (the
    /// `Session` checks one out of its free list).
    pub fn preprocess_threaded(
        &self,
        graph: &Coo,
        weighted: bool,
        threads: usize,
    ) -> Result<Preprocessed> {
        let threads = crate::sched::resolve_threads(threads);
        if threads <= 1 {
            return self.preprocess(graph, weighted);
        }
        let mut pool = WorkerPool::new(threads);
        self.preprocess_pooled(graph, weighted, &mut pool)
    }

    /// [`preprocess_threaded`](Self::preprocess_threaded) on a
    /// caller-owned persistent pool (its worker count is the fan-out).
    pub fn preprocess_pooled(
        &self,
        graph: &Coo,
        weighted: bool,
        pool: &mut WorkerPool,
    ) -> Result<Preprocessed> {
        Ok(self.preprocess_timed(graph, weighted, Some(pool))?.0)
    }

    /// Alg. 1 with per-phase wall times, optionally fanned out over a
    /// worker pool (`None` = sequential). Bit-identity is structural:
    /// each parallel phase merges worker results in chunk/range order
    /// into the same finalize / `from_counts` / emission code the
    /// sequential path uses, so chunk boundaries never change an
    /// artifact byte (see ROADMAP's chunk-merge determinism rule).
    pub fn preprocess_timed(
        &self,
        graph: &Coo,
        weighted: bool,
        mut pool: Option<&mut WorkerPool>,
    ) -> Result<(Preprocessed, PreprocessTiming)> {
        self.config.validate()?;
        let threads = pool.as_ref().map_or(1, |p| p.workers());
        let mut timing = PreprocessTiming { threads: threads as u32, ..Default::default() };
        let c = self.config.crossbar_size;

        let t = Instant::now();
        let part = match pool.as_deref_mut() {
            Some(pool) if threads > 1 => {
                let chunks = chunk_slices(&graph.edges, threads);
                let mut merged = WindowMap::default();
                for m in pool.bucket_chunks(&chunks, c, weighted) {
                    merge_windows(&mut merged, m);
                }
                finalize_windows(merged, c, graph.num_vertices, weighted)
            }
            _ => partition(graph, c, weighted),
        };
        timing.partition_ns = t.elapsed().as_nanos() as u64;

        let t = Instant::now();
        let ranking = match pool.as_deref_mut() {
            Some(pool) if threads > 1 => {
                let chunks = chunk_slices(&part.subgraphs, threads);
                let mut counts: HashMap<Pattern, u32> = HashMap::new();
                for m in pool.count_chunks(&chunks) {
                    merge_counts(&mut counts, m.into_iter().map(|(p, n)| (p, i64::from(n))));
                }
                PatternRanking::from_counts(counts, part.num_subgraphs())
            }
            _ => PatternRanking::from_partitioned(&part),
        };
        timing.rank_ns = t.elapsed().as_nanos() as u64;

        let t = Instant::now();
        let ct = self.build_config_table(&ranking);
        let st = SubgraphTable::build(&part, &ranking, self.config.order);
        timing.tables_ns = t.elapsed().as_nanos() as u64;

        let t = Instant::now();
        let plan = match pool {
            Some(pool) if threads > 1 => {
                ExecutionPlan::build_pooled(&part, &ct, &st, &self.config, pool)
            }
            _ => ExecutionPlan::build(&part, &ct, &st, &self.config),
        };
        timing.plan_ns = t.elapsed().as_nanos() as u64;

        Ok((Preprocessed { part, ranking, ct, st, plan }, timing))
    }

    /// Sharded Alg. 1: split `graph` by contiguous block rows
    /// ([`graph::shard::split`](crate::graph::shard::split)) and compile
    /// one [`Preprocessed`] per shard under a **global** pattern ranking
    /// — per-shard occurrence counts merge shard-ascending into one
    /// ranking/config table (the chunk-merge determinism rule applied at
    /// shard granularity), then each shard builds its own subgraph table
    /// and execution plan. Every shard artifact therefore carries the
    /// same rank→pattern map and static configuration, which is what
    /// [`ShardPlans`](crate::sched::ShardPlans) validates before a
    /// sharded run. `shards <= 1` delegates to
    /// [`preprocess_timed`](Self::preprocess_timed), so a 1-shard
    /// compile is whole-struct-equal to the unsharded compile.
    ///
    /// Per-shard timings cover that shard's partition / count / ST+plan
    /// phases; the two global phases (ranking finalize, config table)
    /// are accounted to shard 0.
    pub fn preprocess_sharded_timed(
        &self,
        graph: &Coo,
        weighted: bool,
        shards: usize,
        mut pool: Option<&mut WorkerPool>,
    ) -> Result<Vec<(Preprocessed, PreprocessTiming)>> {
        if shards <= 1 {
            return Ok(vec![self.preprocess_timed(graph, weighted, pool.take())?]);
        }
        self.config.validate()?;
        let shard_graphs =
            crate::graph::shard::split(graph, self.config.crossbar_size, shards);
        self.preprocess_shard_graphs_timed(&shard_graphs, weighted, pool)
    }

    /// Compile an already-bucketed shard set — the streaming path: a
    /// [`Sharder`](crate::graph::shard::Sharder) fed by
    /// [`rmat_stream`](crate::graph::generator::rmat_stream) (or any
    /// edge source) hands its `ShardGraph`s straight here, so the global
    /// edge list is never materialized in one `Vec`. Identical merge
    /// discipline to [`preprocess_sharded_timed`](Self::preprocess_sharded_timed)
    /// (which delegates here after `split`): per-shard counts fold
    /// shard-ascending into one global ranking, so a streamed compile of
    /// a shard set equals the materialized compile of its `unshard`.
    pub fn preprocess_shard_graphs_timed(
        &self,
        shard_graphs: &[crate::graph::shard::ShardGraph],
        weighted: bool,
        mut pool: Option<&mut WorkerPool>,
    ) -> Result<Vec<(Preprocessed, PreprocessTiming)>> {
        self.config.validate()?;
        let threads = pool.as_ref().map_or(1, |p| p.workers());
        let c = self.config.crossbar_size;
        let mut timings =
            vec![
                PreprocessTiming { threads: threads as u32, ..Default::default() };
                shard_graphs.len()
            ];

        // Phase ①: per-shard partition (chunk-parallel within a shard).
        let mut parts = Vec::with_capacity(shard_graphs.len());
        for (s, sg) in shard_graphs.iter().enumerate() {
            let t = Instant::now();
            let part = match pool.as_deref_mut() {
                Some(pool) if threads > 1 && !sg.graph.edges.is_empty() => {
                    let chunks = chunk_slices(&sg.graph.edges, threads);
                    let mut merged = WindowMap::default();
                    for m in pool.bucket_chunks(&chunks, c, weighted) {
                        merge_windows(&mut merged, m);
                    }
                    finalize_windows(merged, c, sg.graph.num_vertices, weighted)
                }
                _ => partition(&sg.graph, c, weighted),
            };
            timings[s].partition_ns = t.elapsed().as_nanos() as u64;
            parts.push(part);
        }

        // Phase ②: per-shard counts, merged shard-ascending into the
        // global ranking (counts are additive over the block-row split).
        let mut counts: HashMap<Pattern, u32> = HashMap::new();
        let mut total_subgraphs = 0usize;
        for (s, part) in parts.iter().enumerate() {
            let t = Instant::now();
            match pool.as_deref_mut() {
                Some(pool) if threads > 1 && !part.subgraphs.is_empty() => {
                    let chunks = chunk_slices(&part.subgraphs, threads);
                    for m in pool.count_chunks(&chunks) {
                        merge_counts(
                            &mut counts,
                            m.into_iter().map(|(p, n)| (p, i64::from(n))),
                        );
                    }
                }
                _ => merge_counts(
                    &mut counts,
                    crate::pattern::rank::count_patterns(&part.subgraphs)
                        .into_iter()
                        .map(|(p, n)| (p, i64::from(n))),
                ),
            }
            total_subgraphs += part.num_subgraphs();
            timings[s].rank_ns = t.elapsed().as_nanos() as u64;
        }
        let t = Instant::now();
        let ranking = PatternRanking::from_counts(counts, total_subgraphs);
        let ct = self.build_config_table(&ranking);
        timings[0].rank_ns += t.elapsed().as_nanos() as u64;

        // Phase ③: per-shard subgraph table + plan against the shared
        // ranking/CT.
        let mut out = Vec::with_capacity(parts.len());
        for (s, part) in parts.into_iter().enumerate() {
            let t = Instant::now();
            let st = SubgraphTable::build(&part, &ranking, self.config.order);
            timings[s].tables_ns = t.elapsed().as_nanos() as u64;
            let t = Instant::now();
            let plan = match pool.as_deref_mut() {
                Some(pool) if threads > 1 => {
                    ExecutionPlan::build_pooled(&part, &ct, &st, &self.config, pool)
                }
                _ => ExecutionPlan::build(&part, &ct, &st, &self.config),
            };
            timings[s].plan_ns = t.elapsed().as_nanos() as u64;
            out.push((
                Preprocessed { part, ranking: ranking.clone(), ct: ct.clone(), st, plan },
                timings[s],
            ));
        }
        Ok(out)
    }

    /// [`preprocess_sharded_timed`](Self::preprocess_sharded_timed)
    /// without the timings, on an optional pool.
    pub fn preprocess_sharded(
        &self,
        graph: &Coo,
        weighted: bool,
        shards: usize,
        pool: Option<&mut WorkerPool>,
    ) -> Result<Vec<Preprocessed>> {
        Ok(self
            .preprocess_sharded_timed(graph, weighted, shards, pool)?
            .into_iter()
            .map(|(p, _)| p)
            .collect())
    }

    /// Build just the engine config table for `ranking` under this
    /// architecture. The CT is the only Alg.-1 output that depends on the
    /// static/dynamic split, so sweeps over N rebuild this table against
    /// shared partition/ranking instead of re-running all of Alg. 1.
    pub fn build_config_table(&self, ranking: &PatternRanking) -> ConfigTable {
        ConfigTable::build(
            ranking,
            self.config.crossbar_size,
            self.config.static_engines,
            self.config.crossbars_per_engine,
            self.config.dynamic_engines() * self.config.crossbars_per_engine,
            self.config.static_assignment,
        )
    }

    /// Alg. 2: run a vertex program on a preprocessed graph — a thin
    /// interpretation of the artifact's compiled execution plan.
    pub fn run(
        &self,
        pre: &Preprocessed,
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
    ) -> Result<SimReport> {
        self.run_threaded(pre, program, executor, 1)
    }

    /// Like [`run`](Self::run) but with `threads` batch-parallel
    /// execution lanes (`0` = one per hardware thread), served by a
    /// transient per-run worker pool. Results are bit-identical for
    /// every thread count — `threads <= 1` takes the sequential
    /// interpreter verbatim. Repeated callers should hold a persistent
    /// [`WorkerPool`](crate::sched::WorkerPool) and use
    /// [`run_pooled`](Self::run_pooled) instead (the `Session` does).
    pub fn run_threaded(
        &self,
        pre: &Preprocessed,
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
        threads: usize,
    ) -> Result<SimReport> {
        let run = crate::sched::par::run_parallel(
            &self.config,
            &self.params,
            &pre.plan,
            program,
            executor,
            threads,
        )?;
        Ok(self.report_of(program, run))
    }

    /// Like [`run_threaded`](Self::run_threaded) but on a caller-owned
    /// persistent worker pool: zero thread spawns per superstep *and* per
    /// run. The pool's worker count is the lane count; results stay
    /// bit-identical to every other execution path.
    pub fn run_pooled(
        &self,
        pre: &Preprocessed,
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
        pool: &mut crate::sched::WorkerPool,
    ) -> Result<SimReport> {
        let workers = pool.workers();
        self.run_pooled_at(pre, program, executor, pool, workers)
    }

    /// Like [`run_pooled`](Self::run_pooled) but capping the lane count
    /// at `threads` (`0` = auto; clamped to the pool size) — how a
    /// per-job parallelism override smaller than the session pool is
    /// honored without respawning workers.
    pub fn run_pooled_at(
        &self,
        pre: &Preprocessed,
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
        pool: &mut crate::sched::WorkerPool,
        threads: usize,
    ) -> Result<SimReport> {
        let run = crate::sched::par::run_parallel_pooled_at(
            &self.config,
            &self.params,
            &pre.plan,
            program,
            executor,
            pool,
            threads,
        )?;
        Ok(self.report_of(program, run))
    }

    /// Multi-job batch variant of [`run_pooled_at`](Self::run_pooled_at):
    /// runs every program against the same artifact in one lane-interleaved
    /// pipeline pass, paying the plan walk, crossbar replay, and pool
    /// dispatch once per batch. Each returned report is bit-identical to
    /// the one [`run_pooled_at`](Self::run_pooled_at) would produce for
    /// that program alone ([`sched::par::run_parallel_pooled_batch`]
    /// carries the determinism proof obligations).
    ///
    /// [`sched::par::run_parallel_pooled_batch`]: crate::sched::par::run_parallel_pooled_batch
    pub fn run_batch_pooled_at(
        &self,
        pre: &Preprocessed,
        programs: &[&dyn VertexProgram],
        executor: &mut dyn StepExecutor,
        pool: &mut crate::sched::WorkerPool,
        threads: usize,
    ) -> Result<Vec<SimReport>> {
        let runs = crate::sched::par::run_parallel_pooled_batch(
            &self.config,
            &self.params,
            &pre.plan,
            programs,
            executor,
            pool,
            threads,
        )?;
        Ok(programs
            .iter()
            .zip(runs)
            .map(|(p, run)| self.report_of(*p, run))
            .collect())
    }

    /// Sharded Alg. 2: lockstep supersteps across a per-shard artifact
    /// set (one [`preprocess_sharded_timed`](Self::preprocess_sharded_timed)
    /// output) with the deterministic cross-shard frontier exchange
    /// ([`sched::exchange`](crate::sched::exchange)), on a transient
    /// worker pool. Bit-identical to every unsharded execution path for
    /// every shard count.
    pub fn run_sharded(
        &self,
        shards: &[&Preprocessed],
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
        threads: usize,
    ) -> Result<SimReport> {
        let sp = crate::sched::ShardPlans::new(shards.iter().map(|p| &p.plan).collect())?;
        let run = crate::sched::run_sharded(
            &self.config,
            &self.params,
            &sp,
            program,
            executor,
            threads,
        )?;
        Ok(self.report_of(program, run))
    }

    /// Like [`run_sharded`](Self::run_sharded) but on caller-owned
    /// persistent pools — one per shard (`pools[shard % len]` serves
    /// each shard's numeric phase, `pools[0]` the global lane replay);
    /// the lane count caps at the smallest pool. This is the `Session`
    /// production path.
    pub fn run_sharded_pooled(
        &self,
        shards: &[&Preprocessed],
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
        pools: &mut [crate::sched::WorkerPool],
        threads: usize,
    ) -> Result<SimReport> {
        let sp = crate::sched::ShardPlans::new(shards.iter().map(|p| &p.plan).collect())?;
        let run = crate::sched::run_sharded_pooled(
            &self.config,
            &self.params,
            &sp,
            program,
            executor,
            pools,
            threads,
        )?;
        Ok(self.report_of(program, run))
    }

    /// Summarize a finished run (shared by every execution path).
    fn report_of(&self, program: &dyn VertexProgram, run: RunResult) -> SimReport {
        let total = run.total_counts();
        SimReport {
            design: "Proposed".to_string(),
            algorithm: program.name().to_string(),
            counts: total,
            energy: total.energy(&self.params),
            exec_time_ns: run.exec_time_ns,
            supersteps: run.supersteps,
            iterations: run.iterations,
            static_hit_rate: run.static_hit_rate(),
            max_cell_writes: run.max_dynamic_cell_writes as u64,
            run: Some(run),
        }
    }

    /// Convenience: preprocess + run in one call.
    pub fn simulate(
        &self,
        graph: &Coo,
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
    ) -> Result<SimReport> {
        let pre = self.preprocess(graph, program.needs_weights())?;
        self.run(&pre, program, executor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Bfs;
    use crate::graph::datasets::Dataset;
    use crate::sched::executor::NativeExecutor;

    #[test]
    fn end_to_end_simulate_tiny() {
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        let report = acc
            .simulate(&g, &Bfs::new(0), &mut NativeExecutor)
            .unwrap();
        assert!(report.energy_j() > 0.0);
        assert!(report.exec_time_ns > 0.0);
        assert!(report.static_hit_rate > 0.0);
        assert_eq!(report.design, "Proposed");
        assert_eq!(report.algorithm, "bfs");
    }

    #[test]
    fn run_threaded_matches_sequential_run() {
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        let pre = acc.preprocess(&g, false).unwrap();
        let a = acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap();
        let b = acc
            .run_threaded(&pre, &Bfs::new(0), &mut NativeExecutor, 4)
            .unwrap();
        assert_eq!(a.run.unwrap().values, b.run.as_ref().unwrap().values);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.exec_time_ns, b.exec_time_ns);
        assert_eq!(a.static_hit_rate, b.static_hit_rate);
    }

    #[test]
    fn run_pooled_matches_sequential_run() {
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        let pre = acc.preprocess(&g, false).unwrap();
        let a = acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap();
        let mut pool = crate::sched::WorkerPool::new(4);
        for _ in 0..2 {
            let b = acc
                .run_pooled(&pre, &Bfs::new(0), &mut NativeExecutor, &mut pool)
                .unwrap();
            assert_eq!(a.run.as_ref().unwrap().values, b.run.as_ref().unwrap().values);
            assert_eq!(a.counts, b.counts);
            assert_eq!(a.exec_time_ns, b.exec_time_ns);
        }
    }

    #[test]
    fn preprocess_threaded_is_whole_struct_equal_to_sequential() {
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        for weighted in [false, true] {
            let want = acc.preprocess(&g, weighted).unwrap();
            for threads in [1usize, 2, 4, 8] {
                let got = acc.preprocess_threaded(&g, weighted, threads).unwrap();
                assert_eq!(got, want, "threads {threads} weighted {weighted}");
            }
            // Pool reuse across compiles must not leak state between them.
            let mut pool = crate::sched::WorkerPool::new(3);
            for _ in 0..2 {
                let got = acc.preprocess_pooled(&g, weighted, &mut pool).unwrap();
                assert_eq!(got, want, "pooled weighted {weighted}");
            }
        }
    }

    #[test]
    fn preprocess_timed_records_every_phase() {
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        let (_, t) = acc.preprocess_timed(&g, false, None).unwrap();
        assert_eq!(t.threads, 1);
        assert_eq!(
            t.total_ns(),
            t.partition_ns + t.rank_ns + t.tables_ns + t.plan_ns
        );
        let mut pool = crate::sched::WorkerPool::new(4);
        let (_, t4) = acc.preprocess_timed(&g, false, Some(&mut pool)).unwrap();
        assert_eq!(t4.threads, 4);
    }

    #[test]
    fn preprocess_sharded_shares_one_global_ranking() {
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        let want = acc.preprocess(&g, false).unwrap();
        // One shard is the unsharded compile, whole-struct.
        let one = acc.preprocess_sharded(&g, false, 1, None).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0], want);
        for shards in [2usize, 3] {
            let pre = acc.preprocess_sharded(&g, false, shards, None).unwrap();
            assert_eq!(pre.len(), shards);
            for p in &pre {
                assert_eq!(p.ranking, want.ranking, "global ranking");
                assert_eq!(p.ct, want.ct, "global config table");
            }
            let total: usize = pre.iter().map(|p| p.part.num_subgraphs()).sum();
            assert_eq!(total, want.part.num_subgraphs());
            // Pooled sharded compile is whole-struct-equal per shard.
            let mut pool = crate::sched::WorkerPool::new(4);
            let pooled =
                acc.preprocess_sharded(&g, false, shards, Some(&mut pool)).unwrap();
            assert_eq!(pooled, pre, "pooled sharded compile");
        }
    }

    #[test]
    fn run_sharded_matches_run() {
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        let pre = acc.preprocess(&g, false).unwrap();
        let want = acc.run(&pre, &Bfs::new(0), &mut NativeExecutor).unwrap();
        let sharded = acc.preprocess_sharded(&g, false, 3, None).unwrap();
        let refs: Vec<&Preprocessed> = sharded.iter().collect();
        let got = acc
            .run_sharded(&refs, &Bfs::new(0), &mut NativeExecutor, 4)
            .unwrap();
        assert_eq!(want.run.as_ref().unwrap().values, got.run.as_ref().unwrap().values);
        assert_eq!(want.counts, got.counts);
        assert_eq!(want.exec_time_ns, got.exec_time_ns);
        assert_eq!(want.static_hit_rate, got.static_hit_rate);
        // Pooled mechanism, pool-per-shard, reused across rounds.
        let mut pools: Vec<crate::sched::WorkerPool> =
            (0..3).map(|_| crate::sched::WorkerPool::new(4)).collect();
        for round in 0..2 {
            let pooled = acc
                .run_sharded_pooled(&refs, &Bfs::new(0), &mut NativeExecutor, &mut pools, 4)
                .unwrap();
            assert_eq!(want.counts, pooled.counts, "round {round}");
            assert_eq!(want.exec_time_ns, pooled.exec_time_ns, "round {round}");
        }
    }

    #[test]
    fn preprocess_exposes_coverage() {
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        let pre = acc.preprocess(&g, false).unwrap();
        let cov = pre.static_coverage();
        assert!(cov > 0.0 && cov <= 1.0);
        assert_eq!(pre.ct.num_static_engines, 16);
        assert!(!pre.st.is_empty());
    }

    #[test]
    fn energy_dominated_by_reads_not_writes() {
        // With 16 static engines, runtime write energy should be a small
        // share — the headline effect of the paper.
        let g = Dataset::Tiny.load().unwrap();
        let acc = Accelerator::with_defaults();
        let r = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor).unwrap();
        assert!(
            r.energy.reram_write_j < r.energy.total_j() * 0.5,
            "write energy {:.3e} of {:.3e}",
            r.energy.reram_write_j,
            r.energy.total_j()
        );
    }

    #[test]
    fn invalid_config_rejected() {
        let g = Dataset::Tiny.load().unwrap();
        let mut config = ArchConfig::default();
        config.static_engines = 99;
        let acc = Accelerator::new(config, CostParams::default());
        assert!(acc.preprocess(&g, false).is_err());
    }
}
