//! Breadth-First Search as a vertex program: values are levels; the
//! edge-compute min-plus uses unit edge cost, so the fixpoint equals the
//! BFS level of every reachable vertex. The paper uses BFS as its
//! baseline benchmark algorithm (§IV.A).

use super::traits::{Semiring, StepKind, VertexProgram, INF};

#[derive(Debug, Clone, Copy)]
pub struct Bfs {
    pub source: u32,
}

impl Bfs {
    pub fn new(source: u32) -> Self {
        Self { source }
    }
}

impl VertexProgram for Bfs {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn semiring(&self) -> Semiring {
        Semiring::MinPlus
    }

    fn step_kind(&self) -> StepKind {
        StepKind::Bfs
    }

    fn init(&self, num_vertices: u32) -> Vec<f32> {
        let mut v = vec![INF; num_vertices as usize];
        if (self.source as usize) < v.len() {
            v[self.source as usize] = 0.0;
        }
        v
    }

    fn apply(&self, old: f32, reduced: f32) -> f32 {
        old.min(reduced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_sets_source_to_zero() {
        let v = Bfs::new(2).init(4);
        assert_eq!(v, vec![INF, INF, 0.0, INF]);
    }

    #[test]
    fn apply_is_min() {
        let b = Bfs::new(0);
        assert_eq!(b.apply(5.0, 3.0), 3.0);
        assert_eq!(b.apply(2.0, 9.0), 2.0);
    }

    #[test]
    fn changed_detects_updates() {
        let b = Bfs::new(0);
        assert!(b.changed(INF, 3.0));
        assert!(!b.changed(3.0, 3.0));
    }

    #[test]
    fn frontier_semantics() {
        let b = Bfs::new(0);
        assert!(!b.processes_all_blocks());
        assert!(!b.needs_weights());
    }
}
