//! Graph algorithms in the vertex programming model (paper §III.D,
//! inherited from GraphR): *edge compute* runs as in-situ MVM on the
//! crossbars, *reduce and apply* runs on the engine ALU. Pure-CPU
//! reference implementations validate the accelerator's numeric output.

pub mod bfs;
pub mod pagerank;
pub mod reference;
pub mod registry;
pub mod sssp;
pub mod traits;
pub mod wcc;

pub use bfs::Bfs;
pub use pagerank::PageRank;
pub use registry::{AlgoParams, AlgorithmId, AlgorithmRegistry, BoxedProgram};
pub use sssp::Sssp;
pub use traits::{Semiring, StepKind, VertexProgram, INF};
pub use wcc::Wcc;
