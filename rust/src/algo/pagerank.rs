//! PageRank: sum-product edge compute (the crossbar's native analog MAC)
//! with damping applied in the reduce/apply phase. Runs a fixed number of
//! synchronous power iterations — the same schedule as the CPU reference,
//! so results are comparable to float tolerance.

use super::traits::{Semiring, StepKind, VertexProgram};

#[derive(Debug, Clone, Copy)]
pub struct PageRank {
    pub damping: f32,
    pub iterations: usize,
}

impl Default for PageRank {
    fn default() -> Self {
        Self { damping: 0.85, iterations: 20 }
    }
}

impl PageRank {
    pub fn new(damping: f32, iterations: usize) -> Self {
        assert!((0.0..1.0).contains(&damping));
        assert!(iterations >= 1);
        Self { damping, iterations }
    }
}

impl VertexProgram for PageRank {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn semiring(&self) -> Semiring {
        Semiring::SumProd
    }

    fn step_kind(&self) -> StepKind {
        StepKind::PageRank
    }

    fn init(&self, num_vertices: u32) -> Vec<f32> {
        let r = 1.0 / num_vertices.max(1) as f32;
        vec![r; num_vertices as usize]
    }

    fn source_value(&self, value: f32, out_degree: u32) -> f32 {
        if out_degree == 0 {
            0.0 // dangling mass dropped, as in GraphR's streaming model
        } else {
            value / out_degree as f32
        }
    }

    /// Not used for SumProd (scheduler accumulates into `acc`); finalize
    /// happens in `post_superstep`.
    fn apply(&self, _old: f32, reduced: f32) -> f32 {
        reduced
    }

    fn post_superstep(
        &self,
        superstep: usize,
        values: &mut [f32],
        acc: &mut [f32],
        _any_changed: bool,
    ) -> bool {
        let n = values.len().max(1) as f32;
        let base = (1.0 - self.damping) / n;
        for (v, a) in values.iter_mut().zip(acc.iter_mut()) {
            *v = base + self.damping * *a;
            *a = 0.0;
        }
        superstep + 1 < self.iterations
    }

    fn max_supersteps(&self) -> usize {
        self.iterations
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_uniform() {
        let v = PageRank::default().init(4);
        assert!(v.iter().all(|&x| (x - 0.25).abs() < 1e-7));
    }

    #[test]
    fn source_value_divides_by_outdegree() {
        let pr = PageRank::default();
        assert_eq!(pr.source_value(0.6, 3), 0.2);
        assert_eq!(pr.source_value(0.6, 0), 0.0);
    }

    #[test]
    fn post_superstep_applies_damping_and_resets_acc() {
        let pr = PageRank::new(0.85, 2);
        let mut values = vec![0.0f32; 2];
        let mut acc = vec![0.4f32, 0.1];
        let cont = pr.post_superstep(0, &mut values, &mut acc, true);
        assert!(cont);
        assert!((values[0] - (0.075 + 0.85 * 0.4)).abs() < 1e-6);
        assert_eq!(acc, vec![0.0, 0.0]);
        // Second superstep is the last.
        assert!(!pr.post_superstep(1, &mut values, &mut acc, true));
    }

    #[test]
    fn processes_all_blocks() {
        assert!(PageRank::default().processes_all_blocks());
    }

    #[test]
    #[should_panic]
    fn rejects_bad_damping() {
        PageRank::new(1.5, 10);
    }
}
