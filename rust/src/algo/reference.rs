//! Pure-CPU reference implementations — ground truth for validating the
//! accelerator's numeric output (end-to-end example + integration tests).

use std::collections::VecDeque;

use crate::graph::Csr;

use super::traits::INF;

/// BFS levels from `source` (INF for unreachable vertices).
pub fn bfs_levels(csr: &Csr, source: u32) -> Vec<f32> {
    let n = csr.num_vertices as usize;
    let mut level = vec![INF; n];
    if source as usize >= n {
        return level;
    }
    level[source as usize] = 0.0;
    let mut q = VecDeque::new();
    q.push_back(source);
    while let Some(v) = q.pop_front() {
        let next = level[v as usize] + 1.0;
        for (u, _) in csr.neighbors(v) {
            if level[u as usize] >= INF {
                level[u as usize] = next;
                q.push_back(u);
            }
        }
    }
    level
}

/// SSSP distances via Bellman–Ford (handles any non-negative weights; the
/// accelerator's synchronous min-plus converges to the same fixpoint).
pub fn sssp_distances(csr: &Csr, source: u32) -> Vec<f32> {
    let n = csr.num_vertices as usize;
    let mut dist = vec![INF; n];
    if source as usize >= n {
        return dist;
    }
    dist[source as usize] = 0.0;
    let mut active: Vec<u32> = vec![source];
    let mut next: Vec<u32> = Vec::new();
    let mut in_next = vec![false; n];
    let mut rounds = 0;
    while !active.is_empty() && rounds <= n {
        for &v in &active {
            let dv = dist[v as usize];
            for (u, w) in csr.neighbors(v) {
                let cand = dv + w;
                if cand < dist[u as usize] {
                    dist[u as usize] = cand;
                    if !in_next[u as usize] {
                        in_next[u as usize] = true;
                        next.push(u);
                    }
                }
            }
        }
        active.clear();
        std::mem::swap(&mut active, &mut next);
        for &v in &active {
            in_next[v as usize] = false;
        }
        rounds += 1;
    }
    dist
}

/// Synchronous PageRank, identical schedule to the accelerator: `iters`
/// power iterations, damping `d`, dangling mass dropped.
pub fn pagerank(csr: &Csr, d: f32, iters: usize) -> Vec<f32> {
    let n = csr.num_vertices as usize;
    if n == 0 {
        return vec![];
    }
    let mut rank = vec![1.0 / n as f32; n];
    let mut acc = vec![0f32; n];
    for _ in 0..iters {
        acc.iter_mut().for_each(|a| *a = 0.0);
        for v in 0..n as u32 {
            let deg = csr.out_degree(v);
            if deg == 0 {
                continue;
            }
            let share = rank[v as usize] / deg as f32;
            for (u, _) in csr.neighbors(v) {
                acc[u as usize] += share;
            }
        }
        let base = (1.0 - d) / n as f32;
        for (r, a) in rank.iter_mut().zip(&acc) {
            *r = base + d * a;
        }
    }
    rank
}

/// Weakly-connected-component labels (min vertex id per component).
/// Assumes the graph is already symmetrized (paper benchmarks are
/// undirected).
pub fn wcc_labels(csr: &Csr) -> Vec<f32> {
    let n = csr.num_vertices as usize;
    let mut label: Vec<f32> = (0..n).map(|v| v as f32).collect();
    let mut changed = true;
    while changed {
        changed = false;
        for v in 0..n as u32 {
            for (u, _) in csr.neighbors(v) {
                let lv = label[v as usize];
                let lu = label[u as usize];
                if lv < lu {
                    label[u as usize] = lv;
                    changed = true;
                } else if lu < lv {
                    label[v as usize] = lu;
                    changed = true;
                }
            }
        }
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::{Coo, Edge};

    fn path_graph() -> Csr {
        // 0 -> 1 -> 2 -> 3, plus isolated 4.
        Csr::from_coo(&Coo::from_edges(
            5,
            vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(2, 3)],
        ))
    }

    #[test]
    fn bfs_levels_on_path() {
        let l = bfs_levels(&path_graph(), 0);
        assert_eq!(&l[..4], &[0.0, 1.0, 2.0, 3.0]);
        assert!(l[4] >= INF);
    }

    #[test]
    fn bfs_from_middle() {
        let l = bfs_levels(&path_graph(), 2);
        assert!(l[0] >= INF); // directed: cannot go back
        assert_eq!(l[3], 1.0);
    }

    #[test]
    fn sssp_prefers_cheaper_path() {
        // 0->1 (5), 0->2 (1), 2->1 (1): dist(1) = 2.
        let g = Coo::from_edges(
            3,
            vec![
                Edge::weighted(0, 1, 5.0),
                Edge::weighted(0, 2, 1.0),
                Edge::weighted(2, 1, 1.0),
            ],
        );
        let d = sssp_distances(&Csr::from_coo(&g), 0);
        assert_eq!(d, vec![0.0, 2.0, 1.0]);
    }

    #[test]
    fn sssp_equals_bfs_on_unit_weights() {
        let g = crate::graph::datasets::Dataset::Tiny.load().unwrap();
        let csr = Csr::from_coo(&g);
        let b = bfs_levels(&csr, 0);
        let s = sssp_distances(&csr, 0);
        for (x, y) in b.iter().zip(&s) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn pagerank_sums_to_at_most_one() {
        let g = crate::graph::datasets::Dataset::Tiny.load().unwrap();
        let csr = Csr::from_coo(&g);
        let r = pagerank(&csr, 0.85, 15);
        let sum: f32 = r.iter().sum();
        assert!(sum > 0.5 && sum <= 1.0 + 1e-3, "sum={sum}");
        assert!(r.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn pagerank_uniform_on_cycle() {
        let g = Coo::from_edges(
            3,
            vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(2, 0)],
        );
        let r = pagerank(&Csr::from_coo(&g), 0.85, 50);
        for &x in &r {
            assert!((x - 1.0 / 3.0).abs() < 1e-5);
        }
    }

    #[test]
    fn wcc_finds_components() {
        let g = Coo::from_edges(
            6,
            vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(4, 5)],
        )
        .symmetrize();
        let l = wcc_labels(&Csr::from_coo(&g));
        assert_eq!(l, vec![0.0, 0.0, 0.0, 3.0, 4.0, 4.0]);
    }
}
