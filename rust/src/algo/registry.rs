//! Open algorithm registry: algorithms as pluggable *data*, not
//! hardcoded control flow.
//!
//! The CLI `run` path, the coordinator `serve` path, and DSE all used to
//! carry their own four-way `match` over BFS/SSSP/PageRank/WCC. The
//! registry collapses those into a single lookup table of factories built
//! on the [`VertexProgram`] trait: adding an algorithm is one
//! [`AlgorithmRegistry::register`] call, visible to every entry point at
//! once (GraphR's framing — graph processing as algorithm-agnostic
//! sparse-MVM episodes — with programmability as a first-class axis).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use anyhow::Result;

use super::traits::VertexProgram;
use super::{Bfs, PageRank, Sssp, Wcc};

/// A boxed, thread-safe vertex program (serve workers run jobs on any
/// thread, so registered programs must be `Send + Sync`).
pub type BoxedProgram = Box<dyn VertexProgram + Send + Sync>;

/// Identifier of a registered algorithm. Case-insensitive: stored and
/// compared lowercase, so `"BFS"`, `"bfs"` and `"Bfs"` name one entry.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AlgorithmId(String);

impl AlgorithmId {
    pub fn new(name: impl AsRef<str>) -> Self {
        Self(name.as_ref().trim().to_ascii_lowercase())
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for AlgorithmId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl From<&str> for AlgorithmId {
    fn from(s: &str) -> Self {
        Self::new(s)
    }
}

impl From<String> for AlgorithmId {
    fn from(s: String) -> Self {
        Self::new(s)
    }
}

/// Open parameter bag for instantiating a vertex program. Factories read
/// the fields they care about and ignore the rest, so one `JobSpec` shape
/// serves every algorithm (and future registrations reuse the same bag).
#[derive(Debug, Clone, PartialEq)]
pub struct AlgoParams {
    /// Source vertex (BFS / SSSP; ignored by PageRank / WCC).
    pub source: u32,
    /// Power iterations (PageRank).
    pub iterations: usize,
    /// Damping factor (PageRank).
    pub damping: f32,
}

impl Default for AlgoParams {
    fn default() -> Self {
        Self { source: 0, iterations: 20, damping: 0.85 }
    }
}

type BuildFn = dyn Fn(&AlgoParams) -> Result<BoxedProgram> + Send + Sync;

/// One registered algorithm: identity plus the factory that turns an
/// [`AlgoParams`] bag into a runnable program. Partitioning requirements
/// (`needs_weights`) come from the instantiated [`VertexProgram`]
/// itself, so the registry cannot disagree with the program.
pub struct AlgorithmEntry {
    id: AlgorithmId,
    build: Box<BuildFn>,
}

impl AlgorithmEntry {
    pub fn id(&self) -> &AlgorithmId {
        &self.id
    }

    pub fn instantiate(&self, params: &AlgoParams) -> Result<BoxedProgram> {
        (self.build)(params)
    }
}

impl fmt::Debug for AlgorithmEntry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AlgorithmEntry")
            .field("id", &self.id)
            .finish_non_exhaustive()
    }
}

/// Lookup table from [`AlgorithmId`] to factory. Immutable once a
/// `Session` is built; construct with [`with_builtins`] and extend via
/// [`register`] before handing it to the session builder.
///
/// [`with_builtins`]: AlgorithmRegistry::with_builtins
/// [`register`]: AlgorithmRegistry::register
#[derive(Debug)]
pub struct AlgorithmRegistry {
    entries: BTreeMap<AlgorithmId, Arc<AlgorithmEntry>>,
}

impl AlgorithmRegistry {
    /// A registry with no entries (library users composing their own set).
    pub fn empty() -> Self {
        Self { entries: BTreeMap::new() }
    }

    /// The paper's four algorithms (§III.D).
    pub fn with_builtins() -> Self {
        let mut r = Self::empty();
        r.register("bfs", |p| Ok(Box::new(Bfs::new(p.source))));
        r.register("sssp", |p| Ok(Box::new(Sssp::new(p.source))));
        r.register("pagerank", |p| {
            anyhow::ensure!(
                (0.0..1.0).contains(&p.damping),
                "pagerank damping must be in [0, 1), got {}",
                p.damping
            );
            anyhow::ensure!(p.iterations >= 1, "pagerank needs at least one iteration");
            Ok(Box::new(PageRank::new(p.damping, p.iterations)))
        });
        r.register("wcc", |_| Ok(Box::new(Wcc)));
        r
    }

    /// Register (or replace) an algorithm: `build` validates the
    /// parameter bag and constructs the program.
    pub fn register(
        &mut self,
        id: impl Into<AlgorithmId>,
        build: impl Fn(&AlgoParams) -> Result<BoxedProgram> + Send + Sync + 'static,
    ) -> &mut Self {
        let id = id.into();
        self.entries
            .insert(id.clone(), Arc::new(AlgorithmEntry { id, build: Box::new(build) }));
        self
    }

    pub fn get(&self, id: &AlgorithmId) -> Option<&Arc<AlgorithmEntry>> {
        self.entries.get(id)
    }

    /// Like [`get`](Self::get), but the error names every known id.
    pub fn resolve(&self, id: &AlgorithmId) -> Result<&Arc<AlgorithmEntry>> {
        self.get(id).ok_or_else(|| {
            let known: Vec<&str> = self.entries.keys().map(AlgorithmId::as_str).collect();
            anyhow::anyhow!("unknown algorithm {:?} (registered: {})", id.as_str(), known.join(" "))
        })
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> impl Iterator<Item = &AlgorithmId> {
        self.entries.keys()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Default for AlgorithmRegistry {
    fn default() -> Self {
        Self::with_builtins()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_cover_the_paper_algorithms() {
        let r = AlgorithmRegistry::with_builtins();
        let ids: Vec<&str> = r.ids().map(AlgorithmId::as_str).collect();
        assert_eq!(ids, vec!["bfs", "pagerank", "sssp", "wcc"]);
        let p = AlgoParams::default();
        let prog = |id: &str| r.get(&id.into()).unwrap().instantiate(&p).unwrap();
        assert!(prog("sssp").needs_weights());
        assert!(!prog("bfs").needs_weights());
    }

    #[test]
    fn ids_are_case_insensitive() {
        let r = AlgorithmRegistry::with_builtins();
        assert!(r.get(&AlgorithmId::new("PageRank")).is_some());
        assert_eq!(AlgorithmId::new(" BFS "), AlgorithmId::new("bfs"));
    }

    #[test]
    fn resolve_error_names_known_ids() {
        let r = AlgorithmRegistry::with_builtins();
        let err = r.resolve(&"sswp".into()).unwrap_err().to_string();
        assert!(err.contains("sswp") && err.contains("sssp"), "{err}");
    }

    #[test]
    fn factories_thread_params_through() {
        let r = AlgorithmRegistry::with_builtins();
        let p = AlgoParams { source: 7, ..AlgoParams::default() };
        let prog = r.resolve(&"bfs".into()).unwrap().instantiate(&p).unwrap();
        let init = prog.init(10);
        assert_eq!(init[7], 0.0);
    }

    #[test]
    fn factories_validate_params() {
        let r = AlgorithmRegistry::with_builtins();
        let bad = AlgoParams { damping: 1.5, ..AlgoParams::default() };
        assert!(r.resolve(&"pagerank".into()).unwrap().instantiate(&bad).is_err());
        let bad = AlgoParams { iterations: 0, ..AlgoParams::default() };
        assert!(r.resolve(&"pagerank".into()).unwrap().instantiate(&bad).is_err());
    }

    #[test]
    fn custom_registration_is_one_call() {
        let mut r = AlgorithmRegistry::with_builtins();
        r.register("bfs-from-42", |_| Ok(Box::new(Bfs::new(42))));
        assert_eq!(r.len(), 5);
        let prog = r
            .resolve(&"bfs-from-42".into())
            .unwrap()
            .instantiate(&AlgoParams::default())
            .unwrap();
        assert_eq!(prog.init(64)[42], 0.0);
    }
}
