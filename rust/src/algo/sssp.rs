//! Single-Source Shortest Path: Bellman-Ford-style min-plus over weighted
//! edges (weights stored in the crossbar; 1-bit ReRAM holds structure and
//! the weight rides in the subgraph table — functionally equivalent for
//! the simulator, see DESIGN.md).

use super::traits::{Semiring, StepKind, VertexProgram, INF};

#[derive(Debug, Clone, Copy)]
pub struct Sssp {
    pub source: u32,
}

impl Sssp {
    pub fn new(source: u32) -> Self {
        Self { source }
    }
}

impl VertexProgram for Sssp {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn semiring(&self) -> Semiring {
        Semiring::MinPlus
    }

    fn step_kind(&self) -> StepKind {
        StepKind::Sssp
    }

    fn needs_weights(&self) -> bool {
        true
    }

    fn init(&self, num_vertices: u32) -> Vec<f32> {
        let mut v = vec![INF; num_vertices as usize];
        if (self.source as usize) < v.len() {
            v[self.source as usize] = 0.0;
        }
        v
    }

    fn apply(&self, old: f32, reduced: f32) -> f32 {
        old.min(reduced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requires_weights() {
        assert!(Sssp::new(0).needs_weights());
    }

    #[test]
    fn init_and_apply() {
        let s = Sssp::new(1);
        assert_eq!(s.init(3), vec![INF, 0.0, INF]);
        assert_eq!(s.apply(7.5, 2.5), 2.5);
    }
}
