//! The vertex-program abstraction shared by the scheduler, the native
//! executor and the AOT (PJRT) executor.
//!
//! Execution is synchronous (Jacobi-style): each superstep computes all
//! edge contributions from a snapshot of the vertex values, then the
//! reduce/apply phase folds them into the new values. This matches the
//! L2 batch-step artifacts, which are pure functions of
//! `(patterns, snapshot)`.

/// "No value" sentinel for the tropical semiring. Mirrors
/// `python/compile/kernels/crossbar_mvm.py::INF` — the two layers must
/// agree so PJRT and native execution are interchangeable.
pub const INF: f32 = 1.0e9;

/// Reduction structure of the edge-compute phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Semiring {
    /// out[j] = min_i (cost[i][j] + x[i])  (BFS, SSSP, WCC).
    MinPlus,
    /// out[j] = sum_i (adj[i][j] * x[i])   (PageRank).
    SumProd,
}

/// Which AOT artifact implements a program's edge-compute step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepKind {
    Bfs,
    Sssp,
    PageRank,
    Wcc,
    Mvm,
}

impl StepKind {
    /// Artifact base name (matches `python/compile/aot.py`).
    pub fn artifact_name(self) -> &'static str {
        match self {
            StepKind::Bfs => "bfs",
            StepKind::Sssp => "sssp",
            StepKind::PageRank => "pagerank",
            StepKind::Wcc => "wcc",
            StepKind::Mvm => "mvm",
        }
    }
}

/// A graph algorithm expressed for the accelerator.
pub trait VertexProgram {
    fn name(&self) -> &'static str;
    fn semiring(&self) -> Semiring;
    fn step_kind(&self) -> StepKind;

    /// Whether edge weights must be kept by partitioning (SSSP).
    fn needs_weights(&self) -> bool {
        false
    }

    /// Initial vertex values.
    fn init(&self, num_vertices: u32) -> Vec<f32>;

    /// Map a vertex value to its wordline input for edge compute.
    /// PageRank divides by out-degree; min-plus programs pass through.
    fn source_value(&self, value: f32, out_degree: u32) -> f32 {
        let _ = out_degree;
        value
    }

    /// Fold one reduced candidate into a vertex value; returns the new
    /// value. (MinPlus: min(old, cand); SumProd: accumulation handled by
    /// the scheduler, `apply` finalizes in `post_superstep`.)
    fn apply(&self, old: f32, reduced: f32) -> f32;

    /// Did `apply` change the vertex (drives the active frontier)?
    fn changed(&self, old: f32, new: f32) -> bool {
        (old - new).abs() > 1e-7
    }

    /// Finalize a superstep. For SumProd programs `acc` holds the summed
    /// contributions and the program writes the new values; returns
    /// `true` if another superstep is needed. MinPlus programs use the
    /// default (continue while the frontier is non-empty).
    fn post_superstep(
        &self,
        superstep: usize,
        values: &mut [f32],
        acc: &mut [f32],
        any_changed: bool,
    ) -> bool {
        let _ = (superstep, values, acc);
        any_changed
    }

    /// Process every subgraph each superstep (SumProd) or only those with
    /// active sources (MinPlus frontier).
    fn processes_all_blocks(&self) -> bool {
        self.semiring() == Semiring::SumProd
    }

    /// Hard cap on supersteps (guards non-converging inputs).
    fn max_supersteps(&self) -> usize {
        10_000
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_match_python_step_names() {
        assert_eq!(StepKind::Bfs.artifact_name(), "bfs");
        assert_eq!(StepKind::Sssp.artifact_name(), "sssp");
        assert_eq!(StepKind::PageRank.artifact_name(), "pagerank");
        assert_eq!(StepKind::Wcc.artifact_name(), "wcc");
        assert_eq!(StepKind::Mvm.artifact_name(), "mvm");
    }

    #[test]
    fn inf_matches_python_sentinel() {
        assert_eq!(INF, 1.0e9);
    }
}
