//! Weakly Connected Components by min-label propagation: min-plus with
//! zero edge cost, so each vertex converges to the minimum vertex id in
//! its (weakly) connected component. Exercises the third "classical"
//! algorithm family the paper's architecture supports.

use super::traits::{Semiring, StepKind, VertexProgram};

#[derive(Debug, Clone, Copy, Default)]
pub struct Wcc;

impl VertexProgram for Wcc {
    fn name(&self) -> &'static str {
        "wcc"
    }

    fn semiring(&self) -> Semiring {
        Semiring::MinPlus
    }

    fn step_kind(&self) -> StepKind {
        StepKind::Wcc
    }

    fn init(&self, num_vertices: u32) -> Vec<f32> {
        (0..num_vertices).map(|v| v as f32).collect()
    }

    fn apply(&self, old: f32, reduced: f32) -> f32 {
        old.min(reduced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_identity_labels() {
        assert_eq!(Wcc.init(4), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn apply_propagates_min_label() {
        assert_eq!(Wcc.apply(3.0, 1.0), 1.0);
    }
}
