//! Shared machinery for the baseline models: coarse (arbitrary-size)
//! window partitioning and the BFS frontier schedule that tells each
//! model which blocks are touched in which superstep.

use std::collections::HashMap;

use crate::accel::SimReport;
use crate::algo::reference::bfs_levels;
use crate::algo::traits::INF;
use crate::cost::CostParams;
use crate::graph::{Coo, Csr};

/// One non-empty window at an arbitrary block size (supports the 128×128
/// crossbars the baselines use — too large for the packed `Pattern`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoarseBlock {
    pub brow: u32,
    pub bcol: u32,
    pub nnz: u32,
}

/// Non-empty C×C windows of `g`'s adjacency matrix with edge counts.
pub fn coarse_partition(g: &Coo, c: u32) -> Vec<CoarseBlock> {
    assert!(c >= 1);
    let mut windows: HashMap<u64, u32> = HashMap::new();
    for e in &g.edges {
        let key = ((e.src / c) as u64) << 32 | (e.dst / c) as u64;
        *windows.entry(key).or_insert(0) += 1;
    }
    let mut blocks: Vec<CoarseBlock> = windows
        .into_iter()
        .map(|(k, nnz)| CoarseBlock { brow: (k >> 32) as u32, bcol: k as u32, nnz })
        .collect();
    blocks.sort_unstable_by_key(|b| (b.bcol, b.brow)); // column-major order
    blocks
}

/// BFS workload schedule at block granularity: for each superstep, which
/// blocks have frontier sources, and how many frontier edges they carry.
#[derive(Debug, Clone)]
pub struct BfsSchedule {
    /// `active[s]` = indices into `blocks` processed in superstep `s`.
    pub active: Vec<Vec<u32>>,
    pub blocks: Vec<CoarseBlock>,
    pub supersteps: usize,
}

impl BfsSchedule {
    /// Total block operations across the run.
    pub fn total_ops(&self) -> u64 {
        self.active.iter().map(|a| a.len() as u64).sum()
    }

    /// Total edges touched (sum of nnz over processed blocks).
    pub fn total_edges_touched(&self) -> u64 {
        self.active
            .iter()
            .flat_map(|a| a.iter())
            .map(|&i| self.blocks[i as usize].nnz as u64)
            .sum()
    }
}

/// Build the BFS schedule: superstep `s` processes every block whose
/// source range contains a vertex at level `s` (the frontier), mirroring
/// the streaming-apply model with active-source filtering.
pub fn bfs_schedule(g: &Coo, c: u32, source: u32) -> BfsSchedule {
    let levels = bfs_levels(&Csr::from_coo(g), source);
    let blocks = coarse_partition(g, c);
    let num_brows = g.num_vertices.div_ceil(c) as usize;

    // level -> set of source block-rows with a frontier vertex.
    let max_level = levels
        .iter()
        .filter(|&&l| l < INF)
        .fold(0f32, |a, &b| a.max(b)) as usize;
    let mut frontier_rows: Vec<Vec<bool>> = vec![vec![false; num_brows]; max_level + 1];
    for (v, &l) in levels.iter().enumerate() {
        if l < INF {
            frontier_rows[l as usize][v / c as usize] = true;
        }
    }

    let active = frontier_rows
        .iter()
        .map(|rows| {
            blocks
                .iter()
                .enumerate()
                .filter(|(_, b)| rows[b.brow as usize])
                .map(|(i, _)| i as u32)
                .collect()
        })
        .collect();
    BfsSchedule { active, blocks, supersteps: max_level + 1 }
}

/// A baseline accelerator model.
pub trait BaselineModel {
    fn name(&self) -> &'static str;
    /// Simulate BFS with `engines` graph engines and Table 3 costs.
    fn simulate_bfs(&self, g: &Coo, source: u32, params: &CostParams, engines: u32)
        -> SimReport;
}

/// 64-byte burst count for `bits` of sequential traffic.
pub fn bursts(bits: u64) -> u64 {
    bits.div_ceil(512)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::Edge;
    use crate::graph::datasets::Dataset;

    #[test]
    fn coarse_partition_counts_nnz() {
        let g = Coo::from_edges(
            256,
            vec![Edge::new(0, 1), Edge::new(3, 200), Edge::new(130, 140), Edge::new(131, 141)],
        );
        let blocks = coarse_partition(&g, 128);
        assert_eq!(blocks.len(), 3); // (0,0), (0,1), (1,1)
        let b11 = blocks.iter().find(|b| (b.brow, b.bcol) == (1, 1)).unwrap();
        assert_eq!(b11.nnz, 2);
    }

    #[test]
    fn coarse_matches_fine_partition_totals() {
        let g = Dataset::Tiny.load().unwrap();
        let blocks = coarse_partition(&g, 4);
        let fine = crate::pattern::extract::partition(&g, 4, false);
        assert_eq!(blocks.len(), fine.num_subgraphs());
        let nnz: u64 = blocks.iter().map(|b| b.nnz as u64).sum();
        assert_eq!(nnz as usize, g.num_edges());
    }

    #[test]
    fn bfs_schedule_covers_frontier() {
        let g = Dataset::Tiny.load().unwrap();
        let s = bfs_schedule(&g, 4, 0);
        assert!(s.supersteps >= 2);
        assert!(s.total_ops() > 0);
        // Superstep 0 processes exactly the blocks whose source row
        // contains vertex 0.
        for &i in &s.active[0] {
            assert_eq!(s.blocks[i as usize].brow, 0);
        }
    }

    #[test]
    fn schedule_larger_blocks_fewer_ops() {
        let g = Dataset::Tiny.load().unwrap();
        let fine = bfs_schedule(&g, 4, 0);
        let coarse = bfs_schedule(&g, 128, 0);
        assert!(coarse.total_ops() < fine.total_ops());
        assert_eq!(fine.supersteps, coarse.supersteps);
    }

    #[test]
    fn bursts_rounding() {
        assert_eq!(bursts(0), 0);
        assert_eq!(bursts(1), 1);
        assert_eq!(bursts(512), 1);
        assert_eq!(bursts(513), 2);
    }
}
