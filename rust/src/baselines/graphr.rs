//! GraphR [10] baseline: uncompressed adjacency blocks streamed into
//! large (default 128×128) ReRAM crossbars every iteration.
//!
//! Model (paper §II.C / Table 1: memory access High/High):
//! * streaming-apply without frontier filtering — every non-empty block
//!   is (re)programmed and processed in every superstep;
//! * programming writes the full C×C submatrix (uncompressed adjacency),
//!   bit-serial (Table 3 per-bit write);
//! * MVM then reads the full crossbar, one ADC conversion per bitline.

use crate::accel::SimReport;
use crate::cost::{timing, CostParams, EventCounts};
use crate::graph::Coo;

use super::common::{bfs_schedule, bursts, BaselineModel};

#[derive(Debug, Clone)]
pub struct GraphR {
    /// Baseline crossbar size (paper §IV.A: 128×128, same capacity).
    pub crossbar: u32,
    /// GraphR stores 4-bit MLC cells (Table 1). Programming an MLC level
    /// takes an incremental program-and-verify sequence; we model it as
    /// this many SLC-equivalent per-bit writes (energy & latency).
    pub mlc_write_factor: u32,
    /// MLC endurance derating vs SLC, folded into the lifetime wear
    /// count (4-bit MLC endures ~an order of magnitude fewer cycles).
    pub mlc_endurance_derate: u32,
}

impl Default for GraphR {
    fn default() -> Self {
        Self { crossbar: 128, mlc_write_factor: 4, mlc_endurance_derate: 25 }
    }
}

impl BaselineModel for GraphR {
    fn name(&self) -> &'static str {
        "GraphR"
    }

    fn simulate_bfs(
        &self,
        g: &Coo,
        source: u32,
        params: &CostParams,
        engines: u32,
    ) -> SimReport {
        let c = self.crossbar as u64;
        let sched = bfs_schedule(g, self.crossbar, source);
        let blocks = sched.blocks.len() as u64;
        let supersteps = sched.supersteps as u64;
        // No frontier filter: all blocks, every superstep.
        let ops = blocks * supersteps;

        let mut counts = EventCounts::default();
        counts.mvm_ops = ops;
        counts.reconfigs = ops;
        // Full uncompressed submatrix at 4-bit MLC program-verify cost.
        counts.write_bits = ops * c * c * self.mlc_write_factor as u64;
        counts.read_bits = ops * c * c; // full-crossbar MVM read
        counts.sense_ops = ops * c;
        counts.adc_ops = ops * c;
        counts.sram_accesses = ops * 2;
        // Block data (c*c bits) + vertex vector stream from main memory.
        counts.main_mem_accesses = ops * (bursts(c * c) + 1);
        counts.alu_ops = ops * c;

        // Per-block latency: bit-serial MLC programming dominates.
        let per_block_ns =
            timing::reconfig_latency_ns(params, (c * c) as u32 * self.mlc_write_factor)
            + timing::mvm_latency_ns(params, self.crossbar, self.crossbar)
            + timing::reduce_latency_ns(params, self.crossbar);
        // Engines process blocks in parallel within each superstep.
        let mut exec_time_ns = 0f64;
        for _ in 0..supersteps {
            let waves = blocks.div_ceil(engines as u64);
            exec_time_ns += waves as f64 * per_block_ns;
        }

        // Lifetime: every cell of an engine's crossbar is programmed on
        // every block load (program-verify pulses), and 4-bit MLC cells
        // endure ~10x fewer cycles than SLC — both folded into an
        // SLC-equivalent wear count (DESIGN.md §Substitutions).
        let max_cell_writes = ops.div_ceil(engines as u64)
            * (self.mlc_write_factor * self.mlc_endurance_derate) as u64;

        SimReport {
            design: self.name().to_string(),
            algorithm: "bfs".to_string(),
            counts,
            energy: counts.energy(params),
            exec_time_ns,
            supersteps: sched.supersteps,
            iterations: ops,
            static_hit_rate: 0.0,
            max_cell_writes,
            run: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;

    #[test]
    fn graphr_is_write_dominated() {
        let g = Dataset::Tiny.load().unwrap();
        let r = GraphR::default().simulate_bfs(&g, 0, &CostParams::default(), 32);
        assert!(r.energy.reram_write_j > r.energy.reram_read_j);
        assert!(r.energy.reram_write_j > 0.5 * r.energy_j());
        assert!(r.max_cell_writes > 0);
    }

    #[test]
    fn smaller_crossbars_fewer_writes_per_op() {
        let g = Dataset::Tiny.load().unwrap();
        let big = GraphR::default().simulate_bfs(&g, 0, &CostParams::default(), 32);
        let small = GraphR { crossbar: 16, ..GraphR::default() }.simulate_bfs(&g, 0, &CostParams::default(), 32);
        // 128x128 programs 16384 cells per op (x4 MLC pulses); 16x16: 256.
        let big_per_op = big.counts.write_bits / big.counts.mvm_ops;
        let small_per_op = small.counts.write_bits / small.counts.mvm_ops;
        assert_eq!(big_per_op, 128 * 128 * 4);
        assert_eq!(small_per_op, 256 * 4);
    }

    #[test]
    fn more_engines_faster() {
        let g = Dataset::Tiny.load().unwrap();
        let p = CostParams::default();
        let few = GraphR::default().simulate_bfs(&g, 0, &p, 8);
        let many = GraphR::default().simulate_bfs(&g, 0, &p, 64);
        assert!(many.exec_time_ns < few.exec_time_ns);
        // Energy is engine-count independent (same work).
        assert!((many.energy_j() - few.energy_j()).abs() < 1e-12);
    }
}
