//! State-of-the-art baseline accelerators (paper §IV.C): analytic event
//! models of GraphR [10], SparseMEM [15] and TARe [16], driven by the
//! same workload (graph + BFS frontier schedule) and the same Table 3
//! constants as the proposed design. Each model implements the mapping
//! scheme the paper attributes to it; see DESIGN.md §Substitutions for
//! the calibration rationale.

pub mod common;
pub mod graphr;
pub mod sparsemem;
pub mod tare;

pub use common::{bfs_schedule, coarse_partition, BaselineModel, BfsSchedule, CoarseBlock};
pub use graphr::GraphR;
pub use sparsemem::SparseMem;
pub use tare::TaRe;

use crate::accel::SimReport;
use crate::cost::CostParams;
use crate::graph::Coo;

/// Run all three baselines on a BFS workload.
pub fn simulate_all(
    g: &Coo,
    source: u32,
    params: &CostParams,
    engines: u32,
) -> Vec<SimReport> {
    vec![
        GraphR::default().simulate_bfs(g, source, params, engines),
        SparseMem::default().simulate_bfs(g, source, params, engines),
        TaRe::default().simulate_bfs(g, source, params, engines),
    ]
}
