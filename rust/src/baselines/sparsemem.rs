//! SparseMEM [15] baseline: compressed hierarchical (CSR-like) mapping.
//!
//! Model (paper §II.C / Table 1: memory access Low/Low, MLC ReRAM):
//! * frontier-filtered streaming (compressed representation gives cheap
//!   access to the edges of active vertices);
//! * loading a block writes only its edges, at multi-bit precision
//!   (destination indices + weights in MLC cells);
//! * no in-situ MVM — edges are *read out* sequentially (vertex-location
//!   crossbar, then destination/weight crossbar) and reduced on the ALU,
//!   which is where its execution time goes (decompression, §IV.C).

use crate::accel::SimReport;
use crate::cost::{timing, CostParams, EventCounts};
use crate::graph::Coo;

use super::common::{bfs_schedule, bursts, BaselineModel};

#[derive(Debug, Clone)]
pub struct SparseMem {
    pub crossbar: u32,
    /// MLC bits written per stored edge (index + weight).
    pub bits_per_edge: u64,
    /// MLC program-verify pulses per bit (SLC-equivalent writes).
    pub mlc_write_factor: u64,
}

impl Default for SparseMem {
    fn default() -> Self {
        Self { crossbar: 128, bits_per_edge: 4, mlc_write_factor: 2 }
    }
}

impl SparseMem {
    /// MLC cells holding one destination-vertex index: the paper notes
    /// SparseMEM "requires high-resolution MLC ReRAM to store vertex
    /// indices" (§II.C) — an index needs ⌈log2(V)⌉ bits at 4 bits/cell,
    /// plus one cell for the weight/location entry.
    fn cells_per_entry(num_vertices: u32) -> u64 {
        let bits = 32 - num_vertices.max(2).leading_zeros() as u64;
        bits.div_ceil(4) + 1
    }

    /// Serial decompression cost per stored edge: *dependent* MLC reads
    /// (location crossbar → index cells of the destination crossbar),
    /// each needing an ADC conversion to recover the multi-bit value,
    /// plus buffer touches and the ALU update. The dependency chain is
    /// what makes SparseMEM slow despite its excellent crossbar
    /// utilization (paper §IV.C: "execution time is higher due to
    /// decompression").
    fn per_edge_ns(params: &CostParams, cells: u64) -> f64 {
        cells as f64 * (params.t_read_bit_ns + params.t_sense_ns + params.t_adc_ns)
            + 2.0 * params.t_sram_ns
            + params.t_alu_ns
    }
}

impl BaselineModel for SparseMem {
    fn name(&self) -> &'static str {
        "SparseMEM"
    }

    fn simulate_bfs(
        &self,
        g: &Coo,
        source: u32,
        params: &CostParams,
        engines: u32,
    ) -> SimReport {
        let sched = bfs_schedule(g, self.crossbar, source);
        let cells = Self::cells_per_entry(g.num_vertices);
        let mut counts = EventCounts::default();
        let mut exec_time_ns = 0f64;
        let mut loads_per_engine = 0u64;

        for active in &sched.active {
            if active.is_empty() {
                continue;
            }
            let mut superstep_edges = 0u64;
            let mut max_block_ns = 0f64;
            for &bi in active {
                let nnz = sched.blocks[bi as usize].nnz as u64;
                superstep_edges += nnz;
                // Load compressed block: nnz entries at MLC precision
                // with program-verify pulses.
                let wbits = nnz * self.bits_per_edge * self.mlc_write_factor;
                counts.write_bits += wbits;
                counts.reconfigs += 1;
                // Process: two reads per edge (location + destination),
                // sequential decompression on the ALU; every decoded
                // edge streams through the SRAM buffer (index + value).
                counts.read_bits += nnz * cells;
                counts.sense_ops += nnz * cells;
                counts.adc_ops += nnz * cells;
                counts.alu_ops += nnz;
                counts.sram_accesses += 2 + nnz * 2;
                counts.mvm_ops += 1; // one block op (not an analog MVM)
                // Write latency: compressed entries pack into crossbar
                // rows, programmed row-by-row. Decompression is the real
                // cost: two *dependent* reads per edge (location crossbar
                // then destination crossbar) + buffer + ALU.
                let row_writes = wbits.div_ceil(self.crossbar as u64);
                let block_ns = timing::reconfig_latency_ns(params, row_writes as u32)
                    + nnz as f64 * Self::per_edge_ns(params, cells);
                max_block_ns = max_block_ns.max(block_ns);
            }
            // Compressed streams burst efficiently from main memory.
            counts.main_mem_accesses += bursts(superstep_edges * 32) + 1;
            // Blocks spread over engines; a superstep costs (waves ×
            // average block), bounded below by the largest block.
            let waves = (active.len() as u64).div_ceil(engines as u64);
            let avg_wbits =
                superstep_edges * self.bits_per_edge * self.mlc_write_factor
                    / active.len() as u64;
            let avg_block_ns = superstep_edges as f64 / active.len() as f64
                * Self::per_edge_ns(params, cells)
                + timing::reconfig_latency_ns(
                    params,
                    avg_wbits.div_ceil(self.crossbar as u64) as u32,
                );
            exec_time_ns += (waves as f64 * avg_block_ns).max(max_block_ns);
            loads_per_engine += (active.len() as u64).div_ceil(engines as u64);
        }

        SimReport {
            design: self.name().to_string(),
            algorithm: "bfs".to_string(),
            counts,
            energy: counts.energy(params),
            exec_time_ns,
            supersteps: sched.supersteps,
            iterations: sched.total_ops(),
            static_hit_rate: 0.0,
            // Cells rewritten (with MLC program-verify pulses) on every
            // block load of this engine: both the location and
            // destination arrays are reloaded, and the co-located
            // per-vertex value cells are rewritten again by the
            // reduce/apply phase of the same superstep.
            max_cell_writes: loads_per_engine * self.mlc_write_factor * 2 * 2,
            run: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::graphr::GraphR;
    use crate::graph::datasets::Dataset;

    #[test]
    fn sparsemem_writes_far_less_than_graphr() {
        let g = Dataset::Tiny.load().unwrap();
        let p = CostParams::default();
        let sm = SparseMem::default().simulate_bfs(&g, 0, &p, 32);
        let gr = GraphR::default().simulate_bfs(&g, 0, &p, 32);
        assert!(gr.counts.write_bits > 20 * sm.counts.write_bits);
        assert!(gr.energy_j() > 10.0 * sm.energy_j());
        assert!(gr.exec_time_ns > sm.exec_time_ns);
    }

    #[test]
    fn sparsemem_reads_scale_with_edges() {
        let g = Dataset::Tiny.load().unwrap();
        let r = SparseMem::default().simulate_bfs(&g, 0, &CostParams::default(), 32);
        // Two reads per touched edge.
        assert_eq!(r.counts.read_bits % 2, 0);
        assert!(r.counts.read_bits >= 2 * g.num_edges() as u64 / 4);
    }

    #[test]
    fn lifetime_writes_track_engine_loads() {
        let g = Dataset::Tiny.load().unwrap();
        let few = SparseMem::default().simulate_bfs(&g, 0, &CostParams::default(), 8);
        let many = SparseMem::default().simulate_bfs(&g, 0, &CostParams::default(), 128);
        assert!(few.max_cell_writes >= many.max_cell_writes);
    }
}
