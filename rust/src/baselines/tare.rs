//! TARe [16] baseline: write-free task-adaptive mapping.
//!
//! Model (paper §II.C / Table 1: memory access High/Low, 1-bit ReRAM):
//! * each crossbar is partitioned into computing blocks (CBs)
//!   preconfigured with *all* possible k×k binary submatrices, so runtime
//!   never writes ReRAM;
//! * a C×C subgraph is evaluated as (C/k)² CB lookups whose partial
//!   results merge on the ALU — the restricted MVM parallelism the paper
//!   calls out (more iterations);
//! * the subgraph's structure is not stored on-chip, so every operation
//!   fetches pattern indices + vertex data from off-chip memory
//!   ("frequent off-chip memory reads degrade performance").

use crate::accel::SimReport;
use crate::cost::{timing, CostParams, EventCounts};
use crate::graph::Coo;

use super::common::{bfs_schedule, BaselineModel};

#[derive(Debug, Clone)]
pub struct TaRe {
    /// Subgraph window size (adapted to classical algorithms at the same
    /// granularity as the proposed design, §IV.A).
    pub window: u32,
    /// Computing-block size k (2 ⇒ 16 preconfigured patterns per CB set).
    pub cb_size: u32,
}

impl Default for TaRe {
    fn default() -> Self {
        Self { window: 4, cb_size: 2 }
    }
}

impl BaselineModel for TaRe {
    fn name(&self) -> &'static str {
        "TARe"
    }

    fn simulate_bfs(
        &self,
        g: &Coo,
        source: u32,
        params: &CostParams,
        engines: u32,
    ) -> SimReport {
        let k = self.cb_size as u64;
        let sub_ops = (self.window as u64 / k).pow(2); // CB lookups per subgraph
        let sched = bfs_schedule(g, self.window, source);

        let mut counts = EventCounts::default();
        let mut exec_time_ns = 0f64;
        for active in &sched.active {
            if active.is_empty() {
                continue;
            }
            let ops = active.len() as u64;
            counts.mvm_ops += ops;
            counts.read_bits += ops * sub_ops * k * k;
            counts.sense_ops += ops * sub_ops * k;
            counts.adc_ops += ops * sub_ops * k;
            counts.sram_accesses += ops * 2;
            // Off-chip fetch per subgraph: pattern CB indices + vertex
            // data, random access — NOT amortizable into bursts.
            counts.main_mem_accesses += ops;
            // Merge partial CB results + reduce.
            counts.alu_ops += ops * (sub_ops + self.window as u64);

            // Serialized CB lookups per subgraph; engines in parallel.
            let per_op_ns = sub_ops as f64
                * timing::mvm_latency_ns(params, self.cb_size, self.cb_size)
                + timing::reduce_latency_ns(params, self.window)
                + params.t_main_mem_ns * 0.75; // off-chip fetch, thinly overlapped
            let waves = ops.div_ceil(engines as u64);
            exec_time_ns += waves as f64 * per_op_ns;
        }

        SimReport {
            design: self.name().to_string(),
            algorithm: "bfs".to_string(),
            counts,
            energy: counts.energy(params),
            exec_time_ns,
            supersteps: sched.supersteps,
            iterations: sched.total_ops(),
            static_hit_rate: 1.0, // by construction: never reconfigured
            max_cell_writes: 0,   // write-free
            run: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;

    #[test]
    fn tare_is_write_free() {
        let g = Dataset::Tiny.load().unwrap();
        let r = TaRe::default().simulate_bfs(&g, 0, &CostParams::default(), 32);
        assert_eq!(r.counts.write_bits, 0);
        assert_eq!(r.max_cell_writes, 0);
        assert_eq!(r.energy.reram_write_j, 0.0);
    }

    #[test]
    fn tare_pays_main_memory() {
        let g = Dataset::Tiny.load().unwrap();
        let r = TaRe::default().simulate_bfs(&g, 0, &CostParams::default(), 32);
        // Off-chip energy dominates its budget.
        assert!(r.energy.main_mem_j > 0.4 * r.energy_j());
        assert_eq!(r.counts.main_mem_accesses, r.counts.mvm_ops);
    }

    #[test]
    fn smaller_cb_more_lookups() {
        let g = Dataset::Tiny.load().unwrap();
        let p = CostParams::default();
        let k2 = TaRe::default().simulate_bfs(&g, 0, &p, 32);
        let k4 = TaRe { window: 4, cb_size: 4 }.simulate_bfs(&g, 0, &p, 32);
        // k=2: 4 lookups per subgraph; k=4: 1 lookup.
        assert!(k2.counts.alu_ops > k4.counts.alu_ops);
        assert!(k2.exec_time_ns > k4.exec_time_ns);
    }
}
