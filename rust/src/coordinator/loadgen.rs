//! Scripted open/closed-loop load generation against a [`Service`] —
//! the reproducible traffic-study harness the serving tier is measured
//! with (`repro loadgen` and `benches/serve.rs` both drive it).
//!
//! Two classic load models:
//!
//! - **Open loop**: jobs arrive on a fixed schedule (`rate_per_s`)
//!   regardless of completions — the honest way to measure queueing
//!   behavior under overload (closed loops self-throttle and hide it).
//! - **Closed loop**: a fixed number of virtual clients each submit
//!   their next job when the previous one resolves — the throughput
//!   ceiling view.
//!
//! The generated trace is deterministic (seeded [`SplitMix64`] over a
//! configured algorithm mix and source-vertex spread), so runs are
//! comparable across machines and commits; results land as
//! `BENCH_serve.json` rows next to the hotpath trajectory.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::graph::datasets::Dataset;
use crate::session::JobSpec;
use crate::util::SplitMix64;

use super::metrics::LatencySummary;
use super::Service;

/// Arrival model for a load run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadMode {
    /// Fixed arrival rate, independent of completions.
    Open { rate_per_s: f64 },
    /// Fixed in-flight concurrency: each virtual client submits its
    /// next job when the previous one resolves.
    Closed { concurrency: usize },
}

/// One load scenario. `Default` is a small closed-loop mixed burst.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Scenario label (lands in the JSON trajectory).
    pub name: String,
    pub dataset: Dataset,
    pub scale: f64,
    /// Total jobs in the trace.
    pub jobs: usize,
    pub mode: LoadMode,
    /// Optional per-job latency budget — expired jobs are load-shed by
    /// the service, which is exactly what an overload study wants to
    /// count.
    pub deadline: Option<Duration>,
    /// Algorithm mix, cycled per job (empty falls back to the builtin
    /// bfs/pagerank/wcc/sssp rotation).
    pub algorithms: Vec<String>,
    /// Iteration count stamped on every job (pagerank honors it; for
    /// the rest it only widens the coalesce-key space).
    pub iterations: usize,
    /// Distinct source vertices the trace cycles through: `1` makes
    /// every job of an algorithm identical (maximum coalescing
    /// pressure), large values spread the key space.
    pub sources: u32,
    /// Trace seed — same seed, same job sequence.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            name: "loadgen".to_string(),
            dataset: Dataset::Tiny,
            scale: 1.0,
            jobs: 32,
            mode: LoadMode::Closed { concurrency: 4 },
            deadline: None,
            algorithms: Vec::new(),
            iterations: 5,
            sources: 8,
            seed: 42,
        }
    }
}

const DEFAULT_MIX: [&str; 4] = ["bfs", "pagerank", "wcc", "sssp"];

/// The deterministic job trace a config expands to.
pub fn traffic(cfg: &LoadgenConfig) -> Vec<JobSpec> {
    let mix: Vec<&str> = if cfg.algorithms.is_empty() {
        DEFAULT_MIX.to_vec()
    } else {
        cfg.algorithms.iter().map(String::as_str).collect()
    };
    let mut rng = SplitMix64::new(cfg.seed);
    (0..cfg.jobs)
        .map(|i| {
            let source = (rng.next_u64() % u64::from(cfg.sources.max(1))) as u32;
            let mut spec = JobSpec::new(cfg.dataset, mix[i % mix.len()])
                .with_scale(cfg.scale)
                .with_source(source)
                .with_iterations(cfg.iterations);
            if let Some(d) = cfg.deadline {
                spec = spec.with_deadline(d);
            }
            spec
        })
        .collect()
}

/// Outcome of one load run, read from the service's cumulative metrics
/// — run scenarios against a **fresh** [`Service`] so counters belong
/// to this trace alone.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub name: String,
    /// Human form of the arrival model, e.g. `open@500/s`.
    pub mode: String,
    pub jobs: usize,
    pub elapsed_s: f64,
    /// Completions per second of wall time.
    pub throughput_jobs_per_s: f64,
    pub completed: u64,
    pub failed: u64,
    pub shed: u64,
    pub coalesced: u64,
    /// Hardware work actually performed (counted once per execution —
    /// the gap against `completed` is the coalescing win).
    pub subgraph_ops: u64,
    /// Jobs that ran inside a multi-job batch (size ≥ 2) — nonzero only
    /// when the service was spawned with `max_batch > 1` and the trace
    /// queued batch-compatible work.
    pub batched: u64,
    pub queue_wait: LatencySummary,
    pub execution: LatencySummary,
}

impl LoadgenReport {
    /// Multi-line human summary for the CLI.
    pub fn render(&self) -> String {
        format!(
            "{} [{}]: {} jobs in {:.3}s -> {:.1} jobs/s\n\
             \x20 completed {} / failed {} / shed {} / coalesced {} / batched {} (ops {})\n\
             \x20 queue-wait {}\n\
             \x20 execution  {}",
            self.name,
            self.mode,
            self.jobs,
            self.elapsed_s,
            self.throughput_jobs_per_s,
            self.completed,
            self.failed,
            self.shed,
            self.coalesced,
            self.batched,
            self.subgraph_ops,
            self.queue_wait.render(),
            self.execution.render(),
        )
    }
}

/// Drive one scenario against `svc` and fold the resulting metrics into
/// a [`LoadgenReport`].
pub fn run(svc: &Service, cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    let specs = traffic(cfg);
    let started = Instant::now();
    match cfg.mode {
        LoadMode::Closed { concurrency } => {
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..concurrency.max(1) {
                    scope.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(spec) = specs.get(i) else { break };
                        // Failures/sheds are the study's data, not this
                        // driver's problem — the metrics count them.
                        let _ = svc.submit_blocking(spec.clone());
                    });
                }
            });
        }
        LoadMode::Open { rate_per_s } => {
            let rate = rate_per_s.max(1e-9);
            let mut pending = Vec::with_capacity(specs.len());
            for (i, spec) in specs.iter().enumerate() {
                let due = started + Duration::from_secs_f64(i as f64 / rate);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if let Ok(p) = svc.submit(spec.clone()) {
                    pending.push(p);
                }
            }
            for p in pending {
                let _ = p.wait();
            }
        }
    }
    let elapsed_s = started.elapsed().as_secs_f64().max(1e-9);
    let snap = svc.snapshot();
    Ok(LoadgenReport {
        name: cfg.name.clone(),
        mode: match cfg.mode {
            LoadMode::Open { rate_per_s } => format!("open@{rate_per_s:.0}/s"),
            LoadMode::Closed { concurrency } => format!("closed@c={concurrency}"),
        },
        jobs: cfg.jobs,
        elapsed_s,
        throughput_jobs_per_s: snap.jobs_completed as f64 / elapsed_s,
        completed: snap.jobs_completed,
        failed: snap.jobs_failed,
        shed: snap.jobs_shed,
        coalesced: snap.jobs_coalesced,
        subgraph_ops: snap.subgraph_ops,
        batched: snap.jobs_batched,
        queue_wait: snap.queue_wait,
        execution: snap.execution,
    })
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialize reports as a JSON array (hand-rolled — the offline image
/// vendors no serde), one row per scenario, mirroring the
/// `BENCH_hotpath.json` trajectory format.
pub fn reports_to_json(reports: &[LoadgenReport]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in reports.iter().enumerate() {
        if i > 0 {
            s.push_str(",\n");
        }
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"mode\": \"{}\", \"jobs\": {}, \"elapsed_s\": {:.6}, \
             \"throughput_jobs_per_s\": {:.2}, \"completed\": {}, \"failed\": {}, \
             \"shed\": {}, \"coalesced\": {}, \"batched\": {}, \"subgraph_ops\": {}, \
             \"queue_wait_p50_us\": {}, \"queue_wait_p99_us\": {}, \
             \"queue_wait_p999_us\": {}, \"queue_wait_max_us\": {}, \
             \"exec_p50_us\": {}, \"exec_p99_us\": {}, \"exec_p999_us\": {}, \
             \"exec_max_us\": {}}}",
            esc(&r.name),
            esc(&r.mode),
            r.jobs,
            r.elapsed_s,
            r.throughput_jobs_per_s,
            r.completed,
            r.failed,
            r.shed,
            r.coalesced,
            r.batched,
            r.subgraph_ops,
            r.queue_wait.p50_us,
            r.queue_wait.p99_us,
            r.queue_wait.p999_us,
            r.queue_wait.max_us,
            r.execution.p50_us,
            r.execution.p99_us,
            r.execution.p999_us,
            r.execution.max_us,
        ));
    }
    s.push_str("\n]\n");
    s
}

/// Write [`reports_to_json`] to `path`.
pub fn write_json(path: impl AsRef<Path>, reports: &[LoadgenReport]) -> std::io::Result<()> {
    std::fs::write(path, reports_to_json(reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Service, ServiceConfig};

    #[test]
    fn traffic_is_deterministic_and_mixed() {
        let cfg = LoadgenConfig { jobs: 12, ..LoadgenConfig::default() };
        let a = traffic(&cfg);
        let b = traffic(&cfg);
        assert_eq!(a, b, "same seed, same trace");
        assert_eq!(a.len(), 12);
        assert_eq!(a[0].algorithm.as_str(), "bfs");
        assert_eq!(a[1].algorithm.as_str(), "pagerank");
        let other = traffic(&LoadgenConfig { jobs: 12, seed: 7, ..LoadgenConfig::default() });
        assert_ne!(a, other, "different seed, different sources");
    }

    #[test]
    fn closed_loop_conserves_jobs() {
        let svc =
            Service::spawn(ServiceConfig { workers: 2, ..ServiceConfig::default() }).unwrap();
        let cfg = LoadgenConfig {
            jobs: 8,
            mode: LoadMode::Closed { concurrency: 2 },
            sources: 2,
            ..LoadgenConfig::default()
        };
        let r = run(&svc, &cfg).unwrap();
        assert_eq!(r.completed + r.failed + r.shed, 8);
        assert_eq!(r.failed, 0);
        assert!(r.throughput_jobs_per_s > 0.0);
        assert_eq!(r.execution.count, r.completed);
    }

    #[test]
    fn batched_service_conserves_jobs() {
        // One worker + deep closed loop so batch-compatible work queues
        // up; conservation must hold whether or not batches formed.
        let svc = Service::spawn(ServiceConfig {
            workers: 1,
            max_batch: 4,
            ..ServiceConfig::default()
        })
        .unwrap();
        let cfg = LoadgenConfig {
            jobs: 16,
            mode: LoadMode::Closed { concurrency: 8 },
            algorithms: vec!["bfs".to_string()],
            sources: 16,
            ..LoadgenConfig::default()
        };
        let r = run(&svc, &cfg).unwrap();
        assert_eq!(r.completed + r.failed + r.shed, 16);
        assert_eq!(r.failed, 0);
        assert!(r.batched <= r.completed, "batched jobs are completed jobs");
    }

    #[test]
    fn open_loop_submits_the_whole_trace() {
        let svc =
            Service::spawn(ServiceConfig { workers: 2, ..ServiceConfig::default() }).unwrap();
        let cfg = LoadgenConfig {
            jobs: 6,
            // Effectively "as fast as possible" — the paced sleep is ~0.
            mode: LoadMode::Open { rate_per_s: 1e6 },
            ..LoadgenConfig::default()
        };
        let r = run(&svc, &cfg).unwrap();
        assert_eq!(r.completed + r.failed + r.shed, 6);
        assert!(r.elapsed_s > 0.0);
    }

    #[test]
    fn json_rows_carry_percentiles_and_escape_names() {
        let report = LoadgenReport {
            name: "a \"quoted\" scenario".to_string(),
            mode: "closed@c=2".to_string(),
            jobs: 4,
            elapsed_s: 0.5,
            throughput_jobs_per_s: 8.0,
            completed: 4,
            failed: 0,
            shed: 0,
            coalesced: 1,
            subgraph_ops: 99,
            batched: 3,
            queue_wait: LatencySummary::default(),
            execution: LatencySummary::default(),
        };
        let json = reports_to_json(&[report]);
        assert!(json.contains("a \\\"quoted\\\" scenario"));
        assert!(json.contains("\"queue_wait_p999_us\""));
        assert!(json.contains("\"exec_p50_us\""));
        assert!(json.contains("\"coalesced\": 1"));
        assert!(json.contains("\"batched\": 3"));
        assert!(json.trim_start().starts_with('['));
        assert!(json.trim_end().ends_with(']'));
    }
}
