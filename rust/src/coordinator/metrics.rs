//! Lightweight service metrics: counters + latency summary, lock-free on
//! the hot path (atomics), snapshot on demand.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    /// Total wall-clock job latency, microseconds.
    total_latency_us: AtomicU64,
    /// Max single-job latency, microseconds.
    max_latency_us: AtomicU64,
    /// Total subgraph ops processed across jobs.
    pub subgraph_ops: AtomicU64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub mean_latency_us: f64,
    pub max_latency_us: u64,
    pub subgraph_ops: u64,
}

impl Metrics {
    pub fn record_completion(&self, latency_us: u64, ops: u64) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(latency_us, Ordering::Relaxed);
        self.max_latency_us.fetch_max(latency_us, Ordering::Relaxed);
        self.subgraph_ops.fetch_add(ops, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.jobs_completed.load(Ordering::Relaxed);
        let total = self.total_latency_us.load(Ordering::Relaxed);
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: completed,
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            mean_latency_us: if completed > 0 { total as f64 / completed as f64 } else { 0.0 },
            max_latency_us: self.max_latency_us.load(Ordering::Relaxed),
            subgraph_ops: self.subgraph_ops.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        m.record_completion(100, 10);
        m.record_completion(300, 20);
        let s = m.snapshot();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_completed, 2);
        assert_eq!(s.mean_latency_us, 200.0);
        assert_eq!(s.max_latency_us, 300);
        assert_eq!(s.subgraph_ops, 30);
    }

    #[test]
    fn empty_snapshot_no_nan() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_latency_us, 0.0);
    }
}
