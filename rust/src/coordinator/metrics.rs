//! Lightweight service metrics: global counters stay lock-free on the
//! hot path (atomics); per-algorithm counters, the in-flight gauge, and
//! the latency histograms live behind a short-critical-section mutex,
//! keyed by the algorithm id from the job's `JobSpec`.
//!
//! Latency is recorded **split**: queue-wait (submit → dequeue) and
//! execution (dequeue → completion) feed separate fixed-bucket
//! log-scale [`Histogram`]s, so tail percentiles can't hide scheduling
//! delay inside compute time (or vice versa). Conservation invariant,
//! enforced by `rust/tests/serve.rs`:
//! `jobs_submitted == jobs_completed + jobs_failed + jobs_shed`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::accel::PreprocessTiming;
use crate::session::DeltaReport;

/// Min/mean/max accumulator for one preprocess phase (nanoseconds).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PhaseStat {
    pub count: u64,
    pub total_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
}

impl PhaseStat {
    pub fn record(&mut self, ns: u64) {
        self.min_ns = if self.count == 0 { ns } else { self.min_ns.min(ns) };
        self.max_ns = self.max_ns.max(ns);
        self.count += 1;
        self.total_ns += ns;
    }

    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.total_ns / self.count
        }
    }
}

/// Cold-preprocess wall time split into partition / rank / tables / plan
/// phases, min/mean/max per compile. The session's `ArtifactStore`
/// records one entry per cold compile (the single source of truth);
/// [`Service::metrics`](crate::coordinator::Service::metrics) copies it
/// into the snapshot and `repro artifacts warm|ls` prints it, so
/// warm-vs-cold regressions are visible in serve fleets.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PreprocessPhases {
    /// Cold compiles recorded.
    pub compiles: u64,
    pub partition: PhaseStat,
    pub rank: PhaseStat,
    pub tables: PhaseStat,
    pub plan: PhaseStat,
    pub total: PhaseStat,
}

impl PreprocessPhases {
    pub fn record(&mut self, t: &PreprocessTiming) {
        self.compiles += 1;
        self.partition.record(t.partition_ns);
        self.rank.record(t.rank_ns);
        self.tables.record(t.tables_ns);
        self.plan.record(t.plan_ns);
        self.total.record(t.total_ns());
    }

    /// One-line human summary for the CLI: per-phase mean with the
    /// total's min/mean/max, microseconds.
    pub fn summary(&self) -> String {
        format!(
            "{} compiles: partition {}us / rank {}us / tables {}us / plan {}us \
             (total min {}us mean {}us max {}us)",
            self.compiles,
            self.partition.mean_ns() / 1_000,
            self.rank.mean_ns() / 1_000,
            self.tables.mean_ns() / 1_000,
            self.plan.mean_ns() / 1_000,
            self.total.min_ns / 1_000,
            self.total.mean_ns() / 1_000,
            self.total.max_ns / 1_000,
        )
    }
}

/// Power-of-two bucket count: bucket `i` covers `[2^i, 2^(i+1))` µs
/// (bucket 0 covers `[0, 2)`), so 40 buckets span sub-microsecond to
/// ~12.7 days — any realistic serve latency without per-request
/// allocation.
const HISTOGRAM_BUCKETS: usize = 40;

/// Fixed-bucket log-scale latency histogram (microseconds).
///
/// Recording is O(1) into a flat array — no allocation, no resize — and
/// percentile queries interpolate linearly inside the hit bucket, giving
/// ~1-bucket relative error at any quantile. By construction
/// `percentile(q)` is monotone in `q` and clamped to the observed max,
/// so `p50 ≤ p99 ≤ p999 ≤ max` always holds.
#[derive(Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; HISTOGRAM_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self { counts: [0; HISTOGRAM_BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count)
            .field("mean_us", &self.mean_us())
            .field("max_us", &self.max_us)
            .finish()
    }
}

impl Histogram {
    fn bucket_of(us: u64) -> usize {
        // floor(log2(us)) with 0 mapped into bucket 0; giants clamp into
        // the last bucket (the percentile cap below keeps them honest).
        ((63 - (us | 1).leading_zeros()) as usize).min(HISTOGRAM_BUCKETS - 1)
    }

    pub fn record(&mut self, us: u64) {
        self.counts[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Fold another histogram in (used to derive the global summary from
    /// the per-algorithm histograms at snapshot time).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Estimated latency at quantile `q` in `[0, 1]`, microseconds.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                let lo = if i == 0 { 0u64 } else { 1u64 << i };
                let hi = 1u64 << (i + 1);
                let frac = (rank - seen) as f64 / c as f64;
                let est = lo as f64 + frac * (hi - lo) as f64;
                return (est as u64).min(self.max_us);
            }
            seen += c;
        }
        self.max_us
    }

    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count,
            mean_us: self.mean_us(),
            max_us: self.max_us,
            p50_us: self.percentile(0.50),
            p99_us: self.percentile(0.99),
            p999_us: self.percentile(0.999),
        }
    }
}

/// Snapshot view of one latency [`Histogram`].
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_us: f64,
    pub max_us: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
}

impl LatencySummary {
    /// One-line human summary for the CLI (microseconds).
    pub fn render(&self) -> String {
        format!(
            "n={} mean {:.0}us p50 {}us p99 {}us p999 {}us max {}us",
            self.count, self.mean_us, self.p50_us, self.p99_us, self.p999_us, self.max_us
        )
    }
}

/// Per-algorithm counters, gauge, and split latency histograms.
#[derive(Debug, Default, Clone, PartialEq)]
struct AlgoEntry {
    completed: u64,
    failed: u64,
    shed: u64,
    coalesced: u64,
    queue_depth: u64,
    queue_wait: Histogram,
    execution: Histogram,
}

/// Per-algorithm snapshot: counters plus the queue-depth gauge and split
/// queue-wait / execution latency summaries.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct AlgoStats {
    pub completed: u64,
    pub failed: u64,
    /// Jobs shed unexecuted because their deadline expired in the queue.
    pub shed: u64,
    /// Follower jobs that shared another job's execution (the leader is
    /// not counted — N identical jobs record N-1 here).
    pub coalesced: u64,
    /// Jobs submitted but not yet finished (queued or running; a
    /// backpressured `submit` counts too — it is in flight for callers).
    pub queue_depth: u64,
    /// Submit → dequeue latency (recorded for completions and sheds).
    pub queue_wait: LatencySummary,
    /// Dequeue → completion latency.
    pub execution: LatencySummary,
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    /// Jobs load-shed unexecuted (deadline already expired at dequeue).
    pub jobs_shed: AtomicU64,
    /// Follower jobs coalesced onto another queued job's execution.
    pub jobs_coalesced: AtomicU64,
    /// Total wall-clock job latency (queue-wait + execution), µs.
    total_latency_us: AtomicU64,
    /// Max single-job latency (queue-wait + execution), µs.
    max_latency_us: AtomicU64,
    /// Total subgraph ops processed across jobs. Counted once per
    /// *execution* — coalesced followers add completions but no ops;
    /// the gap between the two is the coalescing win made visible.
    pub subgraph_ops: AtomicU64,
    /// Jobs that executed as part of a multi-job batch (batch size ≥ 2;
    /// solo runs add nothing). Each batched job still records its own
    /// completion and its own ops, so conservation is untouched — this
    /// counter only makes the batching win visible:
    /// `jobs_batched / jobs_completed` is the batched fraction.
    pub jobs_batched: AtomicU64,
    /// Streaming-mutation counters (fed by the service's `apply_delta`
    /// entry point): delta batches accepted.
    pub delta_batches: AtomicU64,
    /// Dirty adjacency windows across all accepted batches.
    pub delta_dirty_partitions: AtomicU64,
    /// Plan ops re-emitted by incremental patching.
    pub delta_patched_ops: AtomicU64,
    /// Cached artifacts patched in place — each one a whole-plan
    /// recompile the delta path avoided.
    pub delta_avoided_recompiles: AtomicU64,
    /// Distribution of formed batch sizes (the [`Histogram`] buckets
    /// hold job counts, not microseconds — same log-bucket layout).
    /// One sample per formed batch, recorded alongside `jobs_batched`.
    batch_sizes: Mutex<Histogram>,
    per_algo: Mutex<BTreeMap<String, AlgoEntry>>,
    /// Completed executions keyed by resolved shard count — the serve
    /// view of the scale-out knob. Purely a placement/throughput
    /// statistic: results are bit-identical for every shard count, so
    /// this never keys anything, it only makes the deployment shape
    /// visible. (Per-shard *compile* cost is visible separately: a
    /// sharded cold compile records one [`PreprocessPhases`] entry per
    /// shard through the session store.)
    runs_by_shards: Mutex<BTreeMap<u32, u64>>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub jobs_shed: u64,
    pub jobs_coalesced: u64,
    pub mean_latency_us: f64,
    pub max_latency_us: u64,
    /// Global submit → dequeue latency, merged across algorithms.
    pub queue_wait: LatencySummary,
    /// Global dequeue → completion latency, merged across algorithms.
    pub execution: LatencySummary,
    pub subgraph_ops: u64,
    /// Jobs that ran as part of a multi-job batch (size ≥ 2).
    pub jobs_batched: u64,
    /// Distribution of formed batch sizes — `count` is the number of
    /// batches formed, and the `*_us` fields hold *job counts* (the
    /// summary reuses the log-bucket latency histogram shape).
    pub batch_size: LatencySummary,
    pub delta_batches: u64,
    pub delta_dirty_partitions: u64,
    pub delta_patched_ops: u64,
    pub delta_avoided_recompiles: u64,
    /// Cold-preprocess phase timing, copied from the session's
    /// `ArtifactStore` by [`Service::metrics`](crate::coordinator::Service::metrics)
    /// (zeroed in a bare [`Metrics::snapshot`] — the store is the single
    /// source of truth for compile timing).
    pub preprocess: PreprocessPhases,
    /// Keyed by algorithm id, sorted.
    pub per_algorithm: BTreeMap<String, AlgoStats>,
    /// Completed executions keyed by the shard count they resolved to
    /// (session default unless the job overrode it). Results are
    /// bit-identical across shard counts, so this is pure deployment
    /// visibility; compile-side cost shows up as one `preprocess`
    /// entry per shard artifact instead.
    pub runs_by_shards: BTreeMap<u32, u64>,
}

impl Metrics {
    /// Poison-safe per-algo table access: every mutation under this lock
    /// is a couple of counter bumps with no intermediate invalid state,
    /// so if a panicking holder ever poisons it we clear the flag and
    /// keep serving instead of cascading the panic through every worker.
    fn algos(&self) -> MutexGuard<'_, BTreeMap<String, AlgoEntry>> {
        self.per_algo.lock().unwrap_or_else(|poisoned| {
            self.per_algo.clear_poison();
            poisoned.into_inner()
        })
    }

    pub fn record_submitted(&self, algo: &str) {
        self.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.algos().entry(algo.to_string()).or_default().queue_depth += 1;
    }

    /// A submitted job joined an already-queued identical job instead of
    /// taking its own queue slot (it still resolves through
    /// `record_completion`/`record_failure`/`record_shed` like any
    /// other, so the conservation invariant is untouched).
    pub fn record_coalesced(&self, algo: &str) {
        self.jobs_coalesced.fetch_add(1, Ordering::Relaxed);
        self.algos().entry(algo.to_string()).or_default().coalesced += 1;
    }

    pub fn record_completion(&self, algo: &str, queue_wait_us: u64, exec_us: u64, ops: u64) {
        let latency_us = queue_wait_us + exec_us;
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(latency_us, Ordering::Relaxed);
        self.max_latency_us.fetch_max(latency_us, Ordering::Relaxed);
        self.subgraph_ops.fetch_add(ops, Ordering::Relaxed);
        let mut m = self.algos();
        let e = m.entry(algo.to_string()).or_default();
        e.completed += 1;
        e.queue_depth = e.queue_depth.saturating_sub(1);
        e.queue_wait.record(queue_wait_us);
        e.execution.record(exec_us);
    }

    pub fn record_failure(&self, algo: &str) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        let mut m = self.algos();
        let e = m.entry(algo.to_string()).or_default();
        e.failed += 1;
        e.queue_depth = e.queue_depth.saturating_sub(1);
    }

    /// A job was load-shed at dequeue: its deadline expired while queued,
    /// so it never executed. The time it wasted waiting still feeds the
    /// queue-wait histogram — shed jobs are exactly the ones whose wait
    /// you need to see.
    pub fn record_shed(&self, algo: &str, queue_wait_us: u64) {
        self.jobs_shed.fetch_add(1, Ordering::Relaxed);
        let mut m = self.algos();
        let e = m.entry(algo.to_string()).or_default();
        e.shed += 1;
        e.queue_depth = e.queue_depth.saturating_sub(1);
        e.queue_wait.record(queue_wait_us);
    }

    /// A job finished executing with the given resolved shard count.
    /// Recorded alongside `record_completion` by the serve loop; kept
    /// separate because coalesced followers share one execution (and
    /// therefore one shard-count sample) while each resolves its own
    /// completion.
    pub fn record_sharded_run(&self, shards: u32) {
        let mut m = self.runs_by_shards.lock().unwrap_or_else(|poisoned| {
            self.runs_by_shards.clear_poison();
            poisoned.into_inner()
        });
        *m.entry(shards.max(1)).or_default() += 1;
    }

    /// A worker formed and successfully executed a multi-job batch of
    /// `size` jobs in one pipeline pass. Only real batches count — the
    /// serve loop never records `size < 2` (a batch of one is a solo
    /// run). Each member job still records its own completion/ops.
    pub fn record_batch(&self, size: usize) {
        debug_assert!(size >= 2, "a batch of {size} is not a batch");
        self.jobs_batched.fetch_add(size as u64, Ordering::Relaxed);
        let mut h = self.batch_sizes.lock().unwrap_or_else(|poisoned| {
            self.batch_sizes.clear_poison();
            poisoned.into_inner()
        });
        h.record(size as u64);
    }

    /// Fold one accepted delta batch's [`DeltaReport`] into the
    /// streaming-mutation counters.
    pub fn record_delta(&self, report: &DeltaReport) {
        self.delta_batches.fetch_add(1, Ordering::Relaxed);
        self.delta_dirty_partitions
            .fetch_add(u64::from(report.stats.dirty_partitions), Ordering::Relaxed);
        self.delta_patched_ops
            .fetch_add(u64::from(report.stats.patched_ops), Ordering::Relaxed);
        self.delta_avoided_recompiles
            .fetch_add(u64::from(report.patched_artifacts), Ordering::Relaxed);
    }

    /// Current in-flight gauge for one algorithm.
    pub fn queue_depth(&self, algo: &str) -> u64 {
        self.algos().get(algo).map_or(0, |e| e.queue_depth)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.jobs_completed.load(Ordering::Relaxed);
        let total = self.total_latency_us.load(Ordering::Relaxed);
        let algos = self.algos();
        let mut queue_wait = Histogram::default();
        let mut execution = Histogram::default();
        let mut per_algorithm = BTreeMap::new();
        for (name, e) in algos.iter() {
            queue_wait.merge(&e.queue_wait);
            execution.merge(&e.execution);
            per_algorithm.insert(
                name.clone(),
                AlgoStats {
                    completed: e.completed,
                    failed: e.failed,
                    shed: e.shed,
                    coalesced: e.coalesced,
                    queue_depth: e.queue_depth,
                    queue_wait: e.queue_wait.summary(),
                    execution: e.execution.summary(),
                },
            );
        }
        drop(algos);
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: completed,
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            jobs_shed: self.jobs_shed.load(Ordering::Relaxed),
            jobs_coalesced: self.jobs_coalesced.load(Ordering::Relaxed),
            mean_latency_us: if completed > 0 { total as f64 / completed as f64 } else { 0.0 },
            max_latency_us: self.max_latency_us.load(Ordering::Relaxed),
            queue_wait: queue_wait.summary(),
            execution: execution.summary(),
            subgraph_ops: self.subgraph_ops.load(Ordering::Relaxed),
            jobs_batched: self.jobs_batched.load(Ordering::Relaxed),
            batch_size: self
                .batch_sizes
                .lock()
                .unwrap_or_else(|poisoned| {
                    self.batch_sizes.clear_poison();
                    poisoned.into_inner()
                })
                .summary(),
            delta_batches: self.delta_batches.load(Ordering::Relaxed),
            delta_dirty_partitions: self.delta_dirty_partitions.load(Ordering::Relaxed),
            delta_patched_ops: self.delta_patched_ops.load(Ordering::Relaxed),
            delta_avoided_recompiles: self.delta_avoided_recompiles.load(Ordering::Relaxed),
            preprocess: PreprocessPhases::default(),
            per_algorithm,
            runs_by_shards: self
                .runs_by_shards
                .lock()
                .unwrap_or_else(|poisoned| {
                    self.runs_by_shards.clear_poison();
                    poisoned.into_inner()
                })
                .clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_submitted("bfs");
        m.record_submitted("bfs");
        m.record_submitted("wcc");
        m.record_completion("bfs", 40, 60, 10);
        m.record_completion("wcc", 100, 200, 20);
        let s = m.snapshot();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_completed, 2);
        assert_eq!(s.mean_latency_us, 200.0);
        assert_eq!(s.max_latency_us, 300);
        assert_eq!(s.subgraph_ops, 30);
        assert_eq!(s.queue_wait.count, 2);
        assert_eq!(s.execution.count, 2);
        assert_eq!(s.execution.max_us, 200);
    }

    #[test]
    fn per_algorithm_counters_and_gauge() {
        let m = Metrics::default();
        m.record_submitted("bfs");
        m.record_submitted("bfs");
        m.record_submitted("sssp");
        assert_eq!(m.queue_depth("bfs"), 2);
        assert_eq!(m.queue_depth("sssp"), 1);
        m.record_completion("bfs", 20, 30, 5);
        m.record_failure("sssp");
        let s = m.snapshot();
        let bfs = &s.per_algorithm["bfs"];
        assert_eq!((bfs.completed, bfs.failed, bfs.queue_depth), (1, 0, 1));
        assert_eq!(bfs.queue_wait.count, 1);
        assert_eq!(bfs.execution.max_us, 30);
        let sssp = &s.per_algorithm["sssp"];
        assert_eq!((sssp.completed, sssp.failed, sssp.queue_depth), (0, 1, 0));
        // Failures record no latency — there is no completion to time.
        assert_eq!(sssp.execution.count, 0);
        assert_eq!(m.queue_depth("pagerank"), 0);
    }

    #[test]
    fn shed_and_coalesced_feed_conservation() {
        let m = Metrics::default();
        for _ in 0..4 {
            m.record_submitted("bfs");
        }
        m.record_coalesced("bfs"); // rider: extra to submit, resolves below
        m.record_completion("bfs", 10, 20, 5); // leader
        m.record_completion("bfs", 10, 20, 0); // follower: no ops
        m.record_shed("bfs", 500);
        m.record_failure("bfs");
        let s = m.snapshot();
        assert_eq!(
            s.jobs_submitted,
            s.jobs_completed + s.jobs_failed + s.jobs_shed
        );
        assert_eq!(s.jobs_coalesced, 1);
        assert_eq!(s.jobs_shed, 1);
        assert_eq!(s.subgraph_ops, 5, "ops counted once per execution");
        let bfs = &s.per_algorithm["bfs"];
        assert_eq!((bfs.shed, bfs.coalesced, bfs.queue_depth), (1, 1, 0));
        // Shed jobs feed the queue-wait histogram (their wait is the
        // signal) but not the execution one (they never ran).
        assert_eq!(bfs.queue_wait.count, 3);
        assert_eq!(bfs.execution.count, 2);
        assert_eq!(bfs.queue_wait.max_us, 500);
    }

    #[test]
    fn batch_counters_track_batched_jobs_and_sizes() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().jobs_batched, 0);
        assert_eq!(m.snapshot().batch_size, LatencySummary::default());
        m.record_batch(2);
        m.record_batch(4);
        // Each batched job still records its own completion + ops, so
        // conservation and per-execution ops accounting are unchanged.
        for _ in 0..6 {
            m.record_submitted("bfs");
            m.record_completion("bfs", 10, 20, 7);
        }
        let s = m.snapshot();
        assert_eq!(s.jobs_batched, 6);
        assert_eq!(s.batch_size.count, 2, "one sample per formed batch");
        assert_eq!(s.batch_size.max_us, 4, "field holds a job count here");
        assert_eq!(s.jobs_submitted, s.jobs_completed + s.jobs_failed + s.jobs_shed);
        assert_eq!(s.subgraph_ops, 6 * 7, "ops once per batched execution");
    }

    #[test]
    fn delta_counters_accumulate_reports() {
        use crate::sched::PatchStats;
        let m = Metrics::default();
        m.record_delta(&DeltaReport {
            deltas: 2,
            patched_artifacts: 2,
            skipped_keys: 0,
            stats: PatchStats { dirty_partitions: 3, patched_ops: 5, ..PatchStats::default() },
        });
        m.record_delta(&DeltaReport {
            deltas: 1,
            patched_artifacts: 0,
            skipped_keys: 2,
            stats: PatchStats { dirty_partitions: 1, patched_ops: 1, ..PatchStats::default() },
        });
        let s = m.snapshot();
        assert_eq!(s.delta_batches, 2);
        assert_eq!(s.delta_dirty_partitions, 4);
        assert_eq!(s.delta_patched_ops, 6);
        assert_eq!(s.delta_avoided_recompiles, 2);
    }

    #[test]
    fn gauge_never_underflows() {
        let m = Metrics::default();
        m.record_completion("bfs", 5, 5, 1); // completion without a submit
        assert_eq!(m.queue_depth("bfs"), 0);
        m.record_shed("bfs", 5); // shed without a submit
        assert_eq!(m.queue_depth("bfs"), 0);
    }

    #[test]
    fn empty_snapshot_no_nan() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_latency_us, 0.0);
        assert!(s.per_algorithm.is_empty());
        assert_eq!(s.preprocess, PreprocessPhases::default());
        assert_eq!(s.queue_wait, LatencySummary::default());
        assert_eq!(s.execution.mean_us, 0.0);
        assert!(s.runs_by_shards.is_empty());
    }

    #[test]
    fn runs_by_shards_bucket_resolved_counts() {
        let m = Metrics::default();
        m.record_sharded_run(1);
        m.record_sharded_run(4);
        m.record_sharded_run(4);
        m.record_sharded_run(0); // defensive clamp: 0 resolves to 1
        let s = m.snapshot();
        assert_eq!(s.runs_by_shards[&1], 2);
        assert_eq!(s.runs_by_shards[&4], 2);
        assert_eq!(s.runs_by_shards.len(), 2);
    }

    #[test]
    fn histogram_percentiles_monotone_and_capped() {
        let mut h = Histogram::default();
        for us in [0u64, 1, 3, 7, 12, 100, 101, 5_000, 80_000, 1_234_567] {
            h.record(us);
        }
        let p50 = h.percentile(0.50);
        let p99 = h.percentile(0.99);
        let p999 = h.percentile(0.999);
        assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
        assert!(p99 <= p999, "p99 {p99} > p999 {p999}");
        assert!(p999 <= h.max_us(), "p999 {p999} > max {}", h.max_us());
        assert_eq!(h.max_us(), 1_234_567);
        assert_eq!(h.count(), 10);
        // Exhaustive monotonicity sweep across the quantile range.
        let mut prev = 0;
        for i in 0..=1000 {
            let p = h.percentile(i as f64 / 1000.0);
            assert!(p >= prev, "percentile not monotone at q={i}/1000");
            prev = p;
        }
    }

    #[test]
    fn histogram_single_value_degenerates_cleanly() {
        let mut h = Histogram::default();
        h.record(42);
        assert_eq!(h.percentile(0.5), 42);
        assert_eq!(h.percentile(0.999), 42);
        let s = h.summary();
        assert_eq!((s.p50_us, s.p99_us, s.p999_us, s.max_us), (42, 42, 42, 42));
        assert_eq!(s.mean_us, 42.0);
    }

    #[test]
    fn histogram_merge_matches_combined_recording() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut combined = Histogram::default();
        for us in [1u64, 10, 100, 1000] {
            a.record(us);
            combined.record(us);
        }
        for us in [5u64, 50, 500, 50_000] {
            b.record(us);
            combined.record(us);
        }
        a.merge(&b);
        assert_eq!(a, combined);
        assert_eq!(a.summary(), combined.summary());
    }

    #[test]
    fn histogram_clamps_giants_into_last_bucket() {
        let mut h = Histogram::default();
        h.record(u64::MAX);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max_us(), u64::MAX);
        // The interpolated estimate is capped by the observed max, and
        // stays in range (no overflow panics from the clamped bucket).
        assert!(h.percentile(0.5) <= u64::MAX);
    }

    #[test]
    fn phase_stats_track_min_mean_max() {
        let mut p = PhaseStat::default();
        assert_eq!(p.mean_ns(), 0);
        p.record(100);
        p.record(300);
        p.record(200);
        assert_eq!((p.count, p.min_ns, p.mean_ns(), p.max_ns), (3, 100, 200, 300));

        let mut agg = PreprocessPhases::default();
        agg.record(&PreprocessTiming {
            partition_ns: 10,
            rank_ns: 20,
            tables_ns: 30,
            plan_ns: 40,
            threads: 4,
        });
        agg.record(&PreprocessTiming {
            partition_ns: 30,
            rank_ns: 40,
            tables_ns: 50,
            plan_ns: 60,
            threads: 4,
        });
        assert_eq!(agg.compiles, 2);
        assert_eq!(agg.partition.mean_ns(), 20);
        assert_eq!(agg.total.min_ns, 100);
        assert_eq!(agg.total.max_ns, 180);
        assert!(agg.summary().contains("2 compiles"));
    }
}
