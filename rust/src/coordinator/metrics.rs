//! Lightweight service metrics: global counters + latency summary stay
//! lock-free on the hot path (atomics); per-algorithm counters and the
//! in-flight gauge live behind a short-critical-section mutex, keyed by
//! the algorithm id from the job's `JobSpec`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::accel::PreprocessTiming;
use crate::session::DeltaReport;

/// Min/mean/max accumulator for one preprocess phase (nanoseconds).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PhaseStat {
    pub count: u64,
    pub total_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
}

impl PhaseStat {
    pub fn record(&mut self, ns: u64) {
        self.min_ns = if self.count == 0 { ns } else { self.min_ns.min(ns) };
        self.max_ns = self.max_ns.max(ns);
        self.count += 1;
        self.total_ns += ns;
    }

    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.total_ns / self.count
        }
    }
}

/// Cold-preprocess wall time split into partition / rank / tables / plan
/// phases, min/mean/max per compile. The session's `ArtifactStore`
/// records one entry per cold compile (the single source of truth);
/// [`Service::metrics`](crate::coordinator::Service::metrics) copies it
/// into the snapshot and `repro artifacts warm|ls` prints it, so
/// warm-vs-cold regressions are visible in serve fleets.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PreprocessPhases {
    /// Cold compiles recorded.
    pub compiles: u64,
    pub partition: PhaseStat,
    pub rank: PhaseStat,
    pub tables: PhaseStat,
    pub plan: PhaseStat,
    pub total: PhaseStat,
}

impl PreprocessPhases {
    pub fn record(&mut self, t: &PreprocessTiming) {
        self.compiles += 1;
        self.partition.record(t.partition_ns);
        self.rank.record(t.rank_ns);
        self.tables.record(t.tables_ns);
        self.plan.record(t.plan_ns);
        self.total.record(t.total_ns());
    }

    /// One-line human summary for the CLI: per-phase mean with the
    /// total's min/mean/max, microseconds.
    pub fn summary(&self) -> String {
        format!(
            "{} compiles: partition {}us / rank {}us / tables {}us / plan {}us \
             (total min {}us mean {}us max {}us)",
            self.compiles,
            self.partition.mean_ns() / 1_000,
            self.rank.mean_ns() / 1_000,
            self.tables.mean_ns() / 1_000,
            self.plan.mean_ns() / 1_000,
            self.total.min_ns / 1_000,
            self.total.mean_ns() / 1_000,
            self.total.max_ns / 1_000,
        )
    }
}

/// Per-algorithm counters plus the queue-depth gauge.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct AlgoStats {
    pub completed: u64,
    pub failed: u64,
    /// Jobs submitted but not yet finished (queued or running).
    pub queue_depth: u64,
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    /// Total wall-clock job latency, microseconds.
    total_latency_us: AtomicU64,
    /// Max single-job latency, microseconds.
    max_latency_us: AtomicU64,
    /// Total subgraph ops processed across jobs.
    pub subgraph_ops: AtomicU64,
    /// Streaming-mutation counters (fed by the service's `apply_delta`
    /// entry point): delta batches accepted.
    pub delta_batches: AtomicU64,
    /// Dirty adjacency windows across all accepted batches.
    pub delta_dirty_partitions: AtomicU64,
    /// Plan ops re-emitted by incremental patching.
    pub delta_patched_ops: AtomicU64,
    /// Cached artifacts patched in place — each one a whole-plan
    /// recompile the delta path avoided.
    pub delta_avoided_recompiles: AtomicU64,
    per_algo: Mutex<BTreeMap<String, AlgoStats>>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub mean_latency_us: f64,
    pub max_latency_us: u64,
    pub subgraph_ops: u64,
    pub delta_batches: u64,
    pub delta_dirty_partitions: u64,
    pub delta_patched_ops: u64,
    pub delta_avoided_recompiles: u64,
    /// Cold-preprocess phase timing, copied from the session's
    /// `ArtifactStore` by [`Service::metrics`](crate::coordinator::Service::metrics)
    /// (zeroed in a bare [`Metrics::snapshot`] — the store is the single
    /// source of truth for compile timing).
    pub preprocess: PreprocessPhases,
    /// Keyed by algorithm id, sorted.
    pub per_algorithm: BTreeMap<String, AlgoStats>,
}

impl Metrics {
    pub fn record_submitted(&self, algo: &str) {
        self.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let mut m = self.per_algo.lock().unwrap();
        m.entry(algo.to_string()).or_default().queue_depth += 1;
    }

    pub fn record_completion(&self, algo: &str, latency_us: u64, ops: u64) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(latency_us, Ordering::Relaxed);
        self.max_latency_us.fetch_max(latency_us, Ordering::Relaxed);
        self.subgraph_ops.fetch_add(ops, Ordering::Relaxed);
        let mut m = self.per_algo.lock().unwrap();
        let e = m.entry(algo.to_string()).or_default();
        e.completed += 1;
        e.queue_depth = e.queue_depth.saturating_sub(1);
    }

    pub fn record_failure(&self, algo: &str) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        let mut m = self.per_algo.lock().unwrap();
        let e = m.entry(algo.to_string()).or_default();
        e.failed += 1;
        e.queue_depth = e.queue_depth.saturating_sub(1);
    }

    /// Fold one accepted delta batch's [`DeltaReport`] into the
    /// streaming-mutation counters.
    pub fn record_delta(&self, report: &DeltaReport) {
        self.delta_batches.fetch_add(1, Ordering::Relaxed);
        self.delta_dirty_partitions
            .fetch_add(u64::from(report.stats.dirty_partitions), Ordering::Relaxed);
        self.delta_patched_ops
            .fetch_add(u64::from(report.stats.patched_ops), Ordering::Relaxed);
        self.delta_avoided_recompiles
            .fetch_add(u64::from(report.patched_artifacts), Ordering::Relaxed);
    }

    /// Current in-flight gauge for one algorithm.
    pub fn queue_depth(&self, algo: &str) -> u64 {
        self.per_algo
            .lock()
            .unwrap()
            .get(algo)
            .map_or(0, |e| e.queue_depth)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.jobs_completed.load(Ordering::Relaxed);
        let total = self.total_latency_us.load(Ordering::Relaxed);
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: completed,
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            mean_latency_us: if completed > 0 { total as f64 / completed as f64 } else { 0.0 },
            max_latency_us: self.max_latency_us.load(Ordering::Relaxed),
            subgraph_ops: self.subgraph_ops.load(Ordering::Relaxed),
            delta_batches: self.delta_batches.load(Ordering::Relaxed),
            delta_dirty_partitions: self.delta_dirty_partitions.load(Ordering::Relaxed),
            delta_patched_ops: self.delta_patched_ops.load(Ordering::Relaxed),
            delta_avoided_recompiles: self.delta_avoided_recompiles.load(Ordering::Relaxed),
            preprocess: PreprocessPhases::default(),
            per_algorithm: self.per_algo.lock().unwrap().clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_submitted("bfs");
        m.record_submitted("bfs");
        m.record_submitted("wcc");
        m.record_completion("bfs", 100, 10);
        m.record_completion("wcc", 300, 20);
        let s = m.snapshot();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_completed, 2);
        assert_eq!(s.mean_latency_us, 200.0);
        assert_eq!(s.max_latency_us, 300);
        assert_eq!(s.subgraph_ops, 30);
    }

    #[test]
    fn per_algorithm_counters_and_gauge() {
        let m = Metrics::default();
        m.record_submitted("bfs");
        m.record_submitted("bfs");
        m.record_submitted("sssp");
        assert_eq!(m.queue_depth("bfs"), 2);
        assert_eq!(m.queue_depth("sssp"), 1);
        m.record_completion("bfs", 50, 5);
        m.record_failure("sssp");
        let s = m.snapshot();
        assert_eq!(s.per_algorithm["bfs"], AlgoStats { completed: 1, failed: 0, queue_depth: 1 });
        assert_eq!(s.per_algorithm["sssp"], AlgoStats { completed: 0, failed: 1, queue_depth: 0 });
        assert_eq!(m.queue_depth("pagerank"), 0);
    }

    #[test]
    fn delta_counters_accumulate_reports() {
        use crate::sched::PatchStats;
        let m = Metrics::default();
        m.record_delta(&DeltaReport {
            deltas: 2,
            patched_artifacts: 2,
            skipped_keys: 0,
            stats: PatchStats { dirty_partitions: 3, patched_ops: 5, ..PatchStats::default() },
        });
        m.record_delta(&DeltaReport {
            deltas: 1,
            patched_artifacts: 0,
            skipped_keys: 2,
            stats: PatchStats { dirty_partitions: 1, patched_ops: 1, ..PatchStats::default() },
        });
        let s = m.snapshot();
        assert_eq!(s.delta_batches, 2);
        assert_eq!(s.delta_dirty_partitions, 4);
        assert_eq!(s.delta_patched_ops, 6);
        assert_eq!(s.delta_avoided_recompiles, 2);
    }

    #[test]
    fn gauge_never_underflows() {
        let m = Metrics::default();
        m.record_completion("bfs", 10, 1); // completion without a submit
        assert_eq!(m.queue_depth("bfs"), 0);
    }

    #[test]
    fn empty_snapshot_no_nan() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_latency_us, 0.0);
        assert!(s.per_algorithm.is_empty());
        assert_eq!(s.preprocess, PreprocessPhases::default());
    }

    #[test]
    fn phase_stats_track_min_mean_max() {
        let mut p = PhaseStat::default();
        assert_eq!(p.mean_ns(), 0);
        p.record(100);
        p.record(300);
        p.record(200);
        assert_eq!((p.count, p.min_ns, p.mean_ns(), p.max_ns), (3, 100, 200, 300));

        let mut agg = PreprocessPhases::default();
        agg.record(&PreprocessTiming {
            partition_ns: 10,
            rank_ns: 20,
            tables_ns: 30,
            plan_ns: 40,
            threads: 4,
        });
        agg.record(&PreprocessTiming {
            partition_ns: 30,
            rank_ns: 40,
            tables_ns: 50,
            plan_ns: 60,
            threads: 4,
        });
        assert_eq!(agg.compiles, 2);
        assert_eq!(agg.partition.mean_ns(), 20);
        assert_eq!(agg.total.min_ns, 100);
        assert_eq!(agg.total.max_ns, 180);
        assert!(agg.summary().contains("2 compiles"));
    }
}
