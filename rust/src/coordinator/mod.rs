//! Serving layer: a leader/worker ordered queue that accepts
//! graph-processing jobs, coalesces identical requests onto one
//! execution, sheds expired-deadline work, runs the rest through a
//! shared [`Session`](crate::session::Session) on worker threads, and
//! exposes split queue-wait/execution latency histograms. This is the
//! deployment shell around the accelerator — the CLI `serve`/`loadgen`
//! commands, the `serve` bench, and the `serving_loop` example drive it.

pub mod loadgen;
pub mod metrics;
pub mod service;

pub use loadgen::{LoadMode, LoadgenConfig, LoadgenReport};
pub use metrics::{
    AlgoStats, Histogram, LatencySummary, Metrics, MetricsSnapshot, PhaseStat, PreprocessPhases,
};
pub use service::{
    BatchSubmitError, JobError, JobResult, Pending, Service, ServiceConfig, DEFAULT_QUEUE_DEPTH,
};
