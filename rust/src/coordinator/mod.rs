//! Serving layer: a leader/worker queue that accepts graph-processing
//! jobs, runs them through a shared [`Session`](crate::session::Session)
//! on worker threads, and exposes metrics. This is the deployment shell
//! around the accelerator — the CLI `serve` command and the
//! `serving_loop` example drive it.

pub mod metrics;
pub mod service;

pub use metrics::{AlgoStats, Metrics, MetricsSnapshot, PhaseStat, PreprocessPhases};
pub use service::{JobResult, Pending, Service, ServiceConfig};
