//! Async serving layer: a tokio-based leader that accepts simulation /
//! graph-processing jobs, runs them on worker tasks, and exposes metrics.
//! This is the deployment shell around the accelerator — the CLI `serve`
//! command and the `serving_loop` example drive it.

pub mod metrics;
pub mod service;

pub use metrics::Metrics;
pub use service::{Job, JobResult, Service, ServiceConfig};
