//! The serving loop: a leader owns a job queue; worker threads pull
//! jobs, run the accelerator (preprocessing cached per dataset/config),
//! and reply over per-job channels. Python is never on this path —
//! numeric edge-compute goes through the native mirror or the AOT PJRT
//! artifact, both pure rust at runtime.
//!
//! Implemented on std threads + mpsc (this image vendors no async
//! runtime offline; the architecture is the same leader/worker queue).

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::accel::{Accelerator, ArchConfig, Preprocessed, SimReport};
use crate::algo::{Bfs, PageRank, Sssp, Wcc};
use crate::cost::CostParams;
use crate::graph::datasets::Dataset;
use crate::sched::executor::NativeExecutor;

use super::metrics::Metrics;

/// A graph-processing request.
#[derive(Debug, Clone)]
pub enum Job {
    Bfs { dataset: Dataset, scale: f64, source: u32 },
    Sssp { dataset: Dataset, scale: f64, source: u32 },
    PageRank { dataset: Dataset, scale: f64, iterations: usize },
    Wcc { dataset: Dataset, scale: f64 },
}

impl Job {
    pub fn dataset(&self) -> Dataset {
        match self {
            Job::Bfs { dataset, .. }
            | Job::Sssp { dataset, .. }
            | Job::PageRank { dataset, .. }
            | Job::Wcc { dataset, .. } => *dataset,
        }
    }

    fn scale(&self) -> f64 {
        match self {
            Job::Bfs { scale, .. }
            | Job::Sssp { scale, .. }
            | Job::PageRank { scale, .. }
            | Job::Wcc { scale, .. } => *scale,
        }
    }

    fn weighted(&self) -> bool {
        matches!(self, Job::Sssp { .. })
    }
}

/// Completed job.
#[derive(Debug)]
pub struct JobResult {
    pub report: SimReport,
    pub wall_time_us: u64,
}

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub arch: ArchConfig,
    pub params: CostParams,
    pub workers: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { arch: ArchConfig::default(), params: CostParams::default(), workers: 2 }
    }
}

type PreCache = Arc<Mutex<HashMap<(Dataset, u64, bool), Arc<Preprocessed>>>>;
type Reply = mpsc::Sender<Result<JobResult>>;

/// Handle to a running service. Dropping it shuts the workers down.
pub struct Service {
    tx: Option<mpsc::Sender<(Job, Reply)>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

/// A pending job submission.
pub struct Pending {
    rx: mpsc::Receiver<Result<JobResult>>,
}

impl Pending {
    /// Block until the worker completes the job.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker dropped job"))?
    }
}

impl Service {
    /// Spawn the leader queue + worker threads.
    pub fn spawn(config: ServiceConfig) -> Self {
        let (tx, rx) = mpsc::channel::<(Job, Reply)>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let cache: PreCache = Arc::new(Mutex::new(HashMap::new()));
        let workers = (0..config.workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let metrics = Arc::clone(&metrics);
                let cache = Arc::clone(&cache);
                let config = config.clone();
                std::thread::spawn(move || loop {
                    let item = { rx.lock().unwrap().recv() };
                    let Ok((job, reply)) = item else { break };
                    let started = Instant::now();
                    let result = Self::run_job(&config, &cache, job).map(|report| JobResult {
                        wall_time_us: started.elapsed().as_micros() as u64,
                        report,
                    });
                    match &result {
                        Ok(r) => {
                            metrics.record_completion(r.wall_time_us, r.report.counts.mvm_ops)
                        }
                        Err(_) => {
                            metrics
                                .jobs_failed
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                    let _ = reply.send(result);
                })
            })
            .collect();
        Self { tx: Some(tx), workers, metrics }
    }

    fn run_job(config: &ServiceConfig, cache: &PreCache, job: Job) -> Result<SimReport> {
        let key = (job.dataset(), (job.scale() * 1e6) as u64, job.weighted());
        // Fast path: cached preprocessing (Alg. 1 runs once per dataset).
        let cached = cache.lock().unwrap().get(&key).cloned();
        let pre = match cached {
            Some(p) => p,
            None => {
                let g = if job.weighted() {
                    job.dataset().load_weighted(job.scale())?
                } else {
                    job.dataset().load_scaled(job.scale())?
                };
                let acc = Accelerator::new(config.arch.clone(), config.params.clone());
                let p = Arc::new(acc.preprocess(&g, job.weighted())?);
                cache
                    .lock()
                    .unwrap()
                    .entry(key)
                    .or_insert_with(|| Arc::clone(&p));
                p
            }
        };
        let acc = Accelerator::new(config.arch.clone(), config.params.clone());
        let mut exec = NativeExecutor;
        match job {
            Job::Bfs { source, .. } => acc.run(&pre, &Bfs::new(source), &mut exec),
            Job::Sssp { source, .. } => acc.run(&pre, &Sssp::new(source), &mut exec),
            Job::PageRank { iterations, .. } => {
                acc.run(&pre, &PageRank::new(0.85, iterations), &mut exec)
            }
            Job::Wcc { .. } => acc.run(&pre, &Wcc, &mut exec),
        }
    }

    /// Submit a job; returns a handle resolving when a worker completes it.
    pub fn submit(&self, job: Job) -> Result<Pending> {
        self.metrics
            .jobs_submitted
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("service running")
            .send((job, tx))
            .map_err(|_| anyhow::anyhow!("service stopped"))?;
        Ok(Pending { rx })
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, job: Job) -> Result<JobResult> {
        self.submit(job)?.wait()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.tx.take(); // close queue; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_service(workers: usize) -> Service {
        Service::spawn(ServiceConfig { workers, ..ServiceConfig::default() })
    }

    #[test]
    fn serves_bfs_jobs() {
        let svc = tiny_service(2);
        let res = svc
            .submit_blocking(Job::Bfs { dataset: Dataset::Tiny, scale: 1.0, source: 0 })
            .unwrap();
        assert_eq!(res.report.algorithm, "bfs");
        assert!(res.report.counts.mvm_ops > 0);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 1);
        assert_eq!(snap.jobs_failed, 0);
    }

    #[test]
    fn concurrent_jobs_share_preprocessing_cache() {
        let svc = tiny_service(4);
        let pending: Vec<_> = (0..8u32)
            .map(|i| {
                svc.submit(Job::Bfs { dataset: Dataset::Tiny, scale: 1.0, source: i })
                    .unwrap()
            })
            .collect();
        for p in pending {
            p.wait().unwrap();
        }
        assert_eq!(svc.metrics.snapshot().jobs_completed, 8);
    }

    #[test]
    fn mixed_algorithms() {
        let svc = tiny_service(2);
        let d = Dataset::Tiny;
        svc.submit_blocking(Job::PageRank { dataset: d, scale: 1.0, iterations: 3 })
            .unwrap();
        svc.submit_blocking(Job::Wcc { dataset: d, scale: 1.0 }).unwrap();
        svc.submit_blocking(Job::Sssp { dataset: d, scale: 1.0, source: 1 })
            .unwrap();
        assert_eq!(svc.metrics.snapshot().jobs_completed, 3);
    }

    #[test]
    fn shutdown_joins_workers() {
        let svc = tiny_service(2);
        svc.submit_blocking(Job::Wcc { dataset: Dataset::Tiny, scale: 1.0 })
            .unwrap();
        drop(svc); // must not hang
    }
}
