//! The serving loop: a leader owns an ordered job queue; worker threads
//! pull [`JobSpec`]s and run them through a shared [`Session`] — same
//! registry, same backend, same preprocessed-artifact cache as the CLI
//! and DSE paths. Python is never on this path — numeric edge-compute
//! goes through the native mirror or the AOT PJRT artifact, both pure
//! rust at runtime.
//!
//! Production-tier queue semantics (all enforced by `rust/tests/serve.rs`):
//!
//! - **Request coalescing.** Identical queued jobs (equal
//!   [`CoalesceKey`] — the result identity; scheduling knobs excluded)
//!   share one execution: followers ride the leader's entry and receive
//!   bit-identical clones of its report. This is the `ArtifactStore`'s
//!   stampede coalescing lifted one level up — the store dedupes the
//!   *compile*, the queue dedupes the *run*.
//! - **Batch formation.** At dequeue a worker also claims queued
//!   entries that are *batch-compatible* with the popped one — equal
//!   [`JobSpec::batch_key`] (dataset, scale, algorithm kind, and every
//!   result-determining parameter except the source) and equal
//!   `parallelism`/`shards` overrides — and runs them as one
//!   multi-source batch through the lane-interleaved pipeline
//!   ([`Session::run_batch_with`]), paying the plan walk, crossbar
//!   replay, and pool dispatch once per batch. Batching is **pure
//!   scheduling**: every job's report is bit-identical to its solo
//!   run, the batch key never feeds the coalesce key, and a failing or
//!   panicking batch falls back to per-entry solo execution (so error
//!   chains are solo-identical too). Off by default
//!   ([`ServiceConfig::max_batch`] = 1).
//! - **Ordered dequeue.** Workers pop the highest-priority entry;
//!   ties break earliest-deadline-first, then FIFO by submission order.
//!   Batch claiming never reorders the leader choice — compatible
//!   followers are claimed *after* the best entry is selected.
//! - **Bounded depth + backpressure.** The queue holds at most
//!   `queue_depth` entries; `submit` blocks until a slot frees (a
//!   coalesced follower never occupies a slot — it is pure win).
//! - **Load shedding.** A job whose deadline expired while queued is
//!   shed at dequeue with a typed [`JobError::DeadlineExceeded`] —
//!   counted per algorithm, never executed.
//! - **Panic isolation.** A panicking job is caught with
//!   `catch_unwind`, reported as a failed job ([`JobError::Panicked`]),
//!   and the worker stays alive (its executor is rebuilt — post-unwind
//!   state is suspect). A one-worker service keeps serving after a
//!   poisoned job.
//!
//! Implemented on std threads + a Mutex/Condvar queue (this image
//! vendors no async runtime offline; the architecture is the same
//! leader/worker queue).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::Result;

use crate::accel::{ArchConfig, SimReport};
use crate::cost::CostParams;
use crate::graph::DeltaBatch;
use crate::sched::StepExecutor;
use crate::session::{Backend, BatchKey, CoalesceKey, DeltaReport, JobSpec, Session};

use super::metrics::Metrics;

/// Completed job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub report: SimReport,
    /// Submit → completion, µs (`queue_wait_us + exec_us`).
    pub wall_time_us: u64,
    /// Submit → dequeue, µs — the scheduling share of the latency.
    pub queue_wait_us: u64,
    /// Dequeue → completion, µs — the compute share.
    pub exec_us: u64,
    /// True when this job rode another identical job's execution (its
    /// report is a bit-identical clone of the leader's).
    pub coalesced: bool,
}

/// Typed serve-queue outcomes that are not execution errors. Carried
/// inside the `anyhow::Error` a [`Pending::wait`] resolves to —
/// downcast to tell a shed or panicked job from an algorithm failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobError {
    /// The job's deadline expired while it sat in the queue; it was
    /// load-shed at dequeue without executing.
    DeadlineExceeded {
        /// How long the job waited before being shed, µs.
        waited_us: u64,
    },
    /// The job panicked mid-execution. The worker survived (the panic
    /// was caught and its executor rebuilt); the payload rides along.
    Panicked(String),
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::DeadlineExceeded { waited_us } => {
                write!(f, "deadline exceeded: shed unexecuted after {waited_us}us in queue")
            }
            JobError::Panicked(msg) => write!(f, "job panicked: {msg}"),
        }
    }
}

impl std::error::Error for JobError {}

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub arch: ArchConfig,
    pub params: CostParams,
    /// Honored by every worker — a PJRT-configured service fails loudly
    /// at spawn when artifacts are missing, never silently runs native.
    pub backend: Backend,
    pub workers: usize,
    /// Superstep execution lanes per job, honored by every worker through
    /// the shared session (default 1; `0` = one lane per hardware thread,
    /// resolved via [`resolve_threads`](crate::sched::resolve_threads)).
    /// Parallel jobs check persistent lane-worker pools out of the
    /// session's free list — concurrent workers each get their own pool,
    /// spawned once and reused across jobs, so the steady state performs
    /// zero thread spawns per superstep *and* per job. Served results
    /// are bit-identical for every setting.
    pub parallelism: usize,
    /// Worker threads a cold preprocess (Alg. 1 + plan compilation) fans
    /// out over on a cache miss (`Some(0)` = one per hardware thread).
    /// `None` inherits each job's lane count; the
    /// `REPRO_PREPROCESS_THREADS` environment variable overrides that
    /// default. The compile runs on the session's pooled workers and is
    /// whole-struct-equal to a sequential compile for every setting.
    pub preprocess_parallelism: Option<usize>,
    /// Default shard count for every served job (must be >= 1; default
    /// 1 — unsharded). With `N > 1` each graph splits into `N`
    /// contiguous block-row shards, each compiled and cached under its
    /// own shard-stamped artifact key and run through the deterministic
    /// cross-shard exchange. A scheduling knob like `parallelism`:
    /// served results are bit-identical for every setting, and a
    /// [`JobSpec::with_shards`] override wins per job. CLI: `--shards`.
    pub shards: u32,
    /// On-disk artifact cache directory (`None` = memory-only). A
    /// redeployed service pointed at a warm directory deserializes its
    /// compiled plans instead of re-running Alg. 1 — zero plan
    /// compilations on restart, the serve-fleet warm start the on-disk
    /// tier exists for. Pre-bake with `repro artifacts warm`.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Maximum queued entries before `submit` blocks (backpressure).
    /// Coalesced followers ride existing entries and are never counted
    /// against the bound. `0` = unbounded.
    pub queue_depth: usize,
    /// Most jobs one worker runs as a single multi-source batch: at
    /// dequeue it claims up to `max_batch - 1` additional queued entries
    /// batch-compatible with the popped one (equal
    /// [`JobSpec::batch_key`] and equal scheduling overrides) and
    /// executes them in one lane-interleaved pipeline pass. Purely a
    /// scheduling knob — every job's report stays bit-identical to its
    /// solo run. `0` or `1` disables batching (the default). CLI:
    /// `--max-batch`.
    pub max_batch: usize,
}

/// Default bound on queued entries (see [`ServiceConfig::queue_depth`]).
pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            arch: ArchConfig::default(),
            params: CostParams::default(),
            backend: Backend::Native,
            workers: 2,
            parallelism: 1,
            preprocess_parallelism: None,
            shards: 1,
            artifact_dir: None,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            max_batch: 1,
        }
    }
}

type Reply = mpsc::Sender<Result<JobResult>>;

/// One submission riding a queue entry: where to send the result, and
/// the per-submission scheduling stamps (satellite fix: submit time is
/// stamped *in `submit`*, so queue-wait is part of every reported
/// latency — a worker-side clock can't see time spent queued).
struct Rider {
    reply: Reply,
    submitted_at: Instant,
    deadline: Option<Instant>,
    coalesced: bool,
}

/// A queued execution: one spec, one eventual run, N riders.
struct QueueEntry {
    spec: JobSpec,
    key: CoalesceKey,
    /// Batch compatibility class (scheduling only — see
    /// [`JobSpec::batch_key`]); computed once at push so `pop_batch`
    /// claims are hash-free comparisons.
    bkey: BatchKey,
    /// Max over riders' priorities — a high-priority follower promotes
    /// the whole entry (it shares the execution either way).
    priority: i8,
    /// FIFO tiebreaker.
    seq: u64,
    /// Cached min over riders' deadlines (`None` = no rider is
    /// deadline-bound), min-merged as followers coalesce on — so the
    /// dequeue scan is O(entries), not O(entries × riders).
    min_deadline: Option<Instant>,
    riders: Vec<Rider>,
}

impl QueueEntry {
    /// Earliest hard deadline among riders (`None` = no rider is
    /// deadline-bound). Drives earliest-deadline-first ordering within a
    /// priority class.
    fn order_deadline(&self) -> Option<Instant> {
        self.min_deadline
    }

    /// Fold one more rider's deadline into the cached minimum.
    fn merge_deadline(&mut self, deadline: Option<Instant>) {
        self.min_deadline = match (self.min_deadline, deadline) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
    }

    /// Whether `other` can run in the same multi-source batch as
    /// `self`: same execution artifact and result-determining params
    /// (the batch key) *and* the same scheduling overrides, so one
    /// pooled pipeline pass serves both. Never consults the coalesce
    /// key — batching shares the walk, not the result.
    fn batch_compatible(&self, other: &QueueEntry) -> bool {
        self.bkey == other.bkey
            && self.spec.parallelism == other.spec.parallelism
            && self.spec.shards == other.spec.shards
    }

    /// Strict "dequeue `a` before `b`" ordering: priority desc, then
    /// earliest-deadline-first (deadline-free entries last), then FIFO.
    fn before(a: &QueueEntry, b: &QueueEntry) -> bool {
        if a.priority != b.priority {
            return a.priority > b.priority;
        }
        match (a.order_deadline(), b.order_deadline()) {
            (Some(x), Some(y)) if x != y => x < y,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            _ => a.seq < b.seq,
        }
    }
}

struct QueueState {
    entries: Vec<QueueEntry>,
    open: bool,
    next_seq: u64,
}

/// How a submission landed in the queue.
enum Submitted {
    /// Took its own entry (and queue slot).
    Queued,
    /// Joined an already-queued identical entry.
    Coalesced,
}

/// The ordered serve queue: bounded, coalescing, priority/deadline-aware.
struct JobQueue {
    state: Mutex<QueueState>,
    /// Signaled when an entry is pushed (workers wait here).
    available: Condvar,
    /// Signaled when an entry is popped (backpressured submitters wait
    /// here).
    space: Condvar,
    capacity: usize,
}

impl JobQueue {
    fn new(queue_depth: usize) -> Self {
        Self {
            state: Mutex::new(QueueState { entries: Vec::new(), open: true, next_seq: 0 }),
            available: Condvar::new(),
            space: Condvar::new(),
            capacity: if queue_depth == 0 { usize::MAX } else { queue_depth },
        }
    }

    /// Poison-safe lock (satellite fix for the poisoned-lock cascade):
    /// every mutation under this lock is a single push/remove that
    /// leaves the queue structurally sound, so if a panicking holder
    /// ever poisons it we clear the flag and keep serving instead of
    /// unwinding every other worker.
    fn lock(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|poisoned| {
            self.state.clear_poison();
            poisoned.into_inner()
        })
    }

    fn wait<'a>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, QueueState>,
    ) -> MutexGuard<'a, QueueState> {
        cv.wait(guard).unwrap_or_else(|poisoned| {
            self.state.clear_poison();
            poisoned.into_inner()
        })
    }

    /// Enqueue a submission. Coalesces onto an identical queued entry
    /// when one exists; otherwise takes a slot, blocking while the queue
    /// is full. Fails only when the queue has closed.
    ///
    /// Wake-token discipline (regression-locked by
    /// `woken_submitter_that_coalesces_passes_the_slot_token_on`): each
    /// `pop` signals `space` once — one freed slot, one woken submitter.
    /// A woken submitter that then exits *without consuming the slot*
    /// (it coalesced onto a later identical arrival, or the queue
    /// closed) must pass the token on with another `notify_one`, or a
    /// still-blocked submitter is stranded with a free slot it never
    /// hears about.
    fn push(&self, spec: JobSpec, reply: Reply, submitted_at: Instant) -> Result<Submitted> {
        let key = spec.coalesce_key();
        let bkey = spec.batch_key();
        let deadline = spec.deadline.map(|d| submitted_at + d);
        let priority = spec.priority;
        let mut st = self.lock();
        let mut waited = false;
        loop {
            if !st.open {
                if waited {
                    self.space.notify_one();
                }
                anyhow::bail!("service stopped");
            }
            if let Some(e) = st.entries.iter_mut().find(|e| e.key == key) {
                e.priority = e.priority.max(priority);
                e.merge_deadline(deadline);
                e.riders.push(Rider { reply, submitted_at, deadline, coalesced: true });
                // Coalescing consumes no slot: hand the wake token to
                // the next blocked submitter instead of swallowing it.
                if waited {
                    self.space.notify_one();
                }
                return Ok(Submitted::Coalesced);
            }
            if st.entries.len() < self.capacity {
                let seq = st.next_seq;
                st.next_seq += 1;
                st.entries.push(QueueEntry {
                    spec,
                    key,
                    bkey,
                    priority,
                    seq,
                    min_deadline: deadline,
                    riders: vec![Rider { reply, submitted_at, deadline, coalesced: false }],
                });
                self.available.notify_one();
                return Ok(Submitted::Queued);
            }
            // Backpressure: block until a worker pops an entry, then
            // rescan — the spec may now coalesce with a later arrival.
            st = self.wait(&self.space, st);
            waited = true;
        }
    }

    /// Dequeue the best entry ([`QueueEntry::before`] order). Blocks
    /// while the queue is open and empty; drains remaining entries after
    /// close; returns `None` once closed *and* empty.
    #[cfg(test)]
    fn pop(&self) -> Option<QueueEntry> {
        self.pop_batch(1).map(|mut batch| batch.remove(0))
    }

    /// Dequeue the best entry ([`QueueEntry::before`] order) plus up to
    /// `max - 1` queued entries batch-compatible with it, all claimed
    /// under one lock hold — the leader is first in the returned vec.
    /// Each claimed entry frees a queue slot (`space` is signaled once
    /// per removal, exactly like a solo pop). Blocks while the queue is
    /// open and empty; drains after close; `None` once closed and empty.
    fn pop_batch(&self, max: usize) -> Option<Vec<QueueEntry>> {
        debug_assert!(max >= 1);
        let mut st = self.lock();
        loop {
            if !st.entries.is_empty() {
                let mut best = 0;
                for i in 1..st.entries.len() {
                    if QueueEntry::before(&st.entries[i], &st.entries[best]) {
                        best = i;
                    }
                }
                let leader = st.entries.swap_remove(best);
                self.space.notify_one();
                let mut batch = vec![leader];
                while batch.len() < max {
                    let claim = st.entries.iter().position(|e| batch[0].batch_compatible(e));
                    match claim {
                        Some(i) => {
                            batch.push(st.entries.swap_remove(i));
                            self.space.notify_one();
                        }
                        None => break,
                    }
                }
                return Some(batch);
            }
            if !st.open {
                return None;
            }
            st = self.wait(&self.available, st);
        }
    }

    fn close(&self) {
        self.lock().open = false;
        self.available.notify_all();
        self.space.notify_all();
    }
}

/// Handle to a running service. Dropping it shuts the workers down.
pub struct Service {
    queue: Arc<JobQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    session: Arc<Session>,
    pub metrics: Arc<Metrics>,
}

/// A pending job submission.
pub struct Pending {
    rx: mpsc::Receiver<Result<JobResult>>,
}

impl Pending {
    /// Block until the worker completes the job.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker dropped job"))?
    }
}

/// A batch submission that failed partway: the jobs submitted before
/// the failing one are *not* lost (satellite fix — the old
/// `collect::<Result<_>>` dropped their handles, leaving queued jobs
/// running with unobservable results). Take them back with
/// [`take_submitted`](BatchSubmitError::take_submitted) and wait them
/// out (or drop them knowingly).
pub struct BatchSubmitError {
    /// Behind a mutex only to keep this type `Sync` (mpsc receivers are
    /// not) so it can ride an `anyhow::Error`.
    submitted: Mutex<Vec<Pending>>,
    /// Index of the job whose submit failed.
    pub index: usize,
    source: anyhow::Error,
}

impl BatchSubmitError {
    /// The handles submitted before the failure, in submission order.
    /// Idempotent — the second call returns an empty vec.
    pub fn take_submitted(&self) -> Vec<Pending> {
        let mut guard = self.submitted.lock().unwrap_or_else(|poisoned| {
            self.submitted.clear_poison();
            poisoned.into_inner()
        });
        std::mem::take(&mut *guard)
    }

    /// The underlying submit error.
    pub fn source_error(&self) -> &anyhow::Error {
        &self.source
    }
}

impl std::fmt::Debug for BatchSubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let pending = self.submitted.lock().map(|v| v.len()).unwrap_or(0);
        f.debug_struct("BatchSubmitError")
            .field("index", &self.index)
            .field("pending_submitted", &pending)
            .field("source", &self.source)
            .finish()
    }
}

impl std::fmt::Display for BatchSubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "batch submit failed at job {}: {:#}", self.index, self.source)
    }
}

impl std::error::Error for BatchSubmitError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(self.source.as_ref())
    }
}

impl Service {
    /// Build a [`Session`] from `config` and spawn the leader queue +
    /// worker threads. Fails eagerly on invalid arch or an unavailable
    /// backend (e.g. PJRT without artifacts).
    pub fn spawn(config: ServiceConfig) -> Result<Self> {
        let mut builder = Session::builder()
            .arch(config.arch)
            .cost_params(config.params)
            .backend(config.backend)
            // `0 = auto` resolves inside `SessionBuilder::build` (the one
            // `resolve_threads` call site on this path).
            .parallelism(config.parallelism)
            .shards(config.shards);
        if let Some(threads) = config.preprocess_parallelism {
            builder = builder.preprocess_parallelism(threads);
        }
        if let Some(dir) = config.artifact_dir {
            builder = builder.artifact_dir(dir);
        }
        let session = builder.build()?;
        Ok(Self::with_session_batch(
            Arc::new(session),
            config.workers,
            config.queue_depth,
            config.max_batch,
        ))
    }

    /// Spawn workers over an existing session (sharing its registry and
    /// artifact store with other callers — CLI, DSE, other services),
    /// with the default queue bound.
    pub fn with_session(session: Arc<Session>, workers: usize) -> Self {
        Self::with_session_depth(session, workers, DEFAULT_QUEUE_DEPTH)
    }

    /// [`with_session`](Service::with_session) with an explicit queue
    /// bound (`0` = unbounded). Batching stays off.
    pub fn with_session_depth(session: Arc<Session>, workers: usize, queue_depth: usize) -> Self {
        Self::with_session_batch(session, workers, queue_depth, 1)
    }

    /// [`with_session_depth`](Service::with_session_depth) with an
    /// explicit batch bound ([`ServiceConfig::max_batch`]; `0` or `1` =
    /// no batching).
    pub fn with_session_batch(
        session: Arc<Session>,
        workers: usize,
        queue_depth: usize,
        max_batch: usize,
    ) -> Self {
        let queue = Arc::new(JobQueue::new(queue_depth));
        let metrics = Arc::new(Metrics::default());
        let max_batch = max_batch.max(1);
        let handles = (0..workers.max(1))
            .map(|_| {
                let queue = Arc::clone(&queue);
                let metrics = Arc::clone(&metrics);
                let session = Arc::clone(&session);
                std::thread::spawn(move || {
                    // One executor per worker, built lazily on the first
                    // job: PJRT compiles each artifact once and reuses it
                    // across the worker's lifetime. A construction error
                    // fails the job (loudly) — there is no fallback.
                    let mut exec: Option<Box<dyn StepExecutor>> = None;
                    while let Some(batch) = queue.pop_batch(max_batch) {
                        Self::serve_batch(&session, &metrics, &mut exec, batch);
                    }
                })
            })
            .collect();
        Self { queue, workers: handles, session, metrics }
    }

    /// Run one dequeued batch. A single entry is exactly the solo path;
    /// two or more live entries execute as one multi-source pipeline
    /// pass ([`Session::run_batch_with`]) with per-job results fanned
    /// out exactly as solo runs would be. A failing or panicking batch
    /// falls back to per-entry solo execution so callers always observe
    /// solo-identical results *and* error chains.
    fn serve_batch(
        session: &Session,
        metrics: &Metrics,
        exec: &mut Option<Box<dyn StepExecutor>>,
        entries: Vec<QueueEntry>,
    ) {
        let dequeued = Instant::now();
        // Load shedding runs per entry first — batch claiming must not
        // resurrect a rider whose deadline already passed.
        let mut live_entries: Vec<(JobSpec, Vec<Rider>)> = Vec::with_capacity(entries.len());
        for entry in entries {
            let QueueEntry { spec, riders, .. } = entry;
            let live = Self::shed_expired(metrics, spec.algorithm.as_str(), dequeued, riders);
            if !live.is_empty() {
                live_entries.push((spec, live));
            }
        }
        if live_entries.len() <= 1 {
            // 0 live jobs: nothing to run. 1: solo semantics, no batch
            // metrics — a batch of one is not a batch.
            if let Some((spec, live)) = live_entries.pop() {
                Self::execute_and_fanout(session, metrics, exec, &spec, live, dequeued);
            }
            return;
        }

        let specs: Vec<JobSpec> = live_entries.iter().map(|(s, _)| s.clone()).collect();
        let outcome = catch_unwind(AssertUnwindSafe(|| Self::run_jobs(session, exec, &specs)));
        let exec_us = dequeued.elapsed().as_micros() as u64;
        match outcome {
            Ok(Ok(reports)) if reports.len() == live_entries.len() => {
                metrics.record_batch(live_entries.len());
                for ((spec, live), report) in live_entries.into_iter().zip(reports) {
                    // Each batched job is its own execution: one
                    // shard-count sample and one ops record per job,
                    // exactly like its solo run.
                    metrics.record_sharded_run(spec.shards.unwrap_or_else(|| session.shards()));
                    Self::fanout_success(
                        metrics,
                        spec.algorithm.as_str(),
                        dequeued,
                        exec_us,
                        live,
                        report,
                    );
                }
            }
            other => {
                // The batch pass failed as a whole (or returned a
                // malformed shape). Post-unwind executor state is
                // suspect — drop it before the retries. Then run every
                // entry solo: per-job errors come from the job's own
                // run, bit-identical chains included, and a healthy job
                // sharing a batch with a poisoned one still completes.
                if other.is_err() {
                    *exec = None;
                }
                for (spec, live) in live_entries {
                    Self::execute_and_fanout(session, metrics, exec, &spec, live, dequeued);
                }
            }
        }
    }

    /// Load shedding: a rider whose deadline passed while queued gets a
    /// typed error instead of an executor. Returns the survivors; when
    /// every rider expired the execution is skipped entirely.
    fn shed_expired(
        metrics: &Metrics,
        algo: &str,
        dequeued: Instant,
        riders: Vec<Rider>,
    ) -> Vec<Rider> {
        let mut live = Vec::with_capacity(riders.len());
        for r in riders {
            match r.deadline {
                Some(d) if d <= dequeued => {
                    let waited_us =
                        dequeued.saturating_duration_since(r.submitted_at).as_micros() as u64;
                    metrics.record_shed(algo, waited_us);
                    let _ = r.reply.send(Err(JobError::DeadlineExceeded { waited_us }.into()));
                }
                _ => live.push(r),
            }
        }
        live
    }

    /// Execute one spec behind a panic guard and fan the outcome out to
    /// its surviving riders — the solo execution path (and the per-entry
    /// fallback when a batch pass fails).
    fn execute_and_fanout(
        session: &Session,
        metrics: &Metrics,
        exec: &mut Option<Box<dyn StepExecutor>>,
        spec: &JobSpec,
        live: Vec<Rider>,
        dequeued: Instant,
    ) {
        let algo = spec.algorithm.as_str();
        // Panic isolation (satellite fix for worker death): a panicking
        // job must cost the service one job, not one worker.
        let outcome = catch_unwind(AssertUnwindSafe(|| Self::run_job(session, exec, spec)));
        let exec_us = dequeued.elapsed().as_micros() as u64;

        match outcome {
            Ok(Ok(report)) => {
                // One execution → one shard-count sample, regardless of
                // how many coalesced riders it resolves.
                metrics.record_sharded_run(spec.shards.unwrap_or_else(|| session.shards()));
                Self::fanout_success(metrics, algo, dequeued, exec_us, live, report);
            }
            Ok(Err(err)) => {
                let msg = format!("{err:#}");
                let mut original = Some(err);
                let n = live.len();
                for r in live {
                    metrics.record_failure(algo);
                    // A lone rider gets the original error (downcastable
                    // chain intact); fan-out riders get formatted copies.
                    let e = if n == 1 {
                        original.take().unwrap()
                    } else {
                        anyhow::anyhow!(msg.clone())
                    };
                    let _ = r.reply.send(Err(e));
                }
            }
            Err(payload) => {
                // Post-unwind executor state is suspect — rebuild lazily
                // on the next job rather than trusting it.
                *exec = None;
                let msg = panic_message(payload);
                for r in live {
                    metrics.record_failure(algo);
                    let _ = r.reply.send(Err(JobError::Panicked(msg.clone()).into()));
                }
            }
        }
    }

    /// Fan one successful execution's report out to every surviving
    /// rider. Hardware work is counted **once per execution**, carried
    /// by whichever rider is delivered first — *not* keyed off the
    /// `coalesced` flag: when the submitting leader was shed at dequeue,
    /// every survivor is a coalesced follower, and the old
    /// leader-carries-the-ops rule dropped the execution's ops on the
    /// floor (the leader-shed accounting hole).
    fn fanout_success(
        metrics: &Metrics,
        algo: &str,
        dequeued: Instant,
        exec_us: u64,
        live: Vec<Rider>,
        report: SimReport,
    ) {
        let mut ops_once = report.counts.mvm_ops;
        let mut report = Some(report);
        let n = live.len();
        for (i, r) in live.into_iter().enumerate() {
            let queue_wait_us =
                dequeued.saturating_duration_since(r.submitted_at).as_micros() as u64;
            let ops = std::mem::take(&mut ops_once);
            metrics.record_completion(algo, queue_wait_us, exec_us, ops);
            let rep = if i + 1 == n {
                report.take().unwrap()
            } else {
                report.as_ref().unwrap().clone()
            };
            let _ = r.reply.send(Ok(JobResult {
                report: rep,
                wall_time_us: queue_wait_us + exec_us,
                queue_wait_us,
                exec_us,
                coalesced: r.coalesced,
            }));
        }
    }

    fn run_job(
        session: &Session,
        exec: &mut Option<Box<dyn StepExecutor>>,
        spec: &JobSpec,
    ) -> Result<SimReport> {
        if exec.is_none() {
            *exec = Some(session.executor()?);
        }
        session.run_with(spec, exec.as_mut().unwrap().as_mut())
    }

    /// Batch counterpart of [`run_job`](Self::run_job): one worker
    /// executor, one lane-interleaved pipeline pass over every spec.
    fn run_jobs(
        session: &Session,
        exec: &mut Option<Box<dyn StepExecutor>>,
        specs: &[JobSpec],
    ) -> Result<Vec<SimReport>> {
        if exec.is_none() {
            *exec = Some(session.executor()?);
        }
        session.run_batch_with(specs, exec.as_mut().unwrap().as_mut())
    }

    /// The shared session (inspect the registry, artifact-cache stats…).
    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    /// A metrics snapshot with the session store's cold-preprocess phase
    /// timing folded in (a bare `metrics.snapshot()` leaves that field
    /// zeroed — the store, not the `Metrics` counters, is the single
    /// source of truth for compile cost).
    pub fn snapshot(&self) -> super::MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.preprocess = self.session.preprocess_phases();
        snap
    }

    /// Apply a streaming edge-delta batch to the spec's `(dataset,
    /// scale)` pair through the shared session
    /// ([`Session::apply_delta`]): every cached artifact is patched in
    /// place, never recompiled, and later jobs — from any worker — serve
    /// the mutated graph. Synchronous (it runs on the caller, not the
    /// job queue): once it returns, every job submitted afterwards sees
    /// the mutated graph; a job already mid-run keeps the artifact it
    /// checked out. Accepted batches feed the `delta_*` metrics.
    pub fn apply_delta(&self, spec: &JobSpec, batch: &DeltaBatch) -> Result<DeltaReport> {
        let report = self.session.apply_delta(spec, batch)?;
        self.metrics.record_delta(&report);
        Ok(report)
    }

    /// Submit a job; returns a handle resolving when a worker completes
    /// it. Blocks while the queue is at `queue_depth` (backpressure);
    /// identical queued jobs coalesce instead of queueing twice.
    pub fn submit(&self, job: impl Into<JobSpec>) -> Result<Pending> {
        let spec: JobSpec = job.into();
        // Fail-fast before anything is recorded: an invalid spec never
        // occupies a slot and never skews the gauges.
        spec.validate()?;
        let algo = spec.algorithm.clone();
        self.metrics.record_submitted(algo.as_str());
        let (tx, rx) = mpsc::channel();
        match self.queue.push(spec, tx, Instant::now()) {
            Ok(Submitted::Queued) => Ok(Pending { rx }),
            Ok(Submitted::Coalesced) => {
                self.metrics.record_coalesced(algo.as_str());
                Ok(Pending { rx })
            }
            Err(err) => {
                // Balance the submit record so the gauges stay conserved.
                self.metrics.record_failure(algo.as_str());
                Err(err)
            }
        }
    }

    /// Submit a batch of jobs in order; pending handles come back in the
    /// same order. The batch shares preprocessing through the session's
    /// artifact store — one Alg.-1 run per distinct dataset key — and
    /// identical specs coalesce into one execution.
    ///
    /// On a mid-batch failure the already-submitted handles are returned
    /// inside the [`BatchSubmitError`] — they are live jobs whose
    /// results remain observable, not leaked work.
    pub fn submit_batch<I>(&self, jobs: I) -> Result<Vec<Pending>, BatchSubmitError>
    where
        I: IntoIterator,
        I::Item: Into<JobSpec>,
    {
        let mut submitted = Vec::new();
        for (index, job) in jobs.into_iter().enumerate() {
            match self.submit(job) {
                Ok(p) => submitted.push(p),
                Err(source) => {
                    return Err(BatchSubmitError {
                        submitted: Mutex::new(submitted),
                        index,
                        source,
                    })
                }
            }
        }
        Ok(submitted)
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, job: impl Into<JobSpec>) -> Result<JobResult> {
        self.submit(job)?.wait()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.queue.close(); // workers drain the queue and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;
    use std::time::Duration;

    fn tiny_service(workers: usize) -> Service {
        Service::spawn(ServiceConfig { workers, ..ServiceConfig::default() }).unwrap()
    }

    #[test]
    fn serves_bfs_jobs() {
        let svc = tiny_service(2);
        let res = svc
            .submit_blocking(JobSpec::new(Dataset::Tiny, "bfs"))
            .unwrap();
        assert_eq!(res.report.algorithm, "bfs");
        assert!(res.report.counts.mvm_ops > 0);
        assert!(!res.coalesced);
        assert_eq!(res.wall_time_us, res.queue_wait_us + res.exec_us);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 1);
        assert_eq!(snap.jobs_failed, 0);
        assert_eq!(snap.per_algorithm["bfs"].completed, 1);
        assert_eq!(snap.per_algorithm["bfs"].queue_depth, 0);
        assert_eq!(snap.per_algorithm["bfs"].execution.count, 1);
        assert_eq!(snap.per_algorithm["bfs"].queue_wait.count, 1);
    }

    #[test]
    fn snapshot_carries_preprocess_phase_timing() {
        let svc = tiny_service(2);
        assert_eq!(svc.snapshot().preprocess.compiles, 0);
        svc.submit_blocking(JobSpec::new(Dataset::Tiny, "bfs")).unwrap();
        let snap = svc.snapshot();
        assert_eq!(snap.preprocess.compiles, 1, "one cold compile served the job");
        assert!(snap.preprocess.total.max_ns > 0);
        // The bare Metrics snapshot stays zeroed — the session store is
        // the single source of truth for compile timing.
        assert_eq!(svc.metrics.snapshot().preprocess.compiles, 0);
    }

    #[test]
    fn pagerank_jobspec_submits() {
        let svc = tiny_service(2);
        let res = svc
            .submit_blocking(JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(3))
            .unwrap();
        assert_eq!(res.report.algorithm, "pagerank");
    }

    #[test]
    fn unknown_algorithm_fails_the_job_not_the_service() {
        let svc = tiny_service(1);
        let err = svc
            .submit_blocking(JobSpec::new(Dataset::Tiny, "nope"))
            .unwrap_err();
        assert!(err.to_string().contains("unknown algorithm"), "{err}");
        // Service keeps serving afterwards.
        svc.submit_blocking(JobSpec::new(Dataset::Tiny, "wcc")).unwrap();
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.jobs_failed, 1);
        assert_eq!(snap.jobs_completed, 1);
    }

    #[test]
    fn invalid_spec_rejected_before_queueing() {
        let svc = tiny_service(1);
        let err = svc
            .submit(JobSpec::new(Dataset::Tiny, "bfs").with_scale(2.0))
            .unwrap_err();
        assert!(err.to_string().contains("scale"), "{err}");
        // Nothing recorded — the spec never reached the queue.
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.jobs_submitted, 0);
        assert_eq!(snap.jobs_failed, 0);
    }

    #[test]
    fn concurrent_jobs_share_preprocessing_cache() {
        let svc = tiny_service(4);
        let pending = svc
            .submit_batch((0..8u32).map(|i| JobSpec::new(Dataset::Tiny, "bfs").with_source(i)))
            .unwrap();
        for p in pending {
            p.wait().unwrap();
        }
        assert_eq!(svc.metrics.snapshot().jobs_completed, 8);
        // Exactly one Alg.-1 run across all 4 workers.
        assert_eq!(svc.session().artifacts().stats().misses, 1);
    }

    #[test]
    fn mixed_algorithms() {
        let svc = tiny_service(2);
        let d = Dataset::Tiny;
        svc.submit_blocking(JobSpec::new(d, "pagerank").with_iterations(3)).unwrap();
        svc.submit_blocking(JobSpec::new(d, "wcc")).unwrap();
        svc.submit_blocking(JobSpec::new(d, "sssp").with_source(1)).unwrap();
        assert_eq!(svc.metrics.snapshot().jobs_completed, 3);
    }

    #[test]
    fn parallel_workers_serve_identical_results() {
        let seq = tiny_service(2);
        let par = Service::spawn(ServiceConfig {
            workers: 2,
            parallelism: 4,
            ..ServiceConfig::default()
        })
        .unwrap();
        let job = || JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(4);
        let a = seq.submit_blocking(job()).unwrap().report;
        let b = par.submit_blocking(job()).unwrap().report;
        assert_eq!(
            a.run.as_ref().unwrap().values,
            b.run.as_ref().unwrap().values
        );
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.exec_time_ns, b.exec_time_ns);
    }

    #[test]
    fn sharded_workers_serve_identical_results() {
        let seq = tiny_service(2);
        let sharded = Service::spawn(ServiceConfig {
            workers: 2,
            parallelism: 4,
            shards: 2,
            ..ServiceConfig::default()
        })
        .unwrap();
        let job = || JobSpec::new(Dataset::Tiny, "wcc");
        let a = seq.submit_blocking(job()).unwrap().report;
        let b = sharded.submit_blocking(job()).unwrap().report;
        assert_eq!(a.run.as_ref().unwrap().values, b.run.as_ref().unwrap().values);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.exec_time_ns, b.exec_time_ns);
        // One artifact per shard behind the served job, and the shard
        // count surfaces in the metrics snapshot.
        assert_eq!(sharded.session().artifacts().stats().entries, 2);
        assert_eq!(sharded.metrics.snapshot().runs_by_shards[&2], 1);
        assert_eq!(seq.metrics.snapshot().runs_by_shards[&1], 1);
        // Zero shards fails service spawn eagerly, like a bad arch.
        assert!(Service::spawn(ServiceConfig { shards: 0, ..ServiceConfig::default() }).is_err());
    }

    #[test]
    fn apply_delta_patches_served_artifacts_and_counts() {
        let svc = tiny_service(2);
        let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(0);
        svc.submit_blocking(spec.clone()).unwrap();

        let g = svc.session().load_graph(&spec).unwrap();
        let e = g.edges[0];
        let batch = crate::graph::DeltaBatch::new(
            g.num_vertices,
            vec![crate::graph::EdgeDelta::remove(e.src, e.dst)],
        )
        .unwrap();
        let report = svc.apply_delta(&spec, &batch).unwrap();
        assert_eq!(report.patched_artifacts, 1);

        // Served from the patched plan — no recompile — and bit-identical
        // to a cold compile of the mutated graph.
        let after = svc.submit_blocking(spec.clone()).unwrap().report;
        assert_eq!(svc.session().artifacts().stats().misses, 1);
        let cold = Session::with_defaults()
            .unwrap()
            .run_on(&spec, &svc.session().load_graph(&spec).unwrap())
            .unwrap();
        assert_eq!(after.counts, cold.counts);
        assert_eq!(after.exec_time_ns, cold.exec_time_ns);

        let snap = svc.metrics.snapshot();
        assert_eq!(snap.delta_batches, 1);
        assert_eq!(snap.delta_avoided_recompiles, 1);
        assert!(snap.delta_dirty_partitions >= 1);
    }

    #[test]
    fn shutdown_joins_workers() {
        let svc = tiny_service(2);
        svc.submit_blocking(JobSpec::new(Dataset::Tiny, "wcc")).unwrap();
        drop(svc); // must not hang
    }

    #[test]
    fn generous_deadline_jobs_complete_normally() {
        let svc = tiny_service(1);
        let res = svc
            .submit_blocking(
                JobSpec::new(Dataset::Tiny, "bfs").with_deadline(Duration::from_secs(3600)),
            )
            .unwrap();
        assert!(!res.coalesced);
        let snap = svc.metrics.snapshot();
        assert_eq!((snap.jobs_completed, snap.jobs_shed), (1, 0));
    }

    // -- queue-unit tests (no workers: poke the JobQueue directly) ------

    fn entry_for(queue: &JobQueue, spec: JobSpec) -> Submitted {
        let (tx, _rx) = mpsc::channel();
        queue.push(spec, tx, Instant::now()).unwrap()
    }

    #[test]
    fn queue_coalesces_identical_specs() {
        let q = JobQueue::new(16);
        assert!(matches!(entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs")), Submitted::Queued));
        assert!(matches!(
            entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs")),
            Submitted::Coalesced
        ));
        // A different source is a different result — no coalescing.
        assert!(matches!(
            entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs").with_source(7)),
            Submitted::Queued
        ));
        let first = q.pop().unwrap();
        assert_eq!(first.riders.len(), 2);
        assert!(!first.riders[0].coalesced);
        assert!(first.riders[1].coalesced);
        let second = q.pop().unwrap();
        assert_eq!(second.riders.len(), 1);
    }

    #[test]
    fn queue_orders_priority_then_deadline_then_fifo() {
        let q = JobQueue::new(16);
        let d = Dataset::Tiny;
        entry_for(&q, JobSpec::new(d, "bfs")); // seq 0, pri 0
        entry_for(&q, JobSpec::new(d, "wcc").with_priority(5)); // pri 5
        entry_for(&q, JobSpec::new(d, "sssp").with_deadline(Duration::from_secs(60))); // pri 0, deadlined
        entry_for(&q, JobSpec::new(d, "pagerank")); // seq 3, pri 0
        q.close();
        let order: Vec<String> =
            std::iter::from_fn(|| q.pop().map(|e| e.spec.algorithm.as_str().to_string()))
                .collect();
        // Highest priority first; then the deadlined entry beats the
        // deadline-free ones; then FIFO.
        assert_eq!(order, ["wcc", "sssp", "bfs", "pagerank"]);
    }

    #[test]
    fn queue_promotes_entry_to_max_rider_priority() {
        let q = JobQueue::new(16);
        let d = Dataset::Tiny;
        entry_for(&q, JobSpec::new(d, "wcc")); // pri 0
        entry_for(&q, JobSpec::new(d, "bfs")); // pri 0, leader
        entry_for(&q, JobSpec::new(d, "bfs").with_priority(9)); // follower promotes
        q.close();
        assert_eq!(q.pop().unwrap().spec.algorithm.as_str(), "bfs");
        assert_eq!(q.pop().unwrap().spec.algorithm.as_str(), "wcc");
    }

    #[test]
    fn queue_rejects_after_close() {
        let q = JobQueue::new(16);
        q.close();
        let (tx, _rx) = mpsc::channel();
        assert!(q.push(JobSpec::new(Dataset::Tiny, "bfs"), tx, Instant::now()).is_err());
        assert!(q.pop().is_none());
    }

    /// Regression (backpressure-wake hole): a submitter woken from the
    /// `space` condvar that then *coalesces* consumes the pop's wake
    /// token without taking the freed slot. Pre-fix, a third blocked
    /// submitter was stranded forever next to a free slot; the fix
    /// re-signals `space` whenever a woken submitter exits without
    /// consuming a slot.
    #[test]
    fn woken_submitter_that_coalesces_passes_the_slot_token_on() {
        let q = Arc::new(JobQueue::new(2));
        // Fill both slots with distinct entries.
        entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs").with_source(1));
        entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs").with_source(2));
        // Three submitters of one identical spec all block on `space`.
        let (done_tx, done_rx) = mpsc::channel();
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                let done = done_tx.clone();
                std::thread::spawn(move || {
                    let (tx, _rx) = mpsc::channel();
                    let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(7);
                    q.push(spec, tx, Instant::now()).unwrap();
                    done.send(()).unwrap();
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(100));
        // Two pops → two wake tokens. The first woken submitter inserts
        // the shared spec (taking a slot); every later one coalesces and
        // must pass its token on so the last submitter unblocks too.
        q.pop().unwrap();
        q.pop().unwrap();
        for i in 0..3 {
            done_rx.recv_timeout(Duration::from_secs(10)).unwrap_or_else(|_| {
                panic!(
                    "submitter {i} stranded: a woken submitter that \
                     coalesced swallowed the wake token"
                )
            });
        }
        for h in handles {
            h.join().unwrap();
        }
        let merged = q.pop().unwrap();
        assert_eq!(merged.riders.len(), 3);
        assert_eq!(merged.riders.iter().filter(|r| !r.coalesced).count(), 1);
    }

    #[test]
    fn pop_batch_claims_only_batch_compatible_entries() {
        let q = JobQueue::new(16);
        let d = Dataset::Tiny;
        entry_for(&q, JobSpec::new(d, "bfs").with_source(0)); // leader (FIFO)
        entry_for(&q, JobSpec::new(d, "bfs").with_source(1)); // claimable
        entry_for(&q, JobSpec::new(d, "wcc")); // different algorithm
        entry_for(&q, JobSpec::new(d, "bfs").with_source(2).with_parallelism(4)); // override differs
        entry_for(&q, JobSpec::new(d, "bfs").with_source(3)); // claimable
        let batch = q.pop_batch(8).unwrap();
        assert_eq!(batch[0].spec.params.source, 0, "claiming never reorders the leader choice");
        let mut claimed: Vec<u32> = batch[1..].iter().map(|e| e.spec.params.source).collect();
        claimed.sort_unstable();
        assert_eq!(claimed, [1, 3], "only equal batch key + equal overrides are claimed");
        // The incompatible entries still serve normally afterwards.
        assert_eq!(q.pop_batch(8).unwrap().len(), 1);
        assert_eq!(q.pop_batch(8).unwrap().len(), 1);
    }

    #[test]
    fn pop_batch_respects_the_batch_bound() {
        let q = JobQueue::new(16);
        for s in 0..5u32 {
            entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs").with_source(s));
        }
        assert_eq!(q.pop_batch(3).unwrap().len(), 3);
        assert_eq!(q.pop_batch(3).unwrap().len(), 2);
        // Solo pops are exactly pop_batch(1).
        for s in 5..7u32 {
            entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs").with_source(s));
        }
        assert_eq!(q.pop_batch(1).unwrap().len(), 1);
        assert_eq!(q.pop().unwrap().riders.len(), 1);
    }

    #[test]
    fn pop_batch_frees_a_slot_per_claimed_entry() {
        // Capacity 3, full; one pop_batch(3) drains every compatible
        // entry and must free *all three* slots — three more submits go
        // through without blocking.
        let q = JobQueue::new(3);
        for s in 0..3u32 {
            entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs").with_source(s));
        }
        assert_eq!(q.pop_batch(3).unwrap().len(), 3);
        for s in 10..13u32 {
            assert!(matches!(
                entry_for(&q, JobSpec::new(Dataset::Tiny, "bfs").with_source(s)),
                Submitted::Queued
            ));
        }
    }
}
