//! The serving loop: a leader owns a job queue; worker threads pull
//! [`JobSpec`]s and run them through a shared [`Session`] — same
//! registry, same backend, same preprocessed-artifact cache as the CLI
//! and DSE paths. Python is never on this path — numeric edge-compute
//! goes through the native mirror or the AOT PJRT artifact, both pure
//! rust at runtime.
//!
//! Implemented on std threads + mpsc (this image vendors no async
//! runtime offline; the architecture is the same leader/worker queue).

use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::accel::{ArchConfig, SimReport};
use crate::cost::CostParams;
use crate::sched::StepExecutor;
use crate::graph::DeltaBatch;
use crate::session::{AlgorithmId, Backend, DeltaReport, JobSpec, Session};

use super::metrics::Metrics;

/// Completed job.
#[derive(Debug)]
pub struct JobResult {
    pub report: SimReport,
    pub wall_time_us: u64,
}

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub arch: ArchConfig,
    pub params: CostParams,
    /// Honored by every worker — a PJRT-configured service fails loudly
    /// at spawn when artifacts are missing, never silently runs native.
    pub backend: Backend,
    pub workers: usize,
    /// Superstep execution lanes per job, honored by every worker through
    /// the shared session (default 1; `0` = one lane per hardware thread,
    /// resolved via [`resolve_threads`](crate::sched::resolve_threads)).
    /// Parallel jobs check persistent lane-worker pools out of the
    /// session's free list — concurrent workers each get their own pool,
    /// spawned once and reused across jobs, so the steady state performs
    /// zero thread spawns per superstep *and* per job. Served results
    /// are bit-identical for every setting.
    pub parallelism: usize,
    /// Worker threads a cold preprocess (Alg. 1 + plan compilation) fans
    /// out over on a cache miss (`Some(0)` = one per hardware thread).
    /// `None` inherits each job's lane count; the
    /// `REPRO_PREPROCESS_THREADS` environment variable overrides that
    /// default. The compile runs on the session's pooled workers and is
    /// whole-struct-equal to a sequential compile for every setting.
    pub preprocess_parallelism: Option<usize>,
    /// On-disk artifact cache directory (`None` = memory-only). A
    /// redeployed service pointed at a warm directory deserializes its
    /// compiled plans instead of re-running Alg. 1 — zero plan
    /// compilations on restart, the serve-fleet warm start the on-disk
    /// tier exists for. Pre-bake with `repro artifacts warm`.
    pub artifact_dir: Option<std::path::PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            arch: ArchConfig::default(),
            params: CostParams::default(),
            backend: Backend::Native,
            workers: 2,
            parallelism: 1,
            preprocess_parallelism: None,
            artifact_dir: None,
        }
    }
}

type Reply = mpsc::Sender<Result<JobResult>>;

/// Balances `record_submitted` even if the worker panics mid-job: unless
/// disarmed by a normal completion/failure record, dropping the guard
/// records a failure, so the per-algorithm queue-depth gauge and the
/// `submitted == completed + failed` invariant survive unwinding.
struct CompletionGuard<'m> {
    metrics: &'m Metrics,
    algo: AlgorithmId,
    armed: bool,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.metrics.record_failure(self.algo.as_str());
        }
    }
}

/// Handle to a running service. Dropping it shuts the workers down.
pub struct Service {
    tx: Option<mpsc::Sender<(JobSpec, Reply)>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    session: Arc<Session>,
    pub metrics: Arc<Metrics>,
}

/// A pending job submission.
pub struct Pending {
    rx: mpsc::Receiver<Result<JobResult>>,
}

impl Pending {
    /// Block until the worker completes the job.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker dropped job"))?
    }
}

impl Service {
    /// Build a [`Session`] from `config` and spawn the leader queue +
    /// worker threads. Fails eagerly on invalid arch or an unavailable
    /// backend (e.g. PJRT without artifacts).
    pub fn spawn(config: ServiceConfig) -> Result<Self> {
        let mut builder = Session::builder()
            .arch(config.arch)
            .cost_params(config.params)
            .backend(config.backend)
            // `0 = auto` resolves inside `SessionBuilder::build` (the one
            // `resolve_threads` call site on this path).
            .parallelism(config.parallelism);
        if let Some(threads) = config.preprocess_parallelism {
            builder = builder.preprocess_parallelism(threads);
        }
        if let Some(dir) = config.artifact_dir {
            builder = builder.artifact_dir(dir);
        }
        let session = builder.build()?;
        Ok(Self::with_session(Arc::new(session), config.workers))
    }

    /// Spawn workers over an existing session (sharing its registry and
    /// artifact store with other callers — CLI, DSE, other services).
    pub fn with_session(session: Arc<Session>, workers: usize) -> Self {
        let (tx, rx) = mpsc::channel::<(JobSpec, Reply)>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let handles = (0..workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let metrics = Arc::clone(&metrics);
                let session = Arc::clone(&session);
                std::thread::spawn(move || {
                    // One executor per worker, built lazily on the first
                    // job: PJRT compiles each artifact once and reuses it
                    // across the worker's lifetime. A construction error
                    // fails the job (loudly) — there is no fallback.
                    let mut exec: Option<Box<dyn StepExecutor>> = None;
                    loop {
                        let item = { rx.lock().unwrap().recv() };
                        let Ok((spec, reply)) = item else { break };
                        let mut guard = CompletionGuard {
                            metrics: &metrics,
                            algo: spec.algorithm.clone(),
                            armed: true,
                        };
                        let started = Instant::now();
                        let result =
                            Self::run_job(&session, &mut exec, &spec).map(|report| JobResult {
                                wall_time_us: started.elapsed().as_micros() as u64,
                                report,
                            });
                        guard.armed = false;
                        match &result {
                            Ok(r) => metrics.record_completion(
                                guard.algo.as_str(),
                                r.wall_time_us,
                                r.report.counts.mvm_ops,
                            ),
                            Err(_) => metrics.record_failure(guard.algo.as_str()),
                        }
                        let _ = reply.send(result);
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers: handles, session, metrics }
    }

    fn run_job(
        session: &Session,
        exec: &mut Option<Box<dyn StepExecutor>>,
        spec: &JobSpec,
    ) -> Result<crate::accel::SimReport> {
        if exec.is_none() {
            *exec = Some(session.executor()?);
        }
        session.run_with(spec, exec.as_mut().unwrap().as_mut())
    }

    /// The shared session (inspect the registry, artifact-cache stats…).
    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    /// A metrics snapshot with the session store's cold-preprocess phase
    /// timing folded in (a bare `metrics.snapshot()` leaves that field
    /// zeroed — the store, not the `Metrics` counters, is the single
    /// source of truth for compile cost).
    pub fn snapshot(&self) -> super::MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.preprocess = self.session.preprocess_phases();
        snap
    }

    /// Apply a streaming edge-delta batch to the spec's `(dataset,
    /// scale)` pair through the shared session
    /// ([`Session::apply_delta`]): every cached artifact is patched in
    /// place, never recompiled, and later jobs — from any worker — serve
    /// the mutated graph. Synchronous (it runs on the caller, not the
    /// job queue): once it returns, every job submitted afterwards sees
    /// the mutated graph; a job already mid-run keeps the artifact it
    /// checked out. Accepted batches feed the `delta_*` metrics.
    pub fn apply_delta(&self, spec: &JobSpec, batch: &DeltaBatch) -> Result<DeltaReport> {
        let report = self.session.apply_delta(spec, batch)?;
        self.metrics.record_delta(&report);
        Ok(report)
    }

    /// Submit a job; returns a handle resolving when a worker completes
    /// it.
    pub fn submit(&self, job: impl Into<JobSpec>) -> Result<Pending> {
        let spec: JobSpec = job.into();
        self.metrics.record_submitted(spec.algorithm.as_str());
        let (tx, rx) = mpsc::channel();
        let sender = self.tx.as_ref().expect("service running");
        if let Err(mpsc::SendError((spec, _))) = sender.send((spec, tx)) {
            // Balance the submit record so the gauges stay conserved.
            self.metrics.record_failure(spec.algorithm.as_str());
            anyhow::bail!("service stopped");
        }
        Ok(Pending { rx })
    }

    /// Submit a batch of jobs in order; pending handles come back in the
    /// same order. The batch shares preprocessing through the session's
    /// artifact store — one Alg.-1 run per distinct dataset key.
    pub fn submit_batch<I>(&self, jobs: I) -> Result<Vec<Pending>>
    where
        I: IntoIterator,
        I::Item: Into<JobSpec>,
    {
        jobs.into_iter().map(|j| self.submit(j)).collect()
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, job: impl Into<JobSpec>) -> Result<JobResult> {
        self.submit(job)?.wait()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.tx.take(); // close queue; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;

    fn tiny_service(workers: usize) -> Service {
        Service::spawn(ServiceConfig { workers, ..ServiceConfig::default() }).unwrap()
    }

    #[test]
    fn serves_bfs_jobs() {
        let svc = tiny_service(2);
        let res = svc
            .submit_blocking(JobSpec::new(Dataset::Tiny, "bfs"))
            .unwrap();
        assert_eq!(res.report.algorithm, "bfs");
        assert!(res.report.counts.mvm_ops > 0);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 1);
        assert_eq!(snap.jobs_failed, 0);
        assert_eq!(snap.per_algorithm["bfs"].completed, 1);
        assert_eq!(snap.per_algorithm["bfs"].queue_depth, 0);
    }

    #[test]
    fn snapshot_carries_preprocess_phase_timing() {
        let svc = tiny_service(2);
        assert_eq!(svc.snapshot().preprocess.compiles, 0);
        svc.submit_blocking(JobSpec::new(Dataset::Tiny, "bfs")).unwrap();
        let snap = svc.snapshot();
        assert_eq!(snap.preprocess.compiles, 1, "one cold compile served the job");
        assert!(snap.preprocess.total.max_ns > 0);
        // The bare Metrics snapshot stays zeroed — the session store is
        // the single source of truth for compile timing.
        assert_eq!(svc.metrics.snapshot().preprocess.compiles, 0);
    }

    #[test]
    fn pagerank_jobspec_submits() {
        let svc = tiny_service(2);
        let res = svc
            .submit_blocking(JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(3))
            .unwrap();
        assert_eq!(res.report.algorithm, "pagerank");
    }

    #[test]
    fn unknown_algorithm_fails_the_job_not_the_service() {
        let svc = tiny_service(1);
        let err = svc
            .submit_blocking(JobSpec::new(Dataset::Tiny, "nope"))
            .unwrap_err();
        assert!(err.to_string().contains("unknown algorithm"), "{err}");
        // Service keeps serving afterwards.
        svc.submit_blocking(JobSpec::new(Dataset::Tiny, "wcc")).unwrap();
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.jobs_failed, 1);
        assert_eq!(snap.jobs_completed, 1);
    }

    #[test]
    fn concurrent_jobs_share_preprocessing_cache() {
        let svc = tiny_service(4);
        let pending = svc
            .submit_batch((0..8u32).map(|i| JobSpec::new(Dataset::Tiny, "bfs").with_source(i)))
            .unwrap();
        for p in pending {
            p.wait().unwrap();
        }
        assert_eq!(svc.metrics.snapshot().jobs_completed, 8);
        // Exactly one Alg.-1 run across all 4 workers.
        assert_eq!(svc.session().artifacts().stats().misses, 1);
    }

    #[test]
    fn mixed_algorithms() {
        let svc = tiny_service(2);
        let d = Dataset::Tiny;
        svc.submit_blocking(JobSpec::new(d, "pagerank").with_iterations(3)).unwrap();
        svc.submit_blocking(JobSpec::new(d, "wcc")).unwrap();
        svc.submit_blocking(JobSpec::new(d, "sssp").with_source(1)).unwrap();
        assert_eq!(svc.metrics.snapshot().jobs_completed, 3);
    }

    #[test]
    fn parallel_workers_serve_identical_results() {
        let seq = tiny_service(2);
        let par = Service::spawn(ServiceConfig {
            workers: 2,
            parallelism: 4,
            ..ServiceConfig::default()
        })
        .unwrap();
        let job = || JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(4);
        let a = seq.submit_blocking(job()).unwrap().report;
        let b = par.submit_blocking(job()).unwrap().report;
        assert_eq!(
            a.run.as_ref().unwrap().values,
            b.run.as_ref().unwrap().values
        );
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.exec_time_ns, b.exec_time_ns);
    }

    #[test]
    fn apply_delta_patches_served_artifacts_and_counts() {
        let svc = tiny_service(2);
        let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(0);
        svc.submit_blocking(spec.clone()).unwrap();

        let g = svc.session().load_graph(&spec).unwrap();
        let e = g.edges[0];
        let batch = crate::graph::DeltaBatch::new(
            g.num_vertices,
            vec![crate::graph::EdgeDelta::remove(e.src, e.dst)],
        )
        .unwrap();
        let report = svc.apply_delta(&spec, &batch).unwrap();
        assert_eq!(report.patched_artifacts, 1);

        // Served from the patched plan — no recompile — and bit-identical
        // to a cold compile of the mutated graph.
        let after = svc.submit_blocking(spec.clone()).unwrap().report;
        assert_eq!(svc.session().artifacts().stats().misses, 1);
        let cold = Session::with_defaults()
            .unwrap()
            .run_on(&spec, &svc.session().load_graph(&spec).unwrap())
            .unwrap();
        assert_eq!(after.counts, cold.counts);
        assert_eq!(after.exec_time_ns, cold.exec_time_ns);

        let snap = svc.metrics.snapshot();
        assert_eq!(snap.delta_batches, 1);
        assert_eq!(snap.delta_avoided_recompiles, 1);
        assert!(snap.delta_dirty_partitions >= 1);
    }

    #[test]
    fn shutdown_joins_workers() {
        let svc = tiny_service(2);
        svc.submit_blocking(JobSpec::new(Dataset::Tiny, "wcc")).unwrap();
        drop(svc); // must not hang
    }
}
