//! Event accounting: the simulator counts hardware events; this module
//! converts them to joules via the Table 3 constants. Keeping *counts*
//! (not joules) in the hot loop makes the accounting exact, additive, and
//! cheap (integer adds only).

use super::params::CostParams;

/// Raw hardware event counts accumulated during a simulation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EventCounts {
    /// ReRAM cells read (bit-reads) during MVM operations.
    pub read_bits: u64,
    /// ReRAM cells written (SET/RESET) during crossbar (re)configuration.
    pub write_bits: u64,
    /// Sense-amplifier samples (one per read bitline).
    pub sense_ops: u64,
    /// SRAM buffer accesses (input/output FIFO entries).
    pub sram_accesses: u64,
    /// ADC conversions.
    pub adc_ops: u64,
    /// ALU reduce/apply operations.
    pub alu_ops: u64,
    /// Off-chip main-memory accesses (ST/CT fetches, write-backs).
    pub main_mem_accesses: u64,
    /// In-situ MVM operations issued (one per subgraph processed).
    pub mvm_ops: u64,
    /// Crossbar reconfigurations (dynamic-engine pattern swaps).
    pub reconfigs: u64,
}

impl EventCounts {
    pub fn add(&mut self, other: &EventCounts) {
        self.read_bits += other.read_bits;
        self.write_bits += other.write_bits;
        self.sense_ops += other.sense_ops;
        self.sram_accesses += other.sram_accesses;
        self.adc_ops += other.adc_ops;
        self.alu_ops += other.alu_ops;
        self.main_mem_accesses += other.main_mem_accesses;
        self.mvm_ops += other.mvm_ops;
        self.reconfigs += other.reconfigs;
    }

    /// Subtract a baseline (e.g. initialization events from a run total
    /// so runtime counts exclude one-time configuration). The baseline
    /// must be componentwise `<= self`.
    pub fn subtract(&mut self, other: &EventCounts) {
        self.read_bits -= other.read_bits;
        self.write_bits -= other.write_bits;
        self.sense_ops -= other.sense_ops;
        self.sram_accesses -= other.sram_accesses;
        self.adc_ops -= other.adc_ops;
        self.alu_ops -= other.alu_ops;
        self.main_mem_accesses -= other.main_mem_accesses;
        self.mvm_ops -= other.mvm_ops;
        self.reconfigs -= other.reconfigs;
    }

    /// Convert to an energy breakdown in joules.
    pub fn energy(&self, p: &CostParams) -> EnergyBreakdown {
        const PJ: f64 = 1e-12;
        EnergyBreakdown {
            reram_read_j: self.read_bits as f64 * p.e_read_bit_pj * PJ
                + self.sense_ops as f64 * p.e_sense_pj * PJ,
            reram_write_j: self.write_bits as f64 * p.e_write_bit_pj * PJ,
            sram_j: self.sram_accesses as f64 * p.e_sram_pj * PJ,
            adc_j: self.adc_ops as f64 * p.e_adc_pj * PJ,
            alu_j: self.alu_ops as f64 * p.e_alu_pj * PJ,
            main_mem_j: self.main_mem_accesses as f64 * p.e_main_mem_pj * PJ,
        }
    }
}

/// Energy per component, joules.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub reram_read_j: f64,
    pub reram_write_j: f64,
    pub sram_j: f64,
    pub adc_j: f64,
    pub alu_j: f64,
    pub main_mem_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.reram_read_j
            + self.reram_write_j
            + self.sram_j
            + self.adc_j
            + self.alu_j
            + self.main_mem_j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_is_componentwise() {
        let mut a = EventCounts { read_bits: 1, write_bits: 2, ..Default::default() };
        let b = EventCounts { read_bits: 10, adc_ops: 3, ..Default::default() };
        a.add(&b);
        assert_eq!(a.read_bits, 11);
        assert_eq!(a.write_bits, 2);
        assert_eq!(a.adc_ops, 3);
    }

    #[test]
    fn energy_uses_table3_constants() {
        let p = CostParams::default();
        let c = EventCounts {
            read_bits: 1000,
            write_bits: 100,
            sense_ops: 0,
            sram_accesses: 10,
            adc_ops: 50,
            ..Default::default()
        };
        let e = c.energy(&p);
        assert!((e.reram_read_j - 1000.0 * 1.1e-12).abs() < 1e-18);
        assert!((e.reram_write_j - 100.0 * 4.9e-12).abs() < 1e-18);
        assert!((e.sram_j - 10.0 * 29.0e-12).abs() < 1e-18);
        assert!((e.adc_j - 50.0 * 2.0e-12).abs() < 1e-18);
        assert!(e.total_j() > 0.0);
    }

    #[test]
    fn zero_counts_zero_energy() {
        let e = EventCounts::default().energy(&CostParams::default());
        assert_eq!(e.total_j(), 0.0);
    }

    #[test]
    fn writes_cost_more_than_reads_per_bit() {
        let p = CostParams::default();
        let reads = EventCounts { read_bits: 1, ..Default::default() }.energy(&p);
        let writes = EventCounts { write_bits: 1, ..Default::default() }.energy(&p);
        assert!(writes.total_j() > 4.0 * reads.total_j());
    }
}
