//! Circuit lifetime model (paper §IV.D).
//!
//! Lifetime = (E / w) × T, where E is cell endurance (~1e8 cycles), w is
//! the maximum number of write operations any single cell accumulates
//! during one execution of the workload, and T is the execution interval
//! (the paper uses one Wiki-Vote run per hour). Engines whose crossbar
//! reaches the endurance limit are retired; static engines are excluded
//! because they are written exactly once at initialization.

use crate::util::fmt;

/// Lifetime in seconds for a given per-execution max cell-write count.
pub fn lifetime_seconds(endurance_cycles: f64, max_writes_per_exec: u64, interval_s: f64) -> f64 {
    if max_writes_per_exec == 0 {
        return f64::INFINITY; // write-free design never wears out
    }
    endurance_cycles / max_writes_per_exec as f64 * interval_s
}

/// Lifetime comparison row for one design.
#[derive(Debug, Clone)]
pub struct LifetimeReport {
    pub design: String,
    /// Max writes any single cell sees in one execution.
    pub max_cell_writes: u64,
    /// Total ReRAM write-bits of one execution (context).
    pub total_write_bits: u64,
    pub lifetime_s: f64,
}

impl LifetimeReport {
    pub fn new(
        design: impl Into<String>,
        max_cell_writes: u64,
        total_write_bits: u64,
        endurance_cycles: f64,
        interval_s: f64,
    ) -> Self {
        Self {
            design: design.into(),
            max_cell_writes,
            total_write_bits,
            lifetime_s: lifetime_seconds(endurance_cycles, max_cell_writes, interval_s),
        }
    }

    pub fn lifetime_human(&self) -> String {
        if self.lifetime_s.is_infinite() {
            "∞ (write-free)".to_string()
        } else {
            fmt::time(self.lifetime_s)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_exceeds_ten_years() {
        // §IV.D: E = 1e8, hourly execution; if a cell sees ≤ ~1100 writes
        // per run the design lasts > 10 years.
        let ten_years = 10.0 * 365.25 * 24.0 * 3600.0;
        assert!(lifetime_seconds(1e8, 1_000, 3600.0) > ten_years);
    }

    #[test]
    fn lifetime_inverse_in_writes() {
        let a = lifetime_seconds(1e8, 100, 3600.0);
        let b = lifetime_seconds(1e8, 200, 3600.0);
        assert!((a / b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_writes_is_infinite() {
        assert!(lifetime_seconds(1e8, 0, 3600.0).is_infinite());
    }

    #[test]
    fn report_formats() {
        let r = LifetimeReport::new("Proposed", 50, 1_000, 1e8, 3600.0);
        assert!(r.lifetime_human().contains("years"));
        let w = LifetimeReport::new("TARe", 0, 0, 1e8, 3600.0);
        assert!(w.lifetime_human().contains("write-free"));
    }
}
