//! Hardware cost model: Table 3 latency/energy constants, event
//! accounting, and the endurance/lifetime model of §IV.D.

pub mod energy;
pub mod lifetime;
pub mod params;
pub mod timing;

pub use energy::{EnergyBreakdown, EventCounts};
pub use lifetime::{lifetime_seconds, LifetimeReport};
pub use params::CostParams;
