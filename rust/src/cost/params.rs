//! Simulation specifications (paper Table 3, 32 nm node).
//!
//! ReRAM numbers come from NVSim, buffer numbers from CACTI-6.5, the ADC
//! from Kull et al. [32]. The paper does not publish main-memory numbers;
//! we use representative DDR-class constants (documented in DESIGN.md
//! §Substitutions) — they only matter for the *relative* ranking of TARe,
//! whose design trades ReRAM writes for off-chip reads.

/// All latencies in nanoseconds, energies in picojoules (converted to
/// joules/seconds at report time).
#[derive(Debug, Clone, PartialEq)]
pub struct CostParams {
    // --- 4x4 ReRAM crossbar, 32 KB, V_SET = V_RESET = 2 V ---
    /// Per-bit read.
    pub t_read_bit_ns: f64,
    pub e_read_bit_pj: f64,
    /// Per-bit write (SET/RESET).
    pub t_write_bit_ns: f64,
    pub e_write_bit_pj: f64,
    /// Sense amplifier, per bitline sample.
    pub t_sense_ns: f64,
    pub e_sense_pj: f64,
    // --- SRAM buffer, 32 KB ---
    pub t_sram_ns: f64,
    pub e_sram_pj: f64,
    // --- ADC, 8-bit resolution ---
    pub t_adc_ns: f64,
    pub e_adc_pj: f64,
    // --- main memory (off-chip), per 64 B access ---
    pub t_main_mem_ns: f64,
    pub e_main_mem_pj: f64,
    // --- lightweight ALU (reduce/apply), per op ---
    pub t_alu_ns: f64,
    pub e_alu_pj: f64,
    /// ReRAM cell endurance in write cycles (paper §IV.D: ~1e8 [23]).
    pub endurance_cycles: f64,
    /// ADCs shared across bitlines: conversions per crossbar read that
    /// must serialize (C bitlines / adc_share ADCs).
    pub adc_share: u32,
}

impl Default for CostParams {
    /// Paper Table 3 values.
    fn default() -> Self {
        Self {
            t_read_bit_ns: 1.3,
            e_read_bit_pj: 1.1,
            t_write_bit_ns: 20.2,
            e_write_bit_pj: 4.9,
            t_sense_ns: 1.0,
            e_sense_pj: 1.0,
            t_sram_ns: 0.31,
            e_sram_pj: 29.0,
            t_adc_ns: 1.0,
            e_adc_pj: 2.0,
            // DDR4-class: ~50 ns random access, ~10 pJ/bit * 512 bit line.
            t_main_mem_ns: 50.0,
            e_main_mem_pj: 640.0,
            // Small fixed-function ALU at 32 nm.
            t_alu_ns: 0.5,
            e_alu_pj: 0.6,
            endurance_cycles: 1.0e8,
            adc_share: 1,
        }
    }
}

impl CostParams {
    /// Sanity bound used by property tests: every constant positive.
    pub fn is_valid(&self) -> bool {
        [
            self.t_read_bit_ns,
            self.e_read_bit_pj,
            self.t_write_bit_ns,
            self.e_write_bit_pj,
            self.t_sense_ns,
            self.e_sense_pj,
            self.t_sram_ns,
            self.e_sram_pj,
            self.t_adc_ns,
            self.e_adc_pj,
            self.t_main_mem_ns,
            self.e_main_mem_pj,
            self.t_alu_ns,
            self.e_alu_pj,
            self.endurance_cycles,
        ]
        .iter()
        .all(|&v| v > 0.0)
            && self.adc_share >= 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table3() {
        let p = CostParams::default();
        assert_eq!(p.t_read_bit_ns, 1.3);
        assert_eq!(p.e_read_bit_pj, 1.1);
        assert_eq!(p.t_write_bit_ns, 20.2);
        assert_eq!(p.e_write_bit_pj, 4.9);
        assert_eq!(p.t_sense_ns, 1.0);
        assert_eq!(p.e_sense_pj, 1.0);
        assert_eq!(p.t_sram_ns, 0.31);
        assert_eq!(p.e_sram_pj, 29.0);
        assert_eq!(p.t_adc_ns, 1.0);
        assert_eq!(p.e_adc_pj, 2.0);
        assert_eq!(p.endurance_cycles, 1.0e8);
    }

    #[test]
    fn write_dominates_read() {
        // The premise of the whole paper: ReRAM writes are ~an order of
        // magnitude slower and costlier than reads.
        let p = CostParams::default();
        assert!(p.t_write_bit_ns > 10.0 * p.t_read_bit_ns);
        assert!(p.e_write_bit_pj > 4.0 * p.e_read_bit_pj);
    }

    #[test]
    fn default_is_valid() {
        assert!(CostParams::default().is_valid());
    }
}
