//! Per-operation latency model.
//!
//! A graph-engine operation on one subgraph is either:
//!
//! * an **MVM** — drive the active wordlines (in-situ, one crossbar
//!   read), sample C bitlines (sense amps in parallel), digitize through
//!   the shared ADC (serialized by the share factor), and stream vertex
//!   data through the input/output SRAM FIFOs; or
//! * a **reconfiguration + MVM** — a dynamic engine first serially writes
//!   the toggled ReRAM cells (the dominant cost: 20.2 ns/bit), then runs
//!   the MVM.
//!
//! Engines operate in parallel (Alg. 2 `parallelforeach`); within an
//! engine, queued operations serialize. The scheduler sums per-engine
//! latencies and takes the max per iteration batch.

use super::params::CostParams;

/// Latency of one in-situ MVM on a crossbar of size `c` with
/// `active_rows` driven wordlines.
#[inline]
pub fn mvm_latency_ns(p: &CostParams, c: u32, _active_rows: u32) -> f64 {
    // Crossbar read is analog-parallel: one bit-read time regardless of
    // rows; bitlines sense in parallel; ADC conversions serialize by the
    // share factor; input + output FIFO accesses bracket the op.
    let adc_serial = (c as f64 / p.adc_share as f64).ceil();
    p.t_read_bit_ns + p.t_sense_ns + adc_serial * p.t_adc_ns + 2.0 * p.t_sram_ns
}

/// Latency of reprogramming `toggled_bits` ReRAM cells (serial per-bit
/// writes — ReRAM crossbars write one wordline at a time, and Table 3 is
/// per-bit).
#[inline]
pub fn reconfig_latency_ns(p: &CostParams, toggled_bits: u32) -> f64 {
    toggled_bits as f64 * p.t_write_bit_ns
}

/// Latency of the ALU reduce/apply over `c` destination vertices.
#[inline]
pub fn reduce_latency_ns(p: &CostParams, c: u32) -> f64 {
    c as f64 * p.t_alu_ns
}

/// Latency of one off-chip main-memory access.
#[inline]
pub fn main_mem_latency_ns(p: &CostParams) -> f64 {
    p.t_main_mem_ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mvm_latency_is_a_few_ns() {
        let p = CostParams::default();
        let t = mvm_latency_ns(&p, 4, 4);
        // 1.3 + 1.0 + 4*1.0 + 2*0.31 = 6.92 ns
        assert!((t - 6.92).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn adc_sharing_reduces_serialization() {
        let mut p = CostParams::default();
        let t1 = mvm_latency_ns(&p, 8, 8);
        p.adc_share = 4;
        let t4 = mvm_latency_ns(&p, 8, 8);
        assert!(t4 < t1);
    }

    #[test]
    fn reconfig_dominates_mvm() {
        // A single-bit reconfiguration (20.2 ns) already outweighs a full
        // 4x4 MVM (~7 ns) — the quantitative core of the paper's premise.
        let p = CostParams::default();
        assert!(reconfig_latency_ns(&p, 1) > 2.0 * mvm_latency_ns(&p, 4, 4));
    }

    #[test]
    fn reconfig_scales_linearly() {
        let p = CostParams::default();
        assert_eq!(reconfig_latency_ns(&p, 0), 0.0);
        assert!((reconfig_latency_ns(&p, 16) - 16.0 * 20.2).abs() < 1e-9);
    }

    #[test]
    fn reduce_scales_with_c() {
        let p = CostParams::default();
        assert!((reduce_latency_ns(&p, 4) - 2.0).abs() < 1e-12);
    }
}
