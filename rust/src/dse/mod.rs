//! Design-space exploration (paper Fig. 2 ③ / Fig. 6): sweeps over the
//! architecture parameters and selection of the best static/dynamic
//! engine split for a given application.

pub mod optimizer;
pub mod sweep;

pub use optimizer::{candidate_splits, find_best_static_split, find_best_static_split_with};
pub use sweep::{
    crossbar_sweep, policy_sweep, static_engine_sweep, static_engine_sweep_with, SweepPoint,
};
