//! "A method to find the best number of static graph engines for a given
//! application" (paper conclusion): sweep candidate splits and return the
//! fastest.

use anyhow::Result;

use crate::accel::ArchConfig;
use crate::algo::traits::VertexProgram;
use crate::cost::CostParams;
use crate::graph::Coo;

use super::sweep::{static_engine_sweep, SweepPoint};

/// Best static/dynamic split for `program` on `g`. Candidates default to
/// every power-of-two-ish split plus the paper's N = C² heuristic.
pub fn find_best_static_split(
    g: &Coo,
    base: &ArchConfig,
    params: &CostParams,
    program: &dyn VertexProgram,
    candidates: Option<&[u32]>,
) -> Result<(u32, Vec<SweepPoint>)> {
    let t = base.total_engines;
    let default: Vec<u32> = {
        let mut v = vec![0u32];
        let mut n = 2;
        while n < t {
            v.push(n);
            n *= 2;
        }
        // The paper's heuristic: at least C² static engines so every
        // single-edge pattern is static (§IV.B).
        let c2 = (base.crossbar_size * base.crossbar_size) as u32;
        if c2 < t && !v.contains(&c2) {
            v.push(c2);
        }
        if t >= 1 {
            v.push(t - 1);
        }
        v.sort_unstable();
        v.dedup();
        v
    };
    let ns = candidates.unwrap_or(&default);
    let points = static_engine_sweep(g, base, params, program, ns)?;
    let best = points
        .iter()
        .max_by(|a, b| a.speedup.total_cmp(&b.speedup))
        .map(|p| p.x)
        .unwrap_or(0);
    Ok((best, points))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Bfs;
    use crate::graph::datasets::Dataset;

    #[test]
    fn finds_a_nontrivial_split() {
        let g = Dataset::Tiny.load().unwrap();
        let (best, points) = find_best_static_split(
            &g,
            &ArchConfig::default(),
            &CostParams::default(),
            &Bfs::new(0),
            None,
        )
        .unwrap();
        assert!(!points.is_empty());
        // All-dynamic should never be optimal on a power-law graph.
        assert!(best > 0, "best split was all-dynamic");
        // The winning point carries the max speedup.
        let best_point = points.iter().find(|p| p.x == best).unwrap();
        for p in &points {
            assert!(best_point.speedup >= p.speedup - 1e-12);
        }
    }

    #[test]
    fn respects_explicit_candidates() {
        let g = Dataset::Tiny.load().unwrap();
        let (best, points) = find_best_static_split(
            &g,
            &ArchConfig::default(),
            &CostParams::default(),
            &Bfs::new(0),
            Some(&[4, 16]),
        )
        .unwrap();
        assert_eq!(points.len(), 2);
        assert!(best == 4 || best == 16);
    }
}
