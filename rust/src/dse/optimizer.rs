//! "A method to find the best number of static graph engines for a given
//! application" (paper conclusion): sweep candidate splits and return the
//! fastest.

use anyhow::Result;

use crate::accel::{ArchConfig, Preprocessed};
use crate::algo::traits::VertexProgram;
use crate::cost::CostParams;
use crate::graph::Coo;

use super::sweep::{static_engine_sweep, static_engine_sweep_with, SweepPoint};

/// Default candidate splits: every power-of-two below T, the paper's
/// N = C² heuristic (at least C² static engines so every single-edge
/// pattern is static, §IV.B), all-dynamic, and T−1.
pub fn candidate_splits(base: &ArchConfig) -> Vec<u32> {
    let t = base.total_engines;
    let mut v = vec![0u32];
    let mut n = 2;
    while n < t {
        v.push(n);
        n *= 2;
    }
    let c2 = (base.crossbar_size * base.crossbar_size) as u32;
    if c2 < t && !v.contains(&c2) {
        v.push(c2);
    }
    if t >= 1 {
        v.push(t - 1);
    }
    v.sort_unstable();
    v.dedup();
    v
}

fn pick_best(points: &[SweepPoint]) -> u32 {
    points
        .iter()
        .max_by(|a, b| a.speedup.total_cmp(&b.speedup))
        .map(|p| p.x)
        .unwrap_or(0)
}

/// Best static/dynamic split for `program` on `g`. Candidates default to
/// [`candidate_splits`].
pub fn find_best_static_split(
    g: &Coo,
    base: &ArchConfig,
    params: &CostParams,
    program: &dyn VertexProgram,
    candidates: Option<&[u32]>,
) -> Result<(u32, Vec<SweepPoint>)> {
    let default = candidate_splits(base);
    let ns = candidates.unwrap_or(&default);
    let points = static_engine_sweep(g, base, params, program, ns)?;
    Ok((pick_best(&points), points))
}

/// Like [`find_best_static_split`] but over an existing Alg.-1 output
/// (no graph load or re-partition; `pre.ct` is scratch).
pub fn find_best_static_split_with(
    pre: &mut Preprocessed,
    base: &ArchConfig,
    params: &CostParams,
    program: &dyn VertexProgram,
    candidates: Option<&[u32]>,
) -> Result<(u32, Vec<SweepPoint>)> {
    let default = candidate_splits(base);
    let ns = candidates.unwrap_or(&default);
    let points = static_engine_sweep_with(pre, base, params, program, ns)?;
    Ok((pick_best(&points), points))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Bfs;
    use crate::graph::datasets::Dataset;

    #[test]
    fn finds_a_nontrivial_split() {
        let g = Dataset::Tiny.load().unwrap();
        let (best, points) = find_best_static_split(
            &g,
            &ArchConfig::default(),
            &CostParams::default(),
            &Bfs::new(0),
            None,
        )
        .unwrap();
        assert!(!points.is_empty());
        // All-dynamic should never be optimal on a power-law graph.
        assert!(best > 0, "best split was all-dynamic");
        // The winning point carries the max speedup.
        let best_point = points.iter().find(|p| p.x == best).unwrap();
        for p in &points {
            assert!(best_point.speedup >= p.speedup - 1e-12);
        }
    }

    #[test]
    fn respects_explicit_candidates() {
        let g = Dataset::Tiny.load().unwrap();
        let (best, points) = find_best_static_split(
            &g,
            &ArchConfig::default(),
            &CostParams::default(),
            &Bfs::new(0),
            Some(&[4, 16]),
        )
        .unwrap();
        assert_eq!(points.len(), 2);
        assert!(best == 4 || best == 16);
    }
}
