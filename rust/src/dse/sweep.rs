//! Parameter sweeps: static-engine allocation (Fig. 6), crossbar size,
//! and replacement-policy ablations.

use anyhow::Result;

use crate::accel::{Accelerator, ArchConfig, PolicyKind, Preprocessed};
use crate::algo::traits::VertexProgram;
use crate::cost::CostParams;
use crate::graph::Coo;
use crate::sched::executor::NativeExecutor;

/// One sweep sample.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Value of the swept parameter.
    pub x: u32,
    pub exec_time_ns: f64,
    pub energy_j: f64,
    pub write_bits: u64,
    pub static_hit_rate: f64,
    /// Speedup relative to the sweep's baseline point.
    pub speedup: f64,
}

/// Fig. 6: sweep the number of static engines with T fixed, normalized
/// to the all-dynamic configuration (N = 0).
pub fn static_engine_sweep(
    g: &Coo,
    base: &ArchConfig,
    params: &CostParams,
    program: &dyn VertexProgram,
    ns: &[u32],
) -> Result<Vec<SweepPoint>> {
    // Partition, ranking and the subgraph table are independent of the
    // static/dynamic split: run Alg. 1 once and rebuild only the
    // N-dependent config table per candidate.
    let mut pre = Accelerator::new(base.clone(), params.clone())
        .preprocess(g, program.needs_weights())?;
    static_engine_sweep_with(&mut pre, base, params, program, ns)
}

/// Like [`static_engine_sweep`] but over an existing Alg.-1 output
/// (e.g. a scratch copy of a session's cached artifact — no graph
/// re-load or re-partition). Per candidate N only the N-dependent pieces
/// are rebuilt: `pre.ct` and the execution plan's static-slot section
/// (`ExecutionPlan::rebuild_static_slots`) — op records, gather data and
/// weights are split-independent and stay as compiled. Both are left at
/// the last swept configuration.
pub fn static_engine_sweep_with(
    pre: &mut Preprocessed,
    base: &ArchConfig,
    params: &CostParams,
    program: &dyn VertexProgram,
    ns: &[u32],
) -> Result<Vec<SweepPoint>> {
    let mut points = Vec::with_capacity(ns.len());
    let mut baseline_ns = None;
    // Always measure N = 0 first for normalization.
    let mut order: Vec<u32> = Vec::new();
    if !ns.contains(&0) {
        order.push(0);
    }
    order.extend_from_slice(ns);
    let mut base_time = 0f64;
    for &n in &order {
        let mut cfg = base.clone();
        cfg.static_engines = n;
        cfg.validate()?;
        let acc = Accelerator::new(cfg, params.clone());
        pre.ct = acc.build_config_table(&pre.ranking);
        pre.plan.rebuild_static_slots(&pre.ct, &acc.config)?;
        let report = acc.run(pre, program, &mut NativeExecutor)?;
        if baseline_ns.is_none() {
            baseline_ns = Some(n);
            base_time = report.exec_time_ns;
        }
        if n == 0 {
            base_time = report.exec_time_ns;
        }
        if ns.contains(&n) {
            points.push(SweepPoint {
                x: n,
                exec_time_ns: report.exec_time_ns,
                energy_j: report.energy_j(),
                write_bits: report.counts.write_bits,
                static_hit_rate: report.static_hit_rate,
                speedup: 0.0, // filled below
            });
        }
    }
    for p in &mut points {
        p.speedup = base_time / p.exec_time_ns;
    }
    Ok(points)
}

/// Crossbar-size ablation (the conclusion's "performs better with
/// smaller, cost-effective crossbars, e.g. 4×4 or 8×8").
pub fn crossbar_sweep(
    g: &Coo,
    base: &ArchConfig,
    params: &CostParams,
    program: &dyn VertexProgram,
    sizes: &[usize],
) -> Result<Vec<SweepPoint>> {
    let mut points = Vec::new();
    let mut base_time = None;
    for &c in sizes {
        let mut cfg = base.clone();
        cfg.crossbar_size = c;
        let acc = Accelerator::new(cfg, params.clone());
        let report = acc.simulate(g, program, &mut NativeExecutor)?;
        let bt = *base_time.get_or_insert(report.exec_time_ns);
        points.push(SweepPoint {
            x: c as u32,
            exec_time_ns: report.exec_time_ns,
            energy_j: report.energy_j(),
            write_bits: report.counts.write_bits,
            static_hit_rate: report.static_hit_rate,
            speedup: bt / report.exec_time_ns,
        });
    }
    Ok(points)
}

/// Replacement-policy ablation over the dynamic engines.
pub fn policy_sweep(
    g: &Coo,
    base: &ArchConfig,
    params: &CostParams,
    program: &dyn VertexProgram,
) -> Result<Vec<(PolicyKind, SweepPoint)>> {
    let kinds = [
        PolicyKind::Lru,
        PolicyKind::RoundRobin,
        PolicyKind::Lfu,
        PolicyKind::Random,
    ];
    let mut out = Vec::new();
    let mut base_time = None;
    for kind in kinds {
        let mut cfg = base.clone();
        cfg.policy = kind;
        let acc = Accelerator::new(cfg, params.clone());
        let report = acc.simulate(g, program, &mut NativeExecutor)?;
        let bt = *base_time.get_or_insert(report.exec_time_ns);
        out.push((
            kind,
            SweepPoint {
                x: 0,
                exec_time_ns: report.exec_time_ns,
                energy_j: report.energy_j(),
                write_bits: report.counts.write_bits,
                static_hit_rate: report.static_hit_rate,
                speedup: bt / report.exec_time_ns,
            },
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Bfs;
    use crate::graph::datasets::Dataset;

    #[test]
    fn static_sweep_humps() {
        let g = Dataset::Tiny.load().unwrap();
        let pts = static_engine_sweep(
            &g,
            &ArchConfig::default(),
            &CostParams::default(),
            &Bfs::new(0),
            &[0, 8, 16, 24, 31],
        )
        .unwrap();
        assert_eq!(pts.len(), 5);
        // N = 0 is the normalization point.
        assert!((pts[0].speedup - 1.0).abs() < 1e-9);
        // Some allocation beats all-dynamic...
        let best = pts.iter().map(|p| p.speedup).fold(0.0, f64::max);
        assert!(best > 1.0, "best speedup {best}");
        // ...and hit rate grows monotonically with N.
        for w in pts.windows(2) {
            assert!(w[1].static_hit_rate >= w[0].static_hit_rate - 1e-9);
        }
    }

    #[test]
    fn crossbar_sweep_runs() {
        let g = Dataset::Tiny.load().unwrap();
        let pts = crossbar_sweep(
            &g,
            &ArchConfig::default(),
            &CostParams::default(),
            &Bfs::new(0),
            &[2, 4, 8],
        )
        .unwrap();
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.energy_j > 0.0));
    }

    #[test]
    fn policy_sweep_covers_all_policies() {
        let g = Dataset::Tiny.load().unwrap();
        let out =
            policy_sweep(&g, &ArchConfig::default(), &CostParams::default(), &Bfs::new(0))
                .unwrap();
        assert_eq!(out.len(), 4);
        // All policies produce identical hit-rate-independent numerics;
        // write volume may differ but stays positive ordering-sane.
        for (_, p) in &out {
            assert!(p.exec_time_ns > 0.0);
        }
    }
}
