//! Single ReRAM crossbar: holds one C×C binary pattern, tracks per-cell
//! write wear (for the §IV.D lifetime analysis).

use crate::pattern::Pattern;

#[derive(Debug, Clone)]
pub struct Crossbar {
    pub c: usize,
    /// Pattern currently programmed into the cells (EMPTY = all RESET).
    pub pattern: Pattern,
    /// Per-cell cumulative write count (length c*c) — wear tracking.
    cell_writes: Vec<u32>,
    /// Total bit-writes this crossbar has absorbed.
    pub total_write_bits: u64,
    /// Number of (re)configurations.
    pub config_count: u64,
}

impl Crossbar {
    pub fn new(c: usize) -> Self {
        Self {
            c,
            pattern: Pattern::EMPTY,
            cell_writes: vec![0; c * c],
            total_write_bits: 0,
            config_count: 0,
        }
    }

    /// Reprogram to `target`. Only toggled cells are written (SET new
    /// edges, RESET removed ones). Returns the number of bit-writes.
    pub fn configure(&mut self, target: Pattern) -> u32 {
        let toggled = target.0 ^ self.pattern.0;
        let n = toggled.count_ones();
        if n > 0 {
            let mut bits = toggled;
            while bits != 0 {
                let cell = bits.trailing_zeros() as usize;
                debug_assert!(cell < self.cell_writes.len(), "pattern exceeds crossbar");
                self.cell_writes[cell] += 1;
                bits &= bits - 1;
            }
            self.total_write_bits += n as u64;
            self.pattern = target;
        }
        self.config_count += 1;
        n
    }

    /// Worst per-cell wear (the `w` of the lifetime formula).
    pub fn max_cell_writes(&self) -> u32 {
        self.cell_writes.iter().copied().max().unwrap_or(0)
    }

    /// True once any cell exceeded the endurance budget — the paper
    /// retires such engines ("graph engines are not used once a crossbar
    /// reaches maximum writes").
    pub fn worn_out(&self, endurance: f64) -> bool {
        self.max_cell_writes() as f64 >= endurance
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configure_writes_only_toggled_cells() {
        let mut cb = Crossbar::new(4);
        let a = Pattern(0b0011);
        let b = Pattern(0b0110);
        assert_eq!(cb.configure(a), 2); // from empty: 2 SETs
        assert_eq!(cb.configure(b), 2); // toggle bits 0 and 2
        assert_eq!(cb.configure(b), 0); // no-op
        assert_eq!(cb.total_write_bits, 4);
        assert_eq!(cb.config_count, 3);
        assert_eq!(cb.pattern, b);
    }

    #[test]
    fn per_cell_wear_tracks_toggles() {
        let mut cb = Crossbar::new(2);
        let a = Pattern(0b01);
        let b = Pattern(0b10);
        for _ in 0..5 {
            cb.configure(a);
            cb.configure(b);
        }
        // Cells 0 and 1 each toggled ~10 times.
        assert_eq!(cb.max_cell_writes(), 10);
        assert_eq!(cb.total_write_bits, 19); // first config writes 1 bit
    }

    #[test]
    fn wear_out_threshold() {
        let mut cb = Crossbar::new(2);
        cb.configure(Pattern(1));
        assert!(!cb.worn_out(2.0));
        cb.configure(Pattern(0));
        cb.configure(Pattern(1));
        assert!(cb.worn_out(2.0));
    }

    #[test]
    fn fresh_crossbar_is_unworn() {
        let cb = Crossbar::new(4);
        assert_eq!(cb.max_cell_writes(), 0);
        assert!(!cb.worn_out(1.0));
    }
}
