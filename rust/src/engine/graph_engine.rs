//! Graph engine (paper Fig. 4): M crossbars sharing a driver, S/H stage,
//! ADC, FIFO buffers and a small ALU. Static engines are configured once
//! at initialization; dynamic engines are reconfigured at runtime by the
//! scheduler's replacement policy.
//!
//! Engines are also the unit of lane sharding for batch-parallel
//! execution (`sched::par`): every mutable field here — busy time, event
//! counters, crossbar contents and wear — is engine-local, so a whole
//! engine can move into a worker lane and replay its queued ops in
//! dispatch order, reproducing the sequential interpreter's per-engine
//! state bit for bit regardless of which thread owns the lane.

use crate::cost::{timing, CostParams, EventCounts};
use crate::pattern::Pattern;

use super::crossbar::Crossbar;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Configured once during initialization (Alg. 2 lines 6–8).
    Static,
    /// Reconfigured at runtime as needed (Alg. 2 lines 13–15).
    Dynamic,
}

#[derive(Debug, Clone)]
pub struct GraphEngine {
    pub id: u32,
    pub kind: EngineKind,
    pub crossbars: Vec<Crossbar>,
    /// Cumulative hardware events issued by this engine.
    pub counts: EventCounts,
    /// Busy time within the current scheduler iteration (ns); the
    /// scheduler resets it per batch and takes the max across engines.
    pub busy_ns: f64,
    /// Ops queued in the current iteration (for activity tracing).
    pub ops_this_iter: u32,
    /// Wear-out retirement flag (§IV.D).
    pub retired: bool,
}

impl GraphEngine {
    pub fn new(id: u32, kind: EngineKind, c: usize, m: u32) -> Self {
        Self {
            id,
            kind,
            crossbars: (0..m).map(|_| Crossbar::new(c)).collect(),
            counts: EventCounts::default(),
            busy_ns: 0.0,
            ops_this_iter: 0,
            retired: false,
        }
    }

    pub fn c(&self) -> usize {
        self.crossbars[0].c
    }

    /// Crossbar index currently holding `p`, if any.
    pub fn crossbar_with(&self, p: Pattern) -> Option<usize> {
        self.crossbars.iter().position(|cb| cb.pattern == p)
    }

    /// Configure crossbar `idx` with `p` (init-time for static engines,
    /// runtime for dynamic). Accounts write events + latency. Energy is
    /// per toggled *bit*; latency is per toggled *row* — the driver
    /// programs one wordline at a time with the row's bitlines in
    /// parallel (standard 1T1R write scheme).
    pub fn configure(&mut self, idx: usize, p: Pattern, params: &CostParams) -> f64 {
        let old = self.crossbars[idx].pattern;
        let toggled_rows = Pattern(old.0 ^ p.0).active_row_count(self.c());
        let toggled = self.crossbars[idx].configure(p);
        self.counts.write_bits += toggled as u64;
        self.counts.reconfigs += 1;
        // Pattern (COO cell) data arrives through the input buffer
        // (Fig. 4: Config_i via the input FIFO). The configuration table
        // is small (#patterns × ~8 B ≪ 32 KB) and lives in the on-chip
        // SRAM buffer, so no off-chip access is charged here.
        self.counts.sram_accesses += 2;
        let lat = timing::reconfig_latency_ns(params, toggled_rows.min(toggled));
        self.busy_ns += lat;
        lat
    }

    /// Issue one in-situ MVM against crossbar `idx` for a subgraph whose
    /// pattern has `active_rows` driven wordlines. `row_addr_shortcut`
    /// models the CT row-address optimization for single-edge patterns
    /// (§III.B): only the addressed row's cells are read.
    pub fn mvm(
        &mut self,
        idx: usize,
        active_rows: u32,
        row_addr_shortcut: bool,
        params: &CostParams,
    ) -> f64 {
        let read_rows = if row_addr_shortcut { 1 } else { active_rows.max(1) as u64 };
        let lat = timing::mvm_latency_ns(params, self.c() as u32, active_rows)
            + timing::reduce_latency_ns(params, self.c() as u32);
        self.mvm_precomputed(idx, read_rows, lat);
        lat
    }

    /// Hot-path variant: the scheduler precomputes `lat` once per run
    /// (it depends only on params and C), so the per-op work is pure
    /// counter arithmetic.
    #[inline]
    pub fn mvm_precomputed(&mut self, idx: usize, read_rows: u64, lat: f64) {
        let c = self.crossbars[0].c as u64;
        self.counts.read_bits += read_rows * c;
        self.counts.sense_ops += c;
        self.counts.adc_ops += c;
        // Vertex data in + processed vertex data out through the FIFOs.
        // (Main-memory traffic is accounted at the system level by the
        // scheduler: ST entries and vertex data stream in 64 B bursts.)
        self.counts.sram_accesses += 2;
        // Reduce/apply on the ALU for the C destination lanes.
        self.counts.alu_ops += c;
        self.counts.mvm_ops += 1;
        self.busy_ns += lat;
        self.ops_this_iter += 1;
        let _ = idx;
    }

    /// Reset per-iteration accounting (scheduler calls between batches).
    pub fn end_iteration(&mut self) -> (f64, u32) {
        let out = (self.busy_ns, self.ops_this_iter);
        self.busy_ns = 0.0;
        self.ops_this_iter = 0;
        out
    }

    /// Worst per-cell wear across this engine's crossbars.
    pub fn max_cell_writes(&self) -> u32 {
        self.crossbars.iter().map(|cb| cb.max_cell_writes()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> CostParams {
        CostParams::default()
    }

    #[test]
    fn mvm_accounts_reads_and_peripherals() {
        let mut e = GraphEngine::new(0, EngineKind::Static, 4, 1);
        let lat = e.mvm(0, 2, false, &params());
        assert!(lat > 0.0);
        assert_eq!(e.counts.read_bits, 8); // 2 active rows x 4 cells
        assert_eq!(e.counts.sense_ops, 4);
        assert_eq!(e.counts.adc_ops, 4);
        assert_eq!(e.counts.sram_accesses, 2);
        assert_eq!(e.counts.mvm_ops, 1);
        assert_eq!(e.counts.write_bits, 0); // MVM never writes ReRAM
        assert_eq!(e.counts.main_mem_accesses, 0); // system-level concern
    }

    #[test]
    fn row_addr_shortcut_reads_one_row() {
        let mut e = GraphEngine::new(0, EngineKind::Static, 4, 1);
        e.mvm(0, 1, true, &params());
        assert_eq!(e.counts.read_bits, 4);
    }

    #[test]
    fn configure_accounts_writes_and_latency() {
        let mut e = GraphEngine::new(1, EngineKind::Dynamic, 4, 2);
        // Pattern 0b111: 3 toggled bits, all in row 0 → energy 3 bits,
        // latency 1 row-write.
        let lat = e.configure(1, Pattern(0b111), &params());
        assert!((lat - 20.2).abs() < 1e-9);
        assert_eq!(e.counts.write_bits, 3);
        assert_eq!(e.counts.reconfigs, 1);
        assert_eq!(e.crossbar_with(Pattern(0b111)), Some(1));
        // Two rows touched → two row-writes.
        let lat2 = e.configure(0, Pattern(1 | 1 << 5), &params());
        assert!((lat2 - 2.0 * 20.2).abs() < 1e-9);
    }

    #[test]
    fn end_iteration_resets_busy() {
        let mut e = GraphEngine::new(0, EngineKind::Dynamic, 4, 1);
        e.mvm(0, 4, false, &params());
        let (busy, ops) = e.end_iteration();
        assert!(busy > 0.0);
        assert_eq!(ops, 1);
        assert_eq!(e.busy_ns, 0.0);
        assert_eq!(e.ops_this_iter, 0);
    }

    #[test]
    fn engine_wear_is_max_over_crossbars() {
        let mut e = GraphEngine::new(0, EngineKind::Dynamic, 2, 2);
        e.configure(0, Pattern(1), &params());
        e.configure(0, Pattern(0), &params());
        e.configure(0, Pattern(1), &params());
        e.configure(1, Pattern(2), &params());
        assert_eq!(e.max_cell_writes(), 3);
    }
}
