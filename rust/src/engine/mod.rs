//! Graph-engine hardware model (paper Fig. 4): ReRAM crossbars plus
//! peripherals (driver, sample-and-hold, shared ADC, FIFO buffers, ALU).
//!
//! The engine model is *event-level*: it tracks state (which pattern each
//! crossbar holds, per-cell write wear) and accumulates `EventCounts`;
//! functional MVM values are computed by the scheduler through the
//! runtime executor (AOT PJRT artifact or the native mirror).

pub mod crossbar;
pub mod graph_engine;

pub use crossbar::Crossbar;
pub use graph_engine::{EngineKind, GraphEngine};
