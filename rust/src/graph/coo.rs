//! Coordinate-list (COO) graph storage — the main-memory format.
//!
//! The paper stores input graphs in COO "to ensure efficient storage and
//! sequential edge access, while utilizing adjacency matrix format in
//! local memory" (§II.B). All preprocessing starts from a sorted,
//! deduplicated, loop-free COO: `from_edges` is the single ingest
//! choke point enforcing the canonical form, so delta application and a
//! cold rebuild of the same mutated graph agree edge-for-edge.

use std::cmp::Ordering;

/// A directed, weighted edge. Unweighted graphs use `weight == 1.0`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: u32,
    pub dst: u32,
    pub weight: f32,
}

impl Edge {
    pub fn new(src: u32, dst: u32) -> Self {
        Self { src, dst, weight: 1.0 }
    }

    pub fn weighted(src: u32, dst: u32, weight: f32) -> Self {
        Self { src, dst, weight }
    }

    /// Ordering key: row-major over (src, dst).
    #[inline]
    fn key(&self) -> (u32, u32) {
        (self.src, self.dst)
    }
}

/// COO graph: vertex count + edge list.
#[derive(Debug, Clone, Default)]
pub struct Coo {
    pub num_vertices: u32,
    pub edges: Vec<Edge>,
}

impl Coo {
    /// Build from raw edges: drops out-of-range endpoints and self-loops
    /// (the generators already reject loops; ingest must agree so every
    /// path to a `Coo` yields the same canonical edge set), sorts
    /// row-major and removes duplicate (src, dst) pairs (keeping the
    /// first weight).
    pub fn from_edges(num_vertices: u32, mut edges: Vec<Edge>) -> Self {
        edges.retain(|e| e.src < num_vertices && e.dst < num_vertices && e.src != e.dst);
        edges.sort_unstable_by(|a, b| a.key().cmp(&b.key()));
        edges.dedup_by(|a, b| a.key() == b.key());
        Self { num_vertices, edges }
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Make the graph undirected by mirroring every edge (a canonical
    /// `Coo` holds no self-loops, so every edge mirrors). Paper
    /// benchmarks are undirected (§IV.A Table 2).
    pub fn symmetrize(&self) -> Coo {
        let mut edges = Vec::with_capacity(self.edges.len() * 2);
        for e in &self.edges {
            edges.push(*e);
            edges.push(Edge::weighted(e.dst, e.src, e.weight));
        }
        Coo::from_edges(self.num_vertices, edges)
    }

    /// Reverse every edge (used for column-major / pull-style traversal).
    pub fn transpose(&self) -> Coo {
        let edges = self
            .edges
            .iter()
            .map(|e| Edge::weighted(e.dst, e.src, e.weight))
            .collect();
        Coo::from_edges(self.num_vertices, edges)
    }

    /// Assign deterministic pseudo-random positive weights in `[lo, hi)`
    /// (for SSSP on originally-unweighted benchmarks).
    pub fn with_random_weights(&self, seed: u64, lo: f32, hi: f32) -> Coo {
        assert!(hi > lo && lo >= 0.0);
        let mut rng = crate::util::SplitMix64::new(seed);
        let edges = self
            .edges
            .iter()
            .map(|e| Edge::weighted(e.src, e.dst, lo + rng.next_f32() * (hi - lo)))
            .collect();
        Coo { num_vertices: self.num_vertices, edges }
    }

    /// True if edges are sorted row-major and unique (invariant after
    /// `from_edges`; property-tested).
    pub fn is_canonical(&self) -> bool {
        self.edges
            .windows(2)
            .all(|w| w[0].key().cmp(&w[1].key()) == Ordering::Less)
    }

    /// Out-degree of every vertex.
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.num_vertices as usize];
        for e in &self.edges {
            d[e.src as usize] += 1;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Coo {
        Coo::from_edges(
            4,
            vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(0, 1), Edge::new(3, 0)],
        )
    }

    #[test]
    fn from_edges_dedups_and_sorts() {
        let g = toy();
        assert_eq!(g.num_edges(), 3);
        assert!(g.is_canonical());
    }

    #[test]
    fn from_edges_drops_out_of_range() {
        let g = Coo::from_edges(2, vec![Edge::new(0, 1), Edge::new(0, 5), Edge::new(7, 1)]);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn symmetrize_mirrors_edges() {
        let g = toy().symmetrize();
        assert_eq!(g.num_edges(), 6);
        assert!(g.edges.iter().any(|e| (e.src, e.dst) == (1, 0)));
        assert!(g.is_canonical());
    }

    #[test]
    fn from_edges_rejects_self_loops() {
        // Ingest agrees with the generators: no path produces a loop.
        let g = Coo::from_edges(3, vec![Edge::new(0, 0), Edge::new(0, 1), Edge::new(2, 2)]);
        assert_eq!(g.num_edges(), 1);
        assert_eq!((g.edges[0].src, g.edges[0].dst), (0, 1));
        assert!(g.is_canonical());
        // ...and symmetrize can't reintroduce one.
        let s = g.symmetrize();
        assert_eq!(s.num_edges(), 2);
        assert!(s.edges.iter().all(|e| e.src != e.dst));
    }

    #[test]
    fn transpose_involution() {
        let g = toy();
        let tt = g.transpose().transpose();
        assert_eq!(g.edges, tt.edges);
    }

    #[test]
    fn random_weights_in_range_and_deterministic() {
        let g = toy().with_random_weights(9, 1.0, 5.0);
        let h = toy().with_random_weights(9, 1.0, 5.0);
        for (a, b) in g.edges.iter().zip(&h.edges) {
            assert_eq!(a.weight, b.weight);
            assert!((1.0..5.0).contains(&a.weight));
        }
    }

    #[test]
    fn out_degrees_count_edges() {
        let g = toy();
        assert_eq!(g.out_degrees(), vec![1, 1, 0, 1]);
    }
}
