//! Compressed Sparse Row view — used by the pure-CPU reference algorithms
//! (`algo::reference`) that validate the accelerator's numeric results.

use super::coo::Coo;

/// CSR adjacency: `row_ptr[v]..row_ptr[v+1]` indexes `col_idx`/`weights`.
#[derive(Debug, Clone)]
pub struct Csr {
    pub num_vertices: u32,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub weights: Vec<f32>,
}

impl Csr {
    pub fn from_coo(g: &Coo) -> Self {
        let n = g.num_vertices as usize;
        let mut row_ptr = vec![0u32; n + 1];
        for e in &g.edges {
            row_ptr[e.src as usize + 1] += 1;
        }
        for i in 0..n {
            row_ptr[i + 1] += row_ptr[i];
        }
        let m = g.edges.len();
        let mut col_idx = vec![0u32; m];
        let mut weights = vec![0f32; m];
        let mut cursor = row_ptr.clone();
        // COO is sorted row-major, so this fills each row in dst order.
        for e in &g.edges {
            let slot = cursor[e.src as usize] as usize;
            col_idx[slot] = e.dst;
            weights[slot] = e.weight;
            cursor[e.src as usize] += 1;
        }
        Self { num_vertices: g.num_vertices, row_ptr, col_idx, weights }
    }

    pub fn num_edges(&self) -> usize {
        self.col_idx.len()
    }

    /// Out-neighbors of `v` with weights.
    #[inline]
    pub fn neighbors(&self, v: u32) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.row_ptr[v as usize] as usize;
        let hi = self.row_ptr[v as usize + 1] as usize;
        self.col_idx[lo..hi]
            .iter()
            .copied()
            .zip(self.weights[lo..hi].iter().copied())
    }

    #[inline]
    pub fn out_degree(&self, v: u32) -> u32 {
        self.row_ptr[v as usize + 1] - self.row_ptr[v as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::Edge;

    fn toy() -> Csr {
        Csr::from_coo(&Coo::from_edges(
            4,
            vec![
                Edge::weighted(0, 1, 2.0),
                Edge::weighted(0, 3, 1.0),
                Edge::weighted(2, 0, 5.0),
            ],
        ))
    }

    #[test]
    fn row_ptr_prefix_sums() {
        let c = toy();
        assert_eq!(c.row_ptr, vec![0, 2, 2, 3, 3]);
        assert_eq!(c.num_edges(), 3);
    }

    #[test]
    fn neighbors_ordered_with_weights() {
        let c = toy();
        let n: Vec<_> = c.neighbors(0).collect();
        assert_eq!(n, vec![(1, 2.0), (3, 1.0)]);
        assert_eq!(c.neighbors(1).count(), 0);
    }

    #[test]
    fn out_degree_matches() {
        let c = toy();
        assert_eq!(c.out_degree(0), 2);
        assert_eq!(c.out_degree(2), 1);
    }

    #[test]
    fn empty_graph() {
        let c = Csr::from_coo(&Coo::from_edges(3, vec![]));
        assert_eq!(c.row_ptr, vec![0, 0, 0, 0]);
        assert_eq!(c.num_edges(), 0);
    }
}
