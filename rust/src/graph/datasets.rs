//! Paper Table 2 dataset presets.
//!
//! The SNAP originals are unavailable offline, so each preset synthesizes
//! a deterministic R-MAT graph matched to the paper's vertex count, edge
//! count and average degree (DESIGN.md §Substitutions). R-MAT's skewed
//! quadrant split reproduces the power-law degree distribution that the
//! paper's pattern-frequency observation rests on [29].
//!
//! `Dataset::load` also accepts `REPRO_DATA_DIR` pointing at real SNAP
//! `.txt` files (`<name>.txt`), which then take precedence.

use anyhow::Result;

use crate::util::SplitMix64;

use super::coo::Coo;
use super::generator::{rmat, RmatParams};
use super::loader::load_edge_list;

/// The six paper benchmarks (Table 2) plus a tiny CI-sized graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// web-Google: 875K vertices, 5.1M edges, web.
    WebGoogle,
    /// Amazon302: 262K vertices, 1.2M edges, recommendation.
    Amazon,
    /// Slashdot0902: 82K vertices, 948K edges, social.
    Slashdot,
    /// soc-Epinions1: 76K vertices, 509K edges, social.
    Epinions,
    /// p2p-Gnutella31: 5K vertices, 148K edges, network (paper's figures).
    Gnutella,
    /// Wiki-Vote: 7K vertices, 104K edges, social — the paper's running example.
    WikiVote,
    /// Tiny R-MAT for unit/integration tests (1K vertices, 8K edges).
    Tiny,
}

pub const ALL_DATASETS: [Dataset; 6] = [
    Dataset::WebGoogle,
    Dataset::Amazon,
    Dataset::Slashdot,
    Dataset::Epinions,
    Dataset::Gnutella,
    Dataset::WikiVote,
];

/// Table 2 row (paper's published statistics).
#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    pub short: &'static str,
    pub name: &'static str,
    pub vertices: u32,
    pub edges: usize,
    pub avg_degree: u32,
    pub sparsity_pct: f64,
    pub domain: &'static str,
    pub seed: u64,
}

impl Dataset {
    pub fn spec(self) -> DatasetSpec {
        match self {
            Dataset::WebGoogle => DatasetSpec {
                short: "WG",
                name: "web-Google",
                vertices: 875_000,
                edges: 5_100_000,
                avg_degree: 12,
                sparsity_pct: 99.999,
                domain: "Web",
                seed: 0x5747,
            },
            Dataset::Amazon => DatasetSpec {
                short: "AZ",
                name: "Amazon302",
                vertices: 262_000,
                edges: 1_200_000,
                avg_degree: 9,
                sparsity_pct: 99.998,
                domain: "Recom.",
                seed: 0x415A,
            },
            Dataset::Slashdot => DatasetSpec {
                short: "SD",
                name: "Slashdot0902",
                vertices: 82_000,
                edges: 948_000,
                avg_degree: 23,
                sparsity_pct: 99.985,
                domain: "Social",
                seed: 0x5344,
            },
            Dataset::Epinions => DatasetSpec {
                short: "EP",
                name: "soc-Epinions1",
                vertices: 76_000,
                edges: 509_000,
                avg_degree: 13,
                sparsity_pct: 99.991,
                domain: "Social",
                seed: 0x4550,
            },
            Dataset::Gnutella => DatasetSpec {
                short: "PG",
                name: "p2p-gnutella31",
                vertices: 5_000,
                edges: 148_000,
                avg_degree: 5,
                sparsity_pct: 99.996,
                domain: "Network",
                seed: 0x5047,
            },
            Dataset::WikiVote => DatasetSpec {
                short: "WV",
                name: "Wiki-vote",
                vertices: 7_000,
                edges: 104_000,
                avg_degree: 29,
                sparsity_pct: 99.795,
                domain: "Social",
                seed: 0x5756,
            },
            Dataset::Tiny => DatasetSpec {
                short: "TN",
                name: "tiny-rmat",
                vertices: 1_000,
                edges: 8_000,
                avg_degree: 8,
                sparsity_pct: 99.2,
                domain: "Test",
                seed: 0x544E,
            },
        }
    }

    pub fn from_short(s: &str) -> Option<Dataset> {
        let all = [
            Dataset::WebGoogle,
            Dataset::Amazon,
            Dataset::Slashdot,
            Dataset::Epinions,
            Dataset::Gnutella,
            Dataset::WikiVote,
            Dataset::Tiny,
        ];
        all.into_iter()
            .find(|d| d.spec().short.eq_ignore_ascii_case(s) || d.spec().name.eq_ignore_ascii_case(s))
    }

    /// Load the dataset at full Table-2 scale.
    pub fn load(self) -> Result<Coo> {
        self.load_scaled(1.0)
    }

    /// Load with vertex/edge counts scaled by `scale` (keeps avg degree).
    /// `scale < 1` bounds simulation time for the largest graphs
    /// (web-Google) — documented in DESIGN.md §Substitutions.
    pub fn load_scaled(self, scale: f64) -> Result<Coo> {
        assert!(scale > 0.0 && scale <= 1.0);
        let spec = self.spec();
        if let Ok(dir) = std::env::var("REPRO_DATA_DIR") {
            let path = std::path::Path::new(&dir).join(format!("{}.txt", spec.name));
            if path.exists() {
                return Ok(load_edge_list(path)?.symmetrize());
            }
        }
        let v = ((spec.vertices as f64 * scale) as u32).max(64);
        let e = ((spec.edges as f64 * scale) as usize).max(256);
        // Directed R-MAT, then symmetrized: Table 2 graphs are undirected.
        // Generate half the target edge count so the mirrored graph lands
        // near the paper's edge total.
        let g = rmat(v, e / 2, RmatParams::default(), spec.seed);
        Ok(g.symmetrize())
    }

    /// Weighted variant for SSSP (deterministic weights in [1, 8)).
    pub fn load_weighted(self, scale: f64) -> Result<Coo> {
        let g = self.load_scaled(scale)?;
        let mut seed_rng = SplitMix64::new(self.spec().seed ^ 0xFEED);
        Ok(g.with_random_weights(seed_rng.next_u64(), 1.0, 8.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::GraphStats;

    #[test]
    fn wiki_vote_matches_table2_scale() {
        let g = Dataset::WikiVote.load().unwrap();
        let s = GraphStats::of(&g);
        assert_eq!(s.num_vertices, 7_000);
        // Symmetrized 52K directed edges ≈ 104K; dedup loses a few percent.
        assert!(
            (90_000..=110_000).contains(&s.num_edges),
            "edges={}",
            s.num_edges
        );
        assert!(s.sparsity_pct > 99.0);
    }

    #[test]
    fn tiny_is_small_and_deterministic() {
        let a = Dataset::Tiny.load().unwrap();
        let b = Dataset::Tiny.load().unwrap();
        assert_eq!(a.edges, b.edges);
        assert!(a.num_edges() < 20_000);
    }

    #[test]
    fn scaling_reduces_size_keeps_density() {
        let full = Dataset::Gnutella.load().unwrap();
        let half = Dataset::Gnutella.load_scaled(0.5).unwrap();
        assert!(half.num_vertices < full.num_vertices);
        let sf = GraphStats::of(&full).avg_degree;
        let sh = GraphStats::of(&half).avg_degree;
        assert!((sf - sh).abs() / sf < 0.35, "avg deg {sf} vs {sh}");
    }

    #[test]
    fn from_short_roundtrip() {
        for d in ALL_DATASETS {
            assert_eq!(Dataset::from_short(d.spec().short), Some(d));
        }
        assert_eq!(Dataset::from_short("wv"), Some(Dataset::WikiVote));
        assert_eq!(Dataset::from_short("nope"), None);
    }

    #[test]
    fn weighted_weights_in_range() {
        let g = Dataset::Tiny.load_weighted(1.0).unwrap();
        assert!(g.edges.iter().all(|e| (1.0..8.0).contains(&e.weight)));
    }

    #[test]
    fn symmetrized_graphs_are_undirected() {
        let g = Dataset::Tiny.load().unwrap();
        use std::collections::HashSet;
        let set: HashSet<(u32, u32)> = g.edges.iter().map(|e| (e.src, e.dst)).collect();
        for e in &g.edges {
            assert!(set.contains(&(e.dst, e.src)));
        }
    }
}
