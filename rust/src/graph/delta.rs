//! Edge deltas — the typed mutation language of the streaming ingest
//! path.
//!
//! A [`DeltaBatch`] is a validated, canonicalized set of edge mutations
//! (add / remove / reweight) against one graph. Validation happens in
//! two stages: graph-independent checks at construction (no self-loops —
//! the same rule `Coo::from_edges` enforces — endpoints in range, finite
//! weights, one op per (src, dst) pair with last-wins dedup), and
//! graph-dependent checks at application time ([`DeltaBatch::apply_to_coo`]
//! rejects adding an edge that exists or removing/reweighting one that
//! doesn't). `apply_to_coo` is the semantic ground truth the incremental
//! plan patcher (`sched::patch`) is differentially tested against: a
//! patched plan must be bit-identical to a cold rebuild of
//! `apply_to_coo`'s output.

use std::fmt;

use super::coo::{Coo, Edge};

/// What a single delta does to its (src, dst) pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeltaOp {
    /// Insert an edge that must not already exist.
    Add,
    /// Delete an edge that must exist.
    Remove,
    /// Replace the weight of an edge that must exist.
    Reweight,
}

/// One validated edge mutation. `weight` is 1.0 for unweighted adds and
/// ignored by `Remove`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeDelta {
    pub op: DeltaOp,
    pub src: u32,
    pub dst: u32,
    pub weight: f32,
}

impl EdgeDelta {
    pub fn add(src: u32, dst: u32) -> Self {
        Self { op: DeltaOp::Add, src, dst, weight: 1.0 }
    }

    pub fn add_weighted(src: u32, dst: u32, weight: f32) -> Self {
        Self { op: DeltaOp::Add, src, dst, weight }
    }

    pub fn remove(src: u32, dst: u32) -> Self {
        Self { op: DeltaOp::Remove, src, dst, weight: 1.0 }
    }

    pub fn reweight(src: u32, dst: u32, weight: f32) -> Self {
        Self { op: DeltaOp::Reweight, src, dst, weight }
    }
}

/// Typed rejection — every invalid delta is a specific error, never a
/// silently dropped edge (dropping would let the patched plan and the
/// cold rebuild disagree about what the mutated graph *is*).
#[derive(Debug, Clone, PartialEq)]
pub enum DeltaError {
    /// (v, v) edges are rejected everywhere (`Coo::from_edges`, the
    /// generators, and here) so all paths agree on the edge set.
    SelfLoop { vertex: u32 },
    VertexOutOfRange { vertex: u32, num_vertices: u32 },
    /// NaN / infinite weights would poison the numeric path.
    BadWeight { src: u32, dst: u32, weight: f32 },
    /// `Add` of an edge already present.
    EdgeExists { src: u32, dst: u32 },
    /// `Remove` / `Reweight` of an edge not present.
    EdgeMissing { src: u32, dst: u32 },
    /// Batch built against a different vertex count than the graph.
    GraphMismatch { batch: u32, graph: u32 },
    /// Text-format parse failure (1-based line number).
    Parse { line: usize, what: &'static str },
}

impl fmt::Display for DeltaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DeltaError::SelfLoop { vertex } => {
                write!(f, "self-loop ({vertex}, {vertex}) rejected")
            }
            DeltaError::VertexOutOfRange { vertex, num_vertices } => {
                write!(f, "vertex {vertex} out of range (graph has {num_vertices})")
            }
            DeltaError::BadWeight { src, dst, weight } => {
                write!(f, "non-finite weight {weight} on ({src}, {dst})")
            }
            DeltaError::EdgeExists { src, dst } => {
                write!(f, "cannot add ({src}, {dst}): edge already exists")
            }
            DeltaError::EdgeMissing { src, dst } => {
                write!(f, "cannot remove/reweight ({src}, {dst}): edge not present")
            }
            DeltaError::GraphMismatch { batch, graph } => {
                write!(f, "batch built for {batch} vertices, graph has {graph}")
            }
            DeltaError::Parse { line, what } => write!(f, "line {line}: {what}"),
        }
    }
}

impl std::error::Error for DeltaError {}

/// A validated, canonicalized batch of edge mutations against a graph
/// with a fixed vertex count. Deltas are stored sorted by (src, dst)
/// with exactly one op per pair (last-wins), so identical mutation sets
/// compare equal and application order within a batch can never matter.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaBatch {
    num_vertices: u32,
    deltas: Vec<EdgeDelta>,
}

impl DeltaBatch {
    /// Validate and canonicalize. Graph-independent checks only; the
    /// exists/missing checks happen against a concrete graph in
    /// [`apply_to_coo`](Self::apply_to_coo).
    pub fn new(num_vertices: u32, deltas: Vec<EdgeDelta>) -> Result<Self, DeltaError> {
        for d in &deltas {
            if d.src == d.dst {
                return Err(DeltaError::SelfLoop { vertex: d.src });
            }
            for v in [d.src, d.dst] {
                if v >= num_vertices {
                    return Err(DeltaError::VertexOutOfRange { vertex: v, num_vertices });
                }
            }
            if !d.weight.is_finite() {
                return Err(DeltaError::BadWeight { src: d.src, dst: d.dst, weight: d.weight });
            }
        }
        // Last-wins dedup per (src, dst): a stable sort on the pair keeps
        // arrival order within a pair, then dedup keeps the final op.
        let mut deltas = deltas;
        deltas.sort_by_key(|d| (d.src, d.dst));
        let mut out: Vec<EdgeDelta> = Vec::with_capacity(deltas.len());
        for d in deltas {
            match out.last_mut() {
                Some(last) if (last.src, last.dst) == (d.src, d.dst) => *last = d,
                _ => out.push(d),
            }
        }
        Ok(Self { num_vertices, deltas: out })
    }

    /// An empty batch (applying it is the identity).
    pub fn empty(num_vertices: u32) -> Self {
        Self { num_vertices, deltas: Vec::new() }
    }

    pub fn num_vertices(&self) -> u32 {
        self.num_vertices
    }

    pub fn len(&self) -> usize {
        self.deltas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    /// Canonical (src, dst)-sorted view of the mutations.
    pub fn deltas(&self) -> &[EdgeDelta] {
        &self.deltas
    }

    /// Apply to a canonical COO, producing the mutated graph — the
    /// ground-truth semantics every incremental path is tested against.
    /// `Add` requires the edge absent; `Remove` / `Reweight` require it
    /// present; violations are typed errors and the input is untouched.
    pub fn apply_to_coo(&self, g: &Coo) -> Result<Coo, DeltaError> {
        if self.num_vertices != g.num_vertices {
            return Err(DeltaError::GraphMismatch {
                batch: self.num_vertices,
                graph: g.num_vertices,
            });
        }
        let find = |src: u32, dst: u32| {
            g.edges.binary_search_by_key(&(src, dst), |e| (e.src, e.dst))
        };
        // Validate the whole batch before building anything, so a failed
        // apply has no partial effect.
        for d in &self.deltas {
            let present = find(d.src, d.dst).is_ok();
            match d.op {
                DeltaOp::Add if present => {
                    return Err(DeltaError::EdgeExists { src: d.src, dst: d.dst });
                }
                DeltaOp::Remove | DeltaOp::Reweight if !present => {
                    return Err(DeltaError::EdgeMissing { src: d.src, dst: d.dst });
                }
                _ => {}
            }
        }
        let mut edges = g.edges.clone();
        for d in &self.deltas {
            match d.op {
                DeltaOp::Add => edges.push(Edge::weighted(d.src, d.dst, d.weight)),
                DeltaOp::Remove => {
                    let i = find(d.src, d.dst).expect("validated above");
                    // Tombstone via an out-of-range endpoint: `from_edges`
                    // drops it, and indices into `g.edges` stay stable.
                    edges[i].src = u32::MAX;
                }
                DeltaOp::Reweight => {
                    let i = find(d.src, d.dst).expect("validated above");
                    edges[i].weight = d.weight;
                }
            }
        }
        Ok(Coo::from_edges(g.num_vertices, edges))
    }

    /// Parse the `repro mutate --deltas <file>` text format: one delta
    /// per line, `#` comments and blank lines ignored.
    ///
    /// ```text
    /// + src dst [weight]   add (weight defaults to 1.0)
    /// - src dst            remove
    /// = src dst weight     reweight
    /// ```
    pub fn parse(text: &str, num_vertices: u32) -> Result<Self, DeltaError> {
        let mut deltas = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = idx + 1;
            let s = raw.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let mut toks = s.split_whitespace();
            let op = toks.next().expect("non-empty line has a token");
            let mut vertex = |what| {
                toks.next()
                    .and_then(|t| t.parse::<u32>().ok())
                    .ok_or(DeltaError::Parse { line, what })
            };
            let src = vertex("expected source vertex")?;
            let dst = vertex("expected destination vertex")?;
            let weight = toks.next().map(|t| {
                t.parse::<f32>().map_err(|_| DeltaError::Parse { line, what: "bad weight" })
            });
            let d = match (op, weight) {
                ("+", None) => EdgeDelta::add(src, dst),
                ("+", Some(w)) => EdgeDelta::add_weighted(src, dst, w?),
                ("-", None) => EdgeDelta::remove(src, dst),
                ("=", Some(w)) => EdgeDelta::reweight(src, dst, w?),
                ("=", None) => {
                    return Err(DeltaError::Parse { line, what: "reweight needs a weight" })
                }
                ("-", Some(_)) => {
                    return Err(DeltaError::Parse { line, what: "remove takes no weight" })
                }
                _ => return Err(DeltaError::Parse { line, what: "expected '+', '-' or '='" }),
            };
            if toks.next().is_some() {
                return Err(DeltaError::Parse { line, what: "trailing tokens" });
            }
            deltas.push(d);
        }
        Self::new(num_vertices, deltas)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Coo {
        Coo::from_edges(
            4,
            vec![
                Edge::weighted(0, 1, 1.5),
                Edge::weighted(1, 2, 2.5),
                Edge::weighted(3, 0, 3.5),
            ],
        )
    }

    #[test]
    fn construction_rejects_invalid_deltas() {
        assert_eq!(
            DeltaBatch::new(4, vec![EdgeDelta::add(2, 2)]),
            Err(DeltaError::SelfLoop { vertex: 2 })
        );
        assert_eq!(
            DeltaBatch::new(4, vec![EdgeDelta::add(0, 9)]),
            Err(DeltaError::VertexOutOfRange { vertex: 9, num_vertices: 4 })
        );
        assert!(matches!(
            DeltaBatch::new(4, vec![EdgeDelta::add_weighted(0, 2, f32::NAN)]),
            Err(DeltaError::BadWeight { src: 0, dst: 2, .. })
        ));
    }

    #[test]
    fn dedup_is_last_wins_and_sorted() {
        let b = DeltaBatch::new(
            4,
            vec![
                EdgeDelta::add(2, 3),
                EdgeDelta::add_weighted(0, 2, 9.0),
                EdgeDelta::remove(2, 3), // supersedes the add above
            ],
        )
        .unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!((b.deltas()[0].src, b.deltas()[0].dst), (0, 2));
        assert_eq!(b.deltas()[1].op, DeltaOp::Remove);
    }

    #[test]
    fn apply_matches_manual_edge_set() {
        let g = toy();
        let b = DeltaBatch::new(
            4,
            vec![
                EdgeDelta::add_weighted(2, 0, 7.0),
                EdgeDelta::remove(1, 2),
                EdgeDelta::reweight(0, 1, 8.0),
            ],
        )
        .unwrap();
        let m = b.apply_to_coo(&g).unwrap();
        let want = Coo::from_edges(
            4,
            vec![
                Edge::weighted(0, 1, 8.0),
                Edge::weighted(2, 0, 7.0),
                Edge::weighted(3, 0, 3.5),
            ],
        );
        assert_eq!(m.edges, want.edges);
        assert!(m.is_canonical());
    }

    #[test]
    fn apply_errors_are_typed_and_leave_input_untouched() {
        let g = toy();
        let exists = DeltaBatch::new(4, vec![EdgeDelta::add(0, 1)]).unwrap();
        assert_eq!(exists.apply_to_coo(&g), Err(DeltaError::EdgeExists { src: 0, dst: 1 }));
        let missing = DeltaBatch::new(4, vec![EdgeDelta::remove(2, 3)]).unwrap();
        assert_eq!(missing.apply_to_coo(&g), Err(DeltaError::EdgeMissing { src: 2, dst: 3 }));
        let mismatch = DeltaBatch::empty(5);
        assert_eq!(
            mismatch.apply_to_coo(&g),
            Err(DeltaError::GraphMismatch { batch: 5, graph: 4 })
        );
    }

    #[test]
    fn empty_batch_is_identity() {
        let g = toy();
        let m = DeltaBatch::empty(4).apply_to_coo(&g).unwrap();
        assert_eq!(m.edges, g.edges);
    }

    #[test]
    fn remove_then_re_add_round_trips_topology() {
        let g = toy();
        let removed = DeltaBatch::new(4, vec![EdgeDelta::remove(1, 2)])
            .unwrap()
            .apply_to_coo(&g)
            .unwrap();
        let back = DeltaBatch::new(4, vec![EdgeDelta::add_weighted(1, 2, 2.5)])
            .unwrap()
            .apply_to_coo(&removed)
            .unwrap();
        assert_eq!(back.edges, g.edges);
    }

    #[test]
    fn parse_text_format() {
        let text = "# churn\n+ 2 0 7.0\n\n- 1 2\n= 0 1 8.0\n+ 2 3\n";
        let b = DeltaBatch::parse(text, 4).unwrap();
        assert_eq!(b.len(), 4);
        let add = b.deltas().iter().find(|d| (d.src, d.dst) == (2, 3)).unwrap();
        assert_eq!((add.op, add.weight), (DeltaOp::Add, 1.0));
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        for (text, line) in [
            ("x 1 2", 1),
            ("+ 1", 1),
            ("+ 1 2 3 4", 1),
            ("- 1 2 3.0", 1),
            ("= 1 2", 1),
            ("+ 0 1\n+ a b", 2),
        ] {
            match DeltaBatch::parse(text, 9) {
                Err(DeltaError::Parse { line: l, .. }) => assert_eq!(l, line, "{text:?}"),
                other => panic!("{text:?}: expected parse error, got {other:?}"),
            }
        }
        // Validation still runs after parsing.
        assert!(matches!(
            DeltaBatch::parse("+ 3 3", 9),
            Err(DeltaError::SelfLoop { vertex: 3 })
        ));
    }
}
