//! Synthetic graph generators.
//!
//! R-MAT (Chakrabarti et al., SDM 2004) with the Graph500 parameters
//! reproduces the power-law degree distribution that drives the paper's
//! central observation — a handful of window patterns (dominated by
//! single-edge submatrices) cover the vast majority of subgraphs
//! (Fig. 1a). Erdős–Rényi and a preferential-attachment generator are
//! included for ablations and tests.

use crate::util::SplitMix64;

use super::coo::{Coo, Edge};

/// R-MAT quadrant probabilities. Must sum to 1.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
}

impl Default for RmatParams {
    /// Graph500 parameters — strongly skewed (power-law-like).
    fn default() -> Self {
        Self { a: 0.57, b: 0.19, c: 0.19, d: 0.05 }
    }
}

/// Generate an R-MAT graph with ~`num_edges` distinct edges over
/// `num_vertices` vertices (rounded up to the next power of two
/// internally; out-of-range endpoints are redrawn).
///
/// # Shortfall
///
/// The retry loop is bounded (`20 × num_edges` draws, min 1024): when a
/// tiny, dense ask approaches the graph's distinct-edge capacity —
/// R-MAT's skew revisits the same hot cells, so near `n·(n-1)` the
/// marginal draw almost never lands on a fresh cell — the generator
/// **returns fewer edges than requested** rather than spinning
/// unboundedly. The shortfall is logged to stderr; callers that need an
/// exact count must check `num_edges()` on the result. This is a
/// documented contract, not a silent truncation.
pub fn rmat(num_vertices: u32, num_edges: usize, params: RmatParams, seed: u64) -> Coo {
    assert!(num_vertices > 0);
    let scale = 32 - (num_vertices.max(2) - 1).leading_zeros(); // ceil(log2 n)
    let mut rng = SplitMix64::new(seed);
    let mut edges = Vec::with_capacity(num_edges + num_edges / 8);
    // Oversample: dedup in from_edges trims duplicates; iterate until the
    // distinct-edge target is met (bounded retries for tiny dense asks).
    let mut attempts = 0usize;
    let max_attempts = num_edges.saturating_mul(20).max(1024);
    let mut g = Coo::default();
    while attempts < max_attempts {
        let need = num_edges.saturating_sub(g.num_edges());
        if need == 0 {
            break;
        }
        for _ in 0..need + need / 4 + 8 {
            let (src, dst) = rmat_edge(scale, params, &mut rng);
            if src < num_vertices && dst < num_vertices && src != dst {
                edges.push(Edge::new(src, dst));
            }
            attempts += 1;
        }
        let mut all = g.edges.clone();
        all.append(&mut edges);
        g = Coo::from_edges(num_vertices, all);
    }
    g.edges.truncate(num_edges);
    if g.num_edges() < num_edges {
        eprintln!(
            "rmat: retry budget exhausted after {attempts} draws; returning \
             {} of {num_edges} requested distinct edges (n={num_vertices})",
            g.num_edges()
        );
    }
    g
}

/// Streaming R-MAT emitter: draws the same candidate sequence as
/// [`rmat`]'s inner loop but hands edges to `sink` in batches of
/// `batch_size` instead of materializing one giant Vec — the 100M+-edge
/// path, fed straight into per-shard bucketing
/// ([`shard::Sharder::push`](super::shard::Sharder::push)).
///
/// Contract:
///
/// * **Batch-invariant:** the concatenated stream is a pure function of
///   `(num_vertices, num_edges, params, seed)` — `batch_size` only
///   changes where the stream is cut, never its content.
/// * **Candidates, not distinct edges:** self-loops and out-of-range
///   endpoints are dropped, but *duplicates pass through* — dedup
///   happens at `Coo::from_edges` in the consumer. Because shards own
///   disjoint source ranges, per-shard dedup equals global dedup, so
///   streaming into a `Sharder` matches splitting the materialized
///   graph edge-for-edge.
/// * **Bounded:** emits up to `num_edges` accepted candidates under the
///   same `20 × num_edges` draw budget as [`rmat`]; after consumer
///   dedup the distinct count may be lower (see [`rmat`]'s shortfall
///   note). Returns the number of candidates emitted.
pub fn rmat_stream<F: FnMut(&[Edge])>(
    num_vertices: u32,
    num_edges: usize,
    params: RmatParams,
    seed: u64,
    batch_size: usize,
    mut sink: F,
) -> usize {
    assert!(num_vertices > 0);
    assert!(batch_size >= 1);
    let scale = 32 - (num_vertices.max(2) - 1).leading_zeros(); // ceil(log2 n)
    let mut rng = SplitMix64::new(seed);
    let max_attempts = num_edges.saturating_mul(20).max(1024);
    let mut batch = Vec::with_capacity(batch_size.min(num_edges.max(1)));
    let mut emitted = 0usize;
    let mut attempts = 0usize;
    while emitted < num_edges && attempts < max_attempts {
        let (src, dst) = rmat_edge(scale, params, &mut rng);
        attempts += 1;
        if src < num_vertices && dst < num_vertices && src != dst {
            batch.push(Edge::new(src, dst));
            emitted += 1;
            if batch.len() == batch_size {
                sink(&batch);
                batch.clear();
            }
        }
    }
    if !batch.is_empty() {
        sink(&batch);
    }
    if emitted < num_edges {
        eprintln!(
            "rmat_stream: retry budget exhausted after {attempts} draws; \
             emitted {emitted} of {num_edges} candidates (n={num_vertices})"
        );
    }
    emitted
}

fn rmat_edge(scale: u32, p: RmatParams, rng: &mut SplitMix64) -> (u32, u32) {
    let (mut src, mut dst) = (0u32, 0u32);
    for _ in 0..scale {
        src <<= 1;
        dst <<= 1;
        let r = rng.next_f64();
        if r < p.a {
            // top-left: nothing
        } else if r < p.a + p.b {
            dst |= 1;
        } else if r < p.a + p.b + p.c {
            src |= 1;
        } else {
            src |= 1;
            dst |= 1;
        }
    }
    (src, dst)
}

/// Erdős–Rényi G(n, m): `num_edges` uniform random distinct edges.
pub fn erdos_renyi(num_vertices: u32, num_edges: usize, seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let mut g = Coo::default();
    let mut guard = 0;
    while g.num_edges() < num_edges && guard < 40 {
        let need = num_edges - g.num_edges();
        let mut edges = g.edges.clone();
        for _ in 0..need + need / 4 + 8 {
            let s = rng.next_bounded(num_vertices as u64) as u32;
            let d = rng.next_bounded(num_vertices as u64) as u32;
            if s != d {
                edges.push(Edge::new(s, d));
            }
        }
        g = Coo::from_edges(num_vertices, edges);
        guard += 1;
    }
    g.edges.truncate(num_edges);
    g
}

/// Simple preferential-attachment (Barabási–Albert flavor): each new
/// vertex attaches `m` edges to endpoints sampled from the existing edge
/// list (which is degree-proportional sampling).
pub fn preferential_attachment(num_vertices: u32, m: usize, seed: u64) -> Coo {
    assert!(m >= 1);
    let mut rng = SplitMix64::new(seed);
    let mut targets: Vec<u32> = vec![0];
    let mut edges = Vec::new();
    for v in 1..num_vertices {
        for _ in 0..m.min(v as usize) {
            // Degree-proportional sampling; redraw self-loops (v is
            // already in `targets` after its first attachment).
            let mut t = targets[rng.next_index(targets.len())];
            let mut guard = 0;
            while t == v && guard < 16 {
                t = targets[rng.next_index(targets.len())];
                guard += 1;
            }
            if t == v {
                continue;
            }
            edges.push(Edge::new(v, t));
            targets.push(t);
            targets.push(v);
        }
    }
    Coo::from_edges(num_vertices, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_hits_edge_target() {
        let g = rmat(1 << 10, 5_000, RmatParams::default(), 1);
        assert_eq!(g.num_edges(), 5_000);
        assert!(g.is_canonical());
    }

    #[test]
    fn rmat_tiny_dense_ask_logs_shortfall_instead_of_spinning() {
        // 4 vertices hold at most 12 directed non-loop edges; R-MAT's
        // skew makes even that unreachable within the retry budget.
        // The documented contract: return what was found, never hang.
        let g = rmat(4, 1_000, RmatParams::default(), 2);
        assert!(g.num_edges() < 1_000, "shortfall expected");
        assert!(g.num_edges() <= 12, "capacity bound");
        assert!(g.is_canonical());
        // Deterministic shortfall: the same ask yields the same edges.
        let h = rmat(4, 1_000, RmatParams::default(), 2);
        assert_eq!(g.edges, h.edges);
    }

    #[test]
    fn rmat_stream_is_batch_invariant() {
        let collect = |batch_size: usize| {
            let mut all = Vec::new();
            let n = rmat_stream(512, 3_000, RmatParams::default(), 13, batch_size, |b| {
                all.extend_from_slice(b)
            });
            assert_eq!(n, all.len());
            all
        };
        let want = collect(3_000);
        assert_eq!(want.len(), 3_000);
        for batch_size in [1usize, 7, 64, 1024, 10_000] {
            assert_eq!(collect(batch_size), want, "batch {batch_size}");
        }
    }

    #[test]
    fn rmat_stream_respects_draw_budget_on_dense_asks() {
        let mut total = 0usize;
        let n = rmat_stream(4, 1_000, RmatParams::default(), 2, 64, |b| total += b.len());
        assert_eq!(n, total);
        assert!(n < 1_000, "budget must cap a saturated ask");
    }

    #[test]
    fn rmat_is_deterministic() {
        let a = rmat(512, 2_000, RmatParams::default(), 7);
        let b = rmat(512, 2_000, RmatParams::default(), 7);
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn rmat_degree_distribution_is_skewed() {
        // Power-law-ish: the max degree should far exceed the average.
        let g = rmat(1 << 12, 40_000, RmatParams::default(), 3);
        let deg = g.out_degrees();
        let max = *deg.iter().max().unwrap() as f64;
        let avg = 40_000.0 / 4096.0;
        assert!(max > 10.0 * avg, "max={max} avg={avg}");
    }

    #[test]
    fn erdos_renyi_is_flat_by_comparison() {
        let g = erdos_renyi(1 << 12, 40_000, 3);
        assert_eq!(g.num_edges(), 40_000);
        let deg = g.out_degrees();
        let max = *deg.iter().max().unwrap() as f64;
        let avg = 40_000.0 / 4096.0;
        assert!(max < 6.0 * avg, "max={max} avg={avg}");
    }

    #[test]
    fn preferential_attachment_connects_everything() {
        let g = preferential_attachment(200, 2, 11).symmetrize();
        let csr = crate::graph::Csr::from_coo(&g);
        // BFS from 0 reaches all vertices.
        let mut seen = vec![false; 200];
        let mut stack = vec![0u32];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for (n, _) in csr.neighbors(v) {
                if !seen[n as usize] {
                    seen[n as usize] = true;
                    stack.push(n);
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn generators_exclude_self_loops() {
        for g in [
            rmat(256, 1_000, RmatParams::default(), 5),
            erdos_renyi(256, 1_000, 5),
            preferential_attachment(256, 3, 5),
        ] {
            assert!(g.edges.iter().all(|e| e.src != e.dst));
        }
    }
}
