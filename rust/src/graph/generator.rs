//! Synthetic graph generators.
//!
//! R-MAT (Chakrabarti et al., SDM 2004) with the Graph500 parameters
//! reproduces the power-law degree distribution that drives the paper's
//! central observation — a handful of window patterns (dominated by
//! single-edge submatrices) cover the vast majority of subgraphs
//! (Fig. 1a). Erdős–Rényi and a preferential-attachment generator are
//! included for ablations and tests.

use crate::util::SplitMix64;

use super::coo::{Coo, Edge};

/// R-MAT quadrant probabilities. Must sum to 1.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
}

impl Default for RmatParams {
    /// Graph500 parameters — strongly skewed (power-law-like).
    fn default() -> Self {
        Self { a: 0.57, b: 0.19, c: 0.19, d: 0.05 }
    }
}

/// Generate an R-MAT graph with ~`num_edges` distinct edges over
/// `num_vertices` vertices (rounded up to the next power of two
/// internally; out-of-range endpoints are redrawn).
pub fn rmat(num_vertices: u32, num_edges: usize, params: RmatParams, seed: u64) -> Coo {
    assert!(num_vertices > 0);
    let scale = 32 - (num_vertices.max(2) - 1).leading_zeros(); // ceil(log2 n)
    let mut rng = SplitMix64::new(seed);
    let mut edges = Vec::with_capacity(num_edges + num_edges / 8);
    // Oversample: dedup in from_edges trims duplicates; iterate until the
    // distinct-edge target is met (bounded retries for tiny dense asks).
    let mut attempts = 0usize;
    let max_attempts = num_edges.saturating_mul(20).max(1024);
    let mut g = Coo::default();
    while attempts < max_attempts {
        let need = num_edges.saturating_sub(g.num_edges());
        if need == 0 {
            break;
        }
        for _ in 0..need + need / 4 + 8 {
            let (src, dst) = rmat_edge(scale, params, &mut rng);
            if src < num_vertices && dst < num_vertices && src != dst {
                edges.push(Edge::new(src, dst));
            }
            attempts += 1;
        }
        let mut all = g.edges.clone();
        all.append(&mut edges);
        g = Coo::from_edges(num_vertices, all);
    }
    g.edges.truncate(num_edges);
    g
}

fn rmat_edge(scale: u32, p: RmatParams, rng: &mut SplitMix64) -> (u32, u32) {
    let (mut src, mut dst) = (0u32, 0u32);
    for _ in 0..scale {
        src <<= 1;
        dst <<= 1;
        let r = rng.next_f64();
        if r < p.a {
            // top-left: nothing
        } else if r < p.a + p.b {
            dst |= 1;
        } else if r < p.a + p.b + p.c {
            src |= 1;
        } else {
            src |= 1;
            dst |= 1;
        }
    }
    (src, dst)
}

/// Erdős–Rényi G(n, m): `num_edges` uniform random distinct edges.
pub fn erdos_renyi(num_vertices: u32, num_edges: usize, seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let mut g = Coo::default();
    let mut guard = 0;
    while g.num_edges() < num_edges && guard < 40 {
        let need = num_edges - g.num_edges();
        let mut edges = g.edges.clone();
        for _ in 0..need + need / 4 + 8 {
            let s = rng.next_bounded(num_vertices as u64) as u32;
            let d = rng.next_bounded(num_vertices as u64) as u32;
            if s != d {
                edges.push(Edge::new(s, d));
            }
        }
        g = Coo::from_edges(num_vertices, edges);
        guard += 1;
    }
    g.edges.truncate(num_edges);
    g
}

/// Simple preferential-attachment (Barabási–Albert flavor): each new
/// vertex attaches `m` edges to endpoints sampled from the existing edge
/// list (which is degree-proportional sampling).
pub fn preferential_attachment(num_vertices: u32, m: usize, seed: u64) -> Coo {
    assert!(m >= 1);
    let mut rng = SplitMix64::new(seed);
    let mut targets: Vec<u32> = vec![0];
    let mut edges = Vec::new();
    for v in 1..num_vertices {
        for _ in 0..m.min(v as usize) {
            // Degree-proportional sampling; redraw self-loops (v is
            // already in `targets` after its first attachment).
            let mut t = targets[rng.next_index(targets.len())];
            let mut guard = 0;
            while t == v && guard < 16 {
                t = targets[rng.next_index(targets.len())];
                guard += 1;
            }
            if t == v {
                continue;
            }
            edges.push(Edge::new(v, t));
            targets.push(t);
            targets.push(v);
        }
    }
    Coo::from_edges(num_vertices, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_hits_edge_target() {
        let g = rmat(1 << 10, 5_000, RmatParams::default(), 1);
        assert_eq!(g.num_edges(), 5_000);
        assert!(g.is_canonical());
    }

    #[test]
    fn rmat_is_deterministic() {
        let a = rmat(512, 2_000, RmatParams::default(), 7);
        let b = rmat(512, 2_000, RmatParams::default(), 7);
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn rmat_degree_distribution_is_skewed() {
        // Power-law-ish: the max degree should far exceed the average.
        let g = rmat(1 << 12, 40_000, RmatParams::default(), 3);
        let deg = g.out_degrees();
        let max = *deg.iter().max().unwrap() as f64;
        let avg = 40_000.0 / 4096.0;
        assert!(max > 10.0 * avg, "max={max} avg={avg}");
    }

    #[test]
    fn erdos_renyi_is_flat_by_comparison() {
        let g = erdos_renyi(1 << 12, 40_000, 3);
        assert_eq!(g.num_edges(), 40_000);
        let deg = g.out_degrees();
        let max = *deg.iter().max().unwrap() as f64;
        let avg = 40_000.0 / 4096.0;
        assert!(max < 6.0 * avg, "max={max} avg={avg}");
    }

    #[test]
    fn preferential_attachment_connects_everything() {
        let g = preferential_attachment(200, 2, 11).symmetrize();
        let csr = crate::graph::Csr::from_coo(&g);
        // BFS from 0 reaches all vertices.
        let mut seen = vec![false; 200];
        let mut stack = vec![0u32];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for (n, _) in csr.neighbors(v) {
                if !seen[n as usize] {
                    seen[n as usize] = true;
                    stack.push(n);
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn generators_exclude_self_loops() {
        for g in [
            rmat(256, 1_000, RmatParams::default(), 5),
            erdos_renyi(256, 1_000, 5),
            preferential_attachment(256, 3, 5),
        ] {
            assert!(g.edges.iter().all(|e| e.src != e.dst));
        }
    }
}
