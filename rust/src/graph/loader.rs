//! SNAP-format edge-list loader.
//!
//! The paper evaluates on SNAP datasets [5]; this image has no network
//! access, so the presets in `datasets.rs` synthesize R-MAT equivalents —
//! but if the user *does* have the real `.txt` files, this loader ingests
//! them unchanged: `#`-comment header lines, whitespace-separated
//! `src dst [weight]` rows, vertices relabeled densely.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use anyhow::{Context, Result};

use super::coo::{Coo, Edge};

/// Parse a SNAP edge list from any reader.
pub fn parse_edge_list<R: Read>(reader: R) -> Result<Coo> {
    let mut relabel: HashMap<u64, u32> = HashMap::new();
    let mut edges = Vec::new();
    let mut next_id = 0u32;
    let id = |raw: u64, relabel: &mut HashMap<u64, u32>, next_id: &mut u32| -> u32 {
        *relabel.entry(raw).or_insert_with(|| {
            let v = *next_id;
            *next_id += 1;
            v
        })
    };

    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line.with_context(|| format!("read error at line {}", lineno + 1))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            anyhow::bail!("line {}: expected `src dst [w]`, got {t:?}", lineno + 1);
        };
        let src: u64 = a.parse().with_context(|| format!("line {}: bad src", lineno + 1))?;
        let dst: u64 = b.parse().with_context(|| format!("line {}: bad dst", lineno + 1))?;
        let w: f32 = match it.next() {
            Some(ws) => ws.parse().with_context(|| format!("line {}: bad weight", lineno + 1))?,
            None => 1.0,
        };
        let s = id(src, &mut relabel, &mut next_id);
        let d = id(dst, &mut relabel, &mut next_id);
        edges.push(Edge::weighted(s, d, w));
    }
    Ok(Coo::from_edges(next_id, edges))
}

/// Load a SNAP edge-list file.
pub fn load_edge_list<P: AsRef<Path>>(path: P) -> Result<Coo> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    parse_edge_list(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_snap_style_input() {
        let text = "# Directed graph\n# Nodes: 4 Edges: 3\n10 20\n20 30\n10\t40\n";
        let g = parse_edge_list(text.as_bytes()).unwrap();
        assert_eq!(g.num_vertices, 4);
        assert_eq!(g.num_edges(), 3);
        // Dense relabeling: 10->0, 20->1, 30->2, 40->3.
        assert!(g.edges.iter().any(|e| (e.src, e.dst) == (0, 1)));
        assert!(g.edges.iter().any(|e| (e.src, e.dst) == (0, 3)));
    }

    #[test]
    fn parses_weights() {
        let g = parse_edge_list("0 1 2.5\n1 0 0.5\n".as_bytes()).unwrap();
        assert_eq!(g.edges[0].weight, 2.5);
        assert_eq!(g.edges[1].weight, 0.5);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let g = parse_edge_list("% matrix-market comment\n\n# snap\n0 1\n".as_bytes()).unwrap();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn rejects_malformed_rows() {
        assert!(parse_edge_list("0\n".as_bytes()).is_err());
        assert!(parse_edge_list("a b\n".as_bytes()).is_err());
    }

    #[test]
    fn empty_input_is_empty_graph() {
        let g = parse_edge_list("".as_bytes()).unwrap();
        assert_eq!(g.num_vertices, 0);
        assert!(g.is_empty());
    }
}
