//! Graph substrate: storage formats, loaders, generators, dataset presets.
//!
//! The accelerator stores input graphs in COO format in main memory
//! (paper §II.B) and converts to adjacency-window views during
//! preprocessing. CSR is used by the pure-CPU reference algorithms.

pub mod coo;
pub mod csr;
pub mod datasets;
pub mod delta;
pub mod generator;
pub mod loader;
pub mod shard;
pub mod stats;

pub use coo::{Coo, Edge};
pub use csr::Csr;
pub use datasets::Dataset;
pub use delta::{DeltaBatch, DeltaError, DeltaOp, EdgeDelta};
pub use shard::{ShardGraph, Sharder};
pub use stats::GraphStats;
