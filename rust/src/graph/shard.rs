//! Block-row sharding of the edge set across N simulated accelerators.
//!
//! # The block-row split
//!
//! The partitioner (`pattern::extract`) buckets edges into C×C adjacency
//! windows keyed by `(brow, bcol) = (src/C, dst/C)`. A shard owns a
//! **contiguous range of block rows**, so every window — and therefore
//! every subgraph op — lands in exactly one shard, and the union of the
//! shards' window sets is byte-identical to the unsharded partition.
//! Contiguity is what makes the cross-shard merge deterministic (see
//! `sched::exchange`): the subgraph table sorts column-major groups by
//! `(bcol, brow)`, so concatenating the shards' same-`bcol` groups in
//! shard order reproduces the global within-group op order exactly, and
//! row-major groups (keyed by `brow`) each live wholly inside one shard.
//!
//! Every [`ShardGraph`] keeps the **global** vertex space
//! (`graph.num_vertices` is the full graph's): block coordinates,
//! `src_start`/`dst_start` and the frontier bitmap stay global indices,
//! which is what lets per-shard plans drive one shared set of vertex
//! values without any index translation at the exchange boundary.
//!
//! Two construction paths agree edge-for-edge:
//!
//! * [`split`] slices an already-canonical [`Coo`] — the row-major edge
//!   sort means each shard's edges are one contiguous slice, found by
//!   binary search, with zero re-sorting.
//! * [`Sharder`] ingests raw edge *batches* (e.g. straight from
//!   [`generator::rmat_stream`](super::generator::rmat_stream)) into
//!   per-shard buckets and canonicalizes each bucket independently —
//!   never materializing (or sorting) one giant global edge Vec. Because
//!   shards own disjoint `src` ranges, per-shard dedup/sort equals the
//!   global dedup/sort restricted to the shard: `Sharder` output is
//!   independent of batch boundaries and equal to [`split`] of the
//!   materialized graph.

use super::coo::{Coo, Edge};

/// One shard: a contiguous block-row slice of the edge set over the
/// global vertex space.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardGraph {
    pub shard_id: u32,
    pub shard_count: u32,
    /// Owned block rows `[brow_start, brow_end)` (window size C).
    pub brow_start: u32,
    pub brow_end: u32,
    /// Shard-local edges, canonical, with **global** vertex ids and the
    /// global `num_vertices`.
    pub graph: Coo,
}

impl ShardGraph {
    /// Source-vertex range `[lo, hi)` owned by this shard.
    pub fn src_range(&self, c: usize) -> (u32, u32) {
        (
            self.brow_start * c as u32,
            (self.brow_end * c as u32).min(self.graph.num_vertices),
        )
    }

    pub fn num_edges(&self) -> usize {
        self.graph.num_edges()
    }
}

/// Contiguous near-equal apportionment of `num_blocks` block rows over
/// `shards` shards: shard `i` gets `num_blocks/shards` rows plus one of
/// the `num_blocks % shards` remainder rows (lowest ids first). Shards
/// past the block count own empty ranges — legal, they just idle.
pub fn brow_ranges(num_blocks: u32, shards: u32) -> Vec<(u32, u32)> {
    let shards = shards.max(1);
    let base = num_blocks / shards;
    let rem = num_blocks % shards;
    let mut out = Vec::with_capacity(shards as usize);
    let mut start = 0u32;
    for i in 0..shards {
        let len = base + u32::from(i < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Shard index owning block row `brow` under [`brow_ranges`]'s
/// apportionment — closed-form, no range scan in the bucketing hot loop.
#[inline]
fn shard_of(brow: u32, base: u32, rem: u32) -> u32 {
    let pivot = rem * (base + 1);
    if brow < pivot {
        brow / (base + 1)
    } else {
        rem + (brow - pivot) / base.max(1)
    }
}

/// Split a canonical [`Coo`] into `shards` [`ShardGraph`]s by contiguous
/// block-row ranges (window size `c`). The row-major edge sort makes
/// each shard a contiguous slice of `g.edges`, located by binary search
/// at the range's first source vertex.
pub fn split(g: &Coo, c: usize, shards: usize) -> Vec<ShardGraph> {
    assert!(c >= 1, "window size must be >= 1");
    debug_assert!(g.is_canonical(), "split requires a canonical Coo");
    let num_blocks = g.num_vertices.div_ceil(c as u32);
    let ranges = brow_ranges(num_blocks, shards as u32);
    let mut out = Vec::with_capacity(ranges.len());
    let mut lo = 0usize;
    for (i, &(bs, be)) in ranges.iter().enumerate() {
        let src_end = (be as u64 * c as u64).min(g.num_vertices as u64) as u32;
        let hi = lo + g.edges[lo..].partition_point(|e| e.src < src_end);
        out.push(ShardGraph {
            shard_id: i as u32,
            shard_count: ranges.len() as u32,
            brow_start: bs,
            brow_end: be,
            graph: Coo {
                num_vertices: g.num_vertices,
                edges: g.edges[lo..hi].to_vec(),
            },
        });
        lo = hi;
    }
    debug_assert_eq!(lo, g.edges.len(), "every edge belongs to a shard");
    out
}

/// Reassemble the global graph from a shard set (test/diagnostic
/// inverse of [`split`]): shard edge slices are disjoint and ascending
/// in `src`, so concatenation in shard order is already canonical.
pub fn unshard(shards: &[ShardGraph]) -> Coo {
    let num_vertices = shards.first().map_or(0, |s| s.graph.num_vertices);
    let mut edges = Vec::with_capacity(shards.iter().map(ShardGraph::num_edges).sum());
    for s in shards {
        edges.extend_from_slice(&s.graph.edges);
    }
    let g = Coo { num_vertices, edges };
    debug_assert!(g.is_canonical());
    g
}

/// Streaming shard builder: ingests raw edge batches into per-shard
/// buckets and canonicalizes each bucket at [`finish`](Self::finish) —
/// the 100M+-edge path where one global sorted edge Vec would not fit
/// the budget. See the module docs for why the result is independent of
/// batch boundaries and equal to [`split`].
#[derive(Debug)]
pub struct Sharder {
    num_vertices: u32,
    c: usize,
    base: u32,
    rem: u32,
    ranges: Vec<(u32, u32)>,
    buckets: Vec<Vec<Edge>>,
}

impl Sharder {
    pub fn new(num_vertices: u32, c: usize, shards: usize) -> Self {
        assert!(c >= 1, "window size must be >= 1");
        let num_blocks = num_vertices.div_ceil(c as u32);
        let shards = shards.max(1) as u32;
        let ranges = brow_ranges(num_blocks, shards);
        Self {
            num_vertices,
            c,
            base: num_blocks / shards,
            rem: num_blocks % shards,
            ranges: ranges.clone(),
            buckets: vec![Vec::new(); ranges.len()],
        }
    }

    /// Bucket one edge batch. Out-of-range endpoints and self-loops are
    /// dropped here (cheaper than carrying them to `from_edges`, and it
    /// keeps bucket sizes honest for the memory budget).
    pub fn push(&mut self, edges: &[Edge]) {
        let c = self.c as u32;
        for e in edges {
            if e.src >= self.num_vertices || e.dst >= self.num_vertices || e.src == e.dst {
                continue;
            }
            let s = shard_of(e.src / c, self.base, self.rem) as usize;
            self.buckets[s].push(*e);
        }
    }

    /// Edges buckets currently hold (post-filter, pre-dedup).
    pub fn buffered_edges(&self) -> usize {
        self.buckets.iter().map(Vec::len).sum()
    }

    /// Canonicalize every bucket into its [`ShardGraph`].
    pub fn finish(self) -> Vec<ShardGraph> {
        let n = self.num_vertices;
        let count = self.ranges.len() as u32;
        self.buckets
            .into_iter()
            .zip(self.ranges)
            .enumerate()
            .map(|(i, (bucket, (bs, be)))| ShardGraph {
                shard_id: i as u32,
                shard_count: count,
                brow_start: bs,
                brow_end: be,
                graph: Coo::from_edges(n, bucket),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, rmat_stream, RmatParams};

    #[test]
    fn brow_ranges_cover_contiguously() {
        for (blocks, shards) in [(10u32, 3u32), (4, 4), (2, 5), (0, 3), (7, 1)] {
            let r = brow_ranges(blocks, shards);
            assert_eq!(r.len(), shards as usize);
            assert_eq!(r[0].0, 0);
            assert_eq!(r.last().unwrap().1, blocks);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
            }
            // Near-equal: sizes differ by at most one block.
            let sizes: Vec<u32> = r.iter().map(|&(a, b)| b - a).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn shard_of_matches_ranges() {
        for (blocks, shards) in [(10u32, 3u32), (4, 4), (2, 5), (13, 6)] {
            let ranges = brow_ranges(blocks, shards);
            let (base, rem) = (blocks / shards, blocks % shards);
            for brow in 0..blocks {
                let s = shard_of(brow, base, rem);
                let (lo, hi) = ranges[s as usize];
                assert!((lo..hi).contains(&brow), "brow {brow} shard {s}");
            }
        }
    }

    #[test]
    fn split_partitions_every_edge_exactly_once() {
        let g = rmat(512, 4_000, RmatParams::default(), 9);
        for shards in [1usize, 2, 3, 4, 7] {
            let sh = split(&g, 4, shards);
            assert_eq!(sh.len(), shards);
            let total: usize = sh.iter().map(ShardGraph::num_edges).sum();
            assert_eq!(total, g.num_edges());
            for s in &sh {
                assert_eq!(s.graph.num_vertices, g.num_vertices, "global vertex space");
                assert!(s.graph.is_canonical());
                let (lo, hi) = s.src_range(4);
                assert!(s.graph.edges.iter().all(|e| (lo..hi.max(lo)).contains(&e.src)));
            }
            assert_eq!(unshard(&sh).edges, g.edges, "unshard inverts split");
        }
    }

    #[test]
    fn split_one_shard_is_the_whole_graph() {
        let g = rmat(256, 2_000, RmatParams::default(), 3);
        let sh = split(&g, 4, 1);
        assert_eq!(sh.len(), 1);
        assert_eq!(sh[0].graph.edges, g.edges);
        assert_eq!((sh[0].brow_start, sh[0].brow_end), (0, 256u32.div_ceil(4)));
    }

    #[test]
    fn more_shards_than_blocks_idle_harmlessly() {
        let g = rmat(8, 20, RmatParams::default(), 1);
        let sh = split(&g, 4, 5); // 2 block rows, 5 shards
        assert_eq!(sh.len(), 5);
        let total: usize = sh.iter().map(ShardGraph::num_edges).sum();
        assert_eq!(total, g.num_edges());
        assert!(sh[2..].iter().all(|s| s.graph.is_empty()));
    }

    #[test]
    fn sharder_is_batch_invariant_and_equals_split() {
        // Stream the same candidate sequence at several batch sizes; all
        // must equal split() of the materialized graph.
        let (n, edges, seed) = (512u32, 6_000usize, 17u64);
        let mut all = Vec::new();
        rmat_stream(n, edges, RmatParams::default(), seed, 256, |b| {
            all.extend_from_slice(b)
        });
        let g = Coo::from_edges(n, all);
        for shards in [1usize, 2, 4] {
            let want = split(&g, 4, shards);
            for batch in [1usize, 97, 1024, edges] {
                let mut sharder = Sharder::new(n, 4, shards);
                rmat_stream(n, edges, RmatParams::default(), seed, batch, |b| {
                    sharder.push(b)
                });
                let got = sharder.finish();
                assert_eq!(got, want, "shards {shards} batch {batch}");
            }
        }
    }

    #[test]
    fn sharder_filters_invalid_edges() {
        let mut s = Sharder::new(8, 2, 2);
        s.push(&[
            Edge::new(0, 1),
            Edge::new(3, 3),  // self-loop
            Edge::new(9, 1),  // out of range
            Edge::new(1, 20), // out of range
        ]);
        assert_eq!(s.buffered_edges(), 1);
        let sh = s.finish();
        assert_eq!(sh.iter().map(ShardGraph::num_edges).sum::<usize>(), 1);
    }
}
