//! Graph statistics (paper Table 2 columns): vertex/edge counts, average
//! degree, sparsity, degree histogram.

use super::coo::Coo;

#[derive(Debug, Clone, PartialEq)]
pub struct GraphStats {
    pub num_vertices: u32,
    pub num_edges: usize,
    pub avg_degree: f64,
    /// Fraction of zero entries in the adjacency matrix, in percent
    /// (Table 2 reports e.g. 99.795 % for Wiki-Vote).
    pub sparsity_pct: f64,
    pub max_out_degree: u32,
}

impl GraphStats {
    pub fn of(g: &Coo) -> Self {
        let n = g.num_vertices as f64;
        let m = g.num_edges() as f64;
        let deg = g.out_degrees();
        Self {
            num_vertices: g.num_vertices,
            num_edges: g.num_edges(),
            avg_degree: if n > 0.0 { m / n } else { 0.0 },
            sparsity_pct: if n > 0.0 { 100.0 * (1.0 - m / (n * n)) } else { 100.0 },
            max_out_degree: deg.iter().copied().max().unwrap_or(0),
        }
    }
}

/// Degree histogram in log2 buckets: bucket 0 holds degrees 0 and 1;
/// bucket k ≥ 1 holds degrees in `[2^(k-1), 2^k)` shifted up — i.e. a
/// vertex of degree d lands in bucket `floor(log2 d) + 1`.
pub fn degree_histogram_log2(g: &Coo) -> Vec<usize> {
    let mut hist = vec![0usize; 33];
    for d in g.out_degrees() {
        let bucket = if d <= 1 { 0 } else { (32 - d.leading_zeros()) as usize };
        hist[bucket] += 1;
    }
    while hist.len() > 1 && *hist.last().unwrap() == 0 {
        hist.pop();
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::Edge;

    #[test]
    fn stats_of_toy_graph() {
        let g = Coo::from_edges(4, vec![Edge::new(0, 1), Edge::new(0, 2), Edge::new(1, 2)]);
        let s = GraphStats::of(&g);
        assert_eq!(s.num_vertices, 4);
        assert_eq!(s.num_edges, 3);
        assert!((s.avg_degree - 0.75).abs() < 1e-12);
        assert!((s.sparsity_pct - 100.0 * (1.0 - 3.0 / 16.0)).abs() < 1e-9);
        assert_eq!(s.max_out_degree, 2);
    }

    #[test]
    fn stats_of_empty() {
        let s = GraphStats::of(&Coo::default());
        assert_eq!(s.num_edges, 0);
        assert_eq!(s.sparsity_pct, 100.0);
    }

    #[test]
    fn histogram_buckets() {
        // degrees: v0=5 (bucket 3: floor(log2 5)+1), v1=1 (bucket 0)
        let g = Coo::from_edges(
            8,
            vec![
                Edge::new(0, 1),
                Edge::new(0, 2),
                Edge::new(0, 3),
                Edge::new(0, 4),
                Edge::new(0, 5),
                Edge::new(1, 0),
            ],
        );
        let h = degree_histogram_log2(&g);
        assert_eq!(h[0], 7); // v1 plus six zero-degree vertices
        assert_eq!(h[3], 1); // v0 (degree 5)
    }
}
