//! # repro — pattern-aware ReRAM graph accelerator
//!
//! Reproduction of *"Leveraging Recurrent Patterns in Graph Accelerators"*
//! (Rahimi & Le Beux, CS.AR 2025): a graph accelerator that partitions the
//! adjacency matrix with a non-overlapping C×C window, ranks the resulting
//! subgraph *patterns* by frequency, and pins the most frequent patterns
//! into **static** graph engines (ReRAM crossbars written once) while the
//! long tail runs on **dynamic** engines (reconfigured at runtime).
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the coordinator: graph substrate, window
//!   partitioner + pattern ranking (Alg. 1), streaming-apply scheduler with
//!   static/dynamic dispatch (Alg. 2), ReRAM engine + cost models
//!   (Table 3), baselines (GraphR / SparseMEM / TARe), DSE, lifetime
//!   analysis, reports, CLI, and an async serving loop.
//! * **L2/L1 (python, build-time only)** — JAX batch-step models calling
//!   Pallas crossbar kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **runtime** — loads the HLO artifacts via the `xla` crate (PJRT CPU
//!   client) and executes them from the rust hot path; python never runs
//!   at request time.

pub mod accel;
pub mod algo;
pub mod baselines;
pub mod coordinator;
pub mod cost;
pub mod dse;
pub mod engine;
pub mod graph;
pub mod pattern;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod session;
pub mod util;

pub use accel::config::ArchConfig;
pub use accel::simulator::{Accelerator, SimReport};
pub use algo::registry::{AlgoParams, AlgorithmId, AlgorithmRegistry};
pub use graph::coo::Coo;
pub use graph::csr::Csr;
pub use pattern::pattern::Pattern;
pub use session::{Backend, JobSpec, Session, SessionBuilder};
