//! `repro` — CLI for the pattern-aware ReRAM graph accelerator.
//!
//! Subcommands map onto the paper's artifacts: `preprocess` (Alg. 1),
//! `run` (Alg. 2 on a dataset/algorithm), `figure` (regenerate any
//! table/figure of the evaluation), `dse` (best static split),
//! `datasets` (Table 2), and `serve` (the leader/worker serving loop).

use anyhow::Result;

use repro::accel::{Accelerator, ArchConfig, PolicyKind};
use repro::algo::{Bfs, PageRank, Sssp, Wcc};
use repro::coordinator::{Job, Service, ServiceConfig};
use repro::cost::CostParams;
use repro::graph::datasets::{Dataset, ALL_DATASETS};
use repro::graph::GraphStats;
use repro::report::{figures, Table};
use repro::sched::executor::NativeExecutor;
use repro::sched::StepExecutor;
use repro::util::cli::Args;
use repro::util::fmt;

const USAGE: &str = "\
repro — pattern-aware ReRAM graph accelerator (CS.AR 2025 reproduction)

USAGE:
  repro preprocess <DATASET> [--scale F] [arch options]
  repro run <DATASET> [--algo bfs|sssp|pagerank|wcc] [--source N]
            [--scale F] [--backend native|pjrt] [--validate] [arch options]
  repro figure <fig1|fig5|fig6|fig7|table1|table4|lifetime|all> [--scale F]
  repro dse <DATASET> [--scale F] [arch options]
  repro datasets
  repro serve [--jobs N] [--workers N]

DATASET: WG AZ SD EP PG WV TN (Table 2 presets; TN = tiny test graph)

ARCH OPTIONS:
  --crossbar C              crossbar size (1..=8, default 4)
  --engines T               total graph engines (default 32)
  --static-engines N        static graph engines (default 16)
  --crossbars-per-engine M  crossbars per engine (default 1)
  --policy P                lru | rr | lfu | random (default lru)
";

fn arch_from(args: &Args) -> Result<ArchConfig> {
    let policy_s: String = args.get_or("policy", "lru".to_string())?;
    let policy = PolicyKind::parse(&policy_s)
        .ok_or_else(|| anyhow::anyhow!("unknown policy {policy_s:?}"))?;
    let cfg = ArchConfig {
        crossbar_size: args.get_or("crossbar", 4usize)?,
        total_engines: args.get_or("engines", 32u32)?,
        static_engines: args.get_or("static-engines", 16u32)?,
        crossbars_per_engine: args.get_or("crossbars-per-engine", 1u32)?,
        policy,
        ..ArchConfig::default()
    };
    cfg.validate()?;
    Ok(cfg)
}

fn parse_dataset(s: &str) -> Result<Dataset> {
    Dataset::from_short(s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {s:?}; expected WG AZ SD EP PG WV TN"))
}

fn scale_for(d: Dataset, args: &Args) -> Result<f64> {
    Ok(args
        .get_parsed::<f64>("scale")?
        .unwrap_or_else(|| figures::default_scale(d)))
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["validate", "help"])?;
    if args.flag("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = args.positional[0].as_str();
    match cmd {
        "preprocess" => cmd_preprocess(&args),
        "run" => cmd_run(&args),
        "figure" => cmd_figure(&args),
        "dse" => cmd_dse(&args),
        "datasets" => cmd_datasets(),
        "serve" => cmd_serve(&args),
        _ => {
            print!("{USAGE}");
            anyhow::bail!("unknown command {cmd:?}")
        }
    }
}

fn dataset_arg(args: &Args) -> Result<Dataset> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("missing <DATASET>\n{USAGE}"))?;
    parse_dataset(name)
}

fn cmd_preprocess(args: &Args) -> Result<()> {
    let d = dataset_arg(args)?;
    let g = d.load_scaled(scale_for(d, args)?)?;
    let acc = Accelerator::new(arch_from(args)?, CostParams::default());
    let pre = acc.preprocess(&g, false)?;
    let s = GraphStats::of(&g);
    println!(
        "{}: {} vertices, {} edges, avg degree {:.1}, sparsity {:.3}%",
        d.spec().name,
        fmt::count(s.num_vertices as u64),
        fmt::count(s.num_edges as u64),
        s.avg_degree,
        s.sparsity_pct
    );
    println!(
        "subgraphs: {}   distinct patterns: {}   top-16 coverage: {:.1}%   static coverage (N*M={}): {:.1}%",
        fmt::count(pre.part.num_subgraphs() as u64),
        pre.ranking.num_patterns(),
        pre.ranking.coverage(16) * 100.0,
        acc.config.static_capacity(),
        pre.static_coverage() * 100.0
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let d = dataset_arg(args)?;
    let algo: String = args.get_or("algo", "bfs".to_string())?;
    let source: u32 = args.get_or("source", 0u32)?;
    let backend: String = args.get_or("backend", "native".to_string())?;
    let sc = scale_for(d, args)?;
    let weighted = algo == "sssp";
    let g = if weighted { d.load_weighted(sc)? } else { d.load_scaled(sc)? };
    let acc = Accelerator::new(arch_from(args)?, CostParams::default());

    let mut native = NativeExecutor;
    let mut pjrt_holder;
    let exec: &mut dyn StepExecutor = match backend.as_str() {
        "native" => &mut native,
        "pjrt" => {
            pjrt_holder = repro::runtime::PjrtExecutor::from_default_dir()?;
            &mut pjrt_holder
        }
        other => anyhow::bail!("unknown backend {other:?} (native|pjrt)"),
    };

    let report = match algo.as_str() {
        "bfs" => acc.simulate(&g, &Bfs::new(source), exec)?,
        "sssp" => acc.simulate(&g, &Sssp::new(source), exec)?,
        "pagerank" => acc.simulate(&g, &PageRank::default(), exec)?,
        "wcc" => acc.simulate(&g, &Wcc, exec)?,
        other => anyhow::bail!("unknown algo {other:?} (bfs|sssp|pagerank|wcc)"),
    };

    let mut t = Table::new(format!(
        "{} on {} ({backend} backend)",
        report.algorithm,
        d.spec().name
    ))
    .header(["metric", "value"]);
    t.row(["energy", &fmt::energy(report.energy_j())]);
    t.row(["exec time (modeled)", &fmt::time(report.exec_time_s())]);
    t.row(["supersteps", &report.supersteps.to_string()]);
    t.row(["iterations", &fmt::count(report.iterations)]);
    t.row(["subgraph ops", &fmt::count(report.counts.mvm_ops)]);
    t.row(["static hit rate", &format!("{:.1}%", report.static_hit_rate * 100.0)]);
    t.row(["ReRAM write bits", &fmt::count(report.counts.write_bits)]);
    t.row(["max cell writes", &fmt::count(report.max_cell_writes)]);
    print!("{}", t.render());

    if args.flag("validate") {
        let csr = repro::graph::Csr::from_coo(&g);
        let run = report.run.as_ref().unwrap();
        let want = match algo.as_str() {
            "bfs" => repro::algo::reference::bfs_levels(&csr, source),
            "sssp" => repro::algo::reference::sssp_distances(&csr, source),
            "pagerank" => repro::algo::reference::pagerank(&csr, 0.85, 20),
            _ => repro::algo::reference::wcc_labels(&csr),
        };
        let worst = run
            .values
            .iter()
            .zip(&want)
            .map(|(a, b)| if *a >= 1e9 && *b >= 1e9 { 0.0 } else { (a - b).abs() })
            .fold(0.0f32, f32::max);
        println!("validation vs CPU reference: max abs error = {worst:.2e}");
        anyhow::ensure!(worst < 1e-2, "validation FAILED");
        println!("validation OK");
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing figure id\n{USAGE}"))?;
    let scale = args.get_parsed::<f64>("scale")?;
    let render = |id: &str| -> Result<String> {
        match id {
            "fig1" => figures::fig1(scale),
            "fig5" => figures::fig5(scale),
            "fig6" => figures::fig6(scale),
            "fig7" => figures::fig7(scale),
            "table1" => figures::table1(),
            "table4" => figures::table4(scale),
            "lifetime" => figures::lifetime(scale),
            other => anyhow::bail!(
                "unknown figure {other:?}; expected fig1|fig5|fig6|fig7|table1|table4|lifetime|all"
            ),
        }
    };
    if id == "all" {
        for id in ["table1", "fig1", "fig5", "fig6", "table4", "fig7", "lifetime"] {
            println!("{}", render(id)?);
        }
    } else {
        println!("{}", render(id)?);
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let d = dataset_arg(args)?;
    let g = d.load_scaled(scale_for(d, args)?)?;
    let cfg = arch_from(args)?;
    let (best, points) = repro::dse::find_best_static_split(
        &g,
        &cfg,
        &CostParams::default(),
        &Bfs::new(0),
        None,
    )?;
    let mut t = Table::new(format!("DSE: static-engine split on {}", d.spec().name))
        .header(["N static", "speedup vs N=0", "energy", "static hit rate"]);
    for p in &points {
        t.row([
            p.x.to_string(),
            format!("{:.2}x", p.speedup),
            fmt::energy(p.energy_j),
            format!("{:.1}%", p.static_hit_rate * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!("best static split: N = {best} (of T = {})", cfg.total_engines);
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    let mut t = Table::new("Table 2: graph datasets (paper spec; generated as seeded R-MAT)")
        .header(["name", "short", "vertices", "edges", "avg deg", "sparsity", "domain"]);
    for d in ALL_DATASETS {
        let s = d.spec();
        t.row([
            s.name.to_string(),
            s.short.to_string(),
            fmt::count(s.vertices as u64),
            fmt::count(s.edges as u64),
            s.avg_degree.to_string(),
            format!("{:.3}%", s.sparsity_pct),
            s.domain.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let jobs: usize = args.get_or("jobs", 16usize)?;
    let workers: usize = args.get_or("workers", 2usize)?;
    let svc = Service::spawn(ServiceConfig { workers, ..ServiceConfig::default() });
    let pending: Vec<_> = (0..jobs)
        .map(|i| {
            let job = match i % 3 {
                0 => Job::Bfs { dataset: Dataset::Tiny, scale: 1.0, source: i as u32 },
                1 => Job::PageRank { dataset: Dataset::Tiny, scale: 1.0, iterations: 5 },
                _ => Job::Wcc { dataset: Dataset::Tiny, scale: 1.0 },
            };
            svc.submit(job)
        })
        .collect::<Result<_>>()?;
    for p in pending {
        let r = p.wait()?;
        println!(
            "job {} done in {} µs ({} subgraph ops)",
            r.report.algorithm,
            r.wall_time_us,
            fmt::count(r.report.counts.mvm_ops)
        );
    }
    let s = svc.metrics.snapshot();
    println!(
        "served {} jobs, mean latency {:.0} µs, max {} µs, {} total subgraph ops",
        s.jobs_completed,
        s.mean_latency_us,
        s.max_latency_us,
        fmt::count(s.subgraph_ops)
    );
    Ok(())
}
