//! `repro` — CLI for the pattern-aware ReRAM graph accelerator.
//!
//! Subcommands map onto the paper's artifacts: `preprocess` (Alg. 1),
//! `run` (Alg. 2 on a dataset/algorithm), `figure` (regenerate any
//! table/figure of the evaluation), `dse` (best static split),
//! `datasets` (Table 2), `serve` (the leader/worker serving loop), and
//! `loadgen` (scripted open/closed-loop traffic studies against it).
//!
//! Every pipeline-building command is a thin adapter over
//! [`Session`](repro::session::Session): one facade wires architecture,
//! cost model, backend, algorithm registry and the shared artifact cache
//! for `run`, `serve` and `dse` alike.

use std::sync::Arc;

use anyhow::Result;

use repro::accel::{ArchConfig, PolicyKind};
use repro::algo::reference;
use repro::coordinator::{loadgen, LoadMode, LoadgenConfig, Service, ServiceConfig};
use repro::graph::datasets::{Dataset, ALL_DATASETS};
use repro::graph::{Csr, DeltaBatch, EdgeDelta, GraphStats};
use repro::report::{figures, Table};
use repro::session::{Backend, DiskStore, JobSpec, Session};
use repro::util::cli::Args;
use repro::util::fmt;

const USAGE: &str = "\
repro — pattern-aware ReRAM graph accelerator (CS.AR 2025 reproduction)

USAGE:
  repro preprocess <DATASET> [--scale F] [arch options]
  repro run <DATASET> [--algo NAME] [--source N] [--iterations K]
            [--damping D] [--scale F] [--backend native|pjrt]
            [--validate] [arch options]
  repro figure <fig1|fig5|fig6|fig7|table1|table4|lifetime|all> [--scale F]
  repro dse <DATASET> [--algo NAME] [--scale F] [arch options]
  repro datasets
  repro serve [--jobs N] [--workers N] [--backend native|pjrt]
              [--dataset DATASET] [--scale F] [--max-batch B]
              [arch options]
  repro loadgen [--dataset DATASET] [--jobs N] [--workers N]
                [--mode closed|open] [--concurrency C] [--rate R]
                [--deadline-ms MS] [--queue-depth Q] [--max-batch B]
                [--sources S] [--seed N] [--algo NAME] [--scale F]
                [--out FILE] [arch options]
  repro artifacts warm <DATASET> --artifact-dir DIR [--algo NAME]
                  [--scale F] [--shards N] [--assert-warm] [arch options]
  repro artifacts ls --artifact-dir DIR
  repro mutate <DATASET> [--deltas FILE] [--scale F]
               [--artifact-dir DIR] [arch options]

Algorithms are session-registry entries (bfs sssp pagerank wcc built in;
library users register more — no CLI change needed). `serve` submits one
mixed batch cycling through every registered algorithm and prints
per-algorithm completion/shed/coalesced counters, queue depths, and
split queue-wait vs execution latency percentiles (p50/p99/p999) on
shutdown. Both `run` and `serve` honor --backend; a PJRT selection
without artifacts fails loudly instead of falling back to native.

`loadgen` replays a deterministic seeded mixed-algorithm trace against
a fresh service in open-loop (--mode open --rate R jobs/s, arrivals
independent of completions — the overload view) or closed-loop
(--mode closed --concurrency C virtual clients — the throughput view),
optionally with a per-job deadline budget (--deadline-ms, expired jobs
are load-shed and counted) and a bounded queue (--queue-depth, submit
blocks when full). --sources 1 makes every job of an algorithm
identical — maximum request-coalescing pressure. The scenario report
(throughput, shed/coalesced/batched counts, latency percentiles) prints
and lands as JSON at --out (default BENCH_serve.json).

--max-batch B (serve and loadgen, default 1 = off) lets each worker
claim up to B batch-compatible queued jobs — same dataset, scale,
algorithm and result-determining params, differing only in source —
at dequeue and run them as one multi-source batch, paying the plan
walk and crossbar replay once per batch. Purely a scheduling knob:
every job's result is bit-identical to its solo run, and batching
never widens coalescing (batch key and coalesce key are distinct).

Every pipeline command accepts --artifact-dir DIR: preprocessed
artifacts — including the compiled execution plan — are serialized
there (versioned + checksummed) and reloaded by later processes, so a
warm start performs zero plan compilations. `artifacts warm` pre-bakes
a directory (every registered algorithm unless --algo narrows it;
--assert-warm exits nonzero if anything had to be compiled — the CI
cache-reuse check), `artifacts ls` lists what a directory holds.

`mutate` streams edge deltas into the dataset's cached artifacts:
every cached plan (memory and --artifact-dir tiers, weighted and
unweighted) is patched in place — dirty adjacency windows only, never
a recompile — and patched files are re-persisted with their delta
provenance (visible in `artifacts ls`). --deltas FILE holds one
mutation per line (`+ src dst [weight]` add, `- src dst` remove,
`= src dst weight` reweight, `#` comments); without it a demo churn
removes the first edge and re-adds it in a second batch.

DATASET: WG AZ SD EP PG WV TN (Table 2 presets; TN = tiny test graph)

ARCH OPTIONS:
  --crossbar C              crossbar size (1..=8, default 4)
  --engines T               total graph engines (default 32)
  --static-engines N        static graph engines (default 16)
  --crossbars-per-engine M  crossbars per engine (default 1)
  --policy P                lru | rr | lfu | random (default lru)
  --threads K               superstep execution lanes served by the
                            session's persistent worker pool, spawned
                            once and reused across jobs (default 1 =
                            sequential, 0 = one per hardware thread);
                            cold preprocessing (Alg. 1 + plan
                            compilation) fans out over the same pooled
                            workers on a cache miss, overridable via
                            REPRO_PREPROCESS_THREADS; results and
                            compiled artifacts are bit-identical for
                            every K
  --shards N                split the graph into N contiguous block-row
                            shards, each compiled and cached as its own
                            artifact and executed in lockstep supersteps
                            with deterministic cross-shard frontier
                            exchange (default 1); a scheduling knob like
                            --threads — results are bit-identical for
                            every N and identical jobs still coalesce
                            across different shard counts
";

fn arch_from(args: &Args) -> Result<ArchConfig> {
    let policy_s: String = args.get_or("policy", "lru".to_string())?;
    let policy = PolicyKind::parse(&policy_s)
        .ok_or_else(|| anyhow::anyhow!("unknown policy {policy_s:?}"))?;
    let cfg = ArchConfig {
        crossbar_size: args.get_or("crossbar", 4usize)?,
        total_engines: args.get_or("engines", 32u32)?,
        static_engines: args.get_or("static-engines", 16u32)?,
        crossbars_per_engine: args.get_or("crossbars-per-engine", 1u32)?,
        policy,
        ..ArchConfig::default()
    };
    cfg.validate()?;
    Ok(cfg)
}

/// The one place the CLI constructs the pipeline: arch + backend in, a
/// validated `Session` out.
fn session_from(args: &Args) -> Result<Session> {
    let backend_s: String = args.get_or("backend", "native".to_string())?;
    let mut builder = Session::builder()
        .arch(arch_from(args)?)
        .backend(Backend::parse(&backend_s)?)
        .parallelism(args.get_or("threads", 1usize)?)
        .shards(args.get_or("shards", 1u32)?);
    if let Some(dir) = args.get_path("artifact-dir") {
        builder = builder.artifact_dir(dir);
    }
    builder.build()
}

fn spec_from(args: &Args, dataset: Dataset) -> Result<JobSpec> {
    let algo: String = args.get_or("algo", "bfs".to_string())?;
    let mut spec = JobSpec::new(dataset, algo.as_str()).with_scale(scale_for(dataset, args)?);
    if let Some(source) = args.get_parsed::<u32>("source")? {
        spec = spec.with_source(source);
    }
    if let Some(iters) = args.get_parsed::<usize>("iterations")? {
        spec = spec.with_iterations(iters);
    }
    if let Some(damping) = args.get_parsed::<f32>("damping")? {
        spec = spec.with_damping(damping);
    }
    Ok(spec)
}

fn parse_dataset(s: &str) -> Result<Dataset> {
    Dataset::from_short(s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {s:?}; expected WG AZ SD EP PG WV TN"))
}

fn scale_for(d: Dataset, args: &Args) -> Result<f64> {
    Ok(args
        .get_parsed::<f64>("scale")?
        .unwrap_or_else(|| figures::default_scale(d)))
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["validate", "help", "assert-warm"])?;
    if args.flag("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = args.positional[0].as_str();
    match cmd {
        "preprocess" => cmd_preprocess(&args),
        "run" => cmd_run(&args),
        "figure" => cmd_figure(&args),
        "dse" => cmd_dse(&args),
        "datasets" => cmd_datasets(),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "artifacts" => cmd_artifacts(&args),
        "mutate" => cmd_mutate(&args),
        _ => {
            print!("{USAGE}");
            anyhow::bail!("unknown command {cmd:?}")
        }
    }
}

fn dataset_arg(args: &Args) -> Result<Dataset> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("missing <DATASET>\n{USAGE}"))?;
    parse_dataset(name)
}

fn cmd_preprocess(args: &Args) -> Result<()> {
    let d = dataset_arg(args)?;
    let session = session_from(args)?;
    let spec = JobSpec::new(d, "bfs").with_scale(scale_for(d, args)?);
    let g = session.load_graph(&spec)?;
    let pre = session.preprocess_on(&spec, &g)?;
    let s = GraphStats::of(&g);
    println!(
        "{}: {} vertices, {} edges, avg degree {:.1}, sparsity {:.3}%",
        d.spec().name,
        fmt::count(s.num_vertices as u64),
        fmt::count(s.num_edges as u64),
        s.avg_degree,
        s.sparsity_pct
    );
    println!(
        "subgraphs: {}   distinct patterns: {}   top-16 coverage: {:.1}%   static coverage (N*M={}): {:.1}%",
        fmt::count(pre.part.num_subgraphs() as u64),
        pre.ranking.num_patterns(),
        pre.ranking.coverage(16) * 100.0,
        session.arch().static_capacity(),
        pre.static_coverage() * 100.0
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let d = dataset_arg(args)?;
    let session = session_from(args)?;
    let spec = spec_from(args, d)?;
    // Load once; `run_on` feeds the same graph to preprocessing and
    // `--validate` reuses it for the reference oracle.
    let g = session.load_graph(&spec)?;
    let report = session.run_on(&spec, &g)?;

    let mut t = Table::new(format!(
        "{} on {} ({} backend)",
        report.algorithm,
        d.spec().name,
        session.backend().name()
    ))
    .header(["metric", "value"]);
    t.row(["energy", &fmt::energy(report.energy_j())]);
    t.row(["exec time (modeled)", &fmt::time(report.exec_time_s())]);
    t.row(["supersteps", &report.supersteps.to_string()]);
    t.row(["iterations", &fmt::count(report.iterations)]);
    t.row(["subgraph ops", &fmt::count(report.counts.mvm_ops)]);
    t.row(["static hit rate", &format!("{:.1}%", report.static_hit_rate * 100.0)]);
    t.row(["ReRAM write bits", &fmt::count(report.counts.write_bits)]);
    t.row(["max cell writes", &fmt::count(report.max_cell_writes)]);
    if session.shards() > 1 {
        t.row(["shards", &session.shards().to_string()]);
    }
    print!("{}", t.render());

    if args.flag("validate") {
        let csr = Csr::from_coo(&g);
        let run = report.run.as_ref().unwrap();
        let want = match spec.algorithm.as_str() {
            "bfs" => reference::bfs_levels(&csr, spec.params.source),
            "sssp" => reference::sssp_distances(&csr, spec.params.source),
            "pagerank" => {
                reference::pagerank(&csr, spec.params.damping, spec.params.iterations)
            }
            "wcc" => reference::wcc_labels(&csr),
            other => anyhow::bail!("no CPU reference oracle for algorithm {other:?}"),
        };
        let worst = run
            .values
            .iter()
            .zip(&want)
            .map(|(a, b)| if *a >= 1e9 && *b >= 1e9 { 0.0 } else { (a - b).abs() })
            .fold(0.0f32, f32::max);
        println!("validation vs CPU reference: max abs error = {worst:.2e}");
        anyhow::ensure!(worst < 1e-2, "validation FAILED");
        println!("validation OK");
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing figure id\n{USAGE}"))?;
    let scale = args.get_parsed::<f64>("scale")?;
    let render = |id: &str| -> Result<String> {
        match id {
            "fig1" => figures::fig1(scale),
            "fig5" => figures::fig5(scale),
            "fig6" => figures::fig6(scale),
            "fig7" => figures::fig7(scale),
            "table1" => figures::table1(),
            "table4" => figures::table4(scale),
            "lifetime" => figures::lifetime(scale),
            other => anyhow::bail!(
                "unknown figure {other:?}; expected fig1|fig5|fig6|fig7|table1|table4|lifetime|all"
            ),
        }
    };
    if id == "all" {
        for id in ["table1", "fig1", "fig5", "fig6", "table4", "fig7", "lifetime"] {
            println!("{}", render(id)?);
        }
    } else {
        println!("{}", render(id)?);
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let d = dataset_arg(args)?;
    let session = session_from(args)?;
    let spec = spec_from(args, d)?;
    let (best, points) = session.dse(&spec, None)?;
    let mut t = Table::new(format!(
        "DSE: static-engine split on {} ({})",
        d.spec().name,
        spec.algorithm
    ))
    .header(["N static", "speedup vs N=0", "energy", "static hit rate"]);
    for p in &points {
        t.row([
            p.x.to_string(),
            format!("{:.2}x", p.speedup),
            fmt::energy(p.energy_j),
            format!("{:.1}%", p.static_hit_rate * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!(
        "best static split: N = {best} (of T = {})",
        session.arch().total_engines
    );
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    let mut t = Table::new("Table 2: graph datasets (paper spec; generated as seeded R-MAT)")
        .header(["name", "short", "vertices", "edges", "avg deg", "sparsity", "domain"]);
    for d in ALL_DATASETS {
        let s = d.spec();
        t.row([
            s.name.to_string(),
            s.short.to_string(),
            fmt::count(s.vertices as u64),
            fmt::count(s.edges as u64),
            s.avg_degree.to_string(),
            format!("{:.3}%", s.sparsity_pct),
            s.domain.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing artifacts subcommand (warm|ls)\n{USAGE}"))?;
    match sub {
        "warm" => cmd_artifacts_warm(args),
        "ls" => cmd_artifacts_ls(args),
        other => {
            print!("{USAGE}");
            anyhow::bail!("unknown artifacts subcommand {other:?} (warm|ls)")
        }
    }
}

/// Pre-bake the on-disk artifact cache: preprocess (and persist) every
/// registered algorithm's key for the dataset, then report the cache
/// counters. With `--assert-warm`, exit nonzero unless the whole pass
/// performed zero plan compilations — the CI cache-reuse check.
fn cmd_artifacts_warm(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(2)
        .ok_or_else(|| anyhow::anyhow!("missing <DATASET>\n{USAGE}"))?;
    let d = parse_dataset(name)?;
    let dir = args.require_path("artifact-dir")?;
    let session = session_from(args)?; // consumes --artifact-dir
    let scale = scale_for(d, args)?;
    let algos: Vec<String> = match args.get("algo") {
        Some(a) => vec![a.to_string()],
        None => session.registry().ids().map(|id| id.as_str().to_string()).collect(),
    };
    for algo in &algos {
        let spec = JobSpec::new(d, algo.as_str()).with_scale(scale);
        if session.shards() > 1 {
            // Warm the whole shard set: one artifact per shard, all
            // persisted, so a later sharded serve is a pure disk-hit.
            let pres = session.preprocess_sharded(&spec)?;
            let ops: usize = pres.iter().map(|p| p.plan.num_ops()).sum();
            println!(
                "  {algo:>9}: {} shard artifact(s), {} plan ops total, {} patterns",
                pres.len(),
                ops,
                pres[0].ranking.num_patterns()
            );
        } else {
            let pre = session.preprocess(&spec)?;
            println!(
                "  {algo:>9}: {} plan ops, {} patterns, static coverage {:.1}%",
                pre.plan.num_ops(),
                pre.ranking.num_patterns(),
                pre.static_coverage() * 100.0
            );
        }
    }
    let s = session.artifacts().stats();
    println!(
        "artifact cache {}: {} compiles, {} disk hits, {} disk misses, {} writes, {} resident",
        dir.display(),
        s.misses,
        s.disk_hits,
        s.disk_misses,
        s.writes,
        s.entries
    );
    let ph = session.preprocess_phases();
    if ph.compiles > 0 {
        println!("preprocess phases: {}", ph.summary());
    }
    if args.flag("assert-warm") {
        anyhow::ensure!(
            s.misses == 0 && s.disk_hits > 0,
            "--assert-warm: cache was cold ({} compiles, {} disk hits) — pre-bake {} first",
            s.misses,
            s.disk_hits,
            dir.display()
        );
        println!("warm: zero plan compilations — every plan loaded from disk");
    }
    Ok(())
}

/// List a directory's serialized artifacts (version, key, size).
fn cmd_artifacts_ls(args: &Args) -> Result<()> {
    let dir = args.require_path("artifact-dir")?;
    // Inspection must not mutate: a typo'd path should error, not be
    // silently created and reported as an empty (cold) cache.
    anyhow::ensure!(
        dir.is_dir(),
        "no such artifact directory: {} (artifacts ls never creates one)",
        dir.display()
    );
    let store = DiskStore::open(&dir)?;
    let entries = store.entries();
    for p in &entries {
        let file = p
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| p.display().to_string());
        match DiskStore::describe(p) {
            Ok(line) => println!("{file}  {line}"),
            Err(e) => println!("{file}  UNREADABLE: {e}"),
        }
    }
    println!("{} artifact(s) in {}", entries.len(), dir.display());
    Ok(())
}

/// Stream edge deltas into a dataset's cached artifacts. With
/// `--deltas FILE` one parsed batch is applied; without it, a demo
/// churn runs as two sequential batches — remove the dataset's first
/// edge, then re-add it — leaving the topology net-unchanged while
/// patching every cached plan twice. (Two batches, not one: within a
/// single batch, remove + add of the same pair would dedup last-wins
/// into a bare add of an existing edge, which is invalid.)
fn cmd_mutate(args: &Args) -> Result<()> {
    let d = dataset_arg(args)?;
    let session = session_from(args)?;
    let spec = JobSpec::new(d, "bfs").with_scale(scale_for(d, args)?);
    let g = session.load_graph(&spec)?;

    let batches = match args.get_path("deltas") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
            vec![DeltaBatch::parse(&text, g.num_vertices)?]
        }
        None => {
            let e = g
                .edges
                .first()
                .copied()
                .ok_or_else(|| anyhow::anyhow!("dataset has no edges to churn"))?;
            vec![
                DeltaBatch::new(g.num_vertices, vec![EdgeDelta::remove(e.src, e.dst)])?,
                DeltaBatch::new(
                    g.num_vertices,
                    vec![EdgeDelta::add_weighted(e.src, e.dst, e.weight)],
                )?,
            ]
        }
    };
    for (i, batch) in batches.iter().enumerate() {
        let r = session.apply_delta(&spec, batch)?;
        println!(
            "batch {}: {} delta(s) → {} artifact(s) patched, {} skipped; \
             {} dirty window(s), {} plan op(s) re-emitted, {} crossbar write(s) ({} bits)",
            i + 1,
            r.deltas,
            r.patched_artifacts,
            r.skipped_keys,
            r.stats.dirty_partitions,
            r.stats.patched_ops,
            r.stats.crossbar_writes,
            r.stats.write_bits
        );
    }
    let s = session.artifacts().stats();
    println!(
        "artifact cache: {} compiles, {} disk hits, {} disk writes, {} resident",
        s.misses, s.disk_hits, s.writes, s.entries
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let jobs: usize = args.get_or("jobs", 16usize)?;
    let workers: usize = args.get_or("workers", 2usize)?;
    let dataset_s: String = args.get_or("dataset", "TN".to_string())?;
    let d = parse_dataset(&dataset_s)?;
    let scale = scale_for(d, args)?;

    let max_batch: usize = args.get_or("max-batch", 1usize)?;

    let session = Arc::new(session_from(args)?);
    let svc = Service::with_session_batch(
        Arc::clone(&session),
        workers,
        repro::coordinator::DEFAULT_QUEUE_DEPTH,
        max_batch,
    );

    // One mixed batch cycling through every registered algorithm.
    let algos: Vec<_> = session.registry().ids().cloned().collect();
    let specs: Vec<JobSpec> = (0..jobs)
        .map(|i| {
            JobSpec::new(d, algos[i % algos.len()].clone())
                .with_scale(scale)
                .with_source(i as u32)
                .with_iterations(5)
        })
        .collect();
    let pending = svc.submit_batch(specs)?;
    for p in pending {
        let r = p.wait()?;
        println!(
            "job {} done in {} µs ({} subgraph ops)",
            r.report.algorithm,
            r.wall_time_us,
            fmt::count(r.report.counts.mvm_ops)
        );
    }

    let s = svc.snapshot();
    let cache = session.artifacts().stats();
    println!(
        "served {} jobs on {} backend, mean latency {:.0} µs, max {} µs, {} total subgraph ops",
        s.jobs_completed,
        session.backend().name(),
        s.mean_latency_us,
        s.max_latency_us,
        fmt::count(s.subgraph_ops)
    );
    println!(
        "shed {} (expired deadlines), coalesced {} (shared executions), \
         batched {} (multi-source batches)",
        s.jobs_shed, s.jobs_coalesced, s.jobs_batched
    );
    if s.batch_size.count > 0 {
        // The batch-size histogram's buckets hold job counts, not µs —
        // render the unitless fields by hand.
        println!(
            "batch sizes (jobs per formed batch) n={} mean {:.1} p50 {} max {}",
            s.batch_size.count, s.batch_size.mean_us, s.batch_size.p50_us, s.batch_size.max_us
        );
    }
    println!("queue-wait {}", s.queue_wait.render());
    println!("execution  {}", s.execution.render());
    println!(
        "artifact cache: {} preprocessing runs, {} hits, {} disk hits, {} disk writes, {} entries",
        cache.misses, cache.hits, cache.disk_hits, cache.writes, cache.entries
    );
    if s.preprocess.compiles > 0 {
        println!("preprocess phases: {}", s.preprocess.summary());
    }
    for (shards, runs) in s.runs_by_shards.iter().filter(|(n, _)| **n > 1) {
        println!("{runs} execution(s) served across {shards} shards (bit-identical results)");
    }
    for (algo, st) in &s.per_algorithm {
        println!(
            "  {algo:>9}: {} completed, {} failed, {} shed, {} coalesced, queue depth {} \
             | wait p50/p99/p999 {}/{}/{} µs | exec p50/p99/p999 {}/{}/{} µs",
            st.completed,
            st.failed,
            st.shed,
            st.coalesced,
            st.queue_depth,
            st.queue_wait.p50_us,
            st.queue_wait.p99_us,
            st.queue_wait.p999_us,
            st.execution.p50_us,
            st.execution.p99_us,
            st.execution.p999_us,
        );
    }
    Ok(())
}

/// Drive a scripted open/closed-loop traffic study against a fresh
/// service and write the scenario report as `BENCH_serve.json` rows.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let dataset_s: String = args.get_or("dataset", "TN".to_string())?;
    let d = parse_dataset(&dataset_s)?;
    let mode_s: String = args.get_or("mode", "closed".to_string())?;
    let mode = match mode_s.as_str() {
        "closed" => LoadMode::Closed { concurrency: args.get_or("concurrency", 4usize)? },
        "open" => LoadMode::Open { rate_per_s: args.get_or("rate", 500.0f64)? },
        other => anyhow::bail!("unknown --mode {other:?} (closed|open)"),
    };
    let backend_s: String = args.get_or("backend", "native".to_string())?;

    let mut cfg = ServiceConfig {
        arch: arch_from(args)?,
        backend: Backend::parse(&backend_s)?,
        workers: args.get_or("workers", 2usize)?,
        parallelism: args.get_or("threads", 1usize)?,
        shards: args.get_or("shards", 1u32)?,
        queue_depth: args.get_or("queue-depth", repro::coordinator::DEFAULT_QUEUE_DEPTH)?,
        max_batch: args.get_or("max-batch", 1usize)?,
        ..ServiceConfig::default()
    };
    if let Some(dir) = args.get_path("artifact-dir") {
        cfg.artifact_dir = Some(dir);
    }
    let svc = Service::spawn(cfg)?;

    let lg = LoadgenConfig {
        name: format!("{}-{}", dataset_s.to_lowercase(), mode_s),
        dataset: d,
        scale: scale_for(d, args)?,
        jobs: args.get_or("jobs", 64usize)?,
        mode,
        deadline: args
            .get_parsed::<u64>("deadline-ms")?
            .map(std::time::Duration::from_millis),
        algorithms: args.get("algo").map(|a| vec![a.to_string()]).unwrap_or_default(),
        iterations: args.get_or("iterations", 5usize)?,
        sources: args.get_or("sources", 8u32)?,
        seed: args.get_or("seed", 42u64)?,
    };
    let report = loadgen::run(&svc, &lg)?;
    println!("{}", report.render());

    let out = args
        .get_path("out")
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_serve.json"));
    loadgen::write_json(&out, &[report])?;
    println!("wrote {}", out.display());
    Ok(())
}
