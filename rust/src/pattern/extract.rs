//! Window-based partitioning (Alg. 1 step ①): a non-overlapping C×C
//! sliding window over the adjacency matrix. All-zero windows are
//! discarded (they involve no processing, §I), which is what makes the
//! approach viable for graphs at 99.99 % sparsity: we bucket *edges* into
//! windows rather than scanning the dense matrix.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use crate::graph::coo::{Coo, Edge};

use super::pattern::{Pattern, MAX_C};

/// One non-empty window of the adjacency matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Subgraph {
    /// Block row: source vertices `[brow*C, (brow+1)*C)`.
    pub brow: u32,
    /// Block column: destination vertices `[bcol*C, (bcol+1)*C)`.
    pub bcol: u32,
    /// The 0/1 structure of the window.
    pub pattern: Pattern,
}

impl Subgraph {
    /// Starting (source, destination) vertex — the only vertex data the
    /// subgraph table stores, since every window has exactly C vertices
    /// per side (Fig. 3e).
    #[inline]
    pub fn start_vertices(&self, c: usize) -> (u32, u32) {
        (self.brow * c as u32, self.bcol * c as u32)
    }
}

/// Partitioning result: subgraphs (sorted row-major by (brow, bcol)) plus
/// optional per-subgraph edge weights (aligned with `Pattern::cells`
/// order) for weighted algorithms.
#[derive(Debug, Clone, PartialEq)]
pub struct Partitioned {
    pub c: usize,
    pub num_vertices: u32,
    pub subgraphs: Vec<Subgraph>,
    /// `weights[k]` holds the weights of subgraph k's edges in the same
    /// order as `subgraphs[k].pattern.cells(c)`; `None` for unweighted
    /// graphs (all weights 1.0).
    pub weights: Option<Vec<Vec<f32>>>,
}

impl Partitioned {
    pub fn num_subgraphs(&self) -> usize {
        self.subgraphs.len()
    }

    /// Total number of block rows/cols of the adjacency matrix.
    pub fn num_blocks(&self) -> u32 {
        self.num_vertices.div_ceil(self.c as u32)
    }

    /// Dense C×C weight matrix of subgraph `k` (for the MVM datapath).
    pub fn dense_weights(&self, k: usize) -> Vec<f32> {
        let mut m = vec![0f32; self.c * self.c];
        self.dense_weights_into(k, &mut m);
        m
    }

    /// Zero-allocation variant: writes subgraph `k`'s dense C×C weight
    /// matrix into `out` (which must be zeroed, length c*c). This is the
    /// PJRT packing hot path — no per-subgraph Vec, no `cells()` Vec.
    #[inline]
    pub fn dense_weights_into(&self, k: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.c * self.c);
        let sg = &self.subgraphs[k];
        match &self.weights {
            None => {
                let mut bits = sg.pattern.0;
                while bits != 0 {
                    out[bits.trailing_zeros() as usize] = 1.0;
                    bits &= bits - 1;
                }
            }
            Some(w) => {
                let mut bits = sg.pattern.0;
                let mut nth = 0usize;
                while bits != 0 {
                    out[bits.trailing_zeros() as usize] = w[k][nth];
                    bits &= bits - 1;
                    nth += 1;
                }
            }
        }
    }
}

/// Per-window accumulator shared by the monolithic, chunked, and pooled
/// bucketing passes: the 0/1 pattern plus (for weighted graphs) the edge
/// weights staged as `(bit, weight)` pairs in arrival order. Weights are
/// sorted by bit once at finalize time, which matches `cells()` order
/// without the second full edge scan the old weighted path paid.
#[derive(Debug, Clone)]
pub(crate) struct WindowAccum {
    pattern: Pattern,
    staged: Vec<(u8, f32)>,
}

impl WindowAccum {
    fn new() -> Self {
        Self { pattern: Pattern::EMPTY, staged: Vec::new() }
    }
}

/// Window key (`(brow, bcol)` packed into u64) → accumulator.
pub(crate) type WindowMap = HashMap<u64, WindowAccum>;

/// Bucket a contiguous edge slice into `windows`. Chunk-invariance is
/// structural: `Coo` canonical form guarantees each `(window, bit)` pair
/// occurs at most once across the whole edge list, so bucketing any
/// partition of the edges into per-chunk maps and merging yields the
/// same per-window pattern (bitwise OR) and staged weight set.
pub(crate) fn bucket_edges(edges: &[Edge], c: usize, weighted: bool, windows: &mut WindowMap) {
    let cu = c as u32;
    for e in edges {
        // Key packs (brow, bcol) into u64.
        let key = ((e.src / cu) as u64) << 32 | (e.dst / cu) as u64;
        let (i, j) = ((e.src % cu) as usize, (e.dst % cu) as usize);
        let w = windows.entry(key).or_insert_with(WindowAccum::new);
        w.pattern = w.pattern.with_edge(i, j, c);
        if weighted {
            w.staged.push(((i * c + j) as u8, e.weight));
        }
    }
}

/// Merge `from` into `into`: pattern OR, staged-weight concatenation.
/// Merge order never reaches the finalized artifact — patterns OR
/// commutatively and staged weights are re-sorted by their (globally
/// unique) bit at finalize time.
pub(crate) fn merge_windows(into: &mut WindowMap, from: WindowMap) {
    for (key, mut w) in from {
        match into.entry(key) {
            Entry::Occupied(mut o) => {
                let acc = o.get_mut();
                acc.pattern = Pattern(acc.pattern.0 | w.pattern.0);
                acc.staged.append(&mut w.staged);
            }
            Entry::Vacant(v) => {
                v.insert(w);
            }
        }
    }
}

/// Turn an accumulated window map into the canonical [`Partitioned`]:
/// subgraphs sorted row-major by `(brow, bcol)`, weights sorted into
/// `cells()` (bit) order. Every partition entry point funnels through
/// here, so chunk boundaries can never change a merged artifact byte.
pub(crate) fn finalize_windows(
    windows: WindowMap,
    c: usize,
    num_vertices: u32,
    weighted: bool,
) -> Partitioned {
    let mut entries: Vec<(u32, u32, WindowAccum)> = windows
        .into_iter()
        .map(|(key, w)| ((key >> 32) as u32, key as u32, w))
        .collect();
    entries.sort_unstable_by_key(|&(brow, bcol, _)| (brow, bcol));
    let mut subgraphs = Vec::with_capacity(entries.len());
    let mut weights = weighted.then(|| Vec::with_capacity(entries.len()));
    for (brow, bcol, mut w) in entries {
        subgraphs.push(Subgraph { brow, bcol, pattern: w.pattern });
        if let Some(out) = &mut weights {
            // Unstable sort on globally unique keys is deterministic.
            w.staged.sort_unstable_by_key(|&(bit, _)| bit);
            out.push(w.staged.iter().map(|&(_, wt)| wt).collect());
        }
    }
    Partitioned { c, num_vertices, subgraphs, weights }
}

/// Partition `g` with a C×C window. `weighted` keeps edge weights (SSSP);
/// BFS/PageRank only need the 0/1 structure. Single pass over the edges
/// either way; this sequential function is the differential oracle for
/// the chunked and pooled paths.
pub fn partition(g: &Coo, c: usize, weighted: bool) -> Partitioned {
    assert!((1..=MAX_C).contains(&c), "window size must be 1..=8, got {c}");
    let mut windows = WindowMap::default();
    bucket_edges(&g.edges, c, weighted, &mut windows);
    finalize_windows(windows, c, g.num_vertices, weighted)
}

/// Chunked variant: bucket `chunk_edges`-sized contiguous edge ranges
/// independently and merge in range order — the sequential reference for
/// the pooled preprocess path, exposed so tests can sweep chunk
/// boundaries. Equal to [`partition`] for every chunk size by
/// construction (all paths share [`finalize_windows`]).
pub fn partition_chunked(g: &Coo, c: usize, weighted: bool, chunk_edges: usize) -> Partitioned {
    assert!((1..=MAX_C).contains(&c), "window size must be 1..=8, got {c}");
    assert!(chunk_edges > 0, "chunk size must be positive");
    let mut merged = WindowMap::default();
    for chunk in g.edges.chunks(chunk_edges) {
        let mut local = WindowMap::default();
        bucket_edges(chunk, c, weighted, &mut local);
        merge_windows(&mut merged, local);
    }
    finalize_windows(merged, c, g.num_vertices, weighted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::Edge;

    /// The paper's Fig. 3 example: 6 vertices, 2×2 windows.
    fn fig3_graph() -> Coo {
        // Edges chosen so S0 (block 0,0) and S4 (block 1,1) share a
        // pattern, mirroring the paper's worked example structure.
        Coo::from_edges(
            6,
            vec![
                Edge::new(0, 1), // block (0,0), local (0,1)
                Edge::new(2, 3), // block (1,1), local (0,1)
                Edge::new(4, 5), // block (2,2), local (0,1)
                Edge::new(1, 2), // block (0,1), local (1,0)
                Edge::new(3, 4), // block (1,2), local (1,0)
                Edge::new(5, 0), // block (2,0), local (1,0)
                Edge::new(0, 4), // block (0,2), local (0,0)
            ],
        )
    }

    #[test]
    fn partitions_into_expected_windows() {
        let p = partition(&fig3_graph(), 2, false);
        assert_eq!(p.num_blocks(), 3);
        assert_eq!(p.num_subgraphs(), 7); // 7 distinct non-empty windows
        // Window (0,0) holds local edge (0,1).
        let s00 = p.subgraphs.iter().find(|s| (s.brow, s.bcol) == (0, 0)).unwrap();
        assert!(s00.pattern.has_edge(0, 1, 2));
        assert_eq!(s00.pattern.nnz(), 1);
    }

    #[test]
    fn identical_windows_share_pattern() {
        let p = partition(&fig3_graph(), 2, false);
        let pat = |br, bc| {
            p.subgraphs
                .iter()
                .find(|s| (s.brow, s.bcol) == (br, bc))
                .unwrap()
                .pattern
        };
        assert_eq!(pat(0, 0), pat(1, 1));
        assert_eq!(pat(0, 0), pat(2, 2));
        assert_eq!(pat(0, 1), pat(1, 2));
        assert_ne!(pat(0, 0), pat(0, 1));
    }

    #[test]
    fn zero_windows_are_discarded() {
        let p = partition(&fig3_graph(), 2, false);
        assert!(p.subgraphs.iter().all(|s| !s.pattern.is_empty()));
        // 9 possible windows, 7 non-empty.
        assert!(p.num_subgraphs() < 9);
    }

    #[test]
    fn edge_count_is_preserved() {
        let g = crate::graph::generator::rmat(
            512,
            4_000,
            crate::graph::generator::RmatParams::default(),
            3,
        );
        let p = partition(&g, 4, false);
        let total: u32 = p.subgraphs.iter().map(|s| s.pattern.nnz()).sum();
        assert_eq!(total as usize, g.num_edges());
    }

    #[test]
    fn start_vertices_scale_with_c() {
        let s = Subgraph { brow: 3, bcol: 5, pattern: Pattern(1) };
        assert_eq!(s.start_vertices(4), (12, 20));
    }

    #[test]
    fn weighted_partition_aligns_weights_with_cells() {
        let g = Coo::from_edges(
            4,
            vec![
                Edge::weighted(0, 2, 3.0),
                Edge::weighted(0, 3, 5.0),
                Edge::weighted(1, 2, 7.0),
            ],
        );
        let p = partition(&g, 2, true);
        assert_eq!(p.num_subgraphs(), 1);
        let cells = p.subgraphs[0].pattern.cells(2);
        let w = &p.weights.as_ref().unwrap()[0];
        let lookup: std::collections::HashMap<(u8, u8), f32> =
            cells.into_iter().zip(w.iter().copied()).collect();
        assert_eq!(lookup[&(0, 0)], 3.0);
        assert_eq!(lookup[&(0, 1)], 5.0);
        assert_eq!(lookup[&(1, 0)], 7.0);
    }

    #[test]
    fn dense_weights_unweighted_is_adjacency() {
        let p = partition(&fig3_graph(), 2, false);
        let k = p
            .subgraphs
            .iter()
            .position(|s| (s.brow, s.bcol) == (0, 0))
            .unwrap();
        assert_eq!(p.dense_weights(k), vec![0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn subgraphs_sorted_row_major() {
        let p = partition(&fig3_graph(), 2, false);
        let keys: Vec<_> = p.subgraphs.iter().map(|s| (s.brow, s.bcol)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }

    #[test]
    #[should_panic]
    fn rejects_oversized_window() {
        partition(&fig3_graph(), 9, false);
    }

    #[test]
    fn chunked_partition_matches_monolithic_for_every_chunk_size() {
        let g = crate::graph::generator::rmat(
            256,
            2_000,
            crate::graph::generator::RmatParams::default(),
            11,
        );
        let gw = Coo::from_edges(
            g.num_vertices,
            g.edges
                .iter()
                .enumerate()
                .map(|(i, e)| Edge::weighted(e.src, e.dst, 0.5 + (i % 17) as f32))
                .collect(),
        );
        for (graph, weighted) in [(&g, false), (&gw, true)] {
            let want = partition(graph, 4, weighted);
            for chunk in [1usize, 7, 64, graph.num_edges()] {
                assert_eq!(
                    partition_chunked(graph, 4, weighted, chunk),
                    want,
                    "chunk {chunk} weighted {weighted}"
                );
            }
        }
    }

    #[test]
    #[should_panic]
    fn chunked_partition_rejects_zero_chunk() {
        partition_chunked(&fig3_graph(), 2, false, 0);
    }
}
