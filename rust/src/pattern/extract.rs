//! Window-based partitioning (Alg. 1 step ①): a non-overlapping C×C
//! sliding window over the adjacency matrix. All-zero windows are
//! discarded (they involve no processing, §I), which is what makes the
//! approach viable for graphs at 99.99 % sparsity: we bucket *edges* into
//! windows rather than scanning the dense matrix.

use std::collections::HashMap;

use crate::graph::coo::Coo;

use super::pattern::{Pattern, MAX_C};

/// One non-empty window of the adjacency matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Subgraph {
    /// Block row: source vertices `[brow*C, (brow+1)*C)`.
    pub brow: u32,
    /// Block column: destination vertices `[bcol*C, (bcol+1)*C)`.
    pub bcol: u32,
    /// The 0/1 structure of the window.
    pub pattern: Pattern,
}

impl Subgraph {
    /// Starting (source, destination) vertex — the only vertex data the
    /// subgraph table stores, since every window has exactly C vertices
    /// per side (Fig. 3e).
    #[inline]
    pub fn start_vertices(&self, c: usize) -> (u32, u32) {
        (self.brow * c as u32, self.bcol * c as u32)
    }
}

/// Partitioning result: subgraphs (sorted row-major by (brow, bcol)) plus
/// optional per-subgraph edge weights (aligned with `Pattern::cells`
/// order) for weighted algorithms.
#[derive(Debug, Clone, PartialEq)]
pub struct Partitioned {
    pub c: usize,
    pub num_vertices: u32,
    pub subgraphs: Vec<Subgraph>,
    /// `weights[k]` holds the weights of subgraph k's edges in the same
    /// order as `subgraphs[k].pattern.cells(c)`; `None` for unweighted
    /// graphs (all weights 1.0).
    pub weights: Option<Vec<Vec<f32>>>,
}

impl Partitioned {
    pub fn num_subgraphs(&self) -> usize {
        self.subgraphs.len()
    }

    /// Total number of block rows/cols of the adjacency matrix.
    pub fn num_blocks(&self) -> u32 {
        self.num_vertices.div_ceil(self.c as u32)
    }

    /// Dense C×C weight matrix of subgraph `k` (for the MVM datapath).
    pub fn dense_weights(&self, k: usize) -> Vec<f32> {
        let mut m = vec![0f32; self.c * self.c];
        self.dense_weights_into(k, &mut m);
        m
    }

    /// Zero-allocation variant: writes subgraph `k`'s dense C×C weight
    /// matrix into `out` (which must be zeroed, length c*c). This is the
    /// PJRT packing hot path — no per-subgraph Vec, no `cells()` Vec.
    #[inline]
    pub fn dense_weights_into(&self, k: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.c * self.c);
        let sg = &self.subgraphs[k];
        match &self.weights {
            None => {
                let mut bits = sg.pattern.0;
                while bits != 0 {
                    out[bits.trailing_zeros() as usize] = 1.0;
                    bits &= bits - 1;
                }
            }
            Some(w) => {
                let mut bits = sg.pattern.0;
                let mut nth = 0usize;
                while bits != 0 {
                    out[bits.trailing_zeros() as usize] = w[k][nth];
                    bits &= bits - 1;
                    nth += 1;
                }
            }
        }
    }
}

/// Partition `g` with a C×C window. `weighted` keeps edge weights (SSSP);
/// BFS/PageRank only need the 0/1 structure.
pub fn partition(g: &Coo, c: usize, weighted: bool) -> Partitioned {
    assert!((1..=MAX_C).contains(&c), "window size must be 1..=8, got {c}");
    let cu = c as u32;
    // Bucket edges by window. Key packs (brow, bcol) into u64.
    let mut windows: HashMap<u64, Pattern> = HashMap::new();
    for e in &g.edges {
        let key = ((e.src / cu) as u64) << 32 | (e.dst / cu) as u64;
        let (i, j) = ((e.src % cu) as usize, (e.dst % cu) as usize);
        let p = windows.entry(key).or_insert(Pattern::EMPTY);
        *p = p.with_edge(i, j, c);
    }

    let mut subgraphs: Vec<Subgraph> = windows
        .into_iter()
        .map(|(key, pattern)| Subgraph {
            brow: (key >> 32) as u32,
            bcol: key as u32,
            pattern,
        })
        .collect();
    subgraphs.sort_unstable_by_key(|s| (s.brow, s.bcol));

    let weights = weighted.then(|| {
        // Second pass: gather weights per window in cells() (bit) order.
        let mut index: HashMap<(u32, u32), usize> = HashMap::with_capacity(subgraphs.len());
        for (k, s) in subgraphs.iter().enumerate() {
            index.insert((s.brow, s.bcol), k);
        }
        let mut out: Vec<Vec<f32>> = subgraphs
            .iter()
            .map(|s| vec![0f32; s.pattern.nnz() as usize])
            .collect();
        for e in &g.edges {
            let k = index[&(e.src / cu, e.dst / cu)];
            let s = &subgraphs[k];
            let bit = (e.src % cu) as usize * c + (e.dst % cu) as usize;
            // Position of this bit among the pattern's set bits.
            let below = s.pattern.0 & ((1u64 << bit) - 1);
            out[k][below.count_ones() as usize] = e.weight;
        }
        out
    });

    Partitioned { c, num_vertices: g.num_vertices, subgraphs, weights }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::Edge;

    /// The paper's Fig. 3 example: 6 vertices, 2×2 windows.
    fn fig3_graph() -> Coo {
        // Edges chosen so S0 (block 0,0) and S4 (block 1,1) share a
        // pattern, mirroring the paper's worked example structure.
        Coo::from_edges(
            6,
            vec![
                Edge::new(0, 1), // block (0,0), local (0,1)
                Edge::new(2, 3), // block (1,1), local (0,1)
                Edge::new(4, 5), // block (2,2), local (0,1)
                Edge::new(1, 2), // block (0,1), local (1,0)
                Edge::new(3, 4), // block (1,2), local (1,0)
                Edge::new(5, 0), // block (2,0), local (1,0)
                Edge::new(0, 4), // block (0,2), local (0,0)
            ],
        )
    }

    #[test]
    fn partitions_into_expected_windows() {
        let p = partition(&fig3_graph(), 2, false);
        assert_eq!(p.num_blocks(), 3);
        assert_eq!(p.num_subgraphs(), 7); // 7 distinct non-empty windows
        // Window (0,0) holds local edge (0,1).
        let s00 = p.subgraphs.iter().find(|s| (s.brow, s.bcol) == (0, 0)).unwrap();
        assert!(s00.pattern.has_edge(0, 1, 2));
        assert_eq!(s00.pattern.nnz(), 1);
    }

    #[test]
    fn identical_windows_share_pattern() {
        let p = partition(&fig3_graph(), 2, false);
        let pat = |br, bc| {
            p.subgraphs
                .iter()
                .find(|s| (s.brow, s.bcol) == (br, bc))
                .unwrap()
                .pattern
        };
        assert_eq!(pat(0, 0), pat(1, 1));
        assert_eq!(pat(0, 0), pat(2, 2));
        assert_eq!(pat(0, 1), pat(1, 2));
        assert_ne!(pat(0, 0), pat(0, 1));
    }

    #[test]
    fn zero_windows_are_discarded() {
        let p = partition(&fig3_graph(), 2, false);
        assert!(p.subgraphs.iter().all(|s| !s.pattern.is_empty()));
        // 9 possible windows, 7 non-empty.
        assert!(p.num_subgraphs() < 9);
    }

    #[test]
    fn edge_count_is_preserved() {
        let g = crate::graph::generator::rmat(
            512,
            4_000,
            crate::graph::generator::RmatParams::default(),
            3,
        );
        let p = partition(&g, 4, false);
        let total: u32 = p.subgraphs.iter().map(|s| s.pattern.nnz()).sum();
        assert_eq!(total as usize, g.num_edges());
    }

    #[test]
    fn start_vertices_scale_with_c() {
        let s = Subgraph { brow: 3, bcol: 5, pattern: Pattern(1) };
        assert_eq!(s.start_vertices(4), (12, 20));
    }

    #[test]
    fn weighted_partition_aligns_weights_with_cells() {
        let g = Coo::from_edges(
            4,
            vec![
                Edge::weighted(0, 2, 3.0),
                Edge::weighted(0, 3, 5.0),
                Edge::weighted(1, 2, 7.0),
            ],
        );
        let p = partition(&g, 2, true);
        assert_eq!(p.num_subgraphs(), 1);
        let cells = p.subgraphs[0].pattern.cells(2);
        let w = &p.weights.as_ref().unwrap()[0];
        let lookup: std::collections::HashMap<(u8, u8), f32> =
            cells.into_iter().zip(w.iter().copied()).collect();
        assert_eq!(lookup[&(0, 0)], 3.0);
        assert_eq!(lookup[&(0, 1)], 5.0);
        assert_eq!(lookup[&(1, 0)], 7.0);
    }

    #[test]
    fn dense_weights_unweighted_is_adjacency() {
        let p = partition(&fig3_graph(), 2, false);
        let k = p
            .subgraphs
            .iter()
            .position(|s| (s.brow, s.bcol) == (0, 0))
            .unwrap();
        assert_eq!(p.dense_weights(k), vec![0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn subgraphs_sorted_row_major() {
        let p = partition(&fig3_graph(), 2, false);
        let keys: Vec<_> = p.subgraphs.iter().map(|s| (s.brow, s.bcol)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }

    #[test]
    #[should_panic]
    fn rejects_oversized_window() {
        partition(&fig3_graph(), 9, false);
    }
}
