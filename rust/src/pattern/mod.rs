//! Pattern layer: window partitioning, pattern extraction/ranking (Alg. 1),
//! and the configuration/subgraph tables the scheduler consumes (Fig. 3e).

pub mod extract;
pub mod pattern;
pub mod rank;
pub mod tables;

pub use extract::{partition, partition_chunked, Partitioned, Subgraph};
pub use pattern::Pattern;
pub use rank::{count_patterns, merge_counts, PatternRanking};
pub use tables::{ConfigTable, EngineSlot, SubgraphTable};
