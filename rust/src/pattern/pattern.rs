//! A *pattern* is the 0/1 structure of one C×C window of the adjacency
//! matrix (paper §I): bit `i*C + j` is set iff local source `i` has an
//! edge to local destination `j`. With C ≤ 8 a pattern packs into a u64,
//! making frequency counting a dense hash over machine words.

/// Packed C×C binary pattern. The crossbar size C is carried externally
/// (it is a global architecture parameter, identical for every pattern).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Pattern(pub u64);

/// Maximum supported window size (bits of u64: 8×8).
pub const MAX_C: usize = 8;

impl Pattern {
    pub const EMPTY: Pattern = Pattern(0);

    /// Set the bit for local edge (i -> j).
    #[inline]
    pub fn with_edge(self, i: usize, j: usize, c: usize) -> Pattern {
        debug_assert!(i < c && j < c && c <= MAX_C);
        Pattern(self.0 | 1u64 << (i * c + j))
    }

    #[inline]
    pub fn has_edge(self, i: usize, j: usize, c: usize) -> bool {
        self.0 >> (i * c + j) & 1 == 1
    }

    /// Number of edges in the pattern.
    #[inline]
    pub fn nnz(self) -> u32 {
        self.0.count_ones()
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// Bitmask over rows that contain at least one edge. The paper stores
    /// the row address of single-edge patterns in the configuration table
    /// so static engines skip inactive wordlines (§III.B).
    #[inline]
    pub fn active_rows(self, c: usize) -> u32 {
        let row_mask = (1u64 << c) - 1;
        let mut rows = 0u32;
        for i in 0..c {
            if self.0 >> (i * c) & row_mask != 0 {
                rows |= 1 << i;
            }
        }
        rows
    }

    /// Number of active rows (wordlines that must be driven for an MVM).
    #[inline]
    pub fn active_row_count(self, c: usize) -> u32 {
        self.active_rows(c).count_ones()
    }

    /// If the pattern has exactly one edge, its (row, col); the CT stores
    /// this to avoid iterating crossbar rows (§III.B).
    pub fn single_edge(self, c: usize) -> Option<(u8, u8)> {
        if self.nnz() != 1 {
            return None;
        }
        let bit = self.0.trailing_zeros() as usize;
        Some(((bit / c) as u8, (bit % c) as u8))
    }

    /// COO cell list ((i, j) pairs in bit order) — the representation the
    /// configuration table stores (Fig. 3e).
    pub fn cells(self, c: usize) -> Vec<(u8, u8)> {
        let mut out = Vec::with_capacity(self.nnz() as usize);
        let mut bits = self.0;
        while bits != 0 {
            let bit = bits.trailing_zeros() as usize;
            out.push(((bit / c) as u8, (bit % c) as u8));
            bits &= bits - 1;
        }
        out
    }

    /// Dense row-major f32 matrix (crossbar conductances) — what the
    /// runtime feeds the AOT executable.
    pub fn to_dense(self, c: usize) -> Vec<f32> {
        let mut m = vec![0f32; c * c];
        for (i, j) in self.cells(c) {
            m[i as usize * c + j as usize] = 1.0;
        }
        m
    }

    /// Build from a dense 0/1 row-major matrix.
    pub fn from_dense(m: &[f32], c: usize) -> Pattern {
        assert_eq!(m.len(), c * c);
        let mut p = Pattern::EMPTY;
        for i in 0..c {
            for j in 0..c {
                if m[i * c + j] != 0.0 {
                    p = p.with_edge(i, j, c);
                }
            }
        }
        p
    }

    /// Number of ReRAM cells that must be written to reprogram a crossbar
    /// currently holding `from` into `self` (toggled cells only — SET on
    /// new edges, RESET on removed ones).
    #[inline]
    pub fn write_cost_from(self, from: Pattern) -> u32 {
        (self.0 ^ from.0).count_ones()
    }
}

impl std::fmt::Display for Pattern {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "P{:#x}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edges_round_trip() {
        let p = Pattern::EMPTY.with_edge(0, 1, 4).with_edge(3, 2, 4);
        assert!(p.has_edge(0, 1, 4));
        assert!(p.has_edge(3, 2, 4));
        assert!(!p.has_edge(1, 0, 4));
        assert_eq!(p.nnz(), 2);
    }

    #[test]
    fn active_rows_tracks_rows_with_edges() {
        let p = Pattern::EMPTY.with_edge(0, 3, 4).with_edge(2, 0, 4).with_edge(2, 1, 4);
        assert_eq!(p.active_rows(4), 0b101);
        assert_eq!(p.active_row_count(4), 2);
    }

    #[test]
    fn single_edge_detection() {
        let p = Pattern::EMPTY.with_edge(2, 3, 4);
        assert_eq!(p.single_edge(4), Some((2, 3)));
        assert_eq!(p.with_edge(0, 0, 4).single_edge(4), None);
        assert_eq!(Pattern::EMPTY.single_edge(4), None);
    }

    #[test]
    fn cells_in_bit_order() {
        let p = Pattern::EMPTY.with_edge(1, 0, 2).with_edge(0, 1, 2);
        assert_eq!(p.cells(2), vec![(0, 1), (1, 0)]);
    }

    #[test]
    fn dense_round_trip() {
        let p = Pattern::EMPTY.with_edge(0, 0, 3).with_edge(2, 1, 3);
        let d = p.to_dense(3);
        assert_eq!(d[0], 1.0);
        assert_eq!(d[2 * 3 + 1], 1.0);
        assert_eq!(d.iter().filter(|&&x| x != 0.0).count(), 2);
        assert_eq!(Pattern::from_dense(&d, 3), p);
    }

    #[test]
    fn write_cost_is_hamming_distance() {
        let a = Pattern(0b1100);
        let b = Pattern(0b1010);
        assert_eq!(a.write_cost_from(b), 2);
        assert_eq!(a.write_cost_from(a), 0);
        assert_eq!(a.write_cost_from(Pattern::EMPTY), 2);
    }

    #[test]
    fn max_c_pattern_uses_all_bits() {
        let mut p = Pattern::EMPTY;
        for i in 0..8 {
            for j in 0..8 {
                p = p.with_edge(i, j, 8);
            }
        }
        assert_eq!(p.0, u64::MAX);
        assert_eq!(p.nnz(), 64);
        assert_eq!(p.active_row_count(8), 8);
    }
}
