//! Pattern identification & ranking (Alg. 1 steps ②–③, Fig. 1a).
//!
//! Counts pattern occurrences across all subgraphs and ranks them by
//! frequency. The ranking drives static-engine assignment and the
//! Fig. 1a histogram (top-16 patterns cover 86 % of Wiki-Vote subgraphs).

use std::collections::HashMap;

use super::extract::{Partitioned, Subgraph};
use super::pattern::Pattern;

/// Count pattern occurrences over a subgraph slice into a pre-sized map
/// — the per-chunk unit of the pooled miner, and the whole-graph fold of
/// [`PatternRanking::from_partitioned`]. Distinct patterns are far fewer
/// than subgraphs on power-law graphs (Fig. 1a), so the pre-size is
/// capped rather than proportional.
pub fn count_patterns(subgraphs: &[Subgraph]) -> HashMap<Pattern, u32> {
    let mut counts: HashMap<Pattern, u32> = HashMap::with_capacity(subgraphs.len().min(1 << 12));
    for s in subgraphs {
        *counts.entry(s.pattern).or_insert(0) += 1;
    }
    counts
}

/// Apply signed occurrence deltas onto `counts`, dropping entries that
/// reach zero — the single merge path shared by the pooled miner
/// (per-chunk counts, all positive) and `sched::patch`'s incremental
/// re-rank (−1 old / +1 new per dirty window). Panics on underflow: a
/// decrement of an uncounted pattern is a caller bug.
pub fn merge_counts(
    counts: &mut HashMap<Pattern, u32>,
    deltas: impl IntoIterator<Item = (Pattern, i64)>,
) {
    for (p, d) in deltas {
        if d == 0 {
            continue;
        }
        let n = i64::from(counts.get(&p).copied().unwrap_or(0)) + d;
        assert!(n >= 0, "pattern count underflow: {p:?} by {d}");
        if n == 0 {
            counts.remove(&p);
        } else {
            counts.insert(p, n as u32);
        }
    }
}

/// Frequency-ranked patterns of a partitioned graph.
#[derive(Debug, Clone, PartialEq)]
pub struct PatternRanking {
    /// `(pattern, occurrences)` sorted by descending occurrence count,
    /// ties broken by pattern value for determinism.
    pub ranked: Vec<(Pattern, u32)>,
    /// pattern -> rank index (0 = most frequent).
    pub rank_of: HashMap<Pattern, u32>,
    /// Total number of (non-empty) subgraphs counted.
    pub total_subgraphs: usize,
}

impl PatternRanking {
    pub fn from_partitioned(p: &Partitioned) -> Self {
        Self::from_counts(count_patterns(&p.subgraphs), p.num_subgraphs())
    }

    pub fn from_counts(counts: impl IntoIterator<Item = (Pattern, u32)>, total: usize) -> Self {
        let mut ranked: Vec<(Pattern, u32)> = counts.into_iter().collect();
        ranked.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let rank_of = ranked
            .iter()
            .enumerate()
            .map(|(i, (p, _))| (*p, i as u32))
            .collect();
        Self { ranked, rank_of, total_subgraphs: total }
    }

    /// Number of distinct patterns.
    pub fn num_patterns(&self) -> usize {
        self.ranked.len()
    }

    /// Fraction of subgraphs covered by the top `k` patterns (Fig. 1a's
    /// "P0..P15 account for 86 %").
    pub fn coverage(&self, k: usize) -> f64 {
        if self.total_subgraphs == 0 {
            return 0.0;
        }
        let covered: u64 = self.ranked.iter().take(k).map(|&(_, c)| c as u64).sum();
        covered as f64 / self.total_subgraphs as f64
    }

    /// Occurrence share of pattern at rank `i` (Fig. 1a bar heights).
    pub fn share(&self, i: usize) -> f64 {
        if self.total_subgraphs == 0 || i >= self.ranked.len() {
            return 0.0;
        }
        self.ranked[i].1 as f64 / self.total_subgraphs as f64
    }

    /// Histogram rows for Fig. 1a: `(rank, pattern, count, share)`.
    pub fn histogram(&self, top: usize) -> Vec<(usize, Pattern, u32, f64)> {
        self.ranked
            .iter()
            .take(top)
            .enumerate()
            .map(|(i, &(p, c))| (i, p, c, self.share(i)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::{Coo, Edge};
    use crate::pattern::extract::partition;

    fn ranking() -> PatternRanking {
        // Three windows with pattern A (single edge (0,1)), one with B.
        let g = Coo::from_edges(
            8,
            vec![
                Edge::new(0, 1),
                Edge::new(2, 3),
                Edge::new(4, 5),
                Edge::new(7, 6), // different local structure: (1,0)
            ],
        );
        PatternRanking::from_partitioned(&partition(&g, 2, false))
    }

    #[test]
    fn ranks_by_descending_frequency() {
        let r = ranking();
        assert_eq!(r.num_patterns(), 2);
        assert_eq!(r.ranked[0].1, 3);
        assert_eq!(r.ranked[1].1, 1);
        assert!(r.ranked[0].0.has_edge(0, 1, 2));
    }

    #[test]
    fn rank_of_is_consistent() {
        let r = ranking();
        for (i, (p, _)) in r.ranked.iter().enumerate() {
            assert_eq!(r.rank_of[p], i as u32);
        }
    }

    #[test]
    fn coverage_monotone_and_complete() {
        let r = ranking();
        assert!((r.coverage(1) - 0.75).abs() < 1e-12);
        assert!((r.coverage(2) - 1.0).abs() < 1e-12);
        assert!((r.coverage(100) - 1.0).abs() < 1e-12);
        assert!(r.coverage(0) == 0.0);
    }

    #[test]
    fn shares_sum_to_one() {
        let r = ranking();
        let total: f64 = (0..r.num_patterns()).map(|i| r.share(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_tie_break() {
        // Two patterns with equal counts must rank by pattern value.
        let g = Coo::from_edges(4, vec![Edge::new(0, 1), Edge::new(3, 2)]);
        let r = PatternRanking::from_partitioned(&partition(&g, 2, false));
        assert_eq!(r.ranked.len(), 2);
        assert!(r.ranked[0].0 < r.ranked[1].0);
    }

    #[test]
    fn merge_counts_applies_signed_deltas_and_drops_zeros() {
        let mut counts = HashMap::new();
        merge_counts(&mut counts, [(Pattern(1), 3), (Pattern(2), 1)]);
        merge_counts(
            &mut counts,
            [(Pattern(1), -2), (Pattern(2), -1), (Pattern(4), 2), (Pattern(8), 0)],
        );
        assert_eq!(counts.get(&Pattern(1)), Some(&1));
        assert!(!counts.contains_key(&Pattern(2)));
        assert_eq!(counts.get(&Pattern(4)), Some(&2));
        assert!(!counts.contains_key(&Pattern(8)));
    }

    #[test]
    #[should_panic]
    fn merge_counts_panics_on_underflow() {
        let mut counts = HashMap::new();
        merge_counts(&mut counts, [(Pattern(1), -1)]);
    }

    #[test]
    fn chunked_counts_merge_to_the_monolithic_fold() {
        let g = crate::graph::generator::rmat(
            256,
            2_000,
            crate::graph::generator::RmatParams::default(),
            5,
        );
        let p = partition(&g, 4, false);
        let want = PatternRanking::from_partitioned(&p);
        for chunk in [1usize, 7, 64, p.num_subgraphs()] {
            let mut counts = HashMap::new();
            for range in p.subgraphs.chunks(chunk) {
                merge_counts(
                    &mut counts,
                    count_patterns(range).into_iter().map(|(pat, n)| (pat, i64::from(n))),
                );
            }
            let got = PatternRanking::from_counts(counts, p.num_subgraphs());
            assert_eq!(got, want, "chunk {chunk}");
        }
    }

    #[test]
    fn skewed_graph_has_skewed_ranking() {
        // The paper's key observation on an R-MAT stand-in for Wiki-Vote:
        // top-16 patterns must cover the large majority of subgraphs.
        let g = crate::graph::datasets::Dataset::Tiny.load().unwrap();
        let r = PatternRanking::from_partitioned(&partition(&g, 4, false));
        assert!(
            r.coverage(16) > 0.6,
            "top-16 coverage {:.3} not skewed",
            r.coverage(16)
        );
        // Single-edge patterns dominate (power-law consequence §III.B).
        assert_eq!(r.ranked[0].0.nnz(), 1);
    }
}
