//! Pattern identification & ranking (Alg. 1 steps ②–③, Fig. 1a).
//!
//! Counts pattern occurrences across all subgraphs and ranks them by
//! frequency. The ranking drives static-engine assignment and the
//! Fig. 1a histogram (top-16 patterns cover 86 % of Wiki-Vote subgraphs).

use std::collections::HashMap;

use super::extract::Partitioned;
use super::pattern::Pattern;

/// Frequency-ranked patterns of a partitioned graph.
#[derive(Debug, Clone, PartialEq)]
pub struct PatternRanking {
    /// `(pattern, occurrences)` sorted by descending occurrence count,
    /// ties broken by pattern value for determinism.
    pub ranked: Vec<(Pattern, u32)>,
    /// pattern -> rank index (0 = most frequent).
    pub rank_of: HashMap<Pattern, u32>,
    /// Total number of (non-empty) subgraphs counted.
    pub total_subgraphs: usize,
}

impl PatternRanking {
    pub fn from_partitioned(p: &Partitioned) -> Self {
        let mut counts: HashMap<Pattern, u32> = HashMap::new();
        for s in &p.subgraphs {
            *counts.entry(s.pattern).or_insert(0) += 1;
        }
        Self::from_counts(counts, p.num_subgraphs())
    }

    pub fn from_counts(counts: impl IntoIterator<Item = (Pattern, u32)>, total: usize) -> Self {
        let mut ranked: Vec<(Pattern, u32)> = counts.into_iter().collect();
        ranked.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let rank_of = ranked
            .iter()
            .enumerate()
            .map(|(i, (p, _))| (*p, i as u32))
            .collect();
        Self { ranked, rank_of, total_subgraphs: total }
    }

    /// Number of distinct patterns.
    pub fn num_patterns(&self) -> usize {
        self.ranked.len()
    }

    /// Fraction of subgraphs covered by the top `k` patterns (Fig. 1a's
    /// "P0..P15 account for 86 %").
    pub fn coverage(&self, k: usize) -> f64 {
        if self.total_subgraphs == 0 {
            return 0.0;
        }
        let covered: u64 = self.ranked.iter().take(k).map(|&(_, c)| c as u64).sum();
        covered as f64 / self.total_subgraphs as f64
    }

    /// Occurrence share of pattern at rank `i` (Fig. 1a bar heights).
    pub fn share(&self, i: usize) -> f64 {
        if self.total_subgraphs == 0 || i >= self.ranked.len() {
            return 0.0;
        }
        self.ranked[i].1 as f64 / self.total_subgraphs as f64
    }

    /// Histogram rows for Fig. 1a: `(rank, pattern, count, share)`.
    pub fn histogram(&self, top: usize) -> Vec<(usize, Pattern, u32, f64)> {
        self.ranked
            .iter()
            .take(top)
            .enumerate()
            .map(|(i, &(p, c))| (i, p, c, self.share(i)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::{Coo, Edge};
    use crate::pattern::extract::partition;

    fn ranking() -> PatternRanking {
        // Three windows with pattern A (single edge (0,1)), one with B.
        let g = Coo::from_edges(
            8,
            vec![
                Edge::new(0, 1),
                Edge::new(2, 3),
                Edge::new(4, 5),
                Edge::new(7, 6), // different local structure: (1,0)
            ],
        );
        PatternRanking::from_partitioned(&partition(&g, 2, false))
    }

    #[test]
    fn ranks_by_descending_frequency() {
        let r = ranking();
        assert_eq!(r.num_patterns(), 2);
        assert_eq!(r.ranked[0].1, 3);
        assert_eq!(r.ranked[1].1, 1);
        assert!(r.ranked[0].0.has_edge(0, 1, 2));
    }

    #[test]
    fn rank_of_is_consistent() {
        let r = ranking();
        for (i, (p, _)) in r.ranked.iter().enumerate() {
            assert_eq!(r.rank_of[p], i as u32);
        }
    }

    #[test]
    fn coverage_monotone_and_complete() {
        let r = ranking();
        assert!((r.coverage(1) - 0.75).abs() < 1e-12);
        assert!((r.coverage(2) - 1.0).abs() < 1e-12);
        assert!((r.coverage(100) - 1.0).abs() < 1e-12);
        assert!(r.coverage(0) == 0.0);
    }

    #[test]
    fn shares_sum_to_one() {
        let r = ranking();
        let total: f64 = (0..r.num_patterns()).map(|i| r.share(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_tie_break() {
        // Two patterns with equal counts must rank by pattern value.
        let g = Coo::from_edges(4, vec![Edge::new(0, 1), Edge::new(3, 2)]);
        let r = PatternRanking::from_partitioned(&partition(&g, 2, false));
        assert_eq!(r.ranked.len(), 2);
        assert!(r.ranked[0].0 < r.ranked[1].0);
    }

    #[test]
    fn skewed_graph_has_skewed_ranking() {
        // The paper's key observation on an R-MAT stand-in for Wiki-Vote:
        // top-16 patterns must cover the large majority of subgraphs.
        let g = crate::graph::datasets::Dataset::Tiny.load().unwrap();
        let r = PatternRanking::from_partitioned(&partition(&g, 4, false));
        assert!(
            r.coverage(16) > 0.6,
            "top-16 coverage {:.3} not skewed",
            r.coverage(16)
        );
        // Single-edge patterns dominate (power-law consequence §III.B).
        assert_eq!(r.ranked[0].0.nnz(), 1);
    }
}
