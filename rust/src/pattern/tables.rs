//! Main-memory tables produced by preprocessing (Alg. 1 steps ④, Fig. 3e).
//!
//! * **Configuration table (CT)** — per pattern: COO cell data, the graph
//!   engine/crossbar slot(s) it is pinned to if static, and the row
//!   address shortcut for single-edge patterns.
//! * **Subgraph table (ST)** — per subgraph: starting source/destination
//!   vertex (all windows have C vertices per side, so one pair suffices)
//!   and the pattern it instantiates, sorted in execution order.
//!
//! Static assignment supports two policies:
//!
//! * `TopK` — the literal Alg. 1: the N×M most frequent patterns get one
//!   static crossbar each.
//! * `Balanced` (default) — the paper's load-balancing refinement
//!   ("patterns assigned to static engines are evenly distributed …
//!   balances pattern load among static engines, improving overall
//!   utilization"): N×M slots are apportioned by a cost-aware greedy
//!   that weighs covering one more pattern against *replicating* a very
//!   frequent one, so hot patterns stop serializing a single engine.
//!   Replicas of a pattern land on distinct engines.

use std::collections::HashMap;

use super::extract::Partitioned;
use super::pattern::Pattern;
use super::rank::PatternRanking;

/// Where a static pattern replica lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineSlot {
    pub engine: u32,
    pub crossbar: u32,
}

/// Static-assignment policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum StaticAssignment {
    TopK,
    #[default]
    Balanced,
}

impl StaticAssignment {
    /// Stable wire code for the on-disk artifact format
    /// (`session::store`) — variant order must never be relied on.
    pub(crate) fn to_code(self) -> u8 {
        match self {
            StaticAssignment::TopK => 0,
            StaticAssignment::Balanced => 1,
        }
    }

    pub(crate) fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(StaticAssignment::TopK),
            1 => Some(StaticAssignment::Balanced),
            _ => None,
        }
    }
}

/// Configuration-table entry for one pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct CtEntry {
    pub pattern: Pattern,
    pub occurrences: u32,
    /// Static crossbar replicas holding this pattern (empty = dynamic).
    pub slots: Vec<EngineSlot>,
    /// Row address shortcut for single-edge patterns (§III.B).
    pub row_addr: Option<u8>,
    /// Cached `pattern.active_row_count(c)` — scheduler hot path.
    pub active_rows: u32,
}

impl CtEntry {
    #[inline]
    pub fn is_static(&self) -> bool {
        !self.slots.is_empty()
    }
}

/// Configuration table: rank-ordered patterns with static assignments.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigTable {
    pub entries: Vec<CtEntry>,
    index: HashMap<Pattern, u32>,
    pub num_static_engines: u32,
    pub crossbars_per_engine: u32,
    pub assignment: StaticAssignment,
}

impl ConfigTable {
    /// Assign `n_static * m` static crossbar slots over the ranking.
    /// `dyn_slots` is the number of dynamic crossbars in the machine —
    /// the balanced apportionment weighs "cover one more pattern"
    /// against "replicate a hot one" using the relative cost of dynamic
    /// ops and the dynamic pool's parallelism.
    pub fn build(
        ranking: &PatternRanking,
        c: usize,
        n_static: u32,
        m: u32,
        dyn_slots: u32,
        assignment: StaticAssignment,
    ) -> Self {
        let capacity = (n_static * m) as usize;
        // replicas[i] = number of slots for rank-i pattern.
        let replicas = match assignment {
            StaticAssignment::TopK => {
                let mut r = vec![0usize; ranking.num_patterns()];
                for x in r.iter_mut().take(capacity) {
                    *x = 1;
                }
                r
            }
            StaticAssignment::Balanced => {
                apportion_balanced(ranking, capacity, dyn_slots, DYN_COST_RATIO)
            }
        };

        // Assign slot positions engine-major in rank order so replicas of
        // the same pattern land on distinct engines.
        let mut next_slot = 0u32;
        let mut slot_at = |_: usize| {
            let s = next_slot;
            next_slot += 1;
            EngineSlot { engine: s % n_static.max(1), crossbar: s / n_static.max(1) }
        };

        let entries: Vec<CtEntry> = ranking
            .ranked
            .iter()
            .enumerate()
            .map(|(i, &(pattern, occurrences))| CtEntry {
                pattern,
                occurrences,
                slots: if n_static == 0 {
                    Vec::new()
                } else {
                    (0..replicas.get(i).copied().unwrap_or(0))
                        .map(|k| slot_at(k))
                        .collect()
                },
                row_addr: pattern.single_edge(c).map(|(r, _)| r),
                active_rows: pattern.active_row_count(c),
            })
            .collect();
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.pattern, i as u32))
            .collect();
        Self {
            entries,
            index,
            num_static_engines: n_static,
            crossbars_per_engine: m,
            assignment,
        }
    }

    /// Reassemble a table from decoded parts (`session::store`): the
    /// pattern index is derived state and is rebuilt here rather than
    /// persisted, so a loaded table can never carry an inconsistent one.
    pub(crate) fn from_parts(
        entries: Vec<CtEntry>,
        num_static_engines: u32,
        crossbars_per_engine: u32,
        assignment: StaticAssignment,
    ) -> Self {
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.pattern, i as u32))
            .collect();
        Self { entries, index, num_static_engines, crossbars_per_engine, assignment }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    #[inline]
    pub fn entry_of(&self, p: Pattern) -> Option<&CtEntry> {
        self.index.get(&p).map(|&i| &self.entries[i as usize])
    }

    /// Entry at a pattern rank. The subgraph table stores ranks, and the
    /// plan compiler ([`crate::sched::ExecutionPlan`]) resolves per-op
    /// metadata through this accessor exactly once — the pattern-keyed
    /// `entry_of` hash lookup never runs in the superstep hot loop.
    #[inline]
    pub fn entry_at(&self, rank: u32) -> &CtEntry {
        &self.entries[rank as usize]
    }

    /// First static slot for a pattern, if any (Alg. 2 line-11 test).
    #[inline]
    pub fn slot_of(&self, p: Pattern) -> Option<EngineSlot> {
        self.entry_of(p).and_then(|e| e.slots.first().copied())
    }

    #[inline]
    pub fn is_static(&self, p: Pattern) -> bool {
        self.entry_of(p).is_some_and(|e| e.is_static())
    }

    /// All (entry, replica slot) pairs — used to preconfigure static
    /// engines at init (Alg. 2 lines 6–8).
    pub fn static_assignments(&self) -> impl Iterator<Item = (&CtEntry, EngineSlot)> {
        self.entries
            .iter()
            .flat_map(|e| e.slots.iter().map(move |&s| (e, s)))
    }

    /// Fraction of subgraph *occurrences* that will hit static engines.
    pub fn static_coverage(&self) -> f64 {
        let total: u64 = self.entries.iter().map(|e| e.occurrences as u64).sum();
        if total == 0 {
            return 0.0;
        }
        let stat: u64 = self
            .entries
            .iter()
            .filter(|e| e.is_static())
            .map(|e| e.occurrences as u64)
            .sum();
        stat as f64 / total as f64
    }
}

/// A dynamic subgraph op (row-parallel reconfiguration + MVM) costs this
/// many static-op equivalents — derived from Table 3: ~2 row-writes at
/// 20.2 ns plus the MVM, vs the ~9 ns static MVM.
pub const DYN_COST_RATIO: f64 = 6.0;

/// Cost-aware greedy apportionment of `capacity` static slots.
///
/// Models the steady-state bottleneck: static ops queue on the engine
/// holding their pattern (per-replica queue = occ / r), while uncovered
/// patterns run on the dynamic pool at `ratio`× the per-op cost spread
/// over `dyn_slots` crossbars. Each slot goes to whichever action —
/// promote the next-ranked pattern to static, or replicate the hottest
/// static pattern — minimizes the resulting makespan. Promotion wins
/// ties (coverage also saves ReRAM writes, which the makespan ignores).
fn apportion_balanced(
    ranking: &PatternRanking,
    capacity: usize,
    dyn_slots: u32,
    ratio: f64,
) -> Vec<usize> {
    let n = ranking.num_patterns();
    let mut replicas = vec![0usize; n];
    if capacity == 0 || n == 0 {
        return replicas;
    }
    let occ: Vec<f64> = ranking.ranked.iter().map(|&(_, c)| c as f64).collect();
    let mut dyn_total: f64 = occ.iter().sum();
    let mut next = 0usize; // next unassigned rank
    let dyn_cost = |d: f64| d * ratio / dyn_slots.max(1) as f64;
    let hottest = |replicas: &[usize], upto: usize| -> (usize, f64) {
        let mut best = (usize::MAX, 0.0f64);
        for i in 0..upto {
            let q = occ[i] / replicas[i] as f64;
            if q > best.1 {
                best = (i, q);
            }
        }
        best
    };
    for _ in 0..capacity {
        let (hot_i, hot_q) = hottest(&replicas, next);
        // Option A: promote pattern `next` to static (one slot).
        let obj_a = if next < n {
            hot_q.max(occ[next]).max(dyn_cost(dyn_total - occ[next]))
        } else {
            f64::INFINITY
        };
        // Option B: replicate the hottest static pattern.
        let obj_b = if hot_i != usize::MAX {
            let mut r2 = replicas[hot_i];
            r2 += 1;
            // New hottest after the replica.
            let mut new_hot = occ[hot_i] / r2 as f64;
            for i in 0..next {
                if i != hot_i {
                    new_hot = new_hot.max(occ[i] / replicas[i] as f64);
                }
            }
            new_hot.max(dyn_cost(dyn_total))
        } else {
            f64::INFINITY
        };
        if obj_a.is_infinite() && obj_b.is_infinite() {
            break;
        }
        if obj_a <= obj_b {
            replicas[next] = 1;
            dyn_total -= occ[next];
            next += 1;
        } else {
            replicas[hot_i] += 1;
        }
    }
    debug_assert!(replicas.iter().sum::<usize>() <= capacity);
    replicas
}

/// Subgraph-table entry: compressed per-subgraph record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StEntry {
    /// Index into `Partitioned::subgraphs` (vertex data + weights live there).
    pub sg_idx: u32,
    /// Starting source vertex (brow * C).
    pub src_start: u32,
    /// Starting destination vertex (bcol * C).
    pub dst_start: u32,
    /// Pattern rank (index into the CT) — small ids for hot patterns.
    pub pattern_rank: u32,
}

/// Execution order of the subgraph table (paper §III.C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ExecOrder {
    /// Group subgraphs sharing destination vertices (baseline, used for BFS).
    #[default]
    ColumnMajor,
    /// Group subgraphs sharing source vertices.
    RowMajor,
}

impl ExecOrder {
    /// Stable wire code for the on-disk artifact format
    /// (`session::store`) — variant order must never be relied on.
    pub(crate) fn to_code(self) -> u8 {
        match self {
            ExecOrder::ColumnMajor => 0,
            ExecOrder::RowMajor => 1,
        }
    }

    pub(crate) fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(ExecOrder::ColumnMajor),
            1 => Some(ExecOrder::RowMajor),
            _ => None,
        }
    }
}

/// Subgraph table in execution order, with group boundaries: each group
/// shares the same destination (column-major) or source (row-major)
/// block — the "batch of subgraphs with same dest. vertices" of Alg. 2.
#[derive(Debug, Clone, PartialEq)]
pub struct SubgraphTable {
    pub order: ExecOrder,
    pub entries: Vec<StEntry>,
    /// `groups[g]..groups[g+1]` delimits group g in `entries`.
    pub groups: Vec<u32>,
}

impl SubgraphTable {
    pub fn build(p: &Partitioned, ranking: &PatternRanking, order: ExecOrder) -> Self {
        let c = p.c as u32;
        let mut keyed: Vec<(u32, u32, StEntry)> = p
            .subgraphs
            .iter()
            .enumerate()
            .map(|(k, s)| {
                let entry = StEntry {
                    sg_idx: k as u32,
                    src_start: s.brow * c,
                    dst_start: s.bcol * c,
                    pattern_rank: ranking.rank_of[&s.pattern],
                };
                match order {
                    ExecOrder::ColumnMajor => (s.bcol, s.brow, entry),
                    ExecOrder::RowMajor => (s.brow, s.bcol, entry),
                }
            })
            .collect();
        keyed.sort_unstable_by_key(|&(a, b, _)| (a, b));

        let mut entries = Vec::with_capacity(keyed.len());
        let mut groups = vec![0u32];
        let mut current: Option<u32> = None;
        for (major, _, e) in keyed {
            if current != Some(major) {
                if current.is_some() {
                    groups.push(entries.len() as u32);
                }
                current = Some(major);
            }
            entries.push(e);
        }
        groups.push(entries.len() as u32);
        if entries.is_empty() {
            groups = vec![0, 0];
        }
        Self { order, entries, groups }
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len() - 1
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries of group `g`.
    pub fn group(&self, g: usize) -> &[StEntry] {
        &self.entries[self.groups[g] as usize..self.groups[g + 1] as usize]
    }

    /// Iterate groups in order.
    pub fn iter_groups(&self) -> impl Iterator<Item = &[StEntry]> {
        (0..self.num_groups()).map(move |g| self.group(g))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::{Coo, Edge};
    use crate::pattern::extract::partition;

    fn setup() -> (Partitioned, PatternRanking) {
        let g = Coo::from_edges(
            8,
            vec![
                Edge::new(0, 1),
                Edge::new(2, 3),
                Edge::new(4, 5),
                Edge::new(7, 6),
                Edge::new(0, 5),
                Edge::new(1, 4),
            ],
        );
        let p = partition(&g, 2, false);
        let r = PatternRanking::from_partitioned(&p);
        (p, r)
    }

    #[test]
    fn topk_assignment_respects_capacity() {
        let (_, r) = setup();
        let ct = ConfigTable::build(&r, 2, 1, 2, 4, StaticAssignment::TopK);
        let n_static = ct.entries.iter().filter(|e| e.is_static()).count();
        assert_eq!(n_static, 2.min(r.num_patterns()));
        assert!(ct.entries[0].is_static());
        // TopK gives exactly one slot per static pattern.
        assert!(ct.entries.iter().all(|e| e.slots.len() <= 1));
    }

    #[test]
    fn balanced_replicates_hot_patterns() {
        let (_, r) = setup();
        // Ranking: one pattern with 3 occurrences, three with 1.
        let ct = ConfigTable::build(&r, 2, 4, 1, 4, StaticAssignment::Balanced);
        let total_slots: usize = ct.entries.iter().map(|e| e.slots.len()).sum();
        assert_eq!(total_slots, 4);
        // D'Hondt: priorities 3, 1.5, 1, 1, 1 → P0 gets 2 slots.
        assert_eq!(ct.entries[0].slots.len(), 2);
        // Replicas land on distinct engines.
        let engines: Vec<u32> = ct.entries[0].slots.iter().map(|s| s.engine).collect();
        assert_ne!(engines[0], engines[1]);
    }

    #[test]
    fn balanced_never_exceeds_capacity_and_is_rank_monotone() {
        let (_, r) = setup();
        for cap in 1..8u32 {
            let ct = ConfigTable::build(&r, 2, cap, 1, 4, StaticAssignment::Balanced);
            let total: usize = ct.entries.iter().map(|e| e.slots.len()).sum();
            assert!(total <= cap as usize);
            // A lower-ranked pattern never has more replicas than a
            // higher-ranked one (D'Hondt is proportional).
            for w in ct.entries.windows(2) {
                assert!(w[0].slots.len() >= w[1].slots.len());
            }
        }
    }

    #[test]
    fn zero_static_engines_means_all_dynamic() {
        let (_, r) = setup();
        for a in [StaticAssignment::TopK, StaticAssignment::Balanced] {
            let ct = ConfigTable::build(&r, 2, 0, 4, 4, a);
            assert!(ct.entries.iter().all(|e| !e.is_static()));
            assert_eq!(ct.static_coverage(), 0.0);
        }
    }

    #[test]
    fn row_addr_only_for_single_edge_patterns() {
        let (_, r) = setup();
        let ct = ConfigTable::build(&r, 2, 4, 1, 4, StaticAssignment::TopK);
        for e in &ct.entries {
            assert_eq!(e.row_addr.is_some(), e.pattern.nnz() == 1, "{:?}", e.pattern);
        }
    }

    #[test]
    fn topk_static_coverage_matches_ranking_coverage() {
        let (_, r) = setup();
        let ct = ConfigTable::build(&r, 2, 1, 1, 4, StaticAssignment::TopK);
        assert!((ct.static_coverage() - r.coverage(1)).abs() < 1e-12);
    }

    #[test]
    fn static_assignments_slots_are_unique() {
        let (_, r) = setup();
        for a in [StaticAssignment::TopK, StaticAssignment::Balanced] {
            let ct = ConfigTable::build(&r, 2, 3, 2, 4, a);
            let mut seen = std::collections::HashSet::new();
            for (_, slot) in ct.static_assignments() {
                assert!(slot.engine < 3 && slot.crossbar < 2);
                assert!(seen.insert((slot.engine, slot.crossbar)), "slot reused");
            }
        }
    }

    #[test]
    fn st_column_major_groups_share_dst_block() {
        let (p, r) = setup();
        let st = SubgraphTable::build(&p, &r, ExecOrder::ColumnMajor);
        assert_eq!(st.len(), p.num_subgraphs());
        for grp in st.iter_groups() {
            assert!(!grp.is_empty());
            let d0 = grp[0].dst_start;
            assert!(grp.iter().all(|e| e.dst_start == d0));
        }
        let firsts: Vec<u32> = st.iter_groups().map(|g| g[0].dst_start).collect();
        let mut sorted = firsts.clone();
        sorted.sort_unstable();
        assert_eq!(firsts, sorted);
    }

    #[test]
    fn st_row_major_groups_share_src_block() {
        let (p, r) = setup();
        let st = SubgraphTable::build(&p, &r, ExecOrder::RowMajor);
        for grp in st.iter_groups() {
            let s0 = grp[0].src_start;
            assert!(grp.iter().all(|e| e.src_start == s0));
        }
    }

    #[test]
    fn st_pattern_ranks_consistent_with_ct() {
        let (p, r) = setup();
        let ct = ConfigTable::build(&r, 2, 2, 1, 4, StaticAssignment::Balanced);
        let st = SubgraphTable::build(&p, &r, ExecOrder::ColumnMajor);
        for e in &st.entries {
            let sg = &p.subgraphs[e.sg_idx as usize];
            assert_eq!(ct.entries[e.pattern_rank as usize].pattern, sg.pattern);
        }
    }

    #[test]
    fn empty_graph_tables() {
        let p = partition(&Coo::from_edges(4, vec![]), 2, false);
        let r = PatternRanking::from_partitioned(&p);
        let ct = ConfigTable::build(&r, 2, 4, 1, 4, StaticAssignment::Balanced);
        let st = SubgraphTable::build(&p, &r, ExecOrder::ColumnMajor);
        assert!(ct.is_empty());
        assert!(st.is_empty());
        assert_eq!(st.num_groups(), 1);
        assert_eq!(st.group(0).len(), 0);
    }

    #[test]
    fn balanced_coverage_is_house_monotone() {
        let (_, r) = setup();
        let mut last = -1.0;
        for cap in 0..8 {
            let ct = ConfigTable::build(&r, 2, cap, 1, 4, StaticAssignment::Balanced);
            let cov = ct.static_coverage();
            assert!(cov >= last - 1e-12, "coverage dropped at cap {cap}");
            last = cov;
        }
    }
}
