//! Regenerators for every table and figure of the paper's evaluation
//! (DESIGN.md §4 maps each one to its bench target). Each function
//! returns the rendered report so the CLI, examples and benches share one
//! implementation.

use anyhow::Result;

use crate::accel::{Accelerator, ArchConfig};
use crate::algo::Bfs;
use crate::baselines;
use crate::cost::{CostParams, LifetimeReport};
use crate::dse::static_engine_sweep;
use crate::graph::datasets::{Dataset, ALL_DATASETS};
use crate::graph::Coo;
use crate::pattern::{extract::partition, rank::PatternRanking};
use crate::sched::executor::NativeExecutor;
use crate::util::fmt;

use super::tables::Table;

/// Default per-dataset scale factors: the two largest graphs are scaled
/// down to bound simulation time (DESIGN.md §Substitutions); all ratios
/// are within-dataset, so scaling does not affect comparisons.
pub fn default_scale(d: Dataset) -> f64 {
    match d {
        Dataset::WebGoogle => 0.12,
        Dataset::Amazon => 0.35,
        _ => 1.0,
    }
}

fn load(d: Dataset, scale: Option<f64>) -> Result<Coo> {
    d.load_scaled(scale.unwrap_or_else(|| default_scale(d)))
}

/// Fig. 1a: pattern-occurrence histogram of Wiki-Vote under a 4×4 window.
pub fn fig1(scale: Option<f64>) -> Result<String> {
    let g = load(Dataset::WikiVote, scale)?;
    let part = partition(&g, 4, false);
    let ranking = PatternRanking::from_partitioned(&part);
    let mut t = Table::new(
        "Figure 1a: pattern occurrence, Wiki-Vote, 4x4 non-overlapping window",
    )
    .header(["rank", "pattern", "edges", "count", "share", "cum."]);
    let mut cum = 0.0;
    for (i, p, c, share) in ranking.histogram(16) {
        cum += share;
        t.row([
            format!("P{i}"),
            format!("{p}"),
            p.nnz().to_string(),
            fmt::count(c as u64),
            format!("{:.1}%", share * 100.0),
            format!("{:.1}%", cum * 100.0),
        ]);
    }
    let rest = 1.0 - cum;
    t.row([
        format!("P16..P{}", ranking.num_patterns().saturating_sub(1)),
        "(tail)".into(),
        "-".into(),
        fmt::count((ranking.total_subgraphs as f64 * rest).round() as u64),
        format!("{:.1}%", rest * 100.0),
        "100.0%".into(),
    ]);
    let mut out = t.render();
    out.push_str(&format!(
        "total subgraphs: {}   distinct patterns: {}   top-16 coverage: {:.1}% (paper: 86%)\n",
        fmt::count(ranking.total_subgraphs as u64),
        ranking.num_patterns(),
        ranking.coverage(16) * 100.0
    ));
    Ok(out)
}

/// Fig. 5: engine read/write activity, Wiki-Vote, 4 static + 2 dynamic
/// engines with 4 crossbars each.
pub fn fig5(scale: Option<f64>) -> Result<String> {
    let g = load(Dataset::WikiVote, scale)?;
    let acc = Accelerator::new(ArchConfig::fig5(), CostParams::default());
    let report = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor)?;
    let run = report.run.as_ref().unwrap();
    let trace = run.activity.as_ref().unwrap();
    let window = (trace.num_iterations() / 24).max(1);
    let (reads, writes) = trace.windowed_activity(window);

    let mut out = format!(
        "Figure 5: engine activity, Wiki-Vote BFS (GE1-GE4 static, GE5-GE6 dynamic)\n\
         iterations: {}   window: {}   activity 0-100 (# = 10 units)\n",
        trace.num_iterations(),
        window
    );
    let bar = |v: f64| "#".repeat((v / 10.0).round() as usize);
    for (series, name) in [(&reads, "READ"), (&writes, "WRITE")] {
        out.push_str(&format!("-- {name} activity --\n"));
        for (e, row) in series.iter().enumerate() {
            let kind = if e < 4 { "static " } else { "dynamic" };
            out.push_str(&format!("GE{} ({kind}): ", e + 1));
            for &v in row {
                out.push_str(&format!("{:>3.0} ", v));
            }
            out.push('\n');
            out.push_str(&format!("             {}\n", row.iter().map(|&v| bar(v)).collect::<Vec<_>>().join(" ")));
        }
    }
    let totals = trace.totals();
    let static_reads: u64 = totals[..4].iter().map(|t| t.0).sum();
    let dynamic_reads: u64 = totals[4..].iter().map(|t| t.0).sum();
    out.push_str(&format!(
        "static-engine reads: {}   dynamic-engine reads: {}   (paper: static ≫ dynamic)\n",
        fmt::count(static_reads),
        fmt::count(dynamic_reads)
    ));
    Ok(out)
}

/// Fig. 6: speedup vs number of static engines (T = 32, M = 1),
/// normalized to N = 0, on three representative datasets.
pub fn fig6(scale: Option<f64>) -> Result<String> {
    let ns = [0u32, 4, 8, 12, 16, 20, 24, 28, 31];
    let datasets = [Dataset::WikiVote, Dataset::Epinions, Dataset::Gnutella];
    let mut t = Table::new(
        "Figure 6: speedup vs static engines (32 engines total, 4x4 crossbars, norm. to N=0)",
    )
    .header(
        std::iter::once("dataset".to_string())
            .chain(ns.iter().map(|n| format!("N={n}"))),
    );
    let mut best_line = String::new();
    for d in datasets {
        let g = load(d, scale)?;
        let points = static_engine_sweep(
            &g,
            &ArchConfig::default(),
            &CostParams::default(),
            &Bfs::new(0),
            &ns,
        )?;
        let mut row = vec![d.spec().short.to_string()];
        row.extend(points.iter().map(|p| format!("{:.2}x", p.speedup)));
        t.row(row);
        let best = points
            .iter()
            .max_by(|a, b| a.speedup.total_cmp(&b.speedup))
            .unwrap();
        best_line.push_str(&format!("{}: best N={} ({:.2}x)  ", d.spec().short, best.x, best.speedup));
    }
    let mut out = t.render();
    out.push_str(&best_line);
    out.push_str("(paper: peak at N=16, ~1.8x)\n");
    Ok(out)
}

/// Shared Table 4 / Fig. 7 computation: all four designs on a dataset.
fn compare_designs(d: Dataset, scale: Option<f64>) -> Result<Vec<crate::accel::SimReport>> {
    let g = load(d, scale)?;
    let params = CostParams::default();
    let engines = 32;
    let acc = Accelerator::new(ArchConfig::default(), params.clone());
    let ours = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor)?;
    let mut reports = baselines::simulate_all(&g, 0, &params, engines);
    reports.push(ours);
    Ok(reports)
}

/// Table 4: BFS energy across all datasets, four designs.
pub fn table4(scale: Option<f64>) -> Result<String> {
    let mut t = Table::new("Table 4: total BFS energy (synthetic Table-2-scale R-MAT graphs)")
        .header(["Dataset", "GraphR", "SparseMEM", "TARe", "Proposed", "vs SparseMEM", "vs TARe"]);
    for d in ALL_DATASETS {
        let reports = compare_designs(d, scale)?;
        let by = |name: &str| {
            reports
                .iter()
                .find(|r| r.design == name)
                .map(|r| r.energy_j())
                .unwrap_or(f64::NAN)
        };
        let (gr, sm, ta, us) = (by("GraphR"), by("SparseMEM"), by("TARe"), by("Proposed"));
        t.row([
            d.spec().short.to_string(),
            fmt::energy(gr),
            fmt::energy(sm),
            fmt::energy(ta),
            fmt::energy(us),
            format!("{:.2}x", sm / us),
            format!("{:.2}x", ta / us),
        ]);
    }
    let mut out = t.render();
    out.push_str("(paper: Proposed ~7.23x vs SparseMEM, ~2.3x vs TARe, ~3 orders vs GraphR)\n");
    Ok(out)
}

/// Fig. 7: BFS speedup normalized to GraphR.
pub fn fig7(scale: Option<f64>) -> Result<String> {
    let mut t = Table::new("Figure 7: BFS speedup normalized to GraphR")
        .header(["Dataset", "GraphR", "SparseMEM", "TARe", "Proposed", "Prop./SpMEM", "Prop./TARe"]);
    let mut gm_sm = 0.0f64;
    let mut gm_ta = 0.0f64;
    let mut n = 0usize;
    for d in ALL_DATASETS {
        let reports = compare_designs(d, scale)?;
        let by = |name: &str| {
            reports
                .iter()
                .find(|r| r.design == name)
                .map(|r| r.exec_time_ns)
                .unwrap_or(f64::NAN)
        };
        let (gr, sm, ta, us) = (by("GraphR"), by("SparseMEM"), by("TARe"), by("Proposed"));
        t.row([
            d.spec().short.to_string(),
            "1.0x".to_string(),
            format!("{:.0}x", gr / sm),
            format!("{:.0}x", gr / ta),
            format!("{:.0}x", gr / us),
            format!("{:.2}x", sm / us),
            format!("{:.2}x", ta / us),
        ]);
        gm_sm += (sm / us).ln();
        gm_ta += (ta / us).ln();
        n += 1;
    }
    let mut out = t.render();
    out.push_str(&format!(
        "geomean speedup: {:.2}x vs SparseMEM, {:.2}x vs TARe (paper: 2.38x, 1.27x)\n",
        (gm_sm / n as f64).exp(),
        (gm_ta / n as f64).exp()
    ));
    Ok(out)
}

/// §IV.D lifetime analysis: 128 engines, Wiki-Vote hourly.
pub fn lifetime(scale: Option<f64>) -> Result<String> {
    let g = load(Dataset::WikiVote, scale)?;
    let params = CostParams::default();
    let interval_s = 3600.0;
    let cfg = ArchConfig::lifetime();
    let engines = cfg.total_engines;
    let acc = Accelerator::new(cfg, params.clone());
    let ours = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor)?;
    let base = baselines::simulate_all(&g, 0, &params, engines);

    let mut rows: Vec<LifetimeReport> = base
        .iter()
        .map(|r| {
            LifetimeReport::new(
                r.design.clone(),
                r.max_cell_writes,
                r.counts.write_bits,
                params.endurance_cycles,
                interval_s,
            )
        })
        .collect();
    rows.push(LifetimeReport::new(
        "Proposed",
        ours.max_cell_writes,
        ours.counts.write_bits,
        params.endurance_cycles,
        interval_s,
    ));

    let mut t = Table::new(
        "Lifetime (sec IV.D): 128 engines, Wiki-Vote once per hour, endurance 1e8",
    )
    .header(["Design", "max cell writes/run", "total write bits/run", "lifetime"]);
    for r in &rows {
        t.row([
            r.design.clone(),
            fmt::count(r.max_cell_writes),
            fmt::count(r.total_write_bits),
            r.lifetime_human(),
        ]);
    }
    let mut out = t.render();
    let get = |name: &str| rows.iter().find(|r| r.design == name).unwrap().lifetime_s;
    out.push_str(&format!(
        "Proposed vs GraphR: {:.0}x   Proposed vs SparseMEM: {:.1}x   (paper: ~100x, ~2x; >10 years)\n",
        get("Proposed") / get("GraphR"),
        get("Proposed") / get("SparseMEM")
    ));
    Ok(out)
}

/// Table 1: qualitative comparison of graph accelerators.
pub fn table1() -> Result<String> {
    let mut t = Table::new("Table 1: comparison of existing graph accelerators").header([
        "Reference",
        "In-engine representation",
        "Memory access (R/W)",
        "MLC ReRAM",
        "Algorithms",
    ]);
    t.row(["GraphR [10]", "Adjacency", "High/High", "4-bit", "Classical"]);
    t.row(["ReFlip [12]", "Compressed", "High/Low", "Variable", "GNN"]);
    t.row(["SparseMEM [15]", "Compressed", "Low/Low", "Variable", "Classical"]);
    t.row(["TARe [16]", "Adjacency", "High/Low", "1-bit", "GNN"]);
    t.row(["Proposed", "Adjacency", "Low/Low", "1-bit", "Classical"]);
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Figures on full-size datasets run in benches/examples; here we pin
    // small-scale behaviour and the qualitative orderings.
    const S: Option<f64> = Some(0.05);

    #[test]
    fn fig1_reports_skewed_coverage() {
        let out = fig1(S).unwrap();
        assert!(out.contains("P0"));
        assert!(out.contains("top-16 coverage"));
    }

    #[test]
    fn fig5_shows_static_dominance() {
        let out = fig5(S).unwrap();
        assert!(out.contains("GE1"));
        assert!(out.contains("READ"));
        assert!(out.contains("WRITE"));
    }

    #[test]
    fn table4_orders_designs() {
        let out = table4(Some(0.03)).unwrap();
        assert!(out.contains("GraphR"));
        assert!(out.contains("Proposed"));
        assert_eq!(out.matches('\n').count() >= 10, true);
    }

    #[test]
    fn table1_is_static_content() {
        let out = table1().unwrap();
        assert!(out.contains("Low/Low"));
        assert!(out.contains("1-bit"));
    }

    #[test]
    fn lifetime_reports_all_designs() {
        let out = lifetime(Some(0.05)).unwrap();
        assert!(out.contains("Proposed"));
        assert!(out.contains("write-free")); // TARe
        assert!(out.contains("Proposed vs SparseMEM"));
    }
}
