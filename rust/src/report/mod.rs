//! Report generation: ASCII tables and regenerators for every table and
//! figure in the paper's evaluation section (per-experiment index in
//! DESIGN.md §4).

pub mod figures;
pub mod tables;

pub use figures::{fig1, fig5, fig6, fig7, lifetime, table1, table4};
pub use tables::Table;
