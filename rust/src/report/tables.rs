//! Minimal ASCII table builder for paper-style report output.

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), ..Default::default() }
    }

    pub fn header<S: Into<String>>(mut self, cols: impl IntoIterator<Item = S>) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>>(&mut self, cols: impl IntoIterator<Item = S>) -> &mut Self {
        self.rows.push(cols.into_iter().map(Into::into).collect());
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let measure = |row: &[String], widths: &mut [usize]| {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        };
        measure(&self.header, &mut widths);
        for r in &self.rows {
            measure(r, &mut widths);
        }
        let fmt_row = |row: &[String], widths: &[usize]| {
            let cells: Vec<String> = widths
                .iter()
                .enumerate()
                .map(|(i, &w)| {
                    let cell = row.get(i).map(String::as_str).unwrap_or("");
                    format!("{cell:<w$}")
                })
                .collect();
            format!("| {} |", cells.join(" | "))
        };
        let sep = format!(
            "+{}+",
            widths
                .iter()
                .map(|&w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("+")
        );
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("Demo").header(["a", "long-column"]);
        t.row(["1", "2"]);
        t.row(["333", "4"]);
        let s = t.render();
        assert!(s.starts_with("Demo\n+"));
        assert!(s.contains("| a   | long-column |"));
        assert!(s.contains("| 333 | 4           |"));
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = Table::new("").header(["x", "y", "z"]);
        t.row(["1"]);
        let s = t.render();
        assert!(s.contains("| 1 |   |   |"));
    }
}
