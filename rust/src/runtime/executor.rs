//! PJRT-backed step executor: the production datapath.
//!
//! Loads HLO text (`HloModuleProto::from_text_file` — the text parser
//! reassigns instruction ids, which is why text, not `.serialize()`, is
//! the interchange format), compiles once per (step, crossbar) variant,
//! and executes batches from the scheduler hot path, chunking/padding the
//! op stream to the artifact's fixed batch size.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::algo::traits::StepKind;
use crate::sched::executor::{identity, StepExecutor};
use crate::sched::plan::StepBatch;

use super::manifest::Manifest;

/// A compiled artifact plus its shape metadata.
struct LoadedStep {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    #[allow(dead_code)]
    c: usize,
}

/// PJRT CPU client + executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    loaded: HashMap<(StepKind, usize), LoadedStep>,
    /// Executions issued (for metrics / amortization checks).
    pub dispatches: u64,
}

impl PjrtRuntime {
    /// Create against an artifact directory (see `make artifacts`).
    pub fn new(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        Ok(Self { client, manifest, dir, loaded: HashMap::new(), dispatches: 0 })
    }

    pub fn from_default_dir() -> Result<Self> {
        Self::new(super::default_artifact_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and cache the artifact for (step, crossbar size).
    fn load(&mut self, kind: StepKind, c: usize) -> Result<&LoadedStep> {
        if !self.loaded.contains_key(&(kind, c)) {
            let entry = self
                .manifest
                .select(kind.artifact_name(), c)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no artifact for step {:?} at C={c}; rerun `make artifacts`",
                        kind
                    )
                })?
                .clone();
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(wrap_xla)
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(wrap_xla)?;
            self.loaded
                .insert((kind, c), LoadedStep { exe, batch: entry.batch, c });
        }
        Ok(&self.loaded[&(kind, c)])
    }

    /// Execute one padded batch: `mats` is (B, C, C) row-major, `xs` is
    /// (B, C); returns the (B, C) output.
    fn dispatch(&mut self, kind: StepKind, c: usize, mats: &[f32], xs: &[f32]) -> Result<Vec<f32>> {
        self.dispatches += 1;
        let step = self.load(kind, c)?;
        let b = step.batch;
        debug_assert_eq!(mats.len(), b * c * c);
        debug_assert_eq!(xs.len(), b * c);
        let m_lit = xla::Literal::vec1(mats)
            .reshape(&[b as i64, c as i64, c as i64])
            .map_err(wrap_xla)?;
        let x_lit = xla::Literal::vec1(xs)
            .reshape(&[b as i64, c as i64])
            .map_err(wrap_xla)?;
        let result = step
            .exe
            .execute::<xla::Literal>(&[m_lit, x_lit])
            .map_err(wrap_xla)?[0][0]
            .to_literal_sync()
            .map_err(wrap_xla)?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(wrap_xla)?;
        out.to_vec::<f32>().map_err(wrap_xla)
    }
}

/// `xla::Error` does not implement `std::error::Error` across versions;
/// stringify defensively.
fn wrap_xla<E: std::fmt::Debug>(e: E) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

/// `StepExecutor` over a `PjrtRuntime`: packs scheduler ops into dense
/// (B, C, C)/(B, C) literals, padding the tail chunk with zero matrices
/// (zero adjacency ⇒ identity candidates in every semiring). Dense
/// matrices unpack straight from the plan-owned packed bits/weights into
/// the reused chunk buffer, so packing memory stays O(batch) rather than
/// O(graph).
pub struct PjrtExecutor {
    pub runtime: PjrtRuntime,
    // Reused packing buffers — no allocation per dispatch.
    mats: Vec<f32>,
    xvec: Vec<f32>,
}

impl PjrtExecutor {
    pub fn new(runtime: PjrtRuntime) -> Self {
        Self { runtime, mats: Vec::new(), xvec: Vec::new() }
    }

    pub fn from_default_dir() -> Result<Self> {
        Ok(Self::new(PjrtRuntime::from_default_dir()?))
    }
}

impl StepExecutor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(
        &mut self,
        kind: StepKind,
        batch: StepBatch<'_>,
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let c = batch.c();
        anyhow::ensure!(xs.len() == batch.len() * c, "xs length mismatch");
        if kind == StepKind::Sssp {
            anyhow::ensure!(batch.weighted(), "SSSP requires weighted partitioning");
        }
        out.clear();
        out.reserve(batch.len() * c);
        let b = self.runtime.load(kind, c)?.batch;
        anyhow::ensure!(b > 0, "artifact for {kind:?} at C={c} declares batch size 0");
        let ident = identity(kind);
        let cc = c * c;

        let mut chunk_start = 0usize;
        while chunk_start < batch.len() {
            let chunk_len = b.min(batch.len() - chunk_start);
            self.mats.clear();
            self.mats.resize(b * cc, 0.0);
            self.xvec.clear();
            self.xvec.resize(b * c, ident);
            for k in 0..chunk_len {
                batch.dense_into(chunk_start + k, &mut self.mats[k * cc..(k + 1) * cc]);
            }
            self.xvec[..chunk_len * c]
                .copy_from_slice(&xs[chunk_start * c..(chunk_start + chunk_len) * c]);
            let mats = std::mem::take(&mut self.mats);
            let xvec = std::mem::take(&mut self.xvec);
            let res = self.runtime.dispatch(kind, c, &mats, &xvec)?;
            self.mats = mats;
            self.xvec = xvec;
            out.extend_from_slice(&res[..chunk_len * c]);
            chunk_start += chunk_len;
        }
        Ok(())
    }

    /// Batched variant: the dense (B, C, C) matrix packing — the
    /// expensive per-op decode on this backend — is done once per chunk
    /// and reused for every lane's dispatch. Each lane's dispatch is the
    /// same padded execution its solo [`execute`](StepExecutor::execute)
    /// would issue (same chunk boundaries, same matrices, same padded
    /// inputs), so per-lane outputs are bit-identical to solo.
    fn execute_multi(
        &mut self,
        kind: StepKind,
        batch: StepBatch<'_>,
        lanes: usize,
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(lanes >= 1, "execute_multi requires at least one lane");
        if lanes == 1 {
            return self.execute(kind, batch, xs, out);
        }
        let c = batch.c();
        anyhow::ensure!(xs.len() == batch.len() * lanes * c, "xs length mismatch");
        if kind == StepKind::Sssp {
            anyhow::ensure!(batch.weighted(), "SSSP requires weighted partitioning");
        }
        let b = self.runtime.load(kind, c)?.batch;
        anyhow::ensure!(b > 0, "artifact for {kind:?} at C={c} declares batch size 0");
        let ident = identity(kind);
        let cc = c * c;
        let len = batch.len() * lanes * c;
        out.truncate(len);
        out.fill(ident);
        out.resize(len, ident);

        let mut chunk_start = 0usize;
        while chunk_start < batch.len() {
            let chunk_len = b.min(batch.len() - chunk_start);
            self.mats.clear();
            self.mats.resize(b * cc, 0.0);
            for k in 0..chunk_len {
                batch.dense_into(chunk_start + k, &mut self.mats[k * cc..(k + 1) * cc]);
            }
            let mats = std::mem::take(&mut self.mats);
            for l in 0..lanes {
                self.xvec.clear();
                self.xvec.resize(b * c, ident);
                for k in 0..chunk_len {
                    let src = ((chunk_start + k) * lanes + l) * c;
                    self.xvec[k * c..(k + 1) * c].copy_from_slice(&xs[src..src + c]);
                }
                let xvec = std::mem::take(&mut self.xvec);
                let res = self.runtime.dispatch(kind, c, &mats, &xvec)?;
                self.xvec = xvec;
                for k in 0..chunk_len {
                    let dst = ((chunk_start + k) * lanes + l) * c;
                    out[dst..dst + c].copy_from_slice(&res[k * c..(k + 1) * c]);
                }
            }
            self.mats = mats;
            chunk_start += chunk_len;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    //! Requires `make artifacts` (skipped silently when absent so pure
    //! cargo-test environments stay green; integration tests in
    //! `rust/tests/` assert the full PJRT path).
    use super::*;
    use crate::algo::traits::INF;
    use crate::graph::coo::{Coo, Edge};
    use crate::pattern::extract::partition;
    use crate::sched::executor::NativeExecutor;
    use crate::sched::plan::ExecutionPlan;

    fn runtime() -> Option<PjrtRuntime> {
        let dir = crate::runtime::default_artifact_dir();
        dir.join("manifest.tsv")
            .exists()
            .then(|| PjrtRuntime::new(dir).unwrap())
    }

    #[test]
    fn pjrt_matches_native_on_bfs_batch() {
        let Some(rt) = runtime() else { return };
        let mut pjrt = PjrtExecutor::new(rt);
        let g = crate::graph::datasets::Dataset::Tiny.load().unwrap();
        let part = partition(&g, 4, false);
        let plan = ExecutionPlan::from_partitioned(&part);
        let n = part.num_subgraphs().min(100);
        let sgs: Vec<u32> = (0..n as u32).collect();
        let mut rng = crate::util::SplitMix64::new(1);
        let xs: Vec<f32> = (0..n * 4)
            .map(|_| if rng.next_bool(0.5) { INF } else { rng.next_f32() * 5.0 })
            .collect();
        let mut got = Vec::new();
        let mut want = Vec::new();
        pjrt.execute(StepKind::Bfs, plan.batch(&sgs), &xs, &mut got).unwrap();
        NativeExecutor.execute(StepKind::Bfs, plan.batch(&sgs), &xs, &mut want).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 || (*g >= INF && *w >= INF), "{g} vs {w}");
        }
    }

    #[test]
    fn pjrt_matches_native_on_pagerank_batch() {
        let Some(rt) = runtime() else { return };
        let mut pjrt = PjrtExecutor::new(rt);
        let g = Coo::from_edges(
            8,
            vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(4, 7), Edge::new(5, 6)],
        );
        let part = partition(&g, 4, false);
        let plan = ExecutionPlan::from_partitioned(&part);
        let sgs: Vec<u32> = (0..part.num_subgraphs() as u32).collect();
        let xs: Vec<f32> = (0..sgs.len() * 4).map(|i| i as f32 * 0.01).collect();
        let mut got = Vec::new();
        let mut want = Vec::new();
        pjrt.execute(StepKind::PageRank, plan.batch(&sgs), &xs, &mut got).unwrap();
        NativeExecutor.execute(StepKind::PageRank, plan.batch(&sgs), &xs, &mut want).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
    }
}
