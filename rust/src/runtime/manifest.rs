//! `artifacts/manifest.tsv` — the contract between `python/compile/aot.py`
//! and the rust runtime: which step variants exist, at which shapes, in
//! which files. (TSV rather than JSON: the offline image vendors no JSON
//! crate, and the schema is a flat table anyway. aot.py also writes a
//! manifest.json for humans/tools.)
//!
//! Line format: `step<TAB>batch<TAB>crossbar<TAB>file`, `#` comments.

use std::path::Path;

use anyhow::{Context, Result};

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub step: String,
    pub batch: usize,
    pub crossbar: usize,
    pub file: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = t.split('\t').collect();
            anyhow::ensure!(
                cols.len() == 4,
                "manifest line {}: expected 4 tab-separated columns, got {}",
                lineno + 1,
                cols.len()
            );
            entries.push(ManifestEntry {
                step: cols[0].to_string(),
                batch: cols[1]
                    .parse()
                    .with_context(|| format!("manifest line {}: bad batch", lineno + 1))?,
                crossbar: cols[2]
                    .parse()
                    .with_context(|| format!("manifest line {}: bad crossbar", lineno + 1))?,
                file: cols[3].to_string(),
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest is empty");
        Ok(Self { entries })
    }

    /// Best variant for (step, crossbar size): the largest batch — bigger
    /// batches amortize PJRT dispatch overhead across more subgraphs.
    pub fn select(&self, step: &str, c: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.step == step && e.crossbar == c)
            .max_by_key(|e| e.batch)
    }

    /// All (step, batch, crossbar) triples, for diagnostics.
    pub fn variants(&self) -> impl Iterator<Item = (&str, usize, usize)> {
        self.entries
            .iter()
            .map(|e| (e.step.as_str(), e.batch, e.crossbar))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# step\tbatch\tcrossbar\tfile\n\
        bfs\t32\t4\tbfs_b32_c4.hlo.txt\n\
        bfs\t128\t4\tbfs_b128_c4.hlo.txt\n\
        bfs\t32\t8\tbfs_b32_c8.hlo.txt\n";

    #[test]
    fn parses_and_selects_largest_batch() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.select("bfs", 4).unwrap().batch, 128);
        assert_eq!(m.select("bfs", 8).unwrap().batch, 32);
        assert!(m.select("bfs", 2).is_none());
        assert!(m.select("sssp", 4).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("bfs\t32\t4\n").is_err()); // 3 cols
        assert!(Manifest::parse("bfs\tx\t4\tf\n").is_err()); // bad number
        assert!(Manifest::parse("# only comments\n").is_err()); // empty
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = crate::runtime::default_artifact_dir();
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.select("bfs", 4).is_some());
            assert!(m.select("pagerank", 4).is_some());
            for e in &m.entries {
                assert!(dir.join(&e.file).exists(), "missing {}", e.file);
            }
        }
    }
}
