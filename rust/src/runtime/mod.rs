//! AOT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client
//! (`xla` crate), and executes them from the scheduler hot path.
//!
//! Python never runs at request time — the artifacts are the only
//! hand-off between the build-time JAX/Pallas layers and this crate.
//!
//! The executor (and its `xla` dependency) is gated behind the `pjrt`
//! cargo feature; the manifest reader always compiles so a non-PJRT
//! build can still *diagnose* an artifact directory.

#[cfg(feature = "pjrt")]
pub mod executor;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use executor::{PjrtExecutor, PjrtRuntime};
pub use manifest::{Manifest, ManifestEntry};

use std::path::PathBuf;

/// Default artifact directory: `$REPRO_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("REPRO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
