//! Lockstep superstep execution across N graph shards with a
//! deterministic cross-shard frontier/value exchange — the scale-out
//! layer over [`graph::shard`](crate::graph::shard)'s block-row split.
//!
//! # The exchange protocol
//!
//! Shards are a **data decomposition, not a hardware decomposition**:
//! all shards drive one global engine array (the same `total_engines`
//! the unsharded run uses), one global replacement policy, one global
//! frontier bitmap and one global vertex-value vector. Each superstep
//! runs three lockstep phases:
//!
//! 1. **Global dispatch** (sequential): one pass over the *merged group
//!    schedule* — per-shard plan groups interleaved back into the exact
//!    global group order (see below) — resolving every scheduling
//!    decision (least-busy replica picks, replacement-policy evictions,
//!    retire-then-repick wear-out) against global state, exactly as the
//!    unsharded dispatcher does. Decisions queue into per-engine lanes;
//!    each accepted op is also appended to its shard's superstep batch
//!    and its shard id to the global merge sequence.
//! 2. **Lane replay** (parallel): identical to [`super::par`] — engines
//!    replay their queued records on worker lanes; merges stay lane-
//!    then engine-ordered.
//! 3. **Numeric + exchange**: each shard gathers its sources from the
//!    shared value snapshot and runs its edge-compute batch (shards in
//!    shard order, chunk-parallel within a shard on the shard's worker
//!    pool). The per-shard candidate buffers are the **outgoing update
//!    buckets**; the exchange merge then applies them in the recorded
//!    merge sequence — shard- then destination-group ordered, i.e. the
//!    byte-exact global reduce order — onto the shared values/frontier.
//!    Ordered application is what keeps `SumProd` (`f32` accumulation
//!    is not associative) bit-identical; the rebuilt frontier bitmap is
//!    global, so next superstep's masking needs no broadcast step.
//!
//! # Why the merged schedule reproduces the global order
//!
//! The subgraph table sorts column-major schedules by `(bcol, brow)`
//! and groups on `bcol`; shards own contiguous disjoint `brow` ranges.
//! So the global `bcol` group is exactly the concatenation of the
//! shards' same-`bcol` groups in shard order — which is how
//! [`ShardPlans`] merges them. Row-major groups key on `brow`, so each
//! lives wholly inside one shard and the merge is a plain key-ordered
//! interleave. Both properties are validated at [`ShardPlans::new`],
//! not assumed.
//!
//! # Determinism contract (extended)
//!
//! `RunResult` is bit-identical for every shard count × thread count ×
//! execution mechanism (sequential / scoped / pooled) and equal to
//! [`oracle::run_reference`](super::oracle::run_reference) — shard
//! count never changes a result byte. `rust/tests/shard.rs` enforces
//! the whole matrix. The one unsupported combination is the activity
//! trace with more than one shard (the trace wants per-group engine
//! snapshots of the sequential interpreter); it is a typed error, never
//! silently wrong.

use anyhow::Result;

use crate::accel::config::ArchConfig;
use crate::algo::traits::{Semiring, StepKind, VertexProgram, INF};
use crate::cost::{CostParams, EventCounts};
use crate::engine::{Crossbar, EngineKind, GraphEngine};
use crate::pattern::tables::ExecOrder;

use super::executor::StepExecutor;
use super::par::{
    self, replay_lanes, resolve_threads, run_numeric, LaneMode, LaneRecord, PoolRef, Scratch,
};
use super::plan::ExecutionPlan;
use super::pool::WorkerPool;
use super::replacement::build_policy;
use super::scheduler::{gather_sources, slot_pos, EngineSummary, RunResult, Scheduler, NONE};

/// One shard's contiguous op range inside a merged group.
#[derive(Debug, Clone, Copy)]
struct ShardRange {
    shard: u32,
    start: u32,
    end: u32,
}

/// The merged group schedule: per-shard plan groups interleaved back
/// into global group order. `groups[g]` delimits a contiguous span of
/// `ranges`; ranges within a span are shard-ascending.
#[derive(Debug)]
struct MergedSchedule {
    groups: Vec<(u32, u32)>,
    ranges: Vec<ShardRange>,
}

/// A validated set of per-shard execution plans plus the precomputed
/// merged schedule and global out-degree table. Construction proves the
/// cross-shard invariants the exchange relies on; the run loop then
/// only interprets.
pub struct ShardPlans<'a> {
    plans: Vec<&'a ExecutionPlan>,
    merged: MergedSchedule,
    out_degrees: Vec<u32>,
}

impl<'a> ShardPlans<'a> {
    /// Validate and merge per-shard plans. Errors when the plans were
    /// not compiled as one shard set: diverging geometry, diverging
    /// global pattern ranking / static configuration, a block row owned
    /// by two shards (row-major), or out-of-order block rows inside a
    /// merged column group.
    pub fn new(plans: Vec<&'a ExecutionPlan>) -> Result<Self> {
        anyhow::ensure!(!plans.is_empty(), "shard set is empty");
        let p0 = plans[0];
        for (s, p) in plans.iter().enumerate().skip(1) {
            anyhow::ensure!(
                p.c == p0.c
                    && p.num_vertices == p0.num_vertices
                    && p.num_blocks == p0.num_blocks
                    && p.weighted == p0.weighted
                    && p.static_engines == p0.static_engines
                    && p.total_engines == p0.total_engines
                    && p.crossbars_per_engine == p0.crossbars_per_engine
                    && p.order == p0.order
                    && p.static_assignment == p0.static_assignment,
                "shard {s}'s plan geometry diverges from shard 0's \
                 (plans must come from one sharded compile)"
            );
            anyhow::ensure!(
                p.num_patterns == p0.num_patterns
                    && (0..p0.num_patterns).all(|r| p.pattern_of_rank(r) == p0.pattern_of_rank(r)),
                "shard {s}'s pattern ranking diverges from shard 0's \
                 (the ranking must be global across the shard set)"
            );
            anyhow::ensure!(
                p.static_config() == p0.static_config(),
                "shard {s}'s static configuration diverges from shard 0's"
            );
        }
        let merged = build_merged(&plans)?;
        // Global out-degrees: shards own disjoint source ranges, so the
        // per-shard tables sum elementwise to the unsharded table.
        let mut out_degrees = vec![0u32; p0.num_vertices as usize];
        for p in &plans {
            for (d, &x) in out_degrees.iter_mut().zip(p.out_degrees()) {
                *d += x;
            }
        }
        Ok(Self { plans, merged, out_degrees })
    }

    /// Number of shards in the set.
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// The validated per-shard plans, in shard order.
    pub fn plans(&self) -> &[&'a ExecutionPlan] {
        &self.plans
    }
}

/// Interleave per-shard groups into global group order, validating the
/// block-row-split contract as it goes (see the module docs).
fn build_merged(plans: &[&ExecutionPlan]) -> Result<MergedSchedule> {
    let order = plans[0].order;
    let c = plans[0].c as u32;
    // (group key, shard, start, end) for every non-empty shard group.
    // A shard's groups have unique keys (the ST groups on the major
    // key), so (key, shard) sorts ranges into merged-group order with
    // shard-ascending runs per key.
    let mut keyed: Vec<(u32, u32, u32, u32)> = Vec::new();
    for (s, plan) in plans.iter().enumerate() {
        for g in 0..plan.num_groups() {
            let (start, end) = plan.group_bounds(g);
            if start == end {
                continue; // empty shard/group — legal, it just idles
            }
            let key = match order {
                ExecOrder::ColumnMajor => plan.ops[start].dst_start / c,
                ExecOrder::RowMajor => plan.ops[start].src_block,
            };
            keyed.push((key, s as u32, start as u32, end as u32));
        }
    }
    keyed.sort_unstable();
    let mut groups = Vec::new();
    let mut ranges: Vec<ShardRange> = Vec::with_capacity(keyed.len());
    let mut i = 0usize;
    while i < keyed.len() {
        let key = keyed[i].0;
        let first = ranges.len() as u32;
        while i < keyed.len() && keyed[i].0 == key {
            let (_, shard, start, end) = keyed[i];
            if let Some(prev) = ranges.get(first as usize..).and_then(|r| r.last()) {
                match order {
                    ExecOrder::RowMajor => anyhow::bail!(
                        "block row {key} appears in shards {} and {shard} — \
                         shards must own disjoint block-row ranges",
                        prev.shard
                    ),
                    ExecOrder::ColumnMajor => {
                        // Concatenation must reproduce the global
                        // within-group (brow-ascending) order.
                        let prev_plan = plans[prev.shard as usize];
                        let last_block = prev_plan.ops[prev.end as usize - 1].src_block;
                        let next_block = plans[shard as usize].ops[start as usize].src_block;
                        anyhow::ensure!(
                            last_block < next_block,
                            "column group {key}: shard {shard} starts at block row \
                             {next_block}, not after shard {}'s last block row \
                             {last_block} — shards are not a contiguous block-row split",
                            prev.shard
                        );
                    }
                }
            }
            ranges.push(ShardRange { shard, start, end });
            i += 1;
        }
        groups.push((first, ranges.len() as u32));
    }
    Ok(MergedSchedule { groups, ranges })
}

/// Phase-2/3 mechanism of a sharded run. Decisions never live here —
/// phase 1 is always the one global sequential pass.
enum Mech<'p> {
    /// `std::thread::scope` workers per superstep; `threads == 1` is the
    /// sequential mechanism (both phase helpers run inline below their
    /// parallel thresholds).
    Scoped { threads: usize },
    /// Persistent pools, one per shard (`pools[shard % len]` serves the
    /// shard's numeric phase; `pools[0]` replays the global lanes).
    Pooled { pools: &'p mut [WorkerPool], threads: usize },
}

impl Mech<'_> {
    fn threads(&self) -> usize {
        match self {
            Mech::Scoped { threads } | Mech::Pooled { threads, .. } => *threads,
        }
    }

    /// Lane mode for the global phase-2 replay.
    fn replay_mode(&mut self) -> LaneMode<'_> {
        match self {
            Mech::Scoped { threads } => LaneMode::Scoped { threads: *threads },
            Mech::Pooled { pools, threads } => LaneMode::Pooled {
                pool: PoolRef::Borrowed(&mut pools[0]),
                threads: *threads,
            },
        }
    }

    /// Lane mode for one shard's phase-3 numeric batch.
    fn numeric_mode(&mut self, shard: usize) -> LaneMode<'_> {
        match self {
            Mech::Scoped { threads } => LaneMode::Scoped { threads: *threads },
            Mech::Pooled { pools, threads } => {
                let idx = shard % pools.len();
                LaneMode::Pooled { pool: PoolRef::Borrowed(&mut pools[idx]), threads: *threads }
            }
        }
    }
}

/// Run `program` across the shard set with `threads` execution lanes on
/// a transient pool. One shard delegates to [`par::run_parallel`]
/// (which itself delegates to the sequential interpreter at
/// `threads <= 1` or under tracing) — a 1-shard "sharded" run *is* the
/// unsharded run, by construction rather than by test.
pub fn run_sharded(
    config: &ArchConfig,
    params: &CostParams,
    shards: &ShardPlans<'_>,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    threads: usize,
) -> Result<RunResult> {
    let threads = resolve_threads(threads);
    if shards.len() == 1 {
        return par::run_parallel(config, params, shards.plans[0], program, executor, threads);
    }
    if threads <= 1 {
        return run_exchange(config, params, shards, program, executor, Mech::Scoped { threads: 1 });
    }
    let mut pools = [WorkerPool::new(threads)];
    run_exchange(
        config,
        params,
        shards,
        program,
        executor,
        Mech::Pooled { pools: &mut pools, threads },
    )
}

/// The scoped-mechanism baseline of [`run_sharded`] — kept so the
/// determinism suite can cross-check all three mechanisms forever.
pub fn run_sharded_scoped(
    config: &ArchConfig,
    params: &CostParams,
    shards: &ShardPlans<'_>,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    threads: usize,
) -> Result<RunResult> {
    let threads = resolve_threads(threads);
    if shards.len() == 1 {
        return par::run_parallel_scoped(config, params, shards.plans[0], program, executor, threads);
    }
    run_exchange(
        config,
        params,
        shards,
        program,
        executor,
        Mech::Scoped { threads: threads.max(1) },
    )
}

/// [`run_sharded`] on caller-owned persistent pools — the production
/// path (`Session` checks one pool per shard out of its free list).
/// `pools[shard % pools.len()]` serves each shard's numeric phase and
/// `pools[0]` the global lane replay; the lane count caps at the
/// smallest pool. One shard delegates to
/// [`par::run_parallel_pooled_at`] on `pools[0]`.
pub fn run_sharded_pooled(
    config: &ArchConfig,
    params: &CostParams,
    shards: &ShardPlans<'_>,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    pools: &mut [WorkerPool],
    threads: usize,
) -> Result<RunResult> {
    anyhow::ensure!(!pools.is_empty(), "sharded pooled run needs at least one pool");
    let workers = pools.iter().map(|p| p.workers()).min().unwrap_or(1);
    let threads = resolve_threads(threads).min(workers);
    if shards.len() == 1 {
        return par::run_parallel_pooled_at(
            config,
            params,
            shards.plans[0],
            program,
            executor,
            &mut pools[0],
            threads,
        );
    }
    if threads <= 1 {
        return run_exchange(config, params, shards, program, executor, Mech::Scoped { threads: 1 });
    }
    run_exchange(config, params, shards, program, executor, Mech::Pooled { pools, threads })
}

/// The sharded three-phase pipeline (see the module docs): global
/// dispatch over the merged schedule, global lane replay, per-shard
/// numeric with the merged-order exchange reduce.
fn run_exchange(
    config: &ArchConfig,
    params: &CostParams,
    sp: &ShardPlans<'_>,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    mut mech: Mech<'_>,
) -> Result<RunResult> {
    config.validate()?;
    anyhow::ensure!(
        !config.trace_activity,
        "activity tracing is not supported across shards — run with --shards 1 to trace"
    );
    let nshards = sp.plans.len();
    let plan0 = sp.plans[0];
    anyhow::ensure!(
        plan0.matches(config),
        "shard plans were compiled for a different architecture \
         (plan C={} N={} T={} M={})",
        plan0.c,
        plan0.static_engines,
        plan0.total_engines,
        plan0.crossbars_per_engine
    );
    if program.needs_weights() {
        anyhow::ensure!(
            plan0.weighted,
            "{} requires weighted partitioning",
            program.name()
        );
    }
    let c = plan0.c;
    let n = plan0.num_vertices as usize;
    let num_blocks = plan0.num_blocks as usize;
    let n_static = config.static_engines;
    let n_total = config.total_engines as usize;
    let m = config.crossbars_per_engine as usize;

    // --- one GLOBAL engine array + dispatch state: shards are a data
    // --- decomposition, the simulated hardware is shared ---
    let mut engines: Vec<Option<GraphEngine>> = (0..n_total)
        .map(|i| {
            let kind =
                if (i as u32) < n_static { EngineKind::Static } else { EngineKind::Dynamic };
            Some(GraphEngine::new(i as u32, kind, c, m as u32))
        })
        .collect();
    let n_dyn_slots = config.dynamic_engines() as usize * m;
    let mut policy = build_policy(config.policy, n_dyn_slots);
    let mut dyn_dir: Vec<u32> = vec![NONE; plan0.num_patterns as usize];
    let mut slot_rank: Vec<u32> = vec![NONE; n_dyn_slots];
    let mut retired: Vec<bool> = vec![false; n_dyn_slots];
    let mut shadow: Vec<Crossbar> = (0..n_dyn_slots).map(|_| Crossbar::new(c)).collect();
    let mut shadow_busy = vec![0f64; n_total];

    // --- initialization: the static configuration is identical across
    // --- the shard set (validated), configured once globally ---
    for &(slot, pattern) in plan0.static_config() {
        engines[slot.engine as usize]
            .as_mut()
            .expect("engine present")
            .configure(slot.crossbar as usize, pattern, params);
    }
    let mut init_counts = EventCounts::default();
    let mut init_time_ns = 0f64;
    for e in engines.iter_mut() {
        let e = e.as_mut().expect("engine present");
        init_counts.add(&e.counts);
        let (busy, _) = e.end_iteration();
        init_time_ns = init_time_ns.max(busy);
    }
    let counts_baseline = init_counts;

    // --- GLOBAL vertex state: values, accumulator and frontier bitmap
    // --- are shared by all shards (plan coordinates are global) ---
    let mut values = program.init(plan0.num_vertices);
    anyhow::ensure!(values.len() == n, "program init length mismatch");
    let mut snapshot = values.clone();
    let semiring = program.semiring();
    let mut acc = match semiring {
        Semiring::SumProd => vec![0f32; n],
        Semiring::MinPlus => Vec::new(),
    };
    let outdeg = &sp.out_degrees;

    let all_blocks = program.processes_all_blocks();
    let mut active_block = vec![false; num_blocks];
    let mut next_active_block = vec![false; num_blocks];
    if !all_blocks {
        for (v, &val) in values.iter().enumerate() {
            if val < INF {
                active_block[v / c] = true;
            }
        }
    }

    // --- per-engine lanes sized for the whole shard set ---
    let mut records: Vec<Vec<LaneRecord>> = (0..n_total)
        .map(|e| {
            let cap: u32 = sp.plans.iter().map(|p| p.lanes().fixed_ops_on(e as u32)).sum();
            Vec::with_capacity(cap as usize)
        })
        .collect();
    let mut scratch = Scratch::new(n_total, mech.threads());

    // --- main loop ---
    let kind: StepKind = program.step_kind();
    let mut exec_time_ns = 0f64;
    let mut sys_counts = EventCounts::default();
    let mut iterations = 0u64;
    let mut static_ops = 0u64;
    let mut dynamic_ops = 0u64;
    let mut dynamic_hits = 0u64;
    let mut supersteps = 0usize;

    // Per-shard superstep batches (the outgoing update buckets) plus the
    // merge sequence: one shard id per accepted op, in global dispatch
    // order — the exchange's application order.
    let mut sup_ops: Vec<Vec<u32>> = vec![Vec::new(); nshards];
    let mut merged_seq: Vec<u32> = Vec::new();
    let mut xs: Vec<f32> = Vec::new();
    let mut cands: Vec<Vec<f32>> = vec![Vec::new(); nshards];

    let lat_mvm = crate::cost::timing::mvm_latency_ns(params, c as u32, c as u32)
        + crate::cost::timing::reduce_latency_ns(params, c as u32);

    for superstep in 0..program.max_supersteps() {
        snapshot.copy_from_slice(&values);
        for ops in sup_ops.iter_mut() {
            ops.clear();
        }
        merged_seq.clear();
        for r in records.iter_mut() {
            r.clear();
        }
        shadow_busy.iter_mut().for_each(|b| *b = 0.0);

        // --- phase 1: ONE global dispatch pass over the merged groups ---
        for &(gs, ge) in &sp.merged.groups {
            let mut ops_in_group = 0u64;
            for r in &sp.merged.ranges[gs as usize..ge as usize] {
                let shard = r.shard as usize;
                let plan = sp.plans[shard];
                let lane_tab = plan.lanes();
                let (start, end) = (r.start as usize, r.end as usize);
                for (off, op) in plan.ops[start..end].iter().enumerate() {
                    if !all_blocks && !active_block[op.src_block as usize] {
                        continue;
                    }
                    ops_in_group += 1;
                    if op.is_static() {
                        let slots = plan.slots_of(op);
                        let slot = if lane_tab.home_of(start + off).is_some() {
                            slots[0]
                        } else {
                            *slots
                                .iter()
                                .min_by(|a, b| {
                                    shadow_busy[a.engine as usize]
                                        .total_cmp(&shadow_busy[b.engine as usize])
                                })
                                .expect("static op has a slot")
                        };
                        shadow_busy[slot.engine as usize] += lat_mvm;
                        records[slot.engine as usize].push(LaneRecord::Mvm {
                            crossbar: slot.crossbar,
                            read_rows: op.read_rows,
                        });
                        static_ops += 1;
                    } else {
                        let rank = op.pattern_rank as usize;
                        let hit = if config.dynamic_reuse {
                            let k = dyn_dir[rank];
                            (k != NONE && !retired[k as usize]).then_some(k as usize)
                        } else {
                            None
                        };
                        let k = match hit {
                            Some(k) => {
                                dynamic_hits += 1;
                                k
                            }
                            None => {
                                let pattern = plan.pattern_of_rank(op.pattern_rank);
                                loop {
                                    let k = policy.pick(&retired).ok_or_else(|| {
                                        anyhow::anyhow!(
                                            "all dynamic crossbars retired (wear-out)"
                                        )
                                    })?;
                                    let (ei, cb) = slot_pos(config, k);
                                    let old = slot_rank[k];
                                    if old != NONE {
                                        dyn_dir[old as usize] = NONE;
                                        slot_rank[k] = NONE;
                                    }
                                    shadow[k].configure(pattern);
                                    records[ei].push(LaneRecord::Configure {
                                        crossbar: cb as u32,
                                        rank: op.pattern_rank,
                                    });
                                    if shadow[k].worn_out(params.endurance_cycles) {
                                        retired[k] = true;
                                        continue;
                                    }
                                    slot_rank[k] = rank as u32;
                                    dyn_dir[rank] = k as u32;
                                    break k;
                                }
                            }
                        };
                        let (ei, cb) = slot_pos(config, k);
                        records[ei].push(LaneRecord::Mvm {
                            crossbar: cb as u32,
                            read_rows: op.rows,
                        });
                        policy.touch(k);
                        dynamic_ops += 1;
                    }
                    sup_ops[shard].push((start + off) as u32);
                    merged_seq.push(r.shard);
                }
            }
            if ops_in_group == 0 {
                continue;
            }
            iterations += 1;
            sys_counts.main_mem_accesses += 2 * ops_in_group.div_ceil(16);
        }

        // --- phase 2: one global lane replay (pattern ranks resolve
        // --- identically through any shard's plan — validated) ---
        {
            let mut lm = mech.replay_mode();
            exec_time_ns += replay_lanes(
                &mut engines,
                &records,
                &mut scratch,
                plan0,
                params,
                lat_mvm,
                &mut lm,
            );
        }

        if merged_seq.is_empty() {
            break;
        }

        // --- phase 3: per-shard numeric (shard order, chunk-parallel
        // --- within a shard), then the merged-order exchange reduce ---
        for (s, plan) in sp.plans.iter().enumerate() {
            cands[s].clear();
            if sup_ops[s].is_empty() {
                continue;
            }
            gather_sources(plan, program, kind, &snapshot, outdeg, &sup_ops[s], &mut xs);
            let mut lm = mech.numeric_mode(s);
            run_numeric(
                executor,
                kind,
                plan,
                &sup_ops[s],
                &xs,
                &mut cands[s],
                &mut scratch.chunk_bufs,
                &mut lm,
            )?;
        }
        let any_changed = reduce_apply_merged(
            &sp.plans,
            program,
            semiring,
            &sup_ops,
            &cands,
            &merged_seq,
            &mut values,
            &mut acc,
            &mut active_block,
            &mut next_active_block,
        );

        supersteps = superstep + 1;
        if !program.post_superstep(superstep, &mut values, &mut acc, any_changed) {
            break;
        }
    }

    // --- final accounting, identical to the unsharded paths ---
    let mut counts = sys_counts;
    let mut summaries = Vec::with_capacity(engines.len());
    let mut max_dyn_writes = 0u32;
    for e in &engines {
        let e = e.as_ref().expect("engine present");
        counts.add(&e.counts);
        if e.kind == EngineKind::Dynamic {
            max_dyn_writes = max_dyn_writes.max(e.max_cell_writes());
        }
        summaries.push(EngineSummary::of(e));
    }
    counts.subtract(&counts_baseline);

    Ok(RunResult {
        values,
        counts,
        init_counts,
        exec_time_ns,
        init_time_ns,
        supersteps,
        iterations,
        static_ops,
        dynamic_ops,
        dynamic_hits,
        max_dynamic_cell_writes: max_dyn_writes,
        engines: summaries,
        activity: None,
    })
}

/// The exchange merge: apply the per-shard candidate buckets onto the
/// shared values/accumulator **in the recorded merge sequence** (shard-
/// then destination-group ordered — the byte-exact global reduce
/// order), advancing one cursor per shard. Mirrors
/// [`scheduler::reduce_apply`](super::scheduler) op for op; ordered
/// application is load-bearing for `SumProd` (`f32` accumulation is not
/// associative) and kept uniform for `MinPlus`.
#[allow(clippy::too_many_arguments)]
fn reduce_apply_merged(
    plans: &[&ExecutionPlan],
    program: &dyn VertexProgram,
    semiring: Semiring,
    sup_ops: &[Vec<u32>],
    cands: &[Vec<f32>],
    merged_seq: &[u32],
    values: &mut [f32],
    acc: &mut [f32],
    active_block: &mut Vec<bool>,
    next_active_block: &mut Vec<bool>,
) -> bool {
    let c = plans[0].c;
    let n = values.len();
    let mut cursor = vec![0usize; plans.len()];
    let mut any_changed = false;
    match semiring {
        Semiring::MinPlus => {
            next_active_block.iter_mut().for_each(|b| *b = false);
            for &sraw in merged_seq {
                let s = sraw as usize;
                let k = cursor[s];
                cursor[s] += 1;
                let op = sup_ops[s][k] as usize;
                let dst_start = plans[s].ops[op].dst_start as usize;
                for j in 0..c {
                    let v = dst_start + j;
                    if v >= n {
                        break;
                    }
                    let old = values[v];
                    let new = program.apply(old, cands[s][k * c + j]);
                    if program.changed(old, new) {
                        values[v] = new;
                        next_active_block[v / c] = true;
                        any_changed = true;
                    }
                }
            }
            std::mem::swap(active_block, next_active_block);
        }
        Semiring::SumProd => {
            for &sraw in merged_seq {
                let s = sraw as usize;
                let k = cursor[s];
                cursor[s] += 1;
                let op = sup_ops[s][k] as usize;
                let dst_start = plans[s].ops[op].dst_start as usize;
                for j in 0..c {
                    let v = dst_start + j;
                    if v >= n {
                        break;
                    }
                    acc[v] += cands[s][k * c + j];
                }
            }
            any_changed = true;
        }
    }
    any_changed
}

/// Compile per-shard plans for `g` under a **global** pattern ranking:
/// per-shard partition → per-shard counts merged shard-ascending →
/// one `PatternRanking`/`ConfigTable` → per-shard ST + plan. This is
/// the reference compile the simulator's sharded preprocess and the
/// test suites share; count additivity (the chunk-merge invariant)
/// makes the 1-shard output whole-struct-equal to the unsharded
/// compile.
pub(crate) fn compile_shard_plans(
    g: &crate::graph::Coo,
    config: &ArchConfig,
    weighted: bool,
    shards: usize,
) -> Vec<ExecutionPlan> {
    use crate::pattern::extract::partition;
    use crate::pattern::rank::{count_patterns, merge_counts, PatternRanking};
    use crate::pattern::tables::{ConfigTable, SubgraphTable};

    let sh = crate::graph::shard::split(g, config.crossbar_size, shards);
    let parts: Vec<_> =
        sh.iter().map(|s| partition(&s.graph, config.crossbar_size, weighted)).collect();
    let mut counts = std::collections::HashMap::new();
    let mut total = 0usize;
    for p in &parts {
        merge_counts(
            &mut counts,
            count_patterns(&p.subgraphs).into_iter().map(|(k, v)| (k, v as i64)),
        );
        total += p.num_subgraphs();
    }
    let ranking = PatternRanking::from_counts(counts, total);
    let ct = ConfigTable::build(
        &ranking,
        config.crossbar_size,
        config.static_engines,
        config.crossbars_per_engine,
        config.dynamic_engines() * config.crossbars_per_engine,
        config.static_assignment,
    );
    parts
        .iter()
        .map(|p| {
            let st = SubgraphTable::build(p, &ranking, config.order);
            ExecutionPlan::build(p, &ct, &st, config)
        })
        .collect()
}

/// Convenience: run sequentially (one lane) across the shard set —
/// the "sequential mechanism" leg of the determinism matrix.
pub fn run_sharded_sequential(
    config: &ArchConfig,
    params: &CostParams,
    shards: &ShardPlans<'_>,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
) -> Result<RunResult> {
    if shards.len() == 1 {
        return Scheduler::new(config, params, shards.plans[0]).run(program, executor);
    }
    run_exchange(config, params, shards, program, executor, Mech::Scoped { threads: 1 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Bfs, PageRank, Wcc};
    use crate::graph::datasets::Dataset;
    use crate::sched::executor::NativeExecutor;

    fn assert_same(a: &RunResult, b: &RunResult, ctx: &str) {
        assert_eq!(a.values, b.values, "{ctx}: values");
        assert_eq!(a.counts, b.counts, "{ctx}: counts");
        assert_eq!(a.init_counts, b.init_counts, "{ctx}: init counts");
        assert_eq!(a.exec_time_ns, b.exec_time_ns, "{ctx}: exec time");
        assert_eq!(a.init_time_ns, b.init_time_ns, "{ctx}: init time");
        assert_eq!(a.supersteps, b.supersteps, "{ctx}: supersteps");
        assert_eq!(a.iterations, b.iterations, "{ctx}: iterations");
        assert_eq!(a.static_ops, b.static_ops, "{ctx}: static ops");
        assert_eq!(a.dynamic_ops, b.dynamic_ops, "{ctx}: dynamic ops");
        assert_eq!(a.dynamic_hits, b.dynamic_hits, "{ctx}: dynamic hits");
        assert_eq!(
            a.max_dynamic_cell_writes, b.max_dynamic_cell_writes,
            "{ctx}: wear"
        );
        assert_eq!(a.engines, b.engines, "{ctx}: engine summaries");
    }

    fn unsharded_reference(
        g: &crate::graph::Coo,
        config: &ArchConfig,
        program: &dyn VertexProgram,
    ) -> RunResult {
        let params = CostParams::default();
        let plans = compile_shard_plans(g, config, program.needs_weights(), 1);
        Scheduler::new(config, &params, &plans[0])
            .run(program, &mut NativeExecutor)
            .unwrap()
    }

    #[test]
    fn sharded_runs_match_the_sequential_interpreter() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let params = CostParams::default();
        for program in
            [&Bfs::new(0) as &dyn VertexProgram, &Wcc, &PageRank::new(0.85, 5)]
        {
            let want = unsharded_reference(&g, &config, program);
            for shards in [1usize, 2, 3, 4] {
                let plans = compile_shard_plans(&g, &config, program.needs_weights(), shards);
                let sp = ShardPlans::new(plans.iter().collect()).unwrap();
                for threads in [1usize, 2, 4] {
                    let got = run_sharded(
                        &config, &params, &sp, program, &mut NativeExecutor, threads,
                    )
                    .unwrap();
                    assert_same(
                        &want,
                        &got,
                        &format!("{} shards={shards} threads={threads}", program.name()),
                    );
                }
                let seq = run_sharded_sequential(
                    &config, &params, &sp, program, &mut NativeExecutor,
                )
                .unwrap();
                assert_same(&want, &seq, &format!("sequential shards={shards}"));
            }
        }
    }

    #[test]
    fn row_major_order_shards_identically() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig { order: ExecOrder::RowMajor, ..ArchConfig::default() };
        let params = CostParams::default();
        let want = unsharded_reference(&g, &config, &Wcc);
        for shards in [2usize, 4] {
            let plans = compile_shard_plans(&g, &config, false, shards);
            let sp = ShardPlans::new(plans.iter().collect()).unwrap();
            let got =
                run_sharded(&config, &params, &sp, &Wcc, &mut NativeExecutor, 4).unwrap();
            assert_same(&want, &got, &format!("row-major shards={shards}"));
        }
    }

    #[test]
    fn scoped_and_pooled_mechanisms_agree_across_shards() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let params = CostParams::default();
        let program = PageRank::new(0.85, 4);
        let want = unsharded_reference(&g, &config, &program);
        let plans = compile_shard_plans(&g, &config, false, 3);
        let sp = ShardPlans::new(plans.iter().collect()).unwrap();
        let scoped =
            run_sharded_scoped(&config, &params, &sp, &program, &mut NativeExecutor, 4)
                .unwrap();
        assert_same(&want, &scoped, "scoped");
        let mut pools: Vec<WorkerPool> = (0..3).map(|_| WorkerPool::new(4)).collect();
        for round in 0..2 {
            let pooled = run_sharded_pooled(
                &config, &params, &sp, &program, &mut NativeExecutor, &mut pools, 4,
            )
            .unwrap();
            assert_same(&want, &pooled, &format!("pooled round {round}"));
        }
    }

    #[test]
    fn more_shards_than_blocks_still_bit_identical() {
        // Shards past the block count compile empty plans (one empty
        // group) — they idle through the merge without a byte of drift.
        let g = crate::graph::generator::rmat(
            16,
            60,
            crate::graph::generator::RmatParams::default(),
            5,
        );
        let config = ArchConfig::default();
        let params = CostParams::default();
        let want = unsharded_reference(&g, &config, &Wcc);
        let blocks = 16u32.div_ceil(config.crossbar_size as u32);
        let shards = blocks as usize + 3;
        let plans = compile_shard_plans(&g, &config, false, shards);
        let sp = ShardPlans::new(plans.iter().collect()).unwrap();
        let got = run_sharded(&config, &params, &sp, &Wcc, &mut NativeExecutor, 4).unwrap();
        assert_same(&want, &got, "shards > blocks");
    }

    #[test]
    fn tracing_multi_shard_is_a_typed_error_and_single_shard_delegates() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig { trace_activity: true, ..ArchConfig::fig5() };
        let params = CostParams::default();
        let plans = compile_shard_plans(&g, &config, false, 2);
        let sp = ShardPlans::new(plans.iter().collect()).unwrap();
        let err = run_sharded(&config, &params, &sp, &Bfs::new(0), &mut NativeExecutor, 4)
            .unwrap_err();
        assert!(err.to_string().contains("tracing"), "{err}");

        let plans1 = compile_shard_plans(&g, &config, false, 1);
        let sp1 = ShardPlans::new(plans1.iter().collect()).unwrap();
        let traced =
            run_sharded(&config, &params, &sp1, &Bfs::new(0), &mut NativeExecutor, 4)
                .unwrap();
        assert!(traced.activity.is_some(), "one shard traces via the interpreter");
    }

    #[test]
    fn shard_plans_reject_foreign_plan_sets() {
        let g = Dataset::Tiny.load().unwrap();
        let a = ArchConfig::default();
        let b = ArchConfig { crossbar_size: 2, ..ArchConfig::default() };
        let pa = compile_shard_plans(&g, &a, false, 2);
        let pb = compile_shard_plans(&g, &b, false, 2);
        // Mixing geometries across "shards" must be rejected up front.
        let err = ShardPlans::new(vec![&pa[0], &pb[1]]).unwrap_err();
        assert!(err.to_string().contains("diverges"), "{err}");
        assert!(ShardPlans::new(vec![]).is_err());
        // Duplicating one shard's plan presents the same block rows
        // twice — caught by the merge validation, not a wrong answer.
        assert!(ShardPlans::new(vec![&pa[0], &pa[0]]).is_err());
    }
}
