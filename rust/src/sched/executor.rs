//! The numeric edge-compute backend.
//!
//! The scheduler is *functional/timing split*: hardware events and
//! latencies are modeled in rust, while the edge-compute *values* flow
//! through a [`StepExecutor`]. Two interchangeable implementations:
//!
//! * [`NativeExecutor`] — a pure-rust mirror of the L1/L2 semantics
//!   (bit-level min-plus / sum-product over the packed patterns). Fast;
//!   used for large sweeps and as the cross-check oracle.
//! * [`runtime::PjrtExecutor`](crate::runtime) — executes the AOT-lowered
//!   HLO artifact on the PJRT CPU client; the production datapath.
//!
//! Both must agree to float tolerance — asserted by integration tests.
//!
//! Executors consume a [`StepBatch`] — a selection of ops from a compiled
//! [`ExecutionPlan`](super::ExecutionPlan) — so per-op operands (packed
//! pattern bits, weight slices, dense matrices) are plan-owned slices
//! rather than shapes rebuilt from a `Partitioned` on every call.

use anyhow::Result;

use crate::algo::traits::{StepKind, INF};

use super::plan::StepBatch;

/// Computes edge-compute candidates for a batch of subgraph ops.
///
/// `xs` holds one C-vector of wordline inputs per selected op (snapshot of
/// source-vertex values, already mapped through
/// `VertexProgram::source_value`); `out` receives one C-vector of
/// candidates per op (destination lanes).
pub trait StepExecutor {
    fn name(&self) -> &'static str;

    fn execute(
        &mut self,
        kind: StepKind,
        batch: StepBatch<'_>,
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// An independent executor instance for a worker thread, when this
    /// backend supports concurrent batch evaluation (pure, stateless
    /// numerics whose per-op outputs are position-independent — so any
    /// chunking of a batch across forks is bit-identical to one call).
    /// The default `None` keeps the numeric phase on the calling thread;
    /// stateful backends (PJRT holds compiled per-process artifacts)
    /// stay sequential under the batch-parallel scheduler.
    fn fork(&self) -> Option<Box<dyn StepExecutor + Send>> {
        None
    }

    /// Multi-job (multi-source) variant of [`execute`](Self::execute):
    /// the same op batch evaluated against `lanes` independent input
    /// vectors at once, so per-op operand decode — packed pattern bits,
    /// weight slices — is paid once per op instead of once per job.
    ///
    /// `xs` and `out` are **op-major lane-interleaved**: the C-vector for
    /// union-op index `k` and lane `l` lives at
    /// `[(k * lanes + l) * c .. (k * lanes + l + 1) * c]`. This keeps a
    /// contiguous chunk of ops `[a, b)` owning the contiguous slice
    /// `xs[a * lanes * c .. b * lanes * c]`, so the fork/chunk pipeline
    /// splits batched work exactly like solo work.
    ///
    /// Determinism contract: lane `l`'s outputs must be bit-identical to
    /// a solo [`execute`](Self::execute) over the same batch with lane
    /// `l`'s inputs — batching changes *when* lanes are evaluated, never
    /// the per-lane float op sequence. The default implementation
    /// guarantees this trivially by deinterleaving each lane into a
    /// scratch buffer and delegating to `execute`; backends override it
    /// to share per-op decode across lanes (see [`NativeExecutor`]).
    fn execute_multi(
        &mut self,
        kind: StepKind,
        batch: StepBatch<'_>,
        lanes: usize,
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(lanes >= 1, "execute_multi requires at least one lane");
        if lanes == 1 {
            return self.execute(kind, batch, xs, out);
        }
        let c = batch.c();
        let n = batch.len();
        anyhow::ensure!(xs.len() == n * lanes * c, "xs length mismatch");
        let id = identity(kind);
        out.truncate(n * lanes * c);
        out.fill(id);
        out.resize(n * lanes * c, id);
        let mut lane_xs = vec![0.0f32; n * c];
        let mut lane_out = Vec::with_capacity(n * c);
        for l in 0..lanes {
            for k in 0..n {
                let src = (k * lanes + l) * c;
                lane_xs[k * c..(k + 1) * c].copy_from_slice(&xs[src..src + c]);
            }
            self.execute(kind, batch, &lane_xs, &mut lane_out)?;
            for k in 0..n {
                let dst = (k * lanes + l) * c;
                out[dst..dst + c].copy_from_slice(&lane_out[k * c..(k + 1) * c]);
            }
        }
        Ok(())
    }
}

/// Pure-rust mirror of the Pallas kernels (bit loops over packed
/// patterns — no dense materialization).
#[derive(Debug, Default, Clone)]
pub struct NativeExecutor;

impl StepExecutor for NativeExecutor {
    fn name(&self) -> &'static str {
        "native"
    }

    fn fork(&self) -> Option<Box<dyn StepExecutor + Send>> {
        Some(Box::new(NativeExecutor))
    }

    fn execute(
        &mut self,
        kind: StepKind,
        batch: StepBatch<'_>,
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let c = batch.c();
        anyhow::ensure!(xs.len() == batch.len() * c, "xs length mismatch");
        if kind == StepKind::Sssp {
            anyhow::ensure!(batch.weighted(), "SSSP requires weighted partitioning");
        }
        // Reinitialize in place, each lane written exactly once whether
        // the batch shrank (`truncate` + `fill`) or grew (`resize` fills
        // the tail); capacity is reused across calls either way.
        let len = batch.len() * c;
        let id = identity(kind);
        out.truncate(len);
        out.fill(id);
        out.resize(len, id);
        for k in 0..batch.len() {
            let x = &xs[k * c..(k + 1) * c];
            let o = &mut out[k * c..(k + 1) * c];
            match kind {
                StepKind::PageRank | StepKind::Mvm => {
                    // out[j] = sum_i adj[i][j] * x[i]
                    let mut bits = batch.bits(k);
                    while bits != 0 {
                        let bit = bits.trailing_zeros() as usize;
                        o[bit % c] += x[bit / c];
                        bits &= bits - 1;
                    }
                }
                StepKind::Bfs | StepKind::Wcc => {
                    let cost = if kind == StepKind::Bfs { 1.0 } else { 0.0 };
                    let mut bits = batch.bits(k);
                    while bits != 0 {
                        let bit = bits.trailing_zeros() as usize;
                        let cand = x[bit / c] + cost;
                        let j = bit % c;
                        if cand < o[j] {
                            o[j] = cand;
                        }
                        bits &= bits - 1;
                    }
                }
                StepKind::Sssp => {
                    let w = batch.weights_of(k);
                    let mut bits = batch.bits(k);
                    let mut nth = 0usize;
                    while bits != 0 {
                        let bit = bits.trailing_zeros() as usize;
                        let cand = x[bit / c] + w[nth];
                        let j = bit % c;
                        if cand < o[j] {
                            o[j] = cand;
                        }
                        bits &= bits - 1;
                        nth += 1;
                    }
                }
            }
        }
        Ok(())
    }

    /// Decode-once batched variant: each op's packed bits are walked a
    /// single time with the lane loop *inside* the bit loop, so every
    /// lane still sees the bits in the same increasing `trailing_zeros`
    /// order as a solo [`execute`](StepExecutor::execute) — the per-lane
    /// float op sequence (and so the result) is bit-identical, while the
    /// decode cost is paid once per op instead of once per lane.
    fn execute_multi(
        &mut self,
        kind: StepKind,
        batch: StepBatch<'_>,
        lanes: usize,
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(lanes >= 1, "execute_multi requires at least one lane");
        if lanes == 1 {
            return self.execute(kind, batch, xs, out);
        }
        let c = batch.c();
        anyhow::ensure!(xs.len() == batch.len() * lanes * c, "xs length mismatch");
        if kind == StepKind::Sssp {
            anyhow::ensure!(batch.weighted(), "SSSP requires weighted partitioning");
        }
        let len = batch.len() * lanes * c;
        let id = identity(kind);
        out.truncate(len);
        out.fill(id);
        out.resize(len, id);
        for k in 0..batch.len() {
            // Op-major lane-interleaved: lane l of op k spans
            // [(k*lanes + l)*c, (k*lanes + l + 1)*c).
            let x_all = &xs[k * lanes * c..(k + 1) * lanes * c];
            let o_all = &mut out[k * lanes * c..(k + 1) * lanes * c];
            match kind {
                StepKind::PageRank | StepKind::Mvm => {
                    let mut bits = batch.bits(k);
                    while bits != 0 {
                        let bit = bits.trailing_zeros() as usize;
                        let (i, j) = (bit / c, bit % c);
                        for l in 0..lanes {
                            o_all[l * c + j] += x_all[l * c + i];
                        }
                        bits &= bits - 1;
                    }
                }
                StepKind::Bfs | StepKind::Wcc => {
                    let cost = if kind == StepKind::Bfs { 1.0 } else { 0.0 };
                    let mut bits = batch.bits(k);
                    while bits != 0 {
                        let bit = bits.trailing_zeros() as usize;
                        let (i, j) = (bit / c, bit % c);
                        for l in 0..lanes {
                            let cand = x_all[l * c + i] + cost;
                            if cand < o_all[l * c + j] {
                                o_all[l * c + j] = cand;
                            }
                        }
                        bits &= bits - 1;
                    }
                }
                StepKind::Sssp => {
                    let w = batch.weights_of(k);
                    let mut bits = batch.bits(k);
                    let mut nth = 0usize;
                    while bits != 0 {
                        let bit = bits.trailing_zeros() as usize;
                        let (i, j) = (bit / c, bit % c);
                        for l in 0..lanes {
                            let cand = x_all[l * c + i] + w[nth];
                            if cand < o_all[l * c + j] {
                                o_all[l * c + j] = cand;
                            }
                        }
                        bits &= bits - 1;
                        nth += 1;
                    }
                }
            }
        }
        Ok(())
    }
}

/// Reduction identity per step kind (must match the L1 kernels).
pub fn identity(kind: StepKind) -> f32 {
    match kind {
        StepKind::Bfs | StepKind::Sssp | StepKind::Wcc => INF,
        StepKind::PageRank | StepKind::Mvm => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::{Coo, Edge};
    use crate::pattern::extract::{partition, Partitioned};
    use crate::sched::plan::ExecutionPlan;

    fn part2() -> Partitioned {
        // One 2x2 window with edges (0,1)=w2.0 and (1,0)=w3.0.
        partition(
            &Coo::from_edges(2, vec![Edge::weighted(0, 1, 2.0), Edge::weighted(1, 0, 3.0)]),
            2,
            true,
        )
    }

    #[test]
    fn bfs_minplus_semantics() {
        let plan = ExecutionPlan::from_partitioned(&part2());
        let mut out = Vec::new();
        let xs = vec![0.0, INF]; // vertex 0 at level 0
        NativeExecutor
            .execute(StepKind::Bfs, plan.batch(&[0]), &xs, &mut out)
            .unwrap();
        assert_eq!(out[1], 1.0); // 0 -> 1 at level 1
        assert!(out[0] >= INF); // 1 -> 0 from unvisited source stays INF
    }

    #[test]
    fn sssp_uses_weights() {
        let plan = ExecutionPlan::from_partitioned(&part2());
        let mut out = Vec::new();
        let xs = vec![1.0, 10.0];
        NativeExecutor
            .execute(StepKind::Sssp, plan.batch(&[0]), &xs, &mut out)
            .unwrap();
        assert_eq!(out[1], 3.0); // 1.0 + w(0,1)=2.0
        assert_eq!(out[0], 13.0); // 10.0 + w(1,0)=3.0
    }

    #[test]
    fn sssp_without_weights_errors() {
        let p = partition(&Coo::from_edges(2, vec![Edge::new(0, 1)]), 2, false);
        let plan = ExecutionPlan::from_partitioned(&p);
        let mut out = Vec::new();
        assert!(NativeExecutor
            .execute(StepKind::Sssp, plan.batch(&[0]), &[0.0, 0.0], &mut out)
            .is_err());
    }

    #[test]
    fn pagerank_sums() {
        let plan = ExecutionPlan::from_partitioned(&part2());
        let mut out = Vec::new();
        let xs = vec![0.25, 0.5];
        NativeExecutor
            .execute(StepKind::PageRank, plan.batch(&[0]), &xs, &mut out)
            .unwrap();
        assert_eq!(out, vec![0.5, 0.25]);
    }

    #[test]
    fn wcc_zero_cost() {
        let plan = ExecutionPlan::from_partitioned(&part2());
        let mut out = Vec::new();
        let xs = vec![0.0, 1.0];
        NativeExecutor
            .execute(StepKind::Wcc, plan.batch(&[0]), &xs, &mut out)
            .unwrap();
        assert_eq!(out[1], 0.0);
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn batch_of_subgraphs() {
        let g = Coo::from_edges(4, vec![Edge::new(0, 1), Edge::new(2, 3)]);
        let p = partition(&g, 2, false);
        assert_eq!(p.num_subgraphs(), 2);
        let plan = ExecutionPlan::from_partitioned(&p);
        let xs = vec![0.0, INF, 5.0, INF];
        let mut out = Vec::new();
        NativeExecutor
            .execute(StepKind::Bfs, plan.batch(&[0, 1]), &xs, &mut out)
            .unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[1], 1.0);
        assert_eq!(out[3], 6.0);
    }

    #[test]
    fn xs_length_checked() {
        let plan = ExecutionPlan::from_partitioned(&part2());
        let mut out = Vec::new();
        assert!(NativeExecutor
            .execute(StepKind::Bfs, plan.batch(&[0]), &[0.0], &mut out)
            .is_err());
    }

    /// Interleave per-lane solo inputs into the op-major lane-interleaved
    /// layout `execute_multi` consumes.
    fn interleave(lane_xs: &[Vec<f32>], n_ops: usize, c: usize) -> Vec<f32> {
        let lanes = lane_xs.len();
        let mut xs = vec![0.0f32; n_ops * lanes * c];
        for (l, lx) in lane_xs.iter().enumerate() {
            for k in 0..n_ops {
                xs[(k * lanes + l) * c..(k * lanes + l + 1) * c]
                    .copy_from_slice(&lx[k * c..(k + 1) * c]);
            }
        }
        xs
    }

    #[test]
    fn execute_multi_is_bit_identical_to_solo_lanes() {
        let plan = ExecutionPlan::from_partitioned(&part2());
        let ops = [0u32];
        let c = 2;
        for kind in [StepKind::Bfs, StepKind::Wcc, StepKind::Sssp, StepKind::PageRank] {
            let lane_inputs = vec![
                vec![0.0, INF],
                vec![INF, 0.0],
                vec![1.5, 2.5],
                vec![7.0, 0.25],
            ];
            for lanes in [1usize, 2, 3, 4] {
                let lane_xs = &lane_inputs[..lanes];
                let xs = interleave(lane_xs, ops.len(), c);
                let mut multi = Vec::new();
                NativeExecutor
                    .execute_multi(kind, plan.batch(&ops), lanes, &xs, &mut multi)
                    .unwrap();
                assert_eq!(multi.len(), ops.len() * lanes * c);
                for (l, lx) in lane_xs.iter().enumerate() {
                    let mut solo = Vec::new();
                    NativeExecutor.execute(kind, plan.batch(&ops), lx, &mut solo).unwrap();
                    for k in 0..ops.len() {
                        assert_eq!(
                            multi[(k * lanes + l) * c..(k * lanes + l + 1) * c].to_vec(),
                            solo[k * c..(k + 1) * c].to_vec(),
                            "{kind:?} lanes={lanes} lane={l} op={k}",
                        );
                    }
                }
            }
        }
    }

    /// The trait's default (deinterleave-and-delegate) implementation
    /// must agree with the native decode-once override bit for bit — it
    /// is the correctness baseline every backend inherits.
    #[test]
    fn default_execute_multi_matches_native_override() {
        // A shim that suppresses the override, exposing the trait default.
        struct DefaultMulti(NativeExecutor);
        impl StepExecutor for DefaultMulti {
            fn name(&self) -> &'static str {
                "default-multi"
            }
            fn execute(
                &mut self,
                kind: StepKind,
                batch: StepBatch<'_>,
                xs: &[f32],
                out: &mut Vec<f32>,
            ) -> Result<()> {
                self.0.execute(kind, batch, xs, out)
            }
        }
        let plan = ExecutionPlan::from_partitioned(&part2());
        let ops = [0u32];
        let lanes = 3;
        let xs = interleave(
            &[vec![0.0, INF], vec![4.0, 1.0], vec![INF, 2.0]],
            ops.len(),
            2,
        );
        for kind in [StepKind::Bfs, StepKind::Sssp, StepKind::PageRank] {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            NativeExecutor
                .execute_multi(kind, plan.batch(&ops), lanes, &xs, &mut a)
                .unwrap();
            DefaultMulti(NativeExecutor)
                .execute_multi(kind, plan.batch(&ops), lanes, &xs, &mut b)
                .unwrap();
            assert_eq!(a, b, "{kind:?}");
        }
    }

    #[test]
    fn execute_multi_checks_lanes_and_length() {
        let plan = ExecutionPlan::from_partitioned(&part2());
        let mut out = Vec::new();
        assert!(NativeExecutor
            .execute_multi(StepKind::Bfs, plan.batch(&[0]), 0, &[], &mut out)
            .is_err());
        assert!(NativeExecutor
            .execute_multi(StepKind::Bfs, plan.batch(&[0]), 2, &[0.0; 3], &mut out)
            .is_err());
    }
}
