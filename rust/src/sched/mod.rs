//! Graph processing & scheduling (paper Alg. 2): a schedule compiled once
//! into an [`ExecutionPlan`] and interpreted per superstep — sequentially
//! by [`Scheduler`] or across per-engine work lanes by
//! [`par::run_parallel`] (bit-identical for every thread count), whose
//! lanes run on a persistent channel-fed [`pool::WorkerPool`] (spawned
//! once, zero per-superstep thread spawns) — static/dynamic engine
//! dispatch, replacement policies, and the executor abstraction that
//! routes numeric edge-compute either through the native mirror or the
//! AOT-compiled PJRT artifact.

pub mod exchange;
pub mod executor;
pub mod oracle;
pub mod par;
pub mod patch;
pub mod plan;
pub mod pool;
pub mod replacement;
pub mod scheduler;

pub use exchange::{run_sharded, run_sharded_pooled, run_sharded_scoped, ShardPlans};
pub use executor::{NativeExecutor, StepExecutor};
pub use par::{
    resolve_threads, run_parallel, run_parallel_pooled, run_parallel_pooled_at,
    run_parallel_pooled_batch, run_parallel_scoped,
};
pub use patch::{patch_preprocessed, PatchStats};
pub use plan::{ExecutionPlan, GatherTable, LaneTable, PlanOp, SectionRebuild, StepBatch};
pub use pool::WorkerPool;
pub use replacement::{build_policy, ReplacementPolicy};
pub use scheduler::{EngineSummary, RunResult, Scheduler};
