//! Differential-testing oracle: the seed scheduler's *on-line* decision
//! derivation, retained verbatim.
//!
//! [`run_reference`] re-derives every scheduling decision inside the
//! superstep loop exactly like the pre-plan scheduler did — it rescans
//! the [`SubgraphTable`] groups, resolves each op through the
//! [`ConfigTable`] (including the `HashMap<Pattern, usize>` dynamic
//! directory), and recomputes read-row counts per op. The compiled-plan
//! interpreter ([`Scheduler::run`](super::Scheduler::run)) must produce
//! **bit-identical** results: same `values`, same `EventCounts`, same
//! timing, same static/dynamic op split. `rust/tests/properties.rs`
//! asserts that equivalence over randomized graphs, architectures and all
//! four algorithms — any divergence is a plan-compilation bug.
//!
//! The only intentional departure from the seed is the wear-out fix
//! (retire-then-repick), which is mirrored here so the equivalence holds
//! under endurance pressure too.
//!
//! Numeric operands still flow through the plan's [`StepBatch`]
//! (plan op index g == subgraph-table entry index g, guaranteed by
//! [`ExecutionPlan::build`](super::ExecutionPlan::build)); the point of
//! this module is independent *decision* derivation, not a second copy of
//! the arithmetic kernels.

use std::collections::HashMap;

use anyhow::Result;

use crate::accel::activity::ActivityTrace;
use crate::accel::config::ArchConfig;
use crate::accel::Preprocessed;
use crate::algo::traits::{Semiring, VertexProgram, INF};
use crate::cost::{CostParams, EventCounts};
use crate::engine::{EngineKind, GraphEngine};
use crate::pattern::Pattern;

use super::executor::StepExecutor;
use super::replacement::{build_policy, ReplacementPolicy};
use super::scheduler::{EngineSummary, RunResult};

/// Run `program` with on-line (table-scanning) scheduling — the seed
/// semantics. See the module docs; use [`Scheduler`](super::Scheduler)
/// for real work.
pub fn run_reference(
    config: &ArchConfig,
    params: &CostParams,
    pre: &Preprocessed,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
) -> Result<RunResult> {
    config.validate()?;
    // The artifact's plan was compiled under the same arch as its ct/st,
    // so this mirrors the interpreter's mismatch guard: a config that
    // doesn't match the artifact would silently produce garbage here.
    anyhow::ensure!(
        pre.plan.matches(config),
        "preprocessed artifact was built for a different architecture"
    );
    let part = &pre.part;
    let ct = &pre.ct;
    let st = &pre.st;
    if program.needs_weights() {
        anyhow::ensure!(
            part.weights.is_some(),
            "{} requires weighted partitioning",
            program.name()
        );
    }
    let c = part.c;
    let n = part.num_vertices as usize;
    let num_blocks = part.num_blocks() as usize;
    let n_static = config.static_engines;
    let n_total = config.total_engines;
    let m = config.crossbars_per_engine as usize;
    let n_dyn = config.dynamic_engines() as usize;
    let slot_pos = |k: usize| (n_static as usize + k % n_dyn, k / n_dyn);

    let mut engines: Vec<GraphEngine> = (0..n_total)
        .map(|i| {
            let kind = if i < n_static { EngineKind::Static } else { EngineKind::Dynamic };
            GraphEngine::new(i, kind, c, m as u32)
        })
        .collect();
    let n_dyn_slots = n_dyn * m;
    let mut policy: Box<dyn ReplacementPolicy> = build_policy(config.policy, n_dyn_slots);
    let mut dyn_dir: HashMap<Pattern, usize> = HashMap::new();
    let mut slot_pattern: Vec<Pattern> = vec![Pattern::EMPTY; n_dyn_slots];
    let mut retired: Vec<bool> = vec![false; n_dyn_slots];

    // Initialization (Alg. 2 l. 6–8) straight off the config table.
    for (entry, slot) in ct.static_assignments() {
        engines[slot.engine as usize].configure(slot.crossbar as usize, entry.pattern, params);
    }
    let mut init_counts = EventCounts::default();
    let mut init_time_ns = 0f64;
    for e in engines.iter_mut() {
        init_counts.add(&e.counts);
        let (busy, _) = e.end_iteration();
        init_time_ns = init_time_ns.max(busy);
    }
    let counts_baseline = init_counts;

    let mut values = program.init(part.num_vertices);
    anyhow::ensure!(values.len() == n, "program init length mismatch");
    let mut snapshot = values.clone();
    let semiring = program.semiring();
    let mut acc = match semiring {
        Semiring::SumProd => vec![0f32; n],
        Semiring::MinPlus => Vec::new(),
    };
    // Independent out-degree derivation (not the plan's copy).
    let outdeg = {
        let mut deg = vec![0u32; n];
        for sg in &part.subgraphs {
            let base = sg.brow as usize * c;
            let mut bits = sg.pattern.0;
            while bits != 0 {
                let bit = bits.trailing_zeros() as usize;
                let v = base + bit / c;
                if v < deg.len() {
                    deg[v] += 1;
                }
                bits &= bits - 1;
            }
        }
        deg
    };

    let all_blocks = program.processes_all_blocks();
    let mut active_block = vec![false; num_blocks];
    let mut next_active_block = vec![false; num_blocks];
    if !all_blocks {
        for (v, &val) in values.iter().enumerate() {
            if val < INF {
                active_block[v / c] = true;
            }
        }
    }

    let mut trace = config.trace_activity.then(|| ActivityTrace::new(n_total as usize));
    let mut prev_reads = vec![0u64; n_total as usize];
    let mut prev_writes = vec![0u64; n_total as usize];
    if trace.is_some() {
        for (i, e) in engines.iter().enumerate() {
            prev_reads[i] = e.counts.read_bits;
            prev_writes[i] = e.counts.write_bits;
        }
    }

    let kind = program.step_kind();
    let mut exec_time_ns = 0f64;
    let mut sys_counts = EventCounts::default();
    let mut iterations = 0u64;
    let mut static_ops = 0u64;
    let mut dynamic_ops = 0u64;
    let mut dynamic_hits = 0u64;
    let mut supersteps = 0usize;

    let mut sup_ops: Vec<u32> = Vec::new();
    let mut sup_dst: Vec<u32> = Vec::new();
    let mut xs: Vec<f32> = Vec::new();
    let mut cand: Vec<f32> = Vec::new();

    let lat_mvm = crate::cost::timing::mvm_latency_ns(params, c as u32, c as u32)
        + crate::cost::timing::reduce_latency_ns(params, c as u32);

    for superstep in 0..program.max_supersteps() {
        snapshot.copy_from_slice(&values);
        sup_ops.clear();
        sup_dst.clear();

        let mut entry_idx = 0usize;
        for group in st.iter_groups() {
            let mut ops_in_group = 0u64;
            for entry in group {
                let global = entry_idx;
                entry_idx += 1;
                if !all_blocks && !active_block[entry.src_start as usize / c] {
                    continue;
                }
                ops_in_group += 1;
                let ct_entry = &ct.entries[entry.pattern_rank as usize];
                let pattern = ct_entry.pattern;
                let rows = ct_entry.active_rows;
                if ct_entry.is_static() {
                    let slot = if ct_entry.slots.len() == 1 {
                        ct_entry.slots[0]
                    } else {
                        *ct_entry
                            .slots
                            .iter()
                            .min_by(|a, b| {
                                engines[a.engine as usize]
                                    .busy_ns
                                    .total_cmp(&engines[b.engine as usize].busy_ns)
                            })
                            .expect("static entry has a slot")
                    };
                    let read_rows =
                        if ct_entry.row_addr.is_some() { 1 } else { rows.max(1) as u64 };
                    engines[slot.engine as usize].mvm_precomputed(
                        slot.crossbar as usize,
                        read_rows,
                        lat_mvm,
                    );
                    static_ops += 1;
                } else {
                    let hit = if config.dynamic_reuse {
                        dyn_dir.get(&pattern).copied().filter(|&k| !retired[k])
                    } else {
                        None
                    };
                    let k = match hit {
                        Some(k) => {
                            dynamic_hits += 1;
                            k
                        }
                        None => loop {
                            // Retire-then-repick (mirrors the fixed
                            // interpreter; see sched/scheduler.rs).
                            let k = policy.pick(&retired).ok_or_else(|| {
                                anyhow::anyhow!("all dynamic crossbars retired (wear-out)")
                            })?;
                            let (ei, cb) = slot_pos(k);
                            let old = slot_pattern[k];
                            if !old.is_empty() {
                                dyn_dir.remove(&old);
                                slot_pattern[k] = Pattern::EMPTY;
                            }
                            engines[ei].configure(cb, pattern, params);
                            if engines[ei].crossbars[cb].worn_out(params.endurance_cycles) {
                                retired[k] = true;
                                continue;
                            }
                            slot_pattern[k] = pattern;
                            dyn_dir.insert(pattern, k);
                            break k;
                        },
                    };
                    let (ei, cb) = slot_pos(k);
                    engines[ei].mvm_precomputed(cb, rows.max(1) as u64, lat_mvm);
                    policy.touch(k);
                    dynamic_ops += 1;
                }
                sup_ops.push(global as u32);
                sup_dst.push(entry.dst_start);
            }
            if ops_in_group == 0 {
                continue;
            }
            iterations += 1;
            sys_counts.main_mem_accesses += 2 * ops_in_group.div_ceil(16);
            if let Some(t) = trace.as_mut() {
                t.push_iteration(engines.iter().enumerate().map(|(i, e)| {
                    let dr = (e.counts.read_bits - prev_reads[i]) as u32;
                    let dw = (e.counts.write_bits - prev_writes[i]) as u32;
                    prev_reads[i] = e.counts.read_bits;
                    prev_writes[i] = e.counts.write_bits;
                    (dr, dw)
                }));
            }
        }

        let mut max_busy = 0f64;
        for e in engines.iter_mut() {
            let (busy, _) = e.end_iteration();
            max_busy = max_busy.max(busy);
        }
        exec_time_ns += max_busy;

        if sup_ops.is_empty() {
            break;
        }

        xs.clear();
        xs.reserve(sup_ops.len() * c);
        for &op in &sup_ops {
            let src_start = st.entries[op as usize].src_start as usize;
            for i in 0..c {
                let v = src_start + i;
                if v < n {
                    xs.push(program.source_value(snapshot[v], outdeg[v]));
                } else {
                    xs.push(super::executor::identity(kind));
                }
            }
        }
        executor.execute(kind, pre.plan.batch(&sup_ops), &xs, &mut cand)?;

        let mut any_changed = false;
        match semiring {
            Semiring::MinPlus => {
                next_active_block.iter_mut().for_each(|b| *b = false);
                for (k, &dst_start) in sup_dst.iter().enumerate() {
                    for j in 0..c {
                        let v = dst_start as usize + j;
                        if v >= n {
                            break;
                        }
                        let old = values[v];
                        let new = program.apply(old, cand[k * c + j]);
                        if program.changed(old, new) {
                            values[v] = new;
                            next_active_block[v / c] = true;
                            any_changed = true;
                        }
                    }
                }
                std::mem::swap(&mut active_block, &mut next_active_block);
            }
            Semiring::SumProd => {
                for (k, &dst_start) in sup_dst.iter().enumerate() {
                    for j in 0..c {
                        let v = dst_start as usize + j;
                        if v >= n {
                            break;
                        }
                        acc[v] += cand[k * c + j];
                    }
                }
                any_changed = true;
            }
        }

        supersteps = superstep + 1;
        if !program.post_superstep(superstep, &mut values, &mut acc, any_changed) {
            break;
        }
    }

    let mut counts = sys_counts;
    let mut summaries = Vec::with_capacity(engines.len());
    let mut max_dyn_writes = 0u32;
    for e in &engines {
        counts.add(&e.counts);
        if e.kind == EngineKind::Dynamic {
            max_dyn_writes = max_dyn_writes.max(e.max_cell_writes());
        }
        summaries.push(EngineSummary::of(e));
    }
    counts.subtract(&counts_baseline);

    Ok(RunResult {
        values,
        counts,
        init_counts,
        exec_time_ns,
        init_time_ns,
        supersteps,
        iterations,
        static_ops,
        dynamic_ops,
        dynamic_hits,
        max_dynamic_cell_writes: max_dyn_writes,
        engines: summaries,
        activity: trace,
    })
}
