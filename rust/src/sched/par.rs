//! Batch-parallel superstep execution over the compiled [`ExecutionPlan`]
//! — the inter-engine parallelism the plan IR was built to unlock.
//!
//! The paper's premise makes the static lanes embarrassingly parallel:
//! static engines hold the frequent patterns, so most subgraph ops touch
//! exactly one engine and share no state with any other engine. This
//! module exploits that with a three-phase superstep:
//!
//! 1. **Dispatch** (sequential, cheap): walk the ready ops in plan order
//!    and resolve every scheduling decision into per-engine work lanes.
//!    Single-replica static ops come pre-homed by the plan's
//!    [`LaneTable`](super::plan::LaneTable); multi-replica static ops take
//!    the least-busy replica against a shadow busy model that replays the
//!    interpreter's f64 accumulation bit-exactly; dynamic ops run the
//!    replacement policy (plus retire-then-repick wear-out) against
//!    dispatcher-owned shadow crossbars.
//! 2. **Lane replay** (parallel): engines move into lanes — each worker
//!    owns whole engines and replays their queued records (configure /
//!    MVM counter arithmetic, crossbar wear) in dispatch order. An
//!    engine's entire queue lives in one lane, so all engine-local state
//!    stays thread-local.
//! 3. **Numeric phase**: the gather runs on the calling thread (an
//!    indexed copy through the plan's
//!    [`GatherTable`](super::plan::GatherTable)), then the edge-compute
//!    batch is chunked across executor forks when the backend supports
//!    it. Per-op outputs are independent, so any chunking is
//!    bit-identical to one sequential call.
//!
//! # Execution mechanisms: pooled (production) vs scoped (baseline)
//!
//! Phases 2 and 3 run on one of two mechanisms behind the same dispatch
//! pass:
//!
//! * **Pooled** — a persistent [`WorkerPool`] (channel-fed, spawned once,
//!   owned by the `Session` or transiently per run): zero thread spawns
//!   and zero steady-state allocation per superstep. This is the
//!   production path; [`run_parallel`] routes here.
//! * **Scoped** — the pre-pool `std::thread::scope` baseline
//!   ([`run_parallel_scoped`]), which pays a spawn/join per superstep.
//!   Kept so `benches/hotpath.rs` can report the pool's win and the test
//!   suite can differential-check both mechanisms forever.
//!
//! Both produce bit-identical `RunResult`s by construction: the dispatch
//! pass is shared and merges are index-ordered (see below).
//!
//! # Why dynamic ops shard by pattern rank / slot, not round-robin
//!
//! A dynamic op's lane is the engine owning the crossbar slot that the
//! replacement policy binds its pattern rank to. That keeps
//! *crossbar-content affinity*: every configure and MVM touching one
//! crossbar — the pattern it currently holds, its per-cell wear counters
//! — replays inside a single lane, in dispatch order, so no crossbar
//! state ever crosses a thread boundary. A fully rank-sharded scheme
//! (one lane per rank, policy state split per lane) cannot reproduce the
//! sequential semantics: the replacement policy is *global* across
//! dynamic slots (an LRU pick for rank A evicts the slot rank B counts
//! on), which is exactly why the *decisions* stay in the sequential
//! dispatch pass and only slot-affine *effects* fan out.
//!
//! # The bit-identical merge invariant
//!
//! Merge order is lane-indexed, then engine-indexed: lane results are
//! joined in lane order (pool replies are collected in worker-index
//! order, which is lane order) and folded back into the engine vector by
//! engine id, and the superstep latency is the max over per-engine busy
//! times folded in engine-id order — the same order the sequential
//! interpreter uses. Combined with the bit-exact dispatch shadow, a run's
//! [`RunResult`] (values, `EventCounts`, timing, wear, per-engine
//! summaries) is **bit-identical for every thread count and both
//! mechanisms**, and identical to [`Scheduler::run`] and to the
//! differential oracle
//! [`oracle::run_reference`](super::oracle::run_reference) —
//! `rust/tests/parallel.rs` locks this down over randomized graphs and
//! all four algorithms. The invariant is what makes the concurrent
//! scheduler safe to evolve: any divergence is a bug by definition, not
//! a tolerance question.
//!
//! The sequential interpreter remains the `threads <= 1` path; runs that
//! record the per-iteration activity trace (Fig. 5) also take it, since
//! the trace wants per-group engine snapshots the deferred lane replay
//! does not produce.

use anyhow::Result;

use crate::accel::config::ArchConfig;
use crate::algo::traits::{Semiring, VertexProgram, INF};
use crate::cost::{CostParams, EventCounts};
use crate::engine::{Crossbar, EngineKind, GraphEngine};

use super::executor::StepExecutor;
use super::plan::ExecutionPlan;
use super::pool::{LaneSlot, WorkerPool};
use super::replacement::build_policy;
use super::scheduler::{
    gather_sources, reduce_apply, slot_pos, EngineSummary, RunResult, Scheduler, NONE,
};

/// Below this many queued records a superstep replays inline: even a
/// pooled channel round-trip costs more than the counter arithmetic it
/// would parallelize. Lane assignment never affects results (per-engine
/// state is self-contained), so this is purely a throughput threshold.
const MIN_PARALLEL_RECORDS: usize = 512;

/// Below this many ops the numeric batch runs on the calling thread for
/// the same reason. Chunking is bit-exact at any size, so the threshold
/// is free to change.
const MIN_PARALLEL_NUMERIC_OPS: usize = 256;

/// One queued effect on an engine, replayed by its lane in dispatch
/// order. Records carry rank indices, not `Pattern`s — the lane resolves
/// them through the shared plan.
#[derive(Debug, Clone, Copy)]
pub(crate) enum LaneRecord {
    /// Reconfigure crossbar `crossbar` to the pattern of `rank`.
    Configure { crossbar: u32, rank: u32 },
    /// One in-situ MVM against `crossbar` reading `read_rows` wordlines.
    Mvm { crossbar: u32, read_rows: u32 },
}

/// Resolve a requested thread count: `0` means one lane per available
/// hardware thread. The one shared helper behind `--threads`,
/// `SessionBuilder::parallelism`, `ServiceConfig.parallelism` and the
/// test harness's `REPRO_THREADS` — results never depend on the resolved
/// value.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// The pool a pooled run executes on: a caller-owned persistent pool,
/// or a transient one spawned **lazily** — a run whose supersteps all
/// stay under the inline thresholds never spawns a thread at all, same
/// as the scoped baseline.
pub(crate) enum PoolRef<'p> {
    Borrowed(&'p mut WorkerPool),
    Lazy { threads: usize, pool: Option<WorkerPool> },
}

impl PoolRef<'_> {
    pub(crate) fn get(&mut self) -> &mut WorkerPool {
        match self {
            PoolRef::Borrowed(pool) => pool,
            PoolRef::Lazy { threads, pool } => {
                pool.get_or_insert_with(|| WorkerPool::new(*threads))
            }
        }
    }
}

/// How phases 2/3 execute. The dispatch pass is identical either way —
/// see the module docs.
pub(crate) enum LaneMode<'p> {
    /// Per-superstep `std::thread::scope` spawns (the pre-pool baseline,
    /// kept for benches and differential tests).
    Scoped { threads: usize },
    /// Persistent channel-fed workers — zero per-superstep spawns.
    /// `threads` caps the lanes actually used (≤ the pool's workers), so
    /// a per-job override smaller than the pool is honored.
    Pooled { pool: PoolRef<'p>, threads: usize },
}

impl LaneMode<'_> {
    pub(crate) fn threads(&self) -> usize {
        match self {
            LaneMode::Scoped { threads } | LaneMode::Pooled { threads, .. } => *threads,
        }
    }
}

/// Run-lifetime scratch for phases 2/3: everything here is allocated
/// once per run (plan-/engine-sized) and only cleared per superstep, so
/// the steady-state hot loop performs no heap allocation.
pub(crate) struct Scratch {
    /// Engine indices with queued records this superstep.
    active: Vec<usize>,
    /// Queued record count per active engine (parallel to `active`).
    loads: Vec<usize>,
    /// Lane index per active engine (parallel to `active`).
    assignment: Vec<usize>,
    /// Greedy-balancer accumulator, one entry per lane.
    lane_load: Vec<usize>,
    /// Per-engine busy time of the current superstep (engine-id order).
    busy_by_engine: Vec<f64>,
    /// Pooled replay: one reusable lane buffer per worker.
    lane_bufs: Vec<Vec<LaneSlot>>,
    /// Pooled numeric: one reusable output buffer per worker,
    /// double-buffered through the pool's channels.
    pub(crate) chunk_bufs: Vec<Vec<f32>>,
}

impl Scratch {
    pub(crate) fn new(n_engines: usize, workers: usize) -> Self {
        Self {
            active: Vec::with_capacity(n_engines),
            loads: Vec::with_capacity(n_engines),
            assignment: Vec::with_capacity(n_engines),
            lane_load: Vec::with_capacity(workers),
            busy_by_engine: vec![0f64; n_engines],
            lane_bufs: (0..workers).map(|_| Vec::new()).collect(),
            chunk_bufs: (0..workers).map(|_| Vec::new()).collect(),
        }
    }
}

/// Deterministic greedy lane assignment into `out`: engines (ascending
/// id) go to the least-loaded lane, ties to the lowest lane index.
/// `loads[i]` is the queued record count of the i-th active engine. With
/// `n_lanes >= 1` and at least one engine, every lane
/// `0..min(n_lanes, loads.len())` receives work — no idle lanes.
fn lane_assignment_into(
    loads: &[usize],
    n_lanes: usize,
    lane_load: &mut Vec<usize>,
    out: &mut Vec<usize>,
) {
    let n_lanes = n_lanes.min(loads.len()).max(1);
    lane_load.clear();
    lane_load.resize(n_lanes, 0);
    out.clear();
    for (i, &load) in loads.iter().enumerate() {
        let lane = if i < n_lanes {
            i // seed each lane before balancing
        } else {
            (0..n_lanes).min_by_key(|&l| lane_load[l]).unwrap()
        };
        lane_load[lane] += load;
        out.push(lane);
    }
}

#[cfg(test)]
fn lane_assignment(loads: &[usize], n_lanes: usize) -> Vec<usize> {
    let (mut lane_load, mut out) = (Vec::new(), Vec::new());
    lane_assignment_into(loads, n_lanes, &mut lane_load, &mut out);
    out
}

/// Replay one engine's queued records in dispatch order. Shared by the
/// inline path, the scoped baseline, and the pool workers.
pub(crate) fn replay_engine(
    e: &mut GraphEngine,
    records: &[LaneRecord],
    plan: &ExecutionPlan,
    params: &CostParams,
    lat_mvm: f64,
) {
    for r in records {
        match *r {
            LaneRecord::Configure { crossbar, rank } => {
                e.configure(crossbar as usize, plan.pattern_of_rank(rank), params);
            }
            LaneRecord::Mvm { crossbar, read_rows } => {
                e.mvm_precomputed(crossbar as usize, read_rows as u64, lat_mvm);
            }
        }
    }
}

/// Phase 2: move record-bearing engines into lanes, replay them on the
/// mode's workers, and merge busy times back in engine-id order. Returns
/// the superstep's max busy (ns). Falls back to an inline replay — no
/// channel round-trip, no spawns — when a single lane would do all the
/// work.
#[allow(clippy::too_many_arguments)]
pub(crate) fn replay_lanes(
    engines: &mut [Option<GraphEngine>],
    records: &[Vec<LaneRecord>],
    scratch: &mut Scratch,
    plan: &ExecutionPlan,
    params: &CostParams,
    lat_mvm: f64,
    mode: &mut LaneMode<'_>,
) -> f64 {
    scratch.active.clear();
    scratch.loads.clear();
    for (e, recs) in records.iter().enumerate() {
        if !recs.is_empty() {
            scratch.active.push(e);
            scratch.loads.push(recs.len());
        }
    }
    if scratch.active.is_empty() {
        return 0.0;
    }
    let total_records: usize = scratch.loads.iter().sum();
    let n_lanes = if total_records < MIN_PARALLEL_RECORDS {
        1
    } else {
        mode.threads().min(scratch.active.len())
    };
    scratch.busy_by_engine.iter_mut().for_each(|b| *b = 0.0);
    if n_lanes <= 1 {
        for &e in &scratch.active {
            let eng = engines[e].as_mut().expect("engine present");
            replay_engine(eng, &records[e], plan, params, lat_mvm);
            let (busy, _) = eng.end_iteration();
            scratch.busy_by_engine[e] = busy;
        }
    } else {
        lane_assignment_into(
            &scratch.loads,
            n_lanes,
            &mut scratch.lane_load,
            &mut scratch.assignment,
        );
        match mode {
            LaneMode::Pooled { pool, .. } => {
                let pool = pool.get();
                let lanes = &mut scratch.lane_bufs[..n_lanes];
                for (i, &e) in scratch.active.iter().enumerate() {
                    lanes[scratch.assignment[i]].push((
                        e,
                        engines[e].take().expect("engine present"),
                        0.0,
                    ));
                }
                pool.replay(lanes, records, plan, params, lat_mvm);
                // Lane- then engine-ordered merge (lanes arrive back in
                // worker == lane order).
                for lane in lanes.iter_mut() {
                    for (e, eng, busy) in lane.drain(..) {
                        scratch.busy_by_engine[e] = busy;
                        engines[e] = Some(eng);
                    }
                }
            }
            LaneMode::Scoped { .. } => {
                let mut lanes: Vec<Vec<(usize, GraphEngine)>> =
                    (0..n_lanes).map(|_| Vec::new()).collect();
                for (i, &e) in scratch.active.iter().enumerate() {
                    lanes[scratch.assignment[i]]
                        .push((e, engines[e].take().expect("engine present")));
                }
                let lane_results: Vec<Vec<(usize, GraphEngine, f64)>> =
                    std::thread::scope(|s| {
                        let handles: Vec<_> = lanes
                            .into_iter()
                            .map(|lane| {
                                s.spawn(move || {
                                    lane.into_iter()
                                        .map(|(e, mut eng)| {
                                            replay_engine(
                                                &mut eng, &records[e], plan, params, lat_mvm,
                                            );
                                            let (busy, _) = eng.end_iteration();
                                            (e, eng, busy)
                                        })
                                        .collect()
                                })
                            })
                            .collect();
                        // Merge in lane order — deterministic by construction.
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("lane worker panicked"))
                            .collect()
                    });
                for lane in lane_results {
                    for (e, eng, busy) in lane {
                        scratch.busy_by_engine[e] = busy;
                        engines[e] = Some(eng);
                    }
                }
            }
        }
    }
    // Engine-id fold order matches the sequential interpreter.
    scratch.busy_by_engine.iter().fold(0f64, |a, &b| a.max(b))
}

/// Phase 3: edge compute, chunked across executor forks when the backend
/// supports concurrent evaluation; otherwise one sequential call on
/// `executor`. Chunk boundaries never affect the result — each op's
/// output lanes are an independent pure function of its operands.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_numeric(
    executor: &mut dyn StepExecutor,
    kind: crate::algo::traits::StepKind,
    plan: &ExecutionPlan,
    sup_ops: &[u32],
    xs: &[f32],
    cand: &mut Vec<f32>,
    chunk_bufs: &mut [Vec<f32>],
    mode: &mut LaneMode<'_>,
) -> Result<()> {
    let c = plan.c;
    let threads = mode.threads();
    if threads <= 1 || sup_ops.len() < MIN_PARALLEL_NUMERIC_OPS.max(2 * threads) {
        return executor.execute(kind, plan.batch(sup_ops), xs, cand);
    }
    let chunk = sup_ops.len().div_ceil(threads);
    match mode {
        LaneMode::Pooled { pool, .. } => {
            let pool = pool.get();
            // Workers keep their forks across supersteps and runs —
            // `ensure_forks` is a cached no-op after the first superstep.
            if !pool.ensure_forks(executor) {
                // Stateful backend (PJRT): the numeric phase stays
                // sequential.
                return executor.execute(kind, plan.batch(sup_ops), xs, cand);
            }
            pool.execute_chunks(kind, plan, sup_ops, xs, chunk, chunk_bufs, cand)
        }
        LaneMode::Scoped { .. } => {
            let n_chunks = sup_ops.len().div_ceil(chunk);
            let mut forks: Vec<Box<dyn StepExecutor + Send>> =
                Vec::with_capacity(n_chunks);
            for _ in 0..n_chunks {
                match executor.fork() {
                    Some(f) => forks.push(f),
                    None => return executor.execute(kind, plan.batch(sup_ops), xs, cand),
                }
            }
            let outputs: Vec<Result<Vec<f32>>> = std::thread::scope(|s| {
                let handles: Vec<_> = sup_ops
                    .chunks(chunk)
                    .zip(xs.chunks(chunk * c))
                    .zip(forks.into_iter())
                    .map(|((ops_chunk, xs_chunk), mut exec)| {
                        s.spawn(move || {
                            let mut out = Vec::new();
                            exec.execute(kind, plan.batch(ops_chunk), xs_chunk, &mut out)
                                .map(|_| out)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("numeric worker panicked"))
                    .collect()
            });
            cand.clear();
            cand.reserve(sup_ops.len() * c);
            for out in outputs {
                cand.extend_from_slice(&out?);
            }
            Ok(())
        }
    }
}

/// Run `program` to convergence with `threads` execution lanes.
///
/// `threads <= 1` (and any run recording the activity trace) takes the
/// sequential interpreter verbatim; `threads == 0` resolves to the
/// available hardware parallelism. Otherwise a **transient**
/// [`WorkerPool`] serves the run — one spawn set per run, never per
/// superstep. Callers that run repeatedly should hold a persistent pool
/// and use [`run_parallel_pooled`] (the `Session` does exactly that).
/// Results are bit-identical to [`Scheduler::run`] for every thread
/// count — see the module docs for the invariant and
/// `rust/tests/parallel.rs` for the lockdown.
pub fn run_parallel(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    threads: usize,
) -> Result<RunResult> {
    let threads = resolve_threads(threads);
    if threads <= 1 || config.trace_activity {
        return Scheduler::new(config, params, plan).run(program, executor);
    }
    // Lazy: a run that never crosses the parallel thresholds spawns no
    // thread at all, matching the old scoped behavior on tiny workloads.
    run_pipeline(
        config,
        params,
        plan,
        program,
        executor,
        LaneMode::Pooled { pool: PoolRef::Lazy { threads, pool: None }, threads },
    )
}

/// Run `program` on a caller-owned persistent [`WorkerPool`] — the
/// zero-spawn production path. The pool's worker count is the lane
/// count; a one-worker pool (and any tracing run) delegates to the
/// sequential interpreter.
pub fn run_parallel_pooled(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    pool: &mut WorkerPool,
) -> Result<RunResult> {
    let workers = pool.workers();
    run_parallel_pooled_at(config, params, plan, program, executor, pool, workers)
}

/// Like [`run_parallel_pooled`] but capping the lane count at `threads`
/// (`0` = auto): a per-job override smaller than the pool uses fewer
/// lanes of the same workers; larger requests clamp to the pool size.
/// An effective lane count of 1 (and any tracing run) delegates to the
/// sequential interpreter. Bit-identical for every cap, as always.
pub fn run_parallel_pooled_at(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    pool: &mut WorkerPool,
    threads: usize,
) -> Result<RunResult> {
    let threads = resolve_threads(threads).min(pool.workers());
    if threads <= 1 || config.trace_activity {
        return Scheduler::new(config, params, plan).run(program, executor);
    }
    run_pipeline(
        config,
        params,
        plan,
        program,
        executor,
        LaneMode::Pooled { pool: PoolRef::Borrowed(pool), threads },
    )
}

/// The pre-pool baseline: identical dispatch, but phases 2/3 spawn
/// `std::thread::scope` workers **every superstep**. Kept so the hotpath
/// bench can report the pool's win over the mechanism it replaced and so
/// the determinism suite can cross-check both mechanisms; new callers
/// should use [`run_parallel`] / [`run_parallel_pooled`].
pub fn run_parallel_scoped(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    threads: usize,
) -> Result<RunResult> {
    let threads = resolve_threads(threads);
    if threads <= 1 || config.trace_activity {
        return Scheduler::new(config, params, plan).run(program, executor);
    }
    run_pipeline(config, params, plan, program, executor, LaneMode::Scoped { threads })
}

/// The shared three-phase pipeline (see the module docs). `mode` selects
/// only the phase-2/3 mechanism; every decision is made here, in the
/// sequential dispatch pass, exactly as the interpreter makes it.
fn run_pipeline(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    mut mode: LaneMode<'_>,
) -> Result<RunResult> {
    config.validate()?;
    anyhow::ensure!(
        plan.matches(config),
        "execution plan was compiled for a different architecture \
         (plan C={} N={} T={} M={})",
        plan.c,
        plan.static_engines,
        plan.total_engines,
        plan.crossbars_per_engine
    );
    if program.needs_weights() {
        anyhow::ensure!(
            plan.weighted,
            "{} requires weighted partitioning",
            program.name()
        );
    }
    let c = plan.c;
    let n = plan.num_vertices as usize;
    let num_blocks = plan.num_blocks as usize;
    let n_static = config.static_engines;
    let n_total = config.total_engines as usize;
    let m = config.crossbars_per_engine as usize;

    // --- engines (moved into lanes per superstep) + dispatch state ---
    let mut engines: Vec<Option<GraphEngine>> = (0..n_total)
        .map(|i| {
            let kind =
                if (i as u32) < n_static { EngineKind::Static } else { EngineKind::Dynamic };
            Some(GraphEngine::new(i as u32, kind, c, m as u32))
        })
        .collect();
    let n_dyn_slots = config.dynamic_engines() as usize * m;
    let mut policy = build_policy(config.policy, n_dyn_slots);
    let mut dyn_dir: Vec<u32> = vec![NONE; plan.num_patterns as usize];
    let mut slot_rank: Vec<u32> = vec![NONE; n_dyn_slots];
    let mut retired: Vec<bool> = vec![false; n_dyn_slots];
    // Dispatcher-owned mirror of the dynamic crossbars: retire-then-repick
    // must know a configure's wear *at decision time*, before the owning
    // lane replays the identical configure on the real crossbar.
    let mut shadow: Vec<Crossbar> = (0..n_dyn_slots).map(|_| Crossbar::new(c)).collect();
    // Shadow of the static engines' busy time, accumulated with the same
    // f64 additions (same order, same addend) as the interpreter — the
    // least-busy replica pick compares bit-identical values.
    let mut shadow_busy = vec![0f64; n_total];

    // --- initialization: configure static engines (Alg. 2 l. 6–8) ---
    for &(slot, pattern) in plan.static_config() {
        engines[slot.engine as usize]
            .as_mut()
            .expect("engine present")
            .configure(slot.crossbar as usize, pattern, params);
    }
    let mut init_counts = EventCounts::default();
    let mut init_time_ns = 0f64;
    for e in engines.iter_mut() {
        let e = e.as_mut().expect("engine present");
        init_counts.add(&e.counts);
        let (busy, _) = e.end_iteration();
        init_time_ns = init_time_ns.max(busy);
    }
    let counts_baseline = init_counts;

    // --- vertex state (identical to the sequential interpreter) ---
    let mut values = program.init(plan.num_vertices);
    anyhow::ensure!(values.len() == n, "program init length mismatch");
    let mut snapshot = values.clone();
    let semiring = program.semiring();
    let mut acc = match semiring {
        Semiring::SumProd => vec![0f32; n],
        Semiring::MinPlus => Vec::new(),
    };
    let outdeg = plan.out_degrees();

    let all_blocks = program.processes_all_blocks();
    let mut active_block = vec![false; num_blocks];
    let mut next_active_block = vec![false; num_blocks];
    if !all_blocks {
        for (v, &val) in values.iter().enumerate() {
            if val < INF {
                active_block[v / c] = true;
            }
        }
    }

    // --- per-engine work lanes + run-lifetime scratch, all preallocated
    // --- (the lane queues to the plan's lane-table bounds) ---
    let lane_tab = plan.lanes();
    let mut records: Vec<Vec<LaneRecord>> = (0..n_total)
        .map(|e| Vec::with_capacity(lane_tab.fixed_ops_on(e as u32) as usize))
        .collect();
    let mut scratch = Scratch::new(n_total, mode.threads());

    // --- main loop ---
    let kind = program.step_kind();
    let mut exec_time_ns = 0f64;
    let mut sys_counts = EventCounts::default();
    let mut iterations = 0u64;
    let mut static_ops = 0u64;
    let mut dynamic_ops = 0u64;
    let mut dynamic_hits = 0u64;
    let mut supersteps = 0usize;

    let mut sup_ops: Vec<u32> = Vec::new();
    let mut xs: Vec<f32> = Vec::new();
    let mut cand: Vec<f32> = Vec::new();

    let lat_mvm = crate::cost::timing::mvm_latency_ns(params, c as u32, c as u32)
        + crate::cost::timing::reduce_latency_ns(params, c as u32);

    for superstep in 0..program.max_supersteps() {
        snapshot.copy_from_slice(&values);
        sup_ops.clear();
        for r in records.iter_mut() {
            r.clear();
        }
        shadow_busy.iter_mut().for_each(|b| *b = 0.0);

        // --- phase 1: sequential dispatch — decisions into lanes ---
        for g in 0..plan.num_groups() {
            let (start, end) = plan.group_bounds(g);
            let mut ops_in_group = 0u64;
            for (off, op) in plan.ops[start..end].iter().enumerate() {
                if !all_blocks && !active_block[op.src_block as usize] {
                    continue;
                }
                ops_in_group += 1;
                if op.is_static() {
                    let slots = plan.slots_of(op);
                    // Compile-time-homed ops (the lane table's fast path:
                    // exactly one replica) skip the least-busy scan; only
                    // multi-replica ops resolve against the shadow busy
                    // model — same choice, bit for bit, as the
                    // interpreter's single-slot shortcut.
                    let slot = if lane_tab.home_of(start + off).is_some() {
                        slots[0]
                    } else {
                        *slots
                            .iter()
                            .min_by(|a, b| {
                                shadow_busy[a.engine as usize]
                                    .total_cmp(&shadow_busy[b.engine as usize])
                            })
                            .expect("static op has a slot")
                    };
                    shadow_busy[slot.engine as usize] += lat_mvm;
                    records[slot.engine as usize].push(LaneRecord::Mvm {
                        crossbar: slot.crossbar,
                        read_rows: op.read_rows,
                    });
                    static_ops += 1;
                } else {
                    let rank = op.pattern_rank as usize;
                    let hit = if config.dynamic_reuse {
                        let k = dyn_dir[rank];
                        (k != NONE && !retired[k as usize]).then_some(k as usize)
                    } else {
                        None
                    };
                    let k = match hit {
                        Some(k) => {
                            dynamic_hits += 1;
                            k
                        }
                        None => {
                            let pattern = plan.pattern_of_rank(op.pattern_rank);
                            // Retire-then-repick, mirrored from the
                            // interpreter: the shadow crossbar absorbs the
                            // same configure the lane will replay, so the
                            // wear decision and the replayed wear agree.
                            loop {
                                let k = policy.pick(&retired).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "all dynamic crossbars retired (wear-out)"
                                    )
                                })?;
                                let (ei, cb) = slot_pos(config, k);
                                let old = slot_rank[k];
                                if old != NONE {
                                    dyn_dir[old as usize] = NONE;
                                    slot_rank[k] = NONE;
                                }
                                shadow[k].configure(pattern);
                                records[ei].push(LaneRecord::Configure {
                                    crossbar: cb as u32,
                                    rank: op.pattern_rank,
                                });
                                if shadow[k].worn_out(params.endurance_cycles) {
                                    retired[k] = true;
                                    continue;
                                }
                                slot_rank[k] = rank as u32;
                                dyn_dir[rank] = k as u32;
                                break k;
                            }
                        }
                    };
                    let (ei, cb) = slot_pos(config, k);
                    records[ei].push(LaneRecord::Mvm {
                        crossbar: cb as u32,
                        read_rows: op.rows,
                    });
                    policy.touch(k);
                    dynamic_ops += 1;
                }
                sup_ops.push((start + off) as u32);
            }
            if ops_in_group == 0 {
                continue;
            }
            iterations += 1;
            sys_counts.main_mem_accesses += 2 * ops_in_group.div_ceil(16);
        }

        // --- phase 2: parallel lane replay, engine-ordered timing merge ---
        exec_time_ns += replay_lanes(
            &mut engines,
            &records,
            &mut scratch,
            plan,
            params,
            lat_mvm,
            &mut mode,
        );

        if sup_ops.is_empty() {
            break;
        }

        // --- phase 3: numeric — gather, chunked edge compute, reduce ---
        // Gather and reduce/apply are the interpreter's own helpers:
        // identical numeric semantics by construction, not by mirroring.
        gather_sources(plan, program, kind, &snapshot, outdeg, &sup_ops, &mut xs);
        run_numeric(
            executor,
            kind,
            plan,
            &sup_ops,
            &xs,
            &mut cand,
            &mut scratch.chunk_bufs,
            &mut mode,
        )?;
        let any_changed = reduce_apply(
            plan,
            program,
            semiring,
            &sup_ops,
            &cand,
            &mut values,
            &mut acc,
            &mut active_block,
            &mut next_active_block,
        );

        supersteps = superstep + 1;
        if !program.post_superstep(superstep, &mut values, &mut acc, any_changed) {
            break;
        }
    }

    // --- final accounting: engines reassemble into summaries ---
    let mut counts = sys_counts;
    let mut summaries = Vec::with_capacity(engines.len());
    let mut max_dyn_writes = 0u32;
    for e in &engines {
        let e = e.as_ref().expect("engine present");
        counts.add(&e.counts);
        if e.kind == EngineKind::Dynamic {
            max_dyn_writes = max_dyn_writes.max(e.max_cell_writes());
        }
        summaries.push(EngineSummary::of(e));
    }
    counts.subtract(&counts_baseline);

    Ok(RunResult {
        values,
        counts,
        init_counts,
        exec_time_ns,
        init_time_ns,
        supersteps,
        iterations,
        static_ops,
        dynamic_ops,
        dynamic_hits,
        max_dynamic_cell_writes: max_dyn_writes,
        engines: summaries,
        activity: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Bfs, PageRank, Wcc};
    use crate::graph::coo::{Coo, Edge};
    use crate::graph::datasets::Dataset;
    use crate::pattern::extract::partition;
    use crate::pattern::rank::PatternRanking;
    use crate::pattern::tables::{ConfigTable, StaticAssignment, SubgraphTable};
    use crate::sched::executor::NativeExecutor;

    fn plan_for(g: &Coo, config: &ArchConfig, weighted: bool) -> ExecutionPlan {
        let part = partition(g, config.crossbar_size, weighted);
        let ranking = PatternRanking::from_partitioned(&part);
        let ct = ConfigTable::build(
            &ranking,
            config.crossbar_size,
            config.static_engines,
            config.crossbars_per_engine,
            config.dynamic_engines() * config.crossbars_per_engine,
            config.static_assignment,
        );
        let st = SubgraphTable::build(&part, &ranking, config.order);
        ExecutionPlan::build(&part, &ct, &st, config)
    }

    fn assert_same(a: &RunResult, b: &RunResult, ctx: &str) {
        assert_eq!(a.values, b.values, "{ctx}: values");
        assert_eq!(a.counts, b.counts, "{ctx}: counts");
        assert_eq!(a.init_counts, b.init_counts, "{ctx}: init counts");
        assert_eq!(a.exec_time_ns, b.exec_time_ns, "{ctx}: exec time");
        assert_eq!(a.init_time_ns, b.init_time_ns, "{ctx}: init time");
        assert_eq!(a.supersteps, b.supersteps, "{ctx}: supersteps");
        assert_eq!(a.iterations, b.iterations, "{ctx}: iterations");
        assert_eq!(a.static_ops, b.static_ops, "{ctx}: static ops");
        assert_eq!(a.dynamic_ops, b.dynamic_ops, "{ctx}: dynamic ops");
        assert_eq!(a.dynamic_hits, b.dynamic_hits, "{ctx}: dynamic hits");
        assert_eq!(
            a.max_dynamic_cell_writes, b.max_dynamic_cell_writes,
            "{ctx}: wear"
        );
        assert_eq!(a.engines, b.engines, "{ctx}: engine summaries");
    }

    fn run_both(
        g: &Coo,
        config: &ArchConfig,
        program: &dyn VertexProgram,
        threads: usize,
    ) -> (RunResult, RunResult) {
        let params = CostParams::default();
        let plan = plan_for(g, config, program.needs_weights());
        let seq = Scheduler::new(config, &params, &plan)
            .run(program, &mut NativeExecutor)
            .unwrap();
        let par =
            run_parallel(config, &params, &plan, program, &mut NativeExecutor, threads)
                .unwrap();
        (seq, par)
    }

    #[test]
    fn lane_assignment_is_deterministic_and_balanced() {
        // Seeding then greedy: e0→l0, e1→l1, then each next engine to the
        // lighter lane (ties to lane 0).
        assert_eq!(lane_assignment(&[5, 1, 1, 1, 5], 2), vec![0, 1, 1, 1, 1]);
        // Never more lanes than engines; single engine → single lane.
        assert_eq!(lane_assignment(&[3], 8), vec![0]);
        // Every lane gets seeded before balancing kicks in.
        assert_eq!(lane_assignment(&[1, 1, 1], 3), vec![0, 1, 2]);
        // Deterministic: same input, same output.
        assert_eq!(lane_assignment(&[2, 2, 2, 2], 2), lane_assignment(&[2, 2, 2, 2], 2));
    }

    #[test]
    fn zero_dynamic_engines_all_ops_static() {
        // Every pattern pinned (TopK, capacity >= patterns) and not a
        // single dynamic engine: the dispatch pass must never touch the
        // (empty) dynamic state and lanes carry only MVM records.
        let g = Dataset::Tiny.load().unwrap();
        let part = partition(&g, 4, false);
        let patterns = PatternRanking::from_partitioned(&part).num_patterns() as u32;
        let config = ArchConfig {
            total_engines: patterns,
            static_engines: patterns,
            static_assignment: StaticAssignment::TopK,
            ..ArchConfig::default()
        };
        let (seq, par) = run_both(&g, &config, &Bfs::new(0), 4);
        assert_same(&seq, &par, "zero dynamic engines");
        assert_eq!(par.dynamic_ops, 0);
        assert!(par.static_ops > 0);
    }

    #[test]
    fn more_threads_than_lanes_falls_back_to_available_engines() {
        // 2 engines, 16 requested lanes: at most 2 lanes may run; the
        // run must still be bit-identical.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig {
            total_engines: 2,
            static_engines: 1,
            ..ArchConfig::default()
        };
        let (seq, par) = run_both(&g, &config, &Bfs::new(0), 16);
        assert_same(&seq, &par, "threads > lanes");
    }

    #[test]
    fn only_dynamic_ops_superstep() {
        // All-dynamic architecture: every superstep's lanes are pure
        // replacement-policy traffic.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig {
            static_engines: 0,
            total_engines: 8,
            ..ArchConfig::default()
        };
        let (seq, par) = run_both(&g, &config, &Wcc, 4);
        assert_same(&seq, &par, "only dynamic ops");
        assert_eq!(par.static_ops, 0);
        assert!(par.dynamic_ops > 0);
    }

    #[test]
    fn empty_frontier_terminates_without_idle_lanes() {
        // Source with no out-edges: the first superstep has an empty
        // frontier, so no lane work is submitted and the run ends after
        // at most one superstep — identically to the sequential path.
        let g = Coo::from_edges(8, vec![Edge::new(1, 2)]);
        let config = ArchConfig::default();
        let (seq, par) = run_both(&g, &config, &Bfs::new(7), 4);
        assert_same(&seq, &par, "empty frontier");
        assert!(par.supersteps <= 1);
        assert_eq!(par.values[7], 0.0);
    }

    #[test]
    fn pagerank_sum_prod_path_is_identical() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let (seq, par) = run_both(&g, &config, &PageRank::new(0.85, 6), 8);
        assert_same(&seq, &par, "pagerank");
        assert_eq!(par.supersteps, 6);
    }

    #[test]
    fn scoped_and_pooled_mechanisms_agree() {
        // The retained scoped baseline and the pooled production path
        // must stay interchangeable bit for bit — on a fresh pool and on
        // a pool reused across consecutive runs.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let params = CostParams::default();
        for program in [&PageRank::new(0.85, 5) as &dyn VertexProgram, &Wcc] {
            let plan = plan_for(&g, &config, false);
            let seq = Scheduler::new(&config, &params, &plan)
                .run(program, &mut NativeExecutor)
                .unwrap();
            let scoped = run_parallel_scoped(
                &config, &params, &plan, program, &mut NativeExecutor, 4,
            )
            .unwrap();
            assert_same(&seq, &scoped, "scoped vs sequential");
            let mut pool = WorkerPool::new(4);
            for round in 0..2 {
                let pooled = run_parallel_pooled(
                    &config, &params, &plan, program, &mut NativeExecutor, &mut pool,
                )
                .unwrap();
                assert_same(&seq, &pooled, &format!("pooled round {round}"));
            }
            // A lane cap below the pool size uses fewer lanes of the same
            // workers — still bit-identical, no respawn.
            let ids = pool.worker_ids();
            for cap in [1usize, 2, 16] {
                let capped = run_parallel_pooled_at(
                    &config, &params, &plan, program, &mut NativeExecutor, &mut pool, cap,
                )
                .unwrap();
                assert_same(&seq, &capped, &format!("pooled cap {cap}"));
            }
            assert_eq!(pool.worker_ids(), ids, "caps never respawn workers");
        }
    }

    #[test]
    fn tracing_runs_take_the_sequential_path() {
        // The activity trace needs per-group engine snapshots, so a
        // tracing run delegates to the interpreter even with threads > 1
        // — on both the transient and the persistent-pool entry points.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::fig5();
        let params = CostParams::default();
        let plan = plan_for(&g, &config, false);
        let par = run_parallel(&config, &params, &plan, &Bfs::new(0), &mut NativeExecutor, 4)
            .unwrap();
        let trace = par.activity.expect("trace recorded via the sequential path");
        assert_eq!(trace.num_engines, 6);
        let seq = Scheduler::new(&config, &params, &plan)
            .run(&Bfs::new(0), &mut NativeExecutor)
            .unwrap();
        assert_same(&seq, &par, "tracing delegation");
        let mut pool = WorkerPool::new(4);
        let pooled = run_parallel_pooled(
            &config, &params, &plan, &Bfs::new(0), &mut NativeExecutor, &mut pool,
        )
        .unwrap();
        assert!(pooled.activity.is_some(), "pooled tracing delegates too");
    }

    #[test]
    fn wearout_error_matches_sequential() {
        // Endurance 1 with one dynamic slot: the dispatch pass must fail
        // exactly like the interpreter's retire-then-repick.
        let g = Coo::from_edges(4, vec![Edge::new(0, 1)]);
        let config = ArchConfig {
            crossbar_size: 2,
            total_engines: 1,
            static_engines: 0,
            ..ArchConfig::default()
        };
        let params = CostParams { endurance_cycles: 1.0, ..CostParams::default() };
        let plan = plan_for(&g, &config, false);
        let err =
            run_parallel(&config, &params, &plan, &Bfs::new(0), &mut NativeExecutor, 4)
                .unwrap_err();
        assert!(err.to_string().contains("retired"), "{err}");
    }

    #[test]
    fn resolve_threads_maps_zero_to_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
