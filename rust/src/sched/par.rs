//! Batch-parallel superstep execution over the compiled [`ExecutionPlan`]
//! — the inter-engine parallelism the plan IR was built to unlock.
//!
//! The paper's premise makes the static lanes embarrassingly parallel:
//! static engines hold the frequent patterns, so most subgraph ops touch
//! exactly one engine and share no state with any other engine. This
//! module exploits that with a three-phase superstep:
//!
//! 1. **Dispatch** (sequential, cheap): walk the ready ops in plan order
//!    and resolve every scheduling decision into per-engine work lanes.
//!    Single-replica static ops come pre-homed by the plan's
//!    [`LaneTable`](super::plan::LaneTable); multi-replica static ops take
//!    the least-busy replica against a shadow busy model that replays the
//!    interpreter's f64 accumulation bit-exactly; dynamic ops run the
//!    replacement policy (plus retire-then-repick wear-out) against
//!    dispatcher-owned shadow crossbars.
//! 2. **Lane replay** (parallel): engines move into lanes — each worker
//!    owns whole engines and replays their queued records (configure /
//!    MVM counter arithmetic, crossbar wear) in dispatch order. An
//!    engine's entire queue lives in one lane, so all engine-local state
//!    stays thread-local.
//! 3. **Numeric phase**: the gather runs on the calling thread (an
//!    indexed copy through the plan's
//!    [`GatherTable`](super::plan::GatherTable)), then the edge-compute
//!    batch is chunked across executor forks when the backend supports
//!    it. Per-op outputs are independent, so any chunking is
//!    bit-identical to one sequential call.
//!
//! # Execution mechanisms: pooled (production) vs scoped (baseline)
//!
//! Phases 2 and 3 run on one of two mechanisms behind the same dispatch
//! pass:
//!
//! * **Pooled** — a persistent [`WorkerPool`] (channel-fed, spawned once,
//!   owned by the `Session` or transiently per run): zero thread spawns
//!   and zero steady-state allocation per superstep. This is the
//!   production path; [`run_parallel`] routes here.
//! * **Scoped** — the pre-pool `std::thread::scope` baseline
//!   ([`run_parallel_scoped`]), which pays a spawn/join per superstep.
//!   Kept so `benches/hotpath.rs` can report the pool's win and the test
//!   suite can differential-check both mechanisms forever.
//!
//! Both produce bit-identical `RunResult`s by construction: the dispatch
//! pass is shared and merges are index-ordered (see below).
//!
//! # Why dynamic ops shard by pattern rank / slot, not round-robin
//!
//! A dynamic op's lane is the engine owning the crossbar slot that the
//! replacement policy binds its pattern rank to. That keeps
//! *crossbar-content affinity*: every configure and MVM touching one
//! crossbar — the pattern it currently holds, its per-cell wear counters
//! — replays inside a single lane, in dispatch order, so no crossbar
//! state ever crosses a thread boundary. A fully rank-sharded scheme
//! (one lane per rank, policy state split per lane) cannot reproduce the
//! sequential semantics: the replacement policy is *global* across
//! dynamic slots (an LRU pick for rank A evicts the slot rank B counts
//! on), which is exactly why the *decisions* stay in the sequential
//! dispatch pass and only slot-affine *effects* fan out.
//!
//! # The bit-identical merge invariant
//!
//! Merge order is lane-indexed, then engine-indexed: lane results are
//! joined in lane order (pool replies are collected in worker-index
//! order, which is lane order) and folded back into the engine vector by
//! engine id, and the superstep latency is the max over per-engine busy
//! times folded in engine-id order — the same order the sequential
//! interpreter uses. Combined with the bit-exact dispatch shadow, a run's
//! [`RunResult`] (values, `EventCounts`, timing, wear, per-engine
//! summaries) is **bit-identical for every thread count and both
//! mechanisms**, and identical to [`Scheduler::run`] and to the
//! differential oracle
//! [`oracle::run_reference`](super::oracle::run_reference) —
//! `rust/tests/parallel.rs` locks this down over randomized graphs and
//! all four algorithms. The invariant is what makes the concurrent
//! scheduler safe to evolve: any divergence is a bug by definition, not
//! a tolerance question.
//!
//! The sequential interpreter remains the `threads <= 1` path; runs that
//! record the per-iteration activity trace (Fig. 5) also take it, since
//! the trace wants per-group engine snapshots the deferred lane replay
//! does not produce.

use anyhow::Result;

use crate::accel::config::ArchConfig;
use crate::algo::traits::{Semiring, VertexProgram, INF};
use crate::cost::{CostParams, EventCounts};
use crate::engine::{Crossbar, EngineKind, GraphEngine};

use super::executor::StepExecutor;
use super::plan::ExecutionPlan;
use super::pool::{LaneSlot, WorkerPool};
use super::replacement::{build_policy, ReplacementPolicy};
use super::scheduler::{
    gather_sources, reduce_apply, slot_pos, EngineSummary, RunResult, Scheduler, NONE,
};

/// Below this many queued records a superstep replays inline: even a
/// pooled channel round-trip costs more than the counter arithmetic it
/// would parallelize. Lane assignment never affects results (per-engine
/// state is self-contained), so this is purely a throughput threshold.
const MIN_PARALLEL_RECORDS: usize = 512;

/// Below this many ops the numeric batch runs on the calling thread for
/// the same reason. Chunking is bit-exact at any size, so the threshold
/// is free to change.
const MIN_PARALLEL_NUMERIC_OPS: usize = 256;

/// One queued effect on an engine, replayed by its lane in dispatch
/// order. Records carry rank indices, not `Pattern`s — the lane resolves
/// them through the shared plan.
#[derive(Debug, Clone, Copy)]
pub(crate) enum LaneRecord {
    /// Reconfigure crossbar `crossbar` to the pattern of `rank`.
    Configure { crossbar: u32, rank: u32 },
    /// One in-situ MVM against `crossbar` reading `read_rows` wordlines.
    Mvm { crossbar: u32, read_rows: u32 },
}

/// Resolve a requested thread count: `0` means one lane per available
/// hardware thread. The one shared helper behind `--threads`,
/// `SessionBuilder::parallelism`, `ServiceConfig.parallelism` and the
/// test harness's `REPRO_THREADS` — results never depend on the resolved
/// value.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// The pool a pooled run executes on: a caller-owned persistent pool,
/// or a transient one spawned **lazily** — a run whose supersteps all
/// stay under the inline thresholds never spawns a thread at all, same
/// as the scoped baseline.
pub(crate) enum PoolRef<'p> {
    Borrowed(&'p mut WorkerPool),
    Lazy { threads: usize, pool: Option<WorkerPool> },
}

impl PoolRef<'_> {
    pub(crate) fn get(&mut self) -> &mut WorkerPool {
        match self {
            PoolRef::Borrowed(pool) => pool,
            PoolRef::Lazy { threads, pool } => {
                pool.get_or_insert_with(|| WorkerPool::new(*threads))
            }
        }
    }
}

/// How phases 2/3 execute. The dispatch pass is identical either way —
/// see the module docs.
pub(crate) enum LaneMode<'p> {
    /// Per-superstep `std::thread::scope` spawns (the pre-pool baseline,
    /// kept for benches and differential tests).
    Scoped { threads: usize },
    /// Persistent channel-fed workers — zero per-superstep spawns.
    /// `threads` caps the lanes actually used (≤ the pool's workers), so
    /// a per-job override smaller than the pool is honored.
    Pooled { pool: PoolRef<'p>, threads: usize },
}

impl LaneMode<'_> {
    pub(crate) fn threads(&self) -> usize {
        match self {
            LaneMode::Scoped { threads } | LaneMode::Pooled { threads, .. } => *threads,
        }
    }
}

/// Run-lifetime scratch for phases 2/3: everything here is allocated
/// once per run (plan-/engine-sized) and only cleared per superstep, so
/// the steady-state hot loop performs no heap allocation.
pub(crate) struct Scratch {
    /// Engine indices with queued records this superstep.
    active: Vec<usize>,
    /// Queued record count per active engine (parallel to `active`).
    loads: Vec<usize>,
    /// Lane index per active engine (parallel to `active`).
    assignment: Vec<usize>,
    /// Greedy-balancer accumulator, one entry per lane.
    lane_load: Vec<usize>,
    /// Per-engine busy time of the current superstep (engine-id order).
    busy_by_engine: Vec<f64>,
    /// Pooled replay: one reusable lane buffer per worker.
    lane_bufs: Vec<Vec<LaneSlot>>,
    /// Pooled numeric: one reusable output buffer per worker,
    /// double-buffered through the pool's channels.
    pub(crate) chunk_bufs: Vec<Vec<f32>>,
}

impl Scratch {
    pub(crate) fn new(n_engines: usize, workers: usize) -> Self {
        Self {
            active: Vec::with_capacity(n_engines),
            loads: Vec::with_capacity(n_engines),
            assignment: Vec::with_capacity(n_engines),
            lane_load: Vec::with_capacity(workers),
            busy_by_engine: vec![0f64; n_engines],
            lane_bufs: (0..workers).map(|_| Vec::new()).collect(),
            chunk_bufs: (0..workers).map(|_| Vec::new()).collect(),
        }
    }
}

/// Deterministic greedy lane assignment into `out`: engines (ascending
/// id) go to the least-loaded lane, ties to the lowest lane index.
/// `loads[i]` is the queued record count of the i-th active engine. With
/// `n_lanes >= 1` and at least one engine, every lane
/// `0..min(n_lanes, loads.len())` receives work — no idle lanes.
fn lane_assignment_into(
    loads: &[usize],
    n_lanes: usize,
    lane_load: &mut Vec<usize>,
    out: &mut Vec<usize>,
) {
    let n_lanes = n_lanes.min(loads.len()).max(1);
    lane_load.clear();
    lane_load.resize(n_lanes, 0);
    out.clear();
    for (i, &load) in loads.iter().enumerate() {
        let lane = if i < n_lanes {
            i // seed each lane before balancing
        } else {
            (0..n_lanes).min_by_key(|&l| lane_load[l]).unwrap()
        };
        lane_load[lane] += load;
        out.push(lane);
    }
}

#[cfg(test)]
fn lane_assignment(loads: &[usize], n_lanes: usize) -> Vec<usize> {
    let (mut lane_load, mut out) = (Vec::new(), Vec::new());
    lane_assignment_into(loads, n_lanes, &mut lane_load, &mut out);
    out
}

/// Replay one engine's queued records in dispatch order. Shared by the
/// inline path, the scoped baseline, and the pool workers.
pub(crate) fn replay_engine(
    e: &mut GraphEngine,
    records: &[LaneRecord],
    plan: &ExecutionPlan,
    params: &CostParams,
    lat_mvm: f64,
) {
    for r in records {
        match *r {
            LaneRecord::Configure { crossbar, rank } => {
                e.configure(crossbar as usize, plan.pattern_of_rank(rank), params);
            }
            LaneRecord::Mvm { crossbar, read_rows } => {
                e.mvm_precomputed(crossbar as usize, read_rows as u64, lat_mvm);
            }
        }
    }
}

/// Phase 2: move record-bearing engines into lanes, replay them on the
/// mode's workers, and merge busy times back in engine-id order. Returns
/// the superstep's max busy (ns). Falls back to an inline replay — no
/// channel round-trip, no spawns — when a single lane would do all the
/// work.
#[allow(clippy::too_many_arguments)]
pub(crate) fn replay_lanes(
    engines: &mut [Option<GraphEngine>],
    records: &[Vec<LaneRecord>],
    scratch: &mut Scratch,
    plan: &ExecutionPlan,
    params: &CostParams,
    lat_mvm: f64,
    mode: &mut LaneMode<'_>,
) -> f64 {
    scratch.active.clear();
    scratch.loads.clear();
    for (e, recs) in records.iter().enumerate() {
        if !recs.is_empty() {
            scratch.active.push(e);
            scratch.loads.push(recs.len());
        }
    }
    if scratch.active.is_empty() {
        return 0.0;
    }
    let total_records: usize = scratch.loads.iter().sum();
    let n_lanes = if total_records < MIN_PARALLEL_RECORDS {
        1
    } else {
        mode.threads().min(scratch.active.len())
    };
    scratch.busy_by_engine.iter_mut().for_each(|b| *b = 0.0);
    if n_lanes <= 1 {
        for &e in &scratch.active {
            let eng = engines[e].as_mut().expect("engine present");
            replay_engine(eng, &records[e], plan, params, lat_mvm);
            let (busy, _) = eng.end_iteration();
            scratch.busy_by_engine[e] = busy;
        }
    } else {
        lane_assignment_into(
            &scratch.loads,
            n_lanes,
            &mut scratch.lane_load,
            &mut scratch.assignment,
        );
        match mode {
            LaneMode::Pooled { pool, .. } => {
                let pool = pool.get();
                let lanes = &mut scratch.lane_bufs[..n_lanes];
                for (i, &e) in scratch.active.iter().enumerate() {
                    lanes[scratch.assignment[i]].push((
                        e,
                        engines[e].take().expect("engine present"),
                        0.0,
                    ));
                }
                pool.replay(lanes, records, plan, params, lat_mvm);
                // Lane- then engine-ordered merge (lanes arrive back in
                // worker == lane order).
                for lane in lanes.iter_mut() {
                    for (e, eng, busy) in lane.drain(..) {
                        scratch.busy_by_engine[e] = busy;
                        engines[e] = Some(eng);
                    }
                }
            }
            LaneMode::Scoped { .. } => {
                let mut lanes: Vec<Vec<(usize, GraphEngine)>> =
                    (0..n_lanes).map(|_| Vec::new()).collect();
                for (i, &e) in scratch.active.iter().enumerate() {
                    lanes[scratch.assignment[i]]
                        .push((e, engines[e].take().expect("engine present")));
                }
                let lane_results: Vec<Vec<(usize, GraphEngine, f64)>> =
                    std::thread::scope(|s| {
                        let handles: Vec<_> = lanes
                            .into_iter()
                            .map(|lane| {
                                s.spawn(move || {
                                    lane.into_iter()
                                        .map(|(e, mut eng)| {
                                            replay_engine(
                                                &mut eng, &records[e], plan, params, lat_mvm,
                                            );
                                            let (busy, _) = eng.end_iteration();
                                            (e, eng, busy)
                                        })
                                        .collect()
                                })
                            })
                            .collect();
                        // Merge in lane order — deterministic by construction.
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("lane worker panicked"))
                            .collect()
                    });
                for lane in lane_results {
                    for (e, eng, busy) in lane {
                        scratch.busy_by_engine[e] = busy;
                        engines[e] = Some(eng);
                    }
                }
            }
        }
    }
    // Engine-id fold order matches the sequential interpreter.
    scratch.busy_by_engine.iter().fold(0f64, |a, &b| a.max(b))
}

/// Phase 3: edge compute, chunked across executor forks when the backend
/// supports concurrent evaluation; otherwise one sequential call on
/// `executor`. Chunk boundaries never affect the result — each op's
/// output lanes are an independent pure function of its operands.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_numeric(
    executor: &mut dyn StepExecutor,
    kind: crate::algo::traits::StepKind,
    plan: &ExecutionPlan,
    sup_ops: &[u32],
    xs: &[f32],
    cand: &mut Vec<f32>,
    chunk_bufs: &mut [Vec<f32>],
    mode: &mut LaneMode<'_>,
) -> Result<()> {
    let c = plan.c;
    let threads = mode.threads();
    if threads <= 1 || sup_ops.len() < MIN_PARALLEL_NUMERIC_OPS.max(2 * threads) {
        return executor.execute(kind, plan.batch(sup_ops), xs, cand);
    }
    let chunk = sup_ops.len().div_ceil(threads);
    match mode {
        LaneMode::Pooled { pool, .. } => {
            let pool = pool.get();
            // Workers keep their forks across supersteps and runs —
            // `ensure_forks` is a cached no-op after the first superstep.
            if !pool.ensure_forks(executor) {
                // Stateful backend (PJRT): the numeric phase stays
                // sequential.
                return executor.execute(kind, plan.batch(sup_ops), xs, cand);
            }
            pool.execute_chunks(kind, plan, sup_ops, 1, xs, chunk, chunk_bufs, cand)
        }
        LaneMode::Scoped { .. } => {
            let n_chunks = sup_ops.len().div_ceil(chunk);
            let mut forks: Vec<Box<dyn StepExecutor + Send>> =
                Vec::with_capacity(n_chunks);
            for _ in 0..n_chunks {
                match executor.fork() {
                    Some(f) => forks.push(f),
                    None => return executor.execute(kind, plan.batch(sup_ops), xs, cand),
                }
            }
            let outputs: Vec<Result<Vec<f32>>> = std::thread::scope(|s| {
                let handles: Vec<_> = sup_ops
                    .chunks(chunk)
                    .zip(xs.chunks(chunk * c))
                    .zip(forks.into_iter())
                    .map(|((ops_chunk, xs_chunk), mut exec)| {
                        s.spawn(move || {
                            let mut out = Vec::new();
                            exec.execute(kind, plan.batch(ops_chunk), xs_chunk, &mut out)
                                .map(|_| out)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("numeric worker panicked"))
                    .collect()
            });
            cand.clear();
            cand.reserve(sup_ops.len() * c);
            for out in outputs {
                cand.extend_from_slice(&out?);
            }
            Ok(())
        }
    }
}

/// Batched phase 3: the union op batch evaluated against `lanes`
/// interleaved per-job input vectors through the executor's
/// `execute_multi` surface, chunked across pool forks exactly like
/// [`run_numeric`]. `xs`/`cand` are op-major lane-interleaved (see
/// [`StepExecutor::execute_multi`]); chunk boundaries sit on op
/// boundaries, so every lane's per-op outputs are bit-identical to its
/// solo run regardless of chunking.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_numeric_multi(
    executor: &mut dyn StepExecutor,
    kind: crate::algo::traits::StepKind,
    plan: &ExecutionPlan,
    union_ops: &[u32],
    lanes: usize,
    xs: &[f32],
    cand: &mut Vec<f32>,
    chunk_bufs: &mut [Vec<f32>],
    mode: &mut LaneMode<'_>,
) -> Result<()> {
    let threads = mode.threads();
    if threads <= 1 || union_ops.len() < MIN_PARALLEL_NUMERIC_OPS.max(2 * threads) {
        return executor.execute_multi(kind, plan.batch(union_ops), lanes, xs, cand);
    }
    let chunk = union_ops.len().div_ceil(threads);
    match mode {
        LaneMode::Pooled { pool, .. } => {
            let pool = pool.get();
            if !pool.ensure_forks(executor) {
                // Stateful backend (PJRT): batched numerics stay on the
                // calling thread, same as the solo path.
                return executor.execute_multi(kind, plan.batch(union_ops), lanes, xs, cand);
            }
            pool.execute_chunks(kind, plan, union_ops, lanes, xs, chunk, chunk_bufs, cand)
        }
        // The batch driver always runs pooled; an inline call keeps the
        // scoped arm correct anyway (bit-identical at any chunking).
        LaneMode::Scoped { .. } => {
            executor.execute_multi(kind, plan.batch(union_ops), lanes, xs, cand)
        }
    }
}

/// Run `program` to convergence with `threads` execution lanes.
///
/// `threads <= 1` (and any run recording the activity trace) takes the
/// sequential interpreter verbatim; `threads == 0` resolves to the
/// available hardware parallelism. Otherwise a **transient**
/// [`WorkerPool`] serves the run — one spawn set per run, never per
/// superstep. Callers that run repeatedly should hold a persistent pool
/// and use [`run_parallel_pooled`] (the `Session` does exactly that).
/// Results are bit-identical to [`Scheduler::run`] for every thread
/// count — see the module docs for the invariant and
/// `rust/tests/parallel.rs` for the lockdown.
pub fn run_parallel(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    threads: usize,
) -> Result<RunResult> {
    let threads = resolve_threads(threads);
    if threads <= 1 || config.trace_activity {
        return Scheduler::new(config, params, plan).run(program, executor);
    }
    // Lazy: a run that never crosses the parallel thresholds spawns no
    // thread at all, matching the old scoped behavior on tiny workloads.
    run_pipeline(
        config,
        params,
        plan,
        program,
        executor,
        LaneMode::Pooled { pool: PoolRef::Lazy { threads, pool: None }, threads },
    )
}

/// Run `program` on a caller-owned persistent [`WorkerPool`] — the
/// zero-spawn production path. The pool's worker count is the lane
/// count; a one-worker pool (and any tracing run) delegates to the
/// sequential interpreter.
pub fn run_parallel_pooled(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    pool: &mut WorkerPool,
) -> Result<RunResult> {
    let workers = pool.workers();
    run_parallel_pooled_at(config, params, plan, program, executor, pool, workers)
}

/// Like [`run_parallel_pooled`] but capping the lane count at `threads`
/// (`0` = auto): a per-job override smaller than the pool uses fewer
/// lanes of the same workers; larger requests clamp to the pool size.
/// An effective lane count of 1 (and any tracing run) delegates to the
/// sequential interpreter. Bit-identical for every cap, as always.
pub fn run_parallel_pooled_at(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    pool: &mut WorkerPool,
    threads: usize,
) -> Result<RunResult> {
    let threads = resolve_threads(threads).min(pool.workers());
    if threads <= 1 || config.trace_activity {
        return Scheduler::new(config, params, plan).run(program, executor);
    }
    run_pipeline(
        config,
        params,
        plan,
        program,
        executor,
        LaneMode::Pooled { pool: PoolRef::Borrowed(pool), threads },
    )
}

/// Run a batch of programs over **one shared plan** on a caller-owned
/// persistent pool, amortizing the per-superstep plan walk, the pool
/// checkout, and per-op operand decode across all of them — the serve
/// tier's multi-job batch formation rides this.
///
/// Every program must drive the same [`StepKind`](crate::algo::traits::StepKind)
/// (the service's `batch_key` guarantees it; enforced here). Per-job
/// *state* — engines, crossbar shadows, replacement policy, vertex
/// values, frontiers, every counter — is fully replicated, so each job's
/// scheduling decisions are exactly the decisions its solo run makes;
/// only the plan traversal and the numeric evaluation are shared.
/// Result: element `i` of the returned vector is **bit-identical** to
/// `run_parallel_pooled_at` on `programs[i]` alone, for every batch
/// composition, thread count, and mechanism (the batch determinism
/// contract; locked down by the in-module tests and
/// `rust/tests/serve.rs`).
///
/// `threads <= 1`, tracing runs, and single-program batches delegate to
/// the solo path per program.
pub fn run_parallel_pooled_batch(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    programs: &[&dyn VertexProgram],
    executor: &mut dyn StepExecutor,
    pool: &mut WorkerPool,
    threads: usize,
) -> Result<Vec<RunResult>> {
    anyhow::ensure!(!programs.is_empty(), "empty program batch");
    let threads = resolve_threads(threads).min(pool.workers());
    if programs.len() == 1 || threads <= 1 || config.trace_activity {
        return programs
            .iter()
            .map(|p| run_parallel_pooled_at(config, params, plan, *p, executor, pool, threads))
            .collect();
    }
    run_pipeline_batch(
        config,
        params,
        plan,
        programs,
        executor,
        LaneMode::Pooled { pool: PoolRef::Borrowed(pool), threads },
    )
}

/// Per-job replicated state for the batched pipeline: everything the
/// solo [`run_pipeline`] keeps as locals, one copy per job, so no
/// scheduling decision or hardware-model effect can leak between jobs.
struct BatchJob<'a> {
    program: &'a dyn VertexProgram,
    semiring: Semiring,
    all_blocks: bool,
    max_supersteps: usize,
    engines: Vec<Option<GraphEngine>>,
    policy: Box<dyn ReplacementPolicy>,
    dyn_dir: Vec<u32>,
    slot_rank: Vec<u32>,
    retired: Vec<bool>,
    shadow: Vec<Crossbar>,
    shadow_busy: Vec<f64>,
    values: Vec<f32>,
    snapshot: Vec<f32>,
    acc: Vec<f32>,
    active_block: Vec<bool>,
    next_active_block: Vec<bool>,
    records: Vec<Vec<LaneRecord>>,
    sup_ops: Vec<u32>,
    xs: Vec<f32>,
    cand: Vec<f32>,
    init_counts: EventCounts,
    counts_baseline: EventCounts,
    init_time_ns: f64,
    exec_time_ns: f64,
    sys_counts: EventCounts,
    iterations: u64,
    static_ops: u64,
    dynamic_ops: u64,
    dynamic_hits: u64,
    supersteps: usize,
    /// Per-group dispatch accumulator (reset at each group boundary).
    ops_in_group: u64,
    /// The job's main loop has exited (empty frontier, `post_superstep`
    /// false, or its superstep budget ran out).
    done: bool,
}

/// The batched three-phase pipeline. Structure per superstep:
///
/// 1. **Dispatch** — ONE op-major plan walk (`for group, for op, for
///    live job`): each live job makes its own decisions against its own
///    shadows in the same op order as its solo dispatch, so the decision
///    sequence — and every resulting record — is identical to solo.
/// 2. **Lane replay** — per job on the shared scratch/mode (the lane
///    merge is per-engine state, so sharing workers is free).
/// 3. **Numeric** — the live jobs' `sup_ops` union into one sorted op
///    list; each job gathers its own inputs over the union, the lanes
///    interleave op-major, and one `execute_multi` pass evaluates every
///    (op, job) pair. Per-job candidates extract by a sorted two-pointer
///    walk; reduce/apply runs per job. Ops a job did not select are
///    computed and discarded for that lane — per-op outputs are
///    independent pure functions, so this cannot perturb its results.
fn run_pipeline_batch(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    programs: &[&dyn VertexProgram],
    executor: &mut dyn StepExecutor,
    mut mode: LaneMode<'_>,
) -> Result<Vec<RunResult>> {
    config.validate()?;
    anyhow::ensure!(
        plan.matches(config),
        "execution plan was compiled for a different architecture \
         (plan C={} N={} T={} M={})",
        plan.c,
        plan.static_engines,
        plan.total_engines,
        plan.crossbars_per_engine
    );
    let kind = programs[0].step_kind();
    for program in programs {
        anyhow::ensure!(
            program.step_kind() == kind,
            "batched programs must share one step kind ({:?} vs {:?})",
            program.step_kind(),
            kind
        );
        if program.needs_weights() {
            anyhow::ensure!(
                plan.weighted,
                "{} requires weighted partitioning",
                program.name()
            );
        }
    }
    let c = plan.c;
    let n = plan.num_vertices as usize;
    let num_blocks = plan.num_blocks as usize;
    let n_static = config.static_engines;
    let n_total = config.total_engines as usize;
    let m = config.crossbars_per_engine as usize;
    let n_dyn_slots = config.dynamic_engines() as usize * m;
    let outdeg = plan.out_degrees();
    let lane_tab = plan.lanes();
    let lat_mvm = crate::cost::timing::mvm_latency_ns(params, c as u32, c as u32)
        + crate::cost::timing::reduce_latency_ns(params, c as u32);

    // --- per-job initialization: the solo init, replicated verbatim ---
    let mut jobs: Vec<BatchJob<'_>> = Vec::with_capacity(programs.len());
    for &program in programs {
        let mut engines: Vec<Option<GraphEngine>> = (0..n_total)
            .map(|i| {
                let kind = if (i as u32) < n_static {
                    EngineKind::Static
                } else {
                    EngineKind::Dynamic
                };
                Some(GraphEngine::new(i as u32, kind, c, m as u32))
            })
            .collect();
        for &(slot, pattern) in plan.static_config() {
            engines[slot.engine as usize]
                .as_mut()
                .expect("engine present")
                .configure(slot.crossbar as usize, pattern, params);
        }
        let mut init_counts = EventCounts::default();
        let mut init_time_ns = 0f64;
        for e in engines.iter_mut() {
            let e = e.as_mut().expect("engine present");
            init_counts.add(&e.counts);
            let (busy, _) = e.end_iteration();
            init_time_ns = init_time_ns.max(busy);
        }
        let values = program.init(plan.num_vertices);
        anyhow::ensure!(values.len() == n, "program init length mismatch");
        let semiring = program.semiring();
        let acc = match semiring {
            Semiring::SumProd => vec![0f32; n],
            Semiring::MinPlus => Vec::new(),
        };
        let all_blocks = program.processes_all_blocks();
        let mut active_block = vec![false; num_blocks];
        if !all_blocks {
            for (v, &val) in values.iter().enumerate() {
                if val < INF {
                    active_block[v / c] = true;
                }
            }
        }
        jobs.push(BatchJob {
            program,
            semiring,
            all_blocks,
            max_supersteps: program.max_supersteps(),
            snapshot: values.clone(),
            values,
            acc,
            active_block,
            next_active_block: vec![false; num_blocks],
            policy: build_policy(config.policy, n_dyn_slots),
            dyn_dir: vec![NONE; plan.num_patterns as usize],
            slot_rank: vec![NONE; n_dyn_slots],
            retired: vec![false; n_dyn_slots],
            shadow: (0..n_dyn_slots).map(|_| Crossbar::new(c)).collect(),
            shadow_busy: vec![0f64; n_total],
            records: (0..n_total)
                .map(|e| Vec::with_capacity(lane_tab.fixed_ops_on(e as u32) as usize))
                .collect(),
            engines,
            sup_ops: Vec::new(),
            xs: Vec::new(),
            cand: Vec::new(),
            counts_baseline: init_counts,
            init_counts,
            init_time_ns,
            exec_time_ns: 0f64,
            sys_counts: EventCounts::default(),
            iterations: 0,
            static_ops: 0,
            dynamic_ops: 0,
            dynamic_hits: 0,
            supersteps: 0,
            ops_in_group: 0,
            done: false,
        });
    }

    let mut scratch = Scratch::new(n_total, mode.threads());
    let mut union_ops: Vec<u32> = Vec::new();
    let mut xs_all: Vec<f32> = Vec::new();
    let mut cand_all: Vec<f32> = Vec::new();
    let max_supersteps_all =
        jobs.iter().map(|j| j.max_supersteps).max().unwrap_or(0);

    for superstep in 0..max_supersteps_all {
        // A job whose own superstep budget ran out has exited its solo
        // loop — it just stops, with `supersteps` as already recorded.
        for job in jobs.iter_mut() {
            if superstep >= job.max_supersteps {
                job.done = true;
            }
        }
        if jobs.iter().all(|j| j.done) {
            break;
        }

        // --- phase 1: one plan walk, per-job decisions on isolated state ---
        for job in jobs.iter_mut().filter(|j| !j.done) {
            job.snapshot.copy_from_slice(&job.values);
            job.sup_ops.clear();
            for r in job.records.iter_mut() {
                r.clear();
            }
            job.shadow_busy.iter_mut().for_each(|b| *b = 0.0);
        }
        for g in 0..plan.num_groups() {
            let (start, end) = plan.group_bounds(g);
            for job in jobs.iter_mut().filter(|j| !j.done) {
                job.ops_in_group = 0;
            }
            for (off, op) in plan.ops[start..end].iter().enumerate() {
                for job in jobs.iter_mut().filter(|j| !j.done) {
                    if !job.all_blocks && !job.active_block[op.src_block as usize] {
                        continue;
                    }
                    job.ops_in_group += 1;
                    if op.is_static() {
                        let slots = plan.slots_of(op);
                        let slot = if lane_tab.home_of(start + off).is_some() {
                            slots[0]
                        } else {
                            *slots
                                .iter()
                                .min_by(|a, b| {
                                    job.shadow_busy[a.engine as usize]
                                        .total_cmp(&job.shadow_busy[b.engine as usize])
                                })
                                .expect("static op has a slot")
                        };
                        job.shadow_busy[slot.engine as usize] += lat_mvm;
                        job.records[slot.engine as usize].push(LaneRecord::Mvm {
                            crossbar: slot.crossbar,
                            read_rows: op.read_rows,
                        });
                        job.static_ops += 1;
                    } else {
                        let rank = op.pattern_rank as usize;
                        let hit = if config.dynamic_reuse {
                            let k = job.dyn_dir[rank];
                            (k != NONE && !job.retired[k as usize]).then_some(k as usize)
                        } else {
                            None
                        };
                        let k = match hit {
                            Some(k) => {
                                job.dynamic_hits += 1;
                                k
                            }
                            None => {
                                let pattern = plan.pattern_of_rank(op.pattern_rank);
                                loop {
                                    let k = job.policy.pick(&job.retired).ok_or_else(|| {
                                        anyhow::anyhow!(
                                            "all dynamic crossbars retired (wear-out)"
                                        )
                                    })?;
                                    let (ei, cb) = slot_pos(config, k);
                                    let old = job.slot_rank[k];
                                    if old != NONE {
                                        job.dyn_dir[old as usize] = NONE;
                                        job.slot_rank[k] = NONE;
                                    }
                                    job.shadow[k].configure(pattern);
                                    job.records[ei].push(LaneRecord::Configure {
                                        crossbar: cb as u32,
                                        rank: op.pattern_rank,
                                    });
                                    if job.shadow[k].worn_out(params.endurance_cycles) {
                                        job.retired[k] = true;
                                        continue;
                                    }
                                    job.slot_rank[k] = rank as u32;
                                    job.dyn_dir[rank] = k as u32;
                                    break k;
                                }
                            }
                        };
                        let (ei, cb) = slot_pos(config, k);
                        job.records[ei].push(LaneRecord::Mvm {
                            crossbar: cb as u32,
                            read_rows: op.rows,
                        });
                        job.policy.touch(k);
                        job.dynamic_ops += 1;
                    }
                    job.sup_ops.push((start + off) as u32);
                }
            }
            for job in jobs.iter_mut().filter(|j| !j.done) {
                if job.ops_in_group > 0 {
                    job.iterations += 1;
                    job.sys_counts.main_mem_accesses += 2 * job.ops_in_group.div_ceil(16);
                }
            }
        }

        // --- phase 2: per-job lane replay (engine state is per job) ---
        for job in jobs.iter_mut().filter(|j| !j.done) {
            job.exec_time_ns += replay_lanes(
                &mut job.engines,
                &job.records,
                &mut scratch,
                plan,
                params,
                lat_mvm,
                &mut mode,
            );
            if job.sup_ops.is_empty() {
                job.done = true;
            }
        }

        // --- phase 3: one batched numeric pass over the sup_ops union ---
        let lanes_n = jobs.iter().filter(|j| !j.done).count();
        if lanes_n == 0 {
            continue; // the all-done check at the loop top will break
        }
        if lanes_n == 1 {
            // Single survivor: take the solo phase 3 verbatim.
            let job = jobs.iter_mut().find(|j| !j.done).expect("one live job");
            gather_sources(
                plan, job.program, kind, &job.snapshot, outdeg, &job.sup_ops, &mut job.xs,
            );
            run_numeric(
                executor,
                kind,
                plan,
                &job.sup_ops,
                &job.xs,
                &mut job.cand,
                &mut scratch.chunk_bufs,
                &mut mode,
            )?;
            finish_superstep(job, plan, superstep);
        } else {
            // Sorted union of the live jobs' op selections (each job's
            // sup_ops is strictly increasing in plan order).
            union_ops.clear();
            for job in jobs.iter().filter(|j| !j.done) {
                union_ops.extend_from_slice(&job.sup_ops);
            }
            union_ops.sort_unstable();
            union_ops.dedup();
            // Per-job gather over the union, then op-major interleave.
            for job in jobs.iter_mut().filter(|j| !j.done) {
                gather_sources(
                    plan, job.program, kind, &job.snapshot, outdeg, &union_ops, &mut job.xs,
                );
            }
            xs_all.clear();
            xs_all.resize(union_ops.len() * lanes_n * c, 0.0);
            for (l, job) in jobs.iter().filter(|j| !j.done).enumerate() {
                for k in 0..union_ops.len() {
                    xs_all[(k * lanes_n + l) * c..(k * lanes_n + l + 1) * c]
                        .copy_from_slice(&job.xs[k * c..(k + 1) * c]);
                }
            }
            run_numeric_multi(
                executor,
                kind,
                plan,
                &union_ops,
                lanes_n,
                &xs_all,
                &mut cand_all,
                &mut scratch.chunk_bufs,
                &mut mode,
            )?;
            // Extract each job's candidates (two-pointer over its sorted
            // sup_ops vs the union), then reduce/apply per job.
            for (l, job) in jobs.iter_mut().filter(|j| !j.done).enumerate() {
                job.cand.clear();
                job.cand.reserve(job.sup_ops.len() * c);
                let mut ptr = 0usize;
                for (k, &op) in union_ops.iter().enumerate() {
                    if ptr < job.sup_ops.len() && job.sup_ops[ptr] == op {
                        let off = (k * lanes_n + l) * c;
                        job.cand.extend_from_slice(&cand_all[off..off + c]);
                        ptr += 1;
                    }
                }
                debug_assert_eq!(ptr, job.sup_ops.len(), "sup_ops ⊄ union");
                finish_superstep(job, plan, superstep);
            }
        }
    }

    // --- final accounting per job, exactly the solo fold ---
    Ok(jobs
        .into_iter()
        .map(|job| {
            let mut counts = job.sys_counts;
            let mut summaries = Vec::with_capacity(job.engines.len());
            let mut max_dyn_writes = 0u32;
            for e in &job.engines {
                let e = e.as_ref().expect("engine present");
                counts.add(&e.counts);
                if e.kind == EngineKind::Dynamic {
                    max_dyn_writes = max_dyn_writes.max(e.max_cell_writes());
                }
                summaries.push(EngineSummary::of(e));
            }
            counts.subtract(&job.counts_baseline);
            RunResult {
                values: job.values,
                counts,
                init_counts: job.init_counts,
                exec_time_ns: job.exec_time_ns,
                init_time_ns: job.init_time_ns,
                supersteps: job.supersteps,
                iterations: job.iterations,
                static_ops: job.static_ops,
                dynamic_ops: job.dynamic_ops,
                dynamic_hits: job.dynamic_hits,
                max_dynamic_cell_writes: max_dyn_writes,
                engines: summaries,
                activity: None,
            }
        })
        .collect())
}

/// Reduce/apply one job's superstep tail — identical to the solo loop's
/// epilogue: apply candidates, record the superstep, and exit the job's
/// loop when its program says stop.
fn finish_superstep(job: &mut BatchJob<'_>, plan: &ExecutionPlan, superstep: usize) {
    let any_changed = reduce_apply(
        plan,
        job.program,
        job.semiring,
        &job.sup_ops,
        &job.cand,
        &mut job.values,
        &mut job.acc,
        &mut job.active_block,
        &mut job.next_active_block,
    );
    job.supersteps = superstep + 1;
    if !job.program.post_superstep(superstep, &mut job.values, &mut job.acc, any_changed) {
        job.done = true;
    }
}

/// The pre-pool baseline: identical dispatch, but phases 2/3 spawn
/// `std::thread::scope` workers **every superstep**. Kept so the hotpath
/// bench can report the pool's win over the mechanism it replaced and so
/// the determinism suite can cross-check both mechanisms; new callers
/// should use [`run_parallel`] / [`run_parallel_pooled`].
pub fn run_parallel_scoped(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    threads: usize,
) -> Result<RunResult> {
    let threads = resolve_threads(threads);
    if threads <= 1 || config.trace_activity {
        return Scheduler::new(config, params, plan).run(program, executor);
    }
    run_pipeline(config, params, plan, program, executor, LaneMode::Scoped { threads })
}

/// The shared three-phase pipeline (see the module docs). `mode` selects
/// only the phase-2/3 mechanism; every decision is made here, in the
/// sequential dispatch pass, exactly as the interpreter makes it.
fn run_pipeline(
    config: &ArchConfig,
    params: &CostParams,
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    executor: &mut dyn StepExecutor,
    mut mode: LaneMode<'_>,
) -> Result<RunResult> {
    config.validate()?;
    anyhow::ensure!(
        plan.matches(config),
        "execution plan was compiled for a different architecture \
         (plan C={} N={} T={} M={})",
        plan.c,
        plan.static_engines,
        plan.total_engines,
        plan.crossbars_per_engine
    );
    if program.needs_weights() {
        anyhow::ensure!(
            plan.weighted,
            "{} requires weighted partitioning",
            program.name()
        );
    }
    let c = plan.c;
    let n = plan.num_vertices as usize;
    let num_blocks = plan.num_blocks as usize;
    let n_static = config.static_engines;
    let n_total = config.total_engines as usize;
    let m = config.crossbars_per_engine as usize;

    // --- engines (moved into lanes per superstep) + dispatch state ---
    let mut engines: Vec<Option<GraphEngine>> = (0..n_total)
        .map(|i| {
            let kind =
                if (i as u32) < n_static { EngineKind::Static } else { EngineKind::Dynamic };
            Some(GraphEngine::new(i as u32, kind, c, m as u32))
        })
        .collect();
    let n_dyn_slots = config.dynamic_engines() as usize * m;
    let mut policy = build_policy(config.policy, n_dyn_slots);
    let mut dyn_dir: Vec<u32> = vec![NONE; plan.num_patterns as usize];
    let mut slot_rank: Vec<u32> = vec![NONE; n_dyn_slots];
    let mut retired: Vec<bool> = vec![false; n_dyn_slots];
    // Dispatcher-owned mirror of the dynamic crossbars: retire-then-repick
    // must know a configure's wear *at decision time*, before the owning
    // lane replays the identical configure on the real crossbar.
    let mut shadow: Vec<Crossbar> = (0..n_dyn_slots).map(|_| Crossbar::new(c)).collect();
    // Shadow of the static engines' busy time, accumulated with the same
    // f64 additions (same order, same addend) as the interpreter — the
    // least-busy replica pick compares bit-identical values.
    let mut shadow_busy = vec![0f64; n_total];

    // --- initialization: configure static engines (Alg. 2 l. 6–8) ---
    for &(slot, pattern) in plan.static_config() {
        engines[slot.engine as usize]
            .as_mut()
            .expect("engine present")
            .configure(slot.crossbar as usize, pattern, params);
    }
    let mut init_counts = EventCounts::default();
    let mut init_time_ns = 0f64;
    for e in engines.iter_mut() {
        let e = e.as_mut().expect("engine present");
        init_counts.add(&e.counts);
        let (busy, _) = e.end_iteration();
        init_time_ns = init_time_ns.max(busy);
    }
    let counts_baseline = init_counts;

    // --- vertex state (identical to the sequential interpreter) ---
    let mut values = program.init(plan.num_vertices);
    anyhow::ensure!(values.len() == n, "program init length mismatch");
    let mut snapshot = values.clone();
    let semiring = program.semiring();
    let mut acc = match semiring {
        Semiring::SumProd => vec![0f32; n],
        Semiring::MinPlus => Vec::new(),
    };
    let outdeg = plan.out_degrees();

    let all_blocks = program.processes_all_blocks();
    let mut active_block = vec![false; num_blocks];
    let mut next_active_block = vec![false; num_blocks];
    if !all_blocks {
        for (v, &val) in values.iter().enumerate() {
            if val < INF {
                active_block[v / c] = true;
            }
        }
    }

    // --- per-engine work lanes + run-lifetime scratch, all preallocated
    // --- (the lane queues to the plan's lane-table bounds) ---
    let lane_tab = plan.lanes();
    let mut records: Vec<Vec<LaneRecord>> = (0..n_total)
        .map(|e| Vec::with_capacity(lane_tab.fixed_ops_on(e as u32) as usize))
        .collect();
    let mut scratch = Scratch::new(n_total, mode.threads());

    // --- main loop ---
    let kind = program.step_kind();
    let mut exec_time_ns = 0f64;
    let mut sys_counts = EventCounts::default();
    let mut iterations = 0u64;
    let mut static_ops = 0u64;
    let mut dynamic_ops = 0u64;
    let mut dynamic_hits = 0u64;
    let mut supersteps = 0usize;

    let mut sup_ops: Vec<u32> = Vec::new();
    let mut xs: Vec<f32> = Vec::new();
    let mut cand: Vec<f32> = Vec::new();

    let lat_mvm = crate::cost::timing::mvm_latency_ns(params, c as u32, c as u32)
        + crate::cost::timing::reduce_latency_ns(params, c as u32);

    for superstep in 0..program.max_supersteps() {
        snapshot.copy_from_slice(&values);
        sup_ops.clear();
        for r in records.iter_mut() {
            r.clear();
        }
        shadow_busy.iter_mut().for_each(|b| *b = 0.0);

        // --- phase 1: sequential dispatch — decisions into lanes ---
        for g in 0..plan.num_groups() {
            let (start, end) = plan.group_bounds(g);
            let mut ops_in_group = 0u64;
            for (off, op) in plan.ops[start..end].iter().enumerate() {
                if !all_blocks && !active_block[op.src_block as usize] {
                    continue;
                }
                ops_in_group += 1;
                if op.is_static() {
                    let slots = plan.slots_of(op);
                    // Compile-time-homed ops (the lane table's fast path:
                    // exactly one replica) skip the least-busy scan; only
                    // multi-replica ops resolve against the shadow busy
                    // model — same choice, bit for bit, as the
                    // interpreter's single-slot shortcut.
                    let slot = if lane_tab.home_of(start + off).is_some() {
                        slots[0]
                    } else {
                        *slots
                            .iter()
                            .min_by(|a, b| {
                                shadow_busy[a.engine as usize]
                                    .total_cmp(&shadow_busy[b.engine as usize])
                            })
                            .expect("static op has a slot")
                    };
                    shadow_busy[slot.engine as usize] += lat_mvm;
                    records[slot.engine as usize].push(LaneRecord::Mvm {
                        crossbar: slot.crossbar,
                        read_rows: op.read_rows,
                    });
                    static_ops += 1;
                } else {
                    let rank = op.pattern_rank as usize;
                    let hit = if config.dynamic_reuse {
                        let k = dyn_dir[rank];
                        (k != NONE && !retired[k as usize]).then_some(k as usize)
                    } else {
                        None
                    };
                    let k = match hit {
                        Some(k) => {
                            dynamic_hits += 1;
                            k
                        }
                        None => {
                            let pattern = plan.pattern_of_rank(op.pattern_rank);
                            // Retire-then-repick, mirrored from the
                            // interpreter: the shadow crossbar absorbs the
                            // same configure the lane will replay, so the
                            // wear decision and the replayed wear agree.
                            loop {
                                let k = policy.pick(&retired).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "all dynamic crossbars retired (wear-out)"
                                    )
                                })?;
                                let (ei, cb) = slot_pos(config, k);
                                let old = slot_rank[k];
                                if old != NONE {
                                    dyn_dir[old as usize] = NONE;
                                    slot_rank[k] = NONE;
                                }
                                shadow[k].configure(pattern);
                                records[ei].push(LaneRecord::Configure {
                                    crossbar: cb as u32,
                                    rank: op.pattern_rank,
                                });
                                if shadow[k].worn_out(params.endurance_cycles) {
                                    retired[k] = true;
                                    continue;
                                }
                                slot_rank[k] = rank as u32;
                                dyn_dir[rank] = k as u32;
                                break k;
                            }
                        }
                    };
                    let (ei, cb) = slot_pos(config, k);
                    records[ei].push(LaneRecord::Mvm {
                        crossbar: cb as u32,
                        read_rows: op.rows,
                    });
                    policy.touch(k);
                    dynamic_ops += 1;
                }
                sup_ops.push((start + off) as u32);
            }
            if ops_in_group == 0 {
                continue;
            }
            iterations += 1;
            sys_counts.main_mem_accesses += 2 * ops_in_group.div_ceil(16);
        }

        // --- phase 2: parallel lane replay, engine-ordered timing merge ---
        exec_time_ns += replay_lanes(
            &mut engines,
            &records,
            &mut scratch,
            plan,
            params,
            lat_mvm,
            &mut mode,
        );

        if sup_ops.is_empty() {
            break;
        }

        // --- phase 3: numeric — gather, chunked edge compute, reduce ---
        // Gather and reduce/apply are the interpreter's own helpers:
        // identical numeric semantics by construction, not by mirroring.
        gather_sources(plan, program, kind, &snapshot, outdeg, &sup_ops, &mut xs);
        run_numeric(
            executor,
            kind,
            plan,
            &sup_ops,
            &xs,
            &mut cand,
            &mut scratch.chunk_bufs,
            &mut mode,
        )?;
        let any_changed = reduce_apply(
            plan,
            program,
            semiring,
            &sup_ops,
            &cand,
            &mut values,
            &mut acc,
            &mut active_block,
            &mut next_active_block,
        );

        supersteps = superstep + 1;
        if !program.post_superstep(superstep, &mut values, &mut acc, any_changed) {
            break;
        }
    }

    // --- final accounting: engines reassemble into summaries ---
    let mut counts = sys_counts;
    let mut summaries = Vec::with_capacity(engines.len());
    let mut max_dyn_writes = 0u32;
    for e in &engines {
        let e = e.as_ref().expect("engine present");
        counts.add(&e.counts);
        if e.kind == EngineKind::Dynamic {
            max_dyn_writes = max_dyn_writes.max(e.max_cell_writes());
        }
        summaries.push(EngineSummary::of(e));
    }
    counts.subtract(&counts_baseline);

    Ok(RunResult {
        values,
        counts,
        init_counts,
        exec_time_ns,
        init_time_ns,
        supersteps,
        iterations,
        static_ops,
        dynamic_ops,
        dynamic_hits,
        max_dynamic_cell_writes: max_dyn_writes,
        engines: summaries,
        activity: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Bfs, PageRank, Wcc};
    use crate::graph::coo::{Coo, Edge};
    use crate::graph::datasets::Dataset;
    use crate::pattern::extract::partition;
    use crate::pattern::rank::PatternRanking;
    use crate::pattern::tables::{ConfigTable, StaticAssignment, SubgraphTable};
    use crate::sched::executor::NativeExecutor;

    fn plan_for(g: &Coo, config: &ArchConfig, weighted: bool) -> ExecutionPlan {
        let part = partition(g, config.crossbar_size, weighted);
        let ranking = PatternRanking::from_partitioned(&part);
        let ct = ConfigTable::build(
            &ranking,
            config.crossbar_size,
            config.static_engines,
            config.crossbars_per_engine,
            config.dynamic_engines() * config.crossbars_per_engine,
            config.static_assignment,
        );
        let st = SubgraphTable::build(&part, &ranking, config.order);
        ExecutionPlan::build(&part, &ct, &st, config)
    }

    fn assert_same(a: &RunResult, b: &RunResult, ctx: &str) {
        assert_eq!(a.values, b.values, "{ctx}: values");
        assert_eq!(a.counts, b.counts, "{ctx}: counts");
        assert_eq!(a.init_counts, b.init_counts, "{ctx}: init counts");
        assert_eq!(a.exec_time_ns, b.exec_time_ns, "{ctx}: exec time");
        assert_eq!(a.init_time_ns, b.init_time_ns, "{ctx}: init time");
        assert_eq!(a.supersteps, b.supersteps, "{ctx}: supersteps");
        assert_eq!(a.iterations, b.iterations, "{ctx}: iterations");
        assert_eq!(a.static_ops, b.static_ops, "{ctx}: static ops");
        assert_eq!(a.dynamic_ops, b.dynamic_ops, "{ctx}: dynamic ops");
        assert_eq!(a.dynamic_hits, b.dynamic_hits, "{ctx}: dynamic hits");
        assert_eq!(
            a.max_dynamic_cell_writes, b.max_dynamic_cell_writes,
            "{ctx}: wear"
        );
        assert_eq!(a.engines, b.engines, "{ctx}: engine summaries");
    }

    fn run_both(
        g: &Coo,
        config: &ArchConfig,
        program: &dyn VertexProgram,
        threads: usize,
    ) -> (RunResult, RunResult) {
        let params = CostParams::default();
        let plan = plan_for(g, config, program.needs_weights());
        let seq = Scheduler::new(config, &params, &plan)
            .run(program, &mut NativeExecutor)
            .unwrap();
        let par =
            run_parallel(config, &params, &plan, program, &mut NativeExecutor, threads)
                .unwrap();
        (seq, par)
    }

    #[test]
    fn lane_assignment_is_deterministic_and_balanced() {
        // Seeding then greedy: e0→l0, e1→l1, then each next engine to the
        // lighter lane (ties to lane 0).
        assert_eq!(lane_assignment(&[5, 1, 1, 1, 5], 2), vec![0, 1, 1, 1, 1]);
        // Never more lanes than engines; single engine → single lane.
        assert_eq!(lane_assignment(&[3], 8), vec![0]);
        // Every lane gets seeded before balancing kicks in.
        assert_eq!(lane_assignment(&[1, 1, 1], 3), vec![0, 1, 2]);
        // Deterministic: same input, same output.
        assert_eq!(lane_assignment(&[2, 2, 2, 2], 2), lane_assignment(&[2, 2, 2, 2], 2));
    }

    #[test]
    fn zero_dynamic_engines_all_ops_static() {
        // Every pattern pinned (TopK, capacity >= patterns) and not a
        // single dynamic engine: the dispatch pass must never touch the
        // (empty) dynamic state and lanes carry only MVM records.
        let g = Dataset::Tiny.load().unwrap();
        let part = partition(&g, 4, false);
        let patterns = PatternRanking::from_partitioned(&part).num_patterns() as u32;
        let config = ArchConfig {
            total_engines: patterns,
            static_engines: patterns,
            static_assignment: StaticAssignment::TopK,
            ..ArchConfig::default()
        };
        let (seq, par) = run_both(&g, &config, &Bfs::new(0), 4);
        assert_same(&seq, &par, "zero dynamic engines");
        assert_eq!(par.dynamic_ops, 0);
        assert!(par.static_ops > 0);
    }

    #[test]
    fn more_threads_than_lanes_falls_back_to_available_engines() {
        // 2 engines, 16 requested lanes: at most 2 lanes may run; the
        // run must still be bit-identical.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig {
            total_engines: 2,
            static_engines: 1,
            ..ArchConfig::default()
        };
        let (seq, par) = run_both(&g, &config, &Bfs::new(0), 16);
        assert_same(&seq, &par, "threads > lanes");
    }

    #[test]
    fn only_dynamic_ops_superstep() {
        // All-dynamic architecture: every superstep's lanes are pure
        // replacement-policy traffic.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig {
            static_engines: 0,
            total_engines: 8,
            ..ArchConfig::default()
        };
        let (seq, par) = run_both(&g, &config, &Wcc, 4);
        assert_same(&seq, &par, "only dynamic ops");
        assert_eq!(par.static_ops, 0);
        assert!(par.dynamic_ops > 0);
    }

    #[test]
    fn empty_frontier_terminates_without_idle_lanes() {
        // Source with no out-edges: the first superstep has an empty
        // frontier, so no lane work is submitted and the run ends after
        // at most one superstep — identically to the sequential path.
        let g = Coo::from_edges(8, vec![Edge::new(1, 2)]);
        let config = ArchConfig::default();
        let (seq, par) = run_both(&g, &config, &Bfs::new(7), 4);
        assert_same(&seq, &par, "empty frontier");
        assert!(par.supersteps <= 1);
        assert_eq!(par.values[7], 0.0);
    }

    #[test]
    fn pagerank_sum_prod_path_is_identical() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let (seq, par) = run_both(&g, &config, &PageRank::new(0.85, 6), 8);
        assert_same(&seq, &par, "pagerank");
        assert_eq!(par.supersteps, 6);
    }

    #[test]
    fn scoped_and_pooled_mechanisms_agree() {
        // The retained scoped baseline and the pooled production path
        // must stay interchangeable bit for bit — on a fresh pool and on
        // a pool reused across consecutive runs.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let params = CostParams::default();
        for program in [&PageRank::new(0.85, 5) as &dyn VertexProgram, &Wcc] {
            let plan = plan_for(&g, &config, false);
            let seq = Scheduler::new(&config, &params, &plan)
                .run(program, &mut NativeExecutor)
                .unwrap();
            let scoped = run_parallel_scoped(
                &config, &params, &plan, program, &mut NativeExecutor, 4,
            )
            .unwrap();
            assert_same(&seq, &scoped, "scoped vs sequential");
            let mut pool = WorkerPool::new(4);
            for round in 0..2 {
                let pooled = run_parallel_pooled(
                    &config, &params, &plan, program, &mut NativeExecutor, &mut pool,
                )
                .unwrap();
                assert_same(&seq, &pooled, &format!("pooled round {round}"));
            }
            // A lane cap below the pool size uses fewer lanes of the same
            // workers — still bit-identical, no respawn.
            let ids = pool.worker_ids();
            for cap in [1usize, 2, 16] {
                let capped = run_parallel_pooled_at(
                    &config, &params, &plan, program, &mut NativeExecutor, &mut pool, cap,
                )
                .unwrap();
                assert_same(&seq, &capped, &format!("pooled cap {cap}"));
            }
            assert_eq!(pool.worker_ids(), ids, "caps never respawn workers");
        }
    }

    #[test]
    fn tracing_runs_take_the_sequential_path() {
        // The activity trace needs per-group engine snapshots, so a
        // tracing run delegates to the interpreter even with threads > 1
        // — on both the transient and the persistent-pool entry points.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::fig5();
        let params = CostParams::default();
        let plan = plan_for(&g, &config, false);
        let par = run_parallel(&config, &params, &plan, &Bfs::new(0), &mut NativeExecutor, 4)
            .unwrap();
        let trace = par.activity.expect("trace recorded via the sequential path");
        assert_eq!(trace.num_engines, 6);
        let seq = Scheduler::new(&config, &params, &plan)
            .run(&Bfs::new(0), &mut NativeExecutor)
            .unwrap();
        assert_same(&seq, &par, "tracing delegation");
        let mut pool = WorkerPool::new(4);
        let pooled = run_parallel_pooled(
            &config, &params, &plan, &Bfs::new(0), &mut NativeExecutor, &mut pool,
        )
        .unwrap();
        assert!(pooled.activity.is_some(), "pooled tracing delegates too");
    }

    #[test]
    fn wearout_error_matches_sequential() {
        // Endurance 1 with one dynamic slot: the dispatch pass must fail
        // exactly like the interpreter's retire-then-repick.
        let g = Coo::from_edges(4, vec![Edge::new(0, 1)]);
        let config = ArchConfig {
            crossbar_size: 2,
            total_engines: 1,
            static_engines: 0,
            ..ArchConfig::default()
        };
        let params = CostParams { endurance_cycles: 1.0, ..CostParams::default() };
        let plan = plan_for(&g, &config, false);
        let err =
            run_parallel(&config, &params, &plan, &Bfs::new(0), &mut NativeExecutor, 4)
                .unwrap_err();
        assert!(err.to_string().contains("retired"), "{err}");
    }

    #[test]
    fn resolve_threads_maps_zero_to_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn batched_runs_are_bit_identical_to_solo_across_sizes_and_threads() {
        // The batch determinism contract: element i of a batched run is
        // bit-identical to programs[i] run alone — every field of every
        // RunResult — across batch sizes, thread counts, and repeated
        // use of one pool.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let params = CostParams::default();
        let plan = plan_for(&g, &config, false);
        let sources = [0u32, 1, 2, 3];
        let programs: Vec<Bfs> = sources.iter().map(|&s| Bfs::new(s)).collect();
        let solo: Vec<RunResult> = programs
            .iter()
            .map(|p| Scheduler::new(&config, &params, &plan).run(p, &mut NativeExecutor).unwrap())
            .collect();
        for threads in [2usize, 4] {
            let mut pool = WorkerPool::new(threads);
            for size in [1usize, 2, 4] {
                let batch: Vec<&dyn VertexProgram> =
                    programs[..size].iter().map(|p| p as &dyn VertexProgram).collect();
                let results = run_parallel_pooled_batch(
                    &config,
                    &params,
                    &plan,
                    &batch,
                    &mut NativeExecutor,
                    &mut pool,
                    threads,
                )
                .unwrap();
                assert_eq!(results.len(), size);
                for (i, r) in results.iter().enumerate() {
                    assert_same(
                        &solo[i],
                        r,
                        &format!("batch size {size}, threads {threads}, job {i}"),
                    );
                }
            }
        }
    }

    #[test]
    fn batched_pagerank_and_wcc_match_solo() {
        // Same-kind batches for the non-frontier semiring (identical
        // programs stress the all-lanes-identical corner) and a frontier
        // algorithm where jobs drop out of the batch at different
        // supersteps (sources with different eccentricities).
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let params = CostParams::default();
        let plan = plan_for(&g, &config, false);
        let mut pool = WorkerPool::new(4);

        let pr = PageRank::new(0.85, 6);
        let solo_pr = Scheduler::new(&config, &params, &plan)
            .run(&pr, &mut NativeExecutor)
            .unwrap();
        let batch: Vec<&dyn VertexProgram> = vec![&pr, &pr, &pr];
        for r in run_parallel_pooled_batch(
            &config, &params, &plan, &batch, &mut NativeExecutor, &mut pool, 4,
        )
        .unwrap()
        {
            assert_same(&solo_pr, &r, "identical pagerank batch");
        }

        let a = Bfs::new(0);
        let b = Bfs::new(5);
        let solo_a = Scheduler::new(&config, &params, &plan)
            .run(&a, &mut NativeExecutor)
            .unwrap();
        let solo_b = Scheduler::new(&config, &params, &plan)
            .run(&b, &mut NativeExecutor)
            .unwrap();
        let batch: Vec<&dyn VertexProgram> = vec![&a, &b];
        let rs = run_parallel_pooled_batch(
            &config, &params, &plan, &batch, &mut NativeExecutor, &mut pool, 4,
        )
        .unwrap();
        assert_same(&solo_a, &rs[0], "staggered-frontier job 0");
        assert_same(&solo_b, &rs[1], "staggered-frontier job 1");
    }

    #[test]
    fn batch_rejects_mixed_step_kinds_and_empty_batches() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let params = CostParams::default();
        let plan = plan_for(&g, &config, false);
        let mut pool = WorkerPool::new(2);
        let empty: Vec<&dyn VertexProgram> = Vec::new();
        assert!(run_parallel_pooled_batch(
            &config, &params, &plan, &empty, &mut NativeExecutor, &mut pool, 2,
        )
        .is_err());
        let bfs = Bfs::new(0);
        let mixed: Vec<&dyn VertexProgram> = vec![&bfs, &Wcc];
        let err = run_parallel_pooled_batch(
            &config, &params, &plan, &mixed, &mut NativeExecutor, &mut pool, 2,
        )
        .unwrap_err();
        assert!(err.to_string().contains("step kind"), "{err}");
    }
}
