//! Incremental plan patching for streaming graph mutation (the delta
//! path). A validated [`DeltaBatch`] touches only the C×C adjacency
//! windows its edges fall in, so instead of re-running Alg. 1 from the
//! raw graph, [`patch_preprocessed`] edits exactly those windows of the
//! cached [`Partitioned`](crate::pattern::extract::Partitioned),
//! re-derives the pattern ranking from
//! incrementally-maintained occurrence counts, rebuilds the (cheap,
//! ranking-sized) config and subgraph tables, and re-emits the execution
//! plan's graph-derived sections in place through the same emission path
//! a cold compile uses.
//!
//! The correctness contract is *bit-identity*: a patched `Preprocessed`
//! compares equal (`PartialEq`, every field) to a cold
//! `Accelerator::preprocess` of the mutated graph, so every downstream
//! run — sequential, scoped, pooled, any thread count — is bit-identical
//! too. This holds because the patched `Partitioned` is reproduced
//! window-for-window (same sort order, same weight alignment as
//! `partition`), and everything downstream of `Partitioned` is a pure
//! deterministic function of it.
//!
//! Atomicity: all delta validation happens against the *current*
//! artifact before anything is mutated, so a rejected batch (duplicate
//! add, missing remove, vertex-count mismatch) leaves the artifact
//! exactly as it was.

use std::collections::{BTreeMap, HashMap};

use anyhow::Result;

use crate::accel::config::ArchConfig;
use crate::accel::simulator::Preprocessed;
use crate::graph::delta::{DeltaBatch, DeltaError, DeltaOp};
use crate::pattern::extract::Subgraph;
use crate::pattern::pattern::Pattern;
use crate::pattern::rank::{merge_counts, PatternRanking};
use crate::pattern::tables::{ConfigTable, SubgraphTable};

/// What one [`patch_preprocessed`] call did, for the session's delta
/// report and the coordinator's streaming-mutation counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PatchStats {
    /// Adjacency windows (subgraph partitions) the batch touched —
    /// created, mutated, or emptied.
    pub dirty_partitions: u32,
    /// Plan ops re-emitted against a mutated subgraph (dirty windows
    /// still non-empty after the batch; emptied windows emit no op).
    pub patched_ops: u32,
    /// Edge additions applied.
    pub adds: u32,
    /// Edge removals applied.
    pub removes: u32,
    /// Weight updates applied.
    pub reweights: u32,
    /// Crossbar writes a live accelerator would pay to morph the old
    /// static-slot section into the patched one.
    pub crossbar_writes: u64,
    /// ReRAM cells toggled across those writes.
    pub write_bits: u64,
}

impl PatchStats {
    /// Fold another patch's stats into this one (session-lifetime
    /// accumulation across batches).
    pub fn absorb(&mut self, other: &PatchStats) {
        self.dirty_partitions += other.dirty_partitions;
        self.patched_ops += other.patched_ops;
        self.adds += other.adds;
        self.removes += other.removes;
        self.reweights += other.reweights;
        self.crossbar_writes += other.crossbar_writes;
        self.write_bits += other.write_bits;
    }
}

/// The patched state of one dirty window, staged during validation and
/// committed only after the whole batch checks out.
struct DirtyWindow {
    brow: u32,
    bcol: u32,
    /// `Ok(k)` if the window already exists at `part.subgraphs[k]`,
    /// `Err(k)` if it would be inserted at `k` (standard binary-search
    /// convention).
    site: std::result::Result<usize, usize>,
    pattern: Pattern,
    /// Weights aligned with `pattern`'s set-bit order; empty when the
    /// partitioning is unweighted.
    weights: Vec<f32>,
}

/// Apply `batch` to a cached preprocessing artifact in place,
/// re-deriving only what the dirty windows invalidate. `arch` must be
/// the architecture the artifact was compiled for (the plan's geometry
/// guards enforce this). On any error the artifact is untouched.
pub fn patch_preprocessed(
    pre: &mut Preprocessed,
    batch: &DeltaBatch,
    arch: &ArchConfig,
) -> Result<PatchStats> {
    let part = &pre.part;
    let c = part.c;
    let cu = c as u32;
    if batch.num_vertices() != part.num_vertices {
        return Err(DeltaError::GraphMismatch {
            batch: batch.num_vertices(),
            graph: part.num_vertices,
        }
        .into());
    }
    let mut stats = PatchStats::default();
    if batch.is_empty() {
        return Ok(stats);
    }

    // ── Stage 1: validate the whole batch against the current windows,
    // computing each dirty window's post-batch pattern and weights
    // without mutating anything. Deltas arrive sorted by (src, dst), so
    // grouping by window keeps a deterministic order.
    let mut dirty: BTreeMap<(u32, u32), DirtyWindow> = BTreeMap::new();
    for d in batch.deltas() {
        let (brow, bcol) = (d.src / cu, d.dst / cu);
        let win = dirty.entry((brow, bcol)).or_insert_with(|| {
            let site = part
                .subgraphs
                .binary_search_by_key(&(brow, bcol), |s| (s.brow, s.bcol));
            match site {
                Ok(k) => DirtyWindow {
                    brow,
                    bcol,
                    site,
                    pattern: part.subgraphs[k].pattern,
                    weights: match &part.weights {
                        Some(w) => w[k].clone(),
                        None => Vec::new(),
                    },
                },
                Err(_) => DirtyWindow {
                    brow,
                    bcol,
                    site,
                    pattern: Pattern::EMPTY,
                    weights: Vec::new(),
                },
            }
        });
        let bit = (d.src % cu) as usize * c + (d.dst % cu) as usize;
        let mask = 1u64 << bit;
        let present = win.pattern.0 & mask != 0;
        // Index of this cell among the pattern's set bits — where its
        // weight lives (or would live) in the aligned weight vector.
        let pos = (win.pattern.0 & (mask - 1)).count_ones() as usize;
        let weighted = part.weights.is_some();
        match d.op {
            DeltaOp::Add => {
                if present {
                    return Err(DeltaError::EdgeExists { src: d.src, dst: d.dst }.into());
                }
                win.pattern = Pattern(win.pattern.0 | mask);
                if weighted {
                    win.weights.insert(pos, d.weight);
                }
                stats.adds += 1;
            }
            DeltaOp::Remove => {
                if !present {
                    return Err(DeltaError::EdgeMissing { src: d.src, dst: d.dst }.into());
                }
                win.pattern = Pattern(win.pattern.0 & !mask);
                if weighted {
                    win.weights.remove(pos);
                }
                stats.removes += 1;
            }
            DeltaOp::Reweight => {
                if !present {
                    return Err(DeltaError::EdgeMissing { src: d.src, dst: d.dst }.into());
                }
                if weighted {
                    win.weights[pos] = d.weight;
                }
                stats.reweights += 1;
            }
        }
    }
    stats.dirty_partitions = dirty.len() as u32;
    stats.patched_ops = dirty.values().filter(|w| !w.pattern.is_empty()).count() as u32;

    // ── Stage 2: commit. Splice the staged windows into a patched
    // `Partitioned`. Removals and insertions shift indices, so windows
    // are applied in reverse key order (sites were computed against the
    // unmodified vector and stay valid from the back).
    let mut patched = pre.part.clone();
    for win in dirty.values().rev() {
        match (win.site, win.pattern.is_empty()) {
            (Ok(k), true) => {
                patched.subgraphs.remove(k);
                if let Some(w) = &mut patched.weights {
                    w.remove(k);
                }
            }
            (Ok(k), false) => {
                patched.subgraphs[k].pattern = win.pattern;
                if let Some(w) = &mut patched.weights {
                    w[k] = win.weights.clone();
                }
            }
            (Err(k), false) => {
                patched.subgraphs.insert(
                    k,
                    Subgraph { brow: win.brow, bcol: win.bcol, pattern: win.pattern },
                );
                if let Some(w) = &mut patched.weights {
                    w.insert(k, win.weights.clone());
                }
            }
            // Dirty-but-still-absent can't happen: reaching it would
            // need a remove/reweight on an absent window (rejected in
            // stage 1) or an add immediately removed (deduped away).
            (Err(_), true) => unreachable!("window neither existed nor was created"),
        }
    }

    // ── Stage 3: re-derive the ranking from incrementally-maintained
    // occurrence counts (only dirty windows change a count), folded
    // through the same `merge_counts` path the pooled miner uses, then
    // rebuild the ranking-sized tables and re-emit the plan sections.
    let mut counts: HashMap<Pattern, u32> = pre.ranking.ranked.iter().copied().collect();
    merge_counts(
        &mut counts,
        dirty.values().flat_map(|win| {
            let old = win
                .site
                .ok()
                .map(|k| (pre.part.subgraphs[k].pattern, -1i64));
            let new = (!win.pattern.is_empty()).then_some((win.pattern, 1i64));
            old.into_iter().chain(new)
        }),
    );
    let ranking = PatternRanking::from_counts(counts, patched.num_subgraphs());
    // Mirrors `Accelerator::build_config_table` — the patched CT must be
    // the one a cold compile under `arch` would produce.
    let ct = ConfigTable::build(
        &ranking,
        arch.crossbar_size,
        arch.static_engines,
        arch.crossbars_per_engine,
        arch.dynamic_engines() * arch.crossbars_per_engine,
        arch.static_assignment,
    );
    let st = SubgraphTable::build(&patched, &ranking, arch.order);
    let rebuild = pre.plan.patch_sections(&patched, &ct, &st, arch)?;
    stats.crossbar_writes = rebuild.crossbar_writes;
    stats.write_bits = rebuild.write_bits;

    pre.part = patched;
    pre.ranking = ranking;
    pre.ct = ct;
    pre.st = st;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::simulator::Accelerator;
    use crate::graph::coo::{Coo, Edge};
    use crate::graph::delta::EdgeDelta;
    use crate::graph::Dataset;

    fn tiny() -> Coo {
        Dataset::Tiny.load().unwrap()
    }

    /// First (src, dst) pair absent from `g` — a guaranteed-valid Add.
    fn absent_pair(g: &Coo) -> (u32, u32) {
        for src in 0..g.num_vertices {
            for dst in 0..g.num_vertices {
                let absent = g
                    .edges
                    .binary_search_by_key(&(src, dst), |e| (e.src, e.dst))
                    .is_err();
                if src != dst && absent {
                    return (src, dst);
                }
            }
        }
        unreachable!("complete graph");
    }

    fn assert_patch_matches_cold(g: &Coo, batch: &DeltaBatch, weighted: bool) -> PatchStats {
        let acc = Accelerator::with_defaults();
        let mut pre = acc.preprocess(g, weighted).unwrap();
        let stats = patch_preprocessed(&mut pre, batch, &acc.config).unwrap();
        let mutated = batch.apply_to_coo(g).unwrap();
        let cold = acc.preprocess(&mutated, weighted).unwrap();
        assert_eq!(pre, cold, "patched artifact must equal cold recompile");
        stats
    }

    #[test]
    fn patched_equals_cold_recompile_unweighted() {
        let g = tiny();
        let e = g.edges[0];
        let (src, dst) = absent_pair(&g);
        let batch = DeltaBatch::new(
            g.num_vertices,
            vec![EdgeDelta::remove(e.src, e.dst), EdgeDelta::add(src, dst)],
        )
        .unwrap();
        let stats = assert_patch_matches_cold(&g, &batch, false);
        assert!(stats.dirty_partitions >= 1);
        assert_eq!((stats.adds, stats.removes), (1, 1));
    }

    #[test]
    fn patched_equals_cold_recompile_weighted() {
        let g = tiny().with_random_weights(7, 0.5, 2.0);
        let e0 = g.edges[0];
        let e1 = g.edges[g.num_edges() / 2];
        let batch = DeltaBatch::new(
            g.num_vertices,
            vec![
                EdgeDelta::reweight(e0.src, e0.dst, 9.25),
                EdgeDelta::remove(e1.src, e1.dst),
            ],
        )
        .unwrap();
        let stats = assert_patch_matches_cold(&g, &batch, true);
        assert_eq!(stats.reweights, 1);
        assert_eq!(stats.removes, 1);
    }

    #[test]
    fn empty_batch_is_identity_with_zero_stats() {
        let g = tiny();
        let acc = Accelerator::with_defaults();
        let mut pre = acc.preprocess(&g, false).unwrap();
        let before = pre.clone();
        let stats =
            patch_preprocessed(&mut pre, &DeltaBatch::empty(g.num_vertices), &acc.config)
                .unwrap();
        assert_eq!(stats, PatchStats::default());
        assert_eq!(pre, before);
    }

    #[test]
    fn rejected_batch_leaves_artifact_untouched() {
        let g = tiny();
        let acc = Accelerator::with_defaults();
        let mut pre = acc.preprocess(&g, false).unwrap();
        let before = pre.clone();
        let e = g.edges[0];
        // Second delta is invalid (edge already present) — the valid
        // remove staged before it must not leak into the artifact.
        let batch = DeltaBatch::new(
            g.num_vertices,
            vec![
                EdgeDelta::remove(e.src, e.dst),
                EdgeDelta::add(g.edges[1].src, g.edges[1].dst),
            ],
        )
        .unwrap();
        assert!(patch_preprocessed(&mut pre, &batch, &acc.config).is_err());
        assert_eq!(pre, before);

        let wrong = DeltaBatch::empty(g.num_vertices + 1);
        assert!(patch_preprocessed(&mut pre, &wrong, &acc.config).is_err());
        assert_eq!(pre, before);
    }

    #[test]
    fn window_creation_and_deletion_round_trip() {
        // A graph where a batch empties one window and creates another.
        let g = Coo::from_edges(
            8,
            vec![Edge::new(0, 1), Edge::new(2, 3), Edge::new(4, 5)],
        );
        let batch = DeltaBatch::new(
            8,
            vec![EdgeDelta::remove(4, 5), EdgeDelta::add(6, 7)],
        )
        .unwrap();
        let stats = assert_patch_matches_cold(&g, &batch, false);
        assert_eq!(stats.dirty_partitions, 2);
        assert_eq!(stats.patched_ops, 1); // (4,5)'s window emptied, (6,7)'s created
        assert_eq!((stats.adds, stats.removes), (1, 1));
    }

    #[test]
    fn stats_absorb_accumulates() {
        let mut a = PatchStats { adds: 1, crossbar_writes: 2, ..PatchStats::default() };
        let b = PatchStats { adds: 3, removes: 1, write_bits: 5, ..PatchStats::default() };
        a.absorb(&b);
        assert_eq!(a.adds, 4);
        assert_eq!(a.removes, 1);
        assert_eq!(a.crossbar_writes, 2);
        assert_eq!(a.write_bits, 5);
    }
}
