//! `ExecutionPlan` — the compiled scheduling IR (compile the schedule once,
//! interpret it per superstep).
//!
//! The paper's central observation is that the subgraph/pattern structure
//! is **static per graph**: static engines are configured once and most
//! ops need no reconfiguration. The seed scheduler nevertheless re-derived
//! every scheduling decision inside the superstep hot loop — a
//! `HashMap<Pattern, usize>` lookup per dynamic op, a CT indirection and a
//! slot scan per static op, and a rebuild of the `xs`/dense-weight shapes
//! per executor call. This module compiles all of that, once per
//! `(graph, architecture)` pair, into a flat index-interned IR that
//! `Scheduler::run` merely interprets. The plan rides inside
//! [`Preprocessed`](crate::accel::Preprocessed), so the session
//! `ArtifactStore` hands the *same compiled plan* to every serve worker
//! and repeat job with the same `(dataset, scale, weighted, arch)` key.
//!
//! # IR ↔ Algorithm 2 mapping
//!
//! | IR field                         | Algorithm 2 role                                        |
//! |----------------------------------|---------------------------------------------------------|
//! | [`ExecutionPlan::static_config`] | ll. 6–8: one-time static engine configuration           |
//! | [`ExecutionPlan::groups`]        | l. 9: batches of subgraphs sharing dest. (src.) vertices |
//! | [`PlanOp::slot_range`] (via [`ExecutionPlan::slots_of`]) | l. 11: "pattern pinned to a static engine?" — pre-resolved replica candidates |
//! | [`PlanOp::read_rows`]            | l. 12: static MVM with the CT row-address shortcut      |
//! | [`PlanOp::pattern_rank`]         | ll. 13–15: dynamic path — rank-interned pattern id for the directory and [`ExecutionPlan::pattern_of_rank`] for `configure` |
//! | [`PlanOp::rows`]                 | l. 15: dynamic MVM wordline count                       |
//! | [`PlanOp::src_block`]            | frontier mask test (which block-row feeds this op)      |
//! | `op_bits` / `weights`            | the numeric edge-compute operands consumed by [`StepBatch`] |
//!
//! Everything mutable at run time (engine busy-times, the rank-keyed
//! dynamic directory, the frontier bitmap, wear state) stays in the
//! interpreter; everything decidable ahead of time lives here as data.
//! Because all per-op decisions are data, batch-parallel execution across
//! engines becomes a plan transformation rather than a scheduler rewrite.
//!
//! The plan deliberately *owns* its executor operands (packed bits,
//! flattened weights in execution order) rather than borrowing from
//! [`Partitioned`]: executors stay independent of the pattern layer and
//! read cache-contiguous slices. The cost is a second copy of the bit
//! patterns (8 B/op) and, for weighted graphs, of the edge weights,
//! alongside the `Partitioned` kept in the same cached artifact.

use crate::accel::config::ArchConfig;
use crate::cost::EventCounts;
use crate::pattern::extract::Partitioned;
use crate::pattern::tables::{ConfigTable, EngineSlot, ExecOrder, StaticAssignment, SubgraphTable};
use crate::pattern::Pattern;
use crate::util::codec::{CodecError, Reader, Writer};

/// One compiled per-op record: Algorithm 2's per-subgraph decisions
/// resolved to indices. Laid out contiguously in execution order,
/// grouped exactly like the subgraph table's destination (source) groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanOp {
    /// Index into `Partitioned::subgraphs` (stable subgraph identity).
    pub sg_idx: u32,
    /// First source vertex (wordline gather base).
    pub src_start: u32,
    /// First destination vertex (candidate scatter base).
    pub dst_start: u32,
    /// Block row feeding this op — the frontier bitmap masks on this.
    pub src_block: u32,
    /// Rank-interned pattern id (index into the CT ranking). The dynamic
    /// directory is a dense vector over these ranks — no `Pattern` hash
    /// keys anywhere in the hot loop.
    pub pattern_rank: u32,
    /// Driven wordlines for a dynamic MVM (`active_rows`, min 1).
    pub rows: u32,
    /// Rows actually read on the static path: 1 when the CT row-address
    /// shortcut applies (single-edge pattern, §III.B), else `rows`.
    pub read_rows: u32,
    /// Pre-resolved static slot candidates: `slot_range` into the plan's
    /// slot pool. Empty range = dynamic op.
    slot_start: u32,
    slot_len: u32,
}

impl PlanOp {
    /// Is this op served by a static engine (Alg. 2 l. 11)?
    #[inline]
    pub fn is_static(&self) -> bool {
        self.slot_len > 0
    }

    /// Candidate-slot range into the plan's slot pool.
    #[inline]
    pub fn slot_range(&self) -> std::ops::Range<usize> {
        self.slot_start as usize..(self.slot_start + self.slot_len) as usize
    }
}

/// The compiled schedule for one `(graph, architecture)` pair.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    /// Crossbar size C the plan was compiled for.
    pub c: usize,
    pub num_vertices: u32,
    /// Block rows/cols of the adjacency matrix (frontier bitmap length).
    pub num_blocks: u32,
    /// Whether edge weights were kept by partitioning (SSSP).
    pub weighted: bool,
    /// Distinct patterns — the dynamic directory is a dense vec of this
    /// length, indexed by `PlanOp::pattern_rank`.
    pub num_patterns: u32,
    // Engine geometry and schedule shape the plan was compiled against;
    // the interpreter refuses to run a plan against a mismatched
    // ArchConfig.
    pub static_engines: u32,
    pub total_engines: u32,
    pub crossbars_per_engine: u32,
    /// Execution order baked into the group structure.
    pub order: ExecOrder,
    /// Static-assignment policy the slot section was built with.
    pub static_assignment: StaticAssignment,
    /// Per-op records, contiguous in execution order.
    pub ops: Vec<PlanOp>,
    /// `groups[g]..groups[g+1]` delimits batch g in `ops` (Alg. 2 l. 9).
    pub groups: Vec<u32>,
    /// Flattened static-slot candidates (`PlanOp::slot_range` indexes here).
    slot_pool: Vec<EngineSlot>,
    /// Precomputed lane partitioning for batch-parallel execution.
    lanes: LaneTable,
    /// Flat CSR-style snapshot→`xs` gather table (see [`GatherTable`]).
    gather: GatherTable,
    /// One-time static configuration (Alg. 2 ll. 6–8), in CT rank order.
    static_config: Vec<(EngineSlot, Pattern)>,
    /// rank → pattern, for dynamic `configure` (ll. 13–15).
    rank_pattern: Vec<Pattern>,
    /// Per-op packed pattern bits, aligned with `ops`.
    op_bits: Vec<u64>,
    /// Per-op weight ranges into `weights` (len ops+1; empty if unweighted).
    weight_off: Vec<u32>,
    /// Flattened per-op edge weights in bit (cell) order.
    weights: Vec<f32>,
    /// Out-degree per vertex (PageRank wordline scaling), built once.
    out_degrees: Vec<u32>,
}

/// Sentinel in [`LaneTable`]: the op's engine is a runtime decision
/// (multi-replica least-busy pick or the dynamic replacement policy).
pub const LANE_RUNTIME: u32 = u32::MAX;

/// Precomputed lane partitioning for batch-parallel superstep execution
/// ([`sched::par`](super::par)): which ops have a compile-time-fixed home
/// engine, and how many such ops each engine can ever receive.
///
/// Lane identity follows engines — an engine's entire per-superstep work
/// queue replays on exactly one worker thread, so all engine-local state
/// (busy time, event counters, crossbar contents, wear) stays
/// thread-local. Single-replica static ops resolve their engine here, at
/// compile time; multi-replica static ops (runtime least-busy) and
/// dynamic ops (runtime replacement policy) are marked [`LANE_RUNTIME`]
/// and resolved by the dispatch pass. Rebuilt alongside the static-slot
/// section by [`ExecutionPlan::rebuild_static_slots`], since the
/// static/dynamic split is exactly what decides op homes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneTable {
    /// op index -> home engine, or [`LANE_RUNTIME`].
    home: Vec<u32>,
    /// Upper bound (frontier ignored) of compile-time-homed ops per
    /// engine; lane work queues preallocate to this.
    fixed_per_engine: Vec<u32>,
    /// Static ops needing a runtime least-busy pick among replicas.
    pub multi_replica_ops: u32,
    /// Ops on the dynamic (replacement-policy) path.
    pub dynamic_path_ops: u32,
}

impl LaneTable {
    fn build(ops: &[PlanOp], slot_pool: &[EngineSlot], total_engines: u32) -> Self {
        let mut home = Vec::with_capacity(ops.len());
        let mut fixed_per_engine = vec![0u32; total_engines as usize];
        let mut multi_replica_ops = 0u32;
        let mut dynamic_path_ops = 0u32;
        for op in ops {
            let h = match op.slot_len {
                0 => {
                    dynamic_path_ops += 1;
                    LANE_RUNTIME
                }
                1 => {
                    let e = slot_pool[op.slot_start as usize].engine;
                    fixed_per_engine[e as usize] += 1;
                    e
                }
                _ => {
                    multi_replica_ops += 1;
                    LANE_RUNTIME
                }
            };
            home.push(h);
        }
        Self { home, fixed_per_engine, multi_replica_ops, dynamic_path_ops }
    }

    /// Compile-time home engine of op `op`, if it has one.
    #[inline]
    pub fn home_of(&self, op: usize) -> Option<u32> {
        (self.home[op] != LANE_RUNTIME).then_some(self.home[op])
    }

    /// Upper bound of compile-time-homed ops engine `engine` can receive
    /// in one superstep (0 for engines outside the table's geometry).
    pub fn fixed_ops_on(&self, engine: u32) -> u32 {
        self.fixed_per_engine.get(engine as usize).copied().unwrap_or(0)
    }

    /// Ops whose home engine is fixed at compile time.
    pub fn fixed_ops(&self) -> u32 {
        self.home.len() as u32 - self.multi_replica_ops - self.dynamic_path_ops
    }

    pub fn len(&self) -> usize {
        self.home.len()
    }

    pub fn is_empty(&self) -> bool {
        self.home.is_empty()
    }
}

/// Flat CSR-style per-op source-gather table: for op `k`,
/// `off[k]..off[k+1]` delimits the source vertex indices feeding its C
/// wordlines (clipped to the vertex count); the remaining
/// `C - (off[k+1] - off[k])` wordlines are identity padding. Built once
/// at plan compile time so the per-superstep snapshot→`xs` gather is an
/// indexed copy — no bounds test per wordline, no re-derivation per
/// superstep — and **preserved verbatim by
/// [`ExecutionPlan::rebuild_static_slots`]** (gather sources are
/// split-independent, like the op records they mirror).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GatherTable {
    off: Vec<u32>,
    idx: Vec<u32>,
}

impl GatherTable {
    fn build(ops: &[PlanOp], c: usize, num_vertices: u32) -> Self {
        let mut off = Vec::with_capacity(ops.len() + 1);
        off.push(0u32);
        let mut idx = Vec::with_capacity(ops.len() * c);
        for op in ops {
            let valid = (num_vertices.saturating_sub(op.src_start) as usize).min(c);
            idx.extend(op.src_start..op.src_start + valid as u32);
            off.push(idx.len() as u32);
        }
        Self { off, idx }
    }

    /// Source vertex indices of op `k` plus the identity-padding count
    /// filling the op's C wordlines.
    #[inline]
    pub fn sources_of(&self, k: usize, c: usize) -> (&[u32], usize) {
        let s = &self.idx[self.off[k] as usize..self.off[k + 1] as usize];
        (s, c - s.len())
    }

    /// Number of ops covered.
    pub fn len(&self) -> usize {
        self.off.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Reconfiguration cost of a plan-section rebuild: what a live
/// accelerator pays to morph the old static configuration into the new
/// one, counted by diffing occupancy per physical crossbar. A pattern
/// re-homed to a different crossbar is exactly **one** crossbar write
/// (programming its new home — the vacated crossbar is abandoned, not
/// erased), never zero (the new home must be programmed) and never two.
/// Returned by [`ExecutionPlan::rebuild_static_slots`] and
/// [`ExecutionPlan::patch_sections`] to feed `sched::patch` stats and
/// the coordinator's delta metrics; run-level `RunResult` accounting is
/// untouched (every run models init from scratch, which is what keeps a
/// patched plan bit-identical to a cold recompile).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SectionRebuild {
    /// Crossbars whose occupant changed (including empty → occupied).
    pub crossbar_writes: u64,
    /// ReRAM cells toggled across those writes (SET + RESET).
    pub write_bits: u64,
}

impl SectionRebuild {
    /// Diff two static configurations by physical crossbar.
    fn between(old: &[(EngineSlot, Pattern)], new: &[(EngineSlot, Pattern)]) -> Self {
        let prior: std::collections::HashMap<(u32, u32), Pattern> =
            old.iter().map(|&(s, p)| ((s.engine, s.crossbar), p)).collect();
        let mut out = Self::default();
        for &(slot, pattern) in new {
            let was = prior
                .get(&(slot.engine, slot.crossbar))
                .copied()
                .unwrap_or(Pattern::EMPTY);
            if pattern != was {
                out.crossbar_writes += 1;
                out.write_bits += pattern.write_cost_from(was) as u64;
            }
        }
        out
    }

    /// The rebuild as hardware events, mirroring what a dynamic-engine
    /// `configure` counts per crossbar write: one reconfiguration, the
    /// toggled cells, and the CT fetch + buffer store pair.
    pub fn event_counts(&self) -> EventCounts {
        EventCounts {
            write_bits: self.write_bits,
            sram_accesses: 2 * self.crossbar_writes,
            reconfigs: self.crossbar_writes,
            ..EventCounts::default()
        }
    }
}

/// Static-slot sections derived from a config table: the slot pool,
/// per-rank candidate ranges, and the init-time configuration list.
fn slot_sections(
    ct: &ConfigTable,
) -> (Vec<EngineSlot>, Vec<(u32, u32)>, Vec<(EngineSlot, Pattern)>) {
    let mut pool = Vec::new();
    let mut ranges = Vec::with_capacity(ct.len());
    let mut init = Vec::new();
    for entry in &ct.entries {
        let start = pool.len() as u32;
        for &slot in &entry.slots {
            pool.push(slot);
            init.push((slot, entry.pattern));
        }
        ranges.push((start, entry.slots.len() as u32));
    }
    (pool, ranges, init)
}

/// Contiguous, group-aligned subgraph-table entry ranges for parallel
/// emission: walk the group boundaries greedily so each of at most `n`
/// ranges holds roughly `st.len() / n` entries. The split can never
/// change the emitted bytes — ranges concatenate in entry order — so
/// balance is purely a latency knob; group alignment keeps each worker
/// on whole destination (source) groups.
fn entry_ranges(st: &SubgraphTable, n: usize) -> Vec<std::ops::Range<usize>> {
    let total = st.len();
    let target = total.div_ceil(n.max(1)).max(1);
    let mut ranges = Vec::new();
    let mut start = 0usize;
    for gw in st.groups.windows(2) {
        let end = gw[1] as usize;
        if end - start >= target {
            ranges.push(start..end);
            start = end;
        }
    }
    if start < total || ranges.is_empty() {
        ranges.push(start..total);
    }
    ranges
}

/// The op/operand records emitted for one contiguous subgraph-table
/// entry range — the unit of parallel plan emission. Ranges
/// concatenated in entry order reproduce the sequential emission byte
/// for byte; `weight_off` holds range-local end offsets, rebased onto
/// the plan-global section at append time.
#[derive(Debug)]
pub(crate) struct EmittedOps {
    ops: Vec<PlanOp>,
    op_bits: Vec<u64>,
    weights: Vec<f32>,
    weight_off: Vec<u32>,
}

impl ExecutionPlan {
    /// Empty plan carrying only compiled geometry — the shared starting
    /// point of [`build`](Self::build) and
    /// [`build_pooled`](Self::build_pooled) before section emission.
    fn shell(part: &Partitioned, st: &SubgraphTable, arch: &ArchConfig) -> Self {
        Self {
            c: part.c,
            num_vertices: part.num_vertices,
            num_blocks: part.num_blocks(),
            weighted: part.weights.is_some(),
            num_patterns: 0,
            static_engines: arch.static_engines,
            total_engines: arch.total_engines,
            crossbars_per_engine: arch.crossbars_per_engine,
            order: st.order,
            static_assignment: arch.static_assignment,
            ops: Vec::new(),
            groups: vec![0, 0],
            slot_pool: Vec::new(),
            lanes: LaneTable::build(&[], &[], arch.total_engines),
            gather: GatherTable::build(&[], part.c, part.num_vertices),
            static_config: Vec::new(),
            rank_pattern: Vec::new(),
            op_bits: Vec::new(),
            weight_off: Vec::new(),
            weights: Vec::new(),
            out_degrees: Vec::new(),
        }
    }

    /// Compile the schedule from the Alg.-1 outputs and the architecture.
    /// Op order mirrors `st.entries` exactly (one op per subgraph, in
    /// execution order), so plan op index g equals subgraph-table entry
    /// index g — the differential oracle relies on this.
    pub fn build(
        part: &Partitioned,
        ct: &ConfigTable,
        st: &SubgraphTable,
        arch: &ArchConfig,
    ) -> Self {
        let mut plan = Self::shell(part, st, arch);
        plan.emit_sections(part, ct, st);
        plan
    }

    /// [`build`](Self::build) with the per-entry emission fanned out over
    /// `pool`: group-aligned entry ranges emit on workers and
    /// concatenate in range order, so the result is field-for-field
    /// identical to the sequential build by construction (both funnel
    /// through [`emit_entry_range`](Self::emit_entry_range)).
    pub fn build_pooled(
        part: &Partitioned,
        ct: &ConfigTable,
        st: &SubgraphTable,
        arch: &ArchConfig,
        pool: &mut super::pool::WorkerPool,
    ) -> Self {
        let mut plan = Self::shell(part, st, arch);
        plan.emit_sections_with(part, ct, st, Some(pool));
        plan
    }

    /// Emit the op/operand records for one contiguous subgraph-table
    /// entry range. Every emission path — sequential build, delta patch,
    /// pooled build — runs entries through this one loop.
    pub(crate) fn emit_entry_range(
        part: &Partitioned,
        ct: &ConfigTable,
        st: &SubgraphTable,
        rank_slots: &[(u32, u32)],
        entries: std::ops::Range<usize>,
        weighted: bool,
    ) -> EmittedOps {
        let c = part.c;
        let n = entries.len();
        let mut out = EmittedOps {
            ops: Vec::with_capacity(n),
            op_bits: Vec::with_capacity(n),
            weights: Vec::new(),
            weight_off: Vec::with_capacity(if weighted { n } else { 0 }),
        };
        for e in &st.entries[entries] {
            let sg = &part.subgraphs[e.sg_idx as usize];
            let entry = ct.entry_at(e.pattern_rank);
            let rows = entry.active_rows.max(1);
            let (slot_start, slot_len) = rank_slots[e.pattern_rank as usize];
            out.ops.push(PlanOp {
                sg_idx: e.sg_idx,
                src_start: e.src_start,
                dst_start: e.dst_start,
                src_block: e.src_start / c as u32,
                pattern_rank: e.pattern_rank,
                rows,
                read_rows: if entry.row_addr.is_some() { 1 } else { rows },
                slot_start,
                slot_len,
            });
            out.op_bits.push(sg.pattern.0);
            if weighted {
                out.weights
                    .extend_from_slice(&part.weights.as_ref().unwrap()[e.sg_idx as usize]);
                out.weight_off.push(out.weights.len() as u32);
            }
        }
        out
    }

    /// Clear and refill every graph-derived section in place — op
    /// records, executor operands (packed bits, flattened weights),
    /// groups, slot pool, static config, interned patterns, lane +
    /// gather tables, out-degrees — from fresh Alg.-1 outputs. The one
    /// emission path shared by [`build`](Self::build) and
    /// [`patch_sections`](Self::patch_sections): compile and patch can
    /// never drift, because there is no second code path to drift.
    /// Geometry fields (C, vertex count, engine counts, order, policy)
    /// are the caller's responsibility and are not touched.
    fn emit_sections(&mut self, part: &Partitioned, ct: &ConfigTable, st: &SubgraphTable) {
        self.emit_sections_with(part, ct, st, None);
    }

    /// [`emit_sections`](Self::emit_sections) with the per-entry loop
    /// optionally fanned out over a worker pool. With `None` the whole
    /// entry span emits inline (one range); with a pool, group-aligned
    /// ranges emit on workers and concatenate in range order. Either
    /// way the emitted sections are identical — the split is a latency
    /// knob that can never reach the artifact bytes. Derived tables
    /// (lanes, gather, out-degrees, slot sections) build after
    /// concatenation, identically on both paths.
    fn emit_sections_with(
        &mut self,
        part: &Partitioned,
        ct: &ConfigTable,
        st: &SubgraphTable,
        pool: Option<&mut super::pool::WorkerPool>,
    ) {
        let c = part.c;
        let weighted = part.weights.is_some();
        let (slot_pool, rank_slots, static_config) = slot_sections(ct);

        self.ops.clear();
        self.ops.reserve(st.len());
        self.op_bits.clear();
        self.op_bits.reserve(st.len());
        self.weight_off.clear();
        self.weights.clear();
        if weighted {
            self.weight_off.reserve(st.len() + 1);
            self.weight_off.push(0);
        }
        let emitted = match pool {
            Some(pool) => {
                let ranges = entry_ranges(st, pool.workers());
                pool.emit_ranges(part, ct, st, &rank_slots, &ranges, weighted)
            }
            None => vec![Self::emit_entry_range(part, ct, st, &rank_slots, 0..st.len(), weighted)],
        };
        for e in emitted {
            self.ops.extend(e.ops);
            self.op_bits.extend(e.op_bits);
            if weighted {
                let base = self.weights.len() as u32;
                self.weight_off.extend(e.weight_off.iter().map(|&end| base + end));
                self.weights.extend(e.weights);
            }
        }

        self.lanes = LaneTable::build(&self.ops, &slot_pool, self.total_engines);
        self.gather = GatherTable::build(&self.ops, c, part.num_vertices);
        self.weighted = weighted;
        self.num_patterns = ct.len() as u32;
        self.groups = st.groups.clone();
        self.slot_pool = slot_pool;
        self.static_config = static_config;
        self.rank_pattern = ct.entries.iter().map(|e| e.pattern).collect();
        self.out_degrees = out_degrees(part);
    }

    /// Re-emit every graph-derived section against the *mutated* Alg.-1
    /// outputs while keeping the compiled geometry — the delta-patch
    /// path (`sched::patch`). The caller re-runs ranking/CT/ST over the
    /// patched `Partitioned` (cheap; partitioning itself is what the
    /// delta path avoids redoing from the raw graph) and this re-emits
    /// through the same code path `build` uses, so the patched plan is
    /// field-for-field identical to a cold compile of the mutated graph
    /// by construction. Errors on anything that is not a pure content
    /// update: changed geometry, vertex count, window size, execution
    /// order, weightedness, or a config table that does not encode
    /// `arch`'s layout. Returns the static-reconfiguration cost
    /// ([`SectionRebuild`]) of morphing the old slot section into the
    /// new one.
    pub(crate) fn patch_sections(
        &mut self,
        part: &Partitioned,
        ct: &ConfigTable,
        st: &SubgraphTable,
        arch: &ArchConfig,
    ) -> anyhow::Result<SectionRebuild> {
        anyhow::ensure!(
            self.matches(arch),
            "section patch cannot change the plan's compiled geometry"
        );
        anyhow::ensure!(
            part.c == self.c && part.num_vertices == self.num_vertices,
            "section patch requires the same window size and vertex count \
             (plan C={} V={}, partitioning C={} V={})",
            self.c,
            self.num_vertices,
            part.c,
            part.num_vertices
        );
        anyhow::ensure!(
            st.order == self.order,
            "section patch cannot change the execution order (plan {:?}, table {:?})",
            self.order,
            st.order
        );
        anyhow::ensure!(
            part.weights.is_some() == self.weighted,
            "section patch cannot change weightedness (plan weighted={})",
            self.weighted
        );
        anyhow::ensure!(
            ct.assignment == arch.static_assignment
                && ct.num_static_engines == arch.static_engines
                && ct.crossbars_per_engine == arch.crossbars_per_engine,
            "config table ({:?}, N={}, M={}) does not match the plan's \
             architecture ({:?}, N={}, M={})",
            ct.assignment,
            ct.num_static_engines,
            ct.crossbars_per_engine,
            arch.static_assignment,
            arch.static_engines,
            arch.crossbars_per_engine
        );
        let old_config = std::mem::take(&mut self.static_config);
        self.emit_sections(part, ct, st);
        Ok(SectionRebuild::between(&old_config, &self.static_config))
    }

    /// An executor-only plan straight from a partitioning: one op per
    /// subgraph in partition order (op index == subgraph index), no
    /// static-slot section, a single group. Lets executor callers (unit
    /// tests, microbenches, PJRT cross-checks) drive [`StepBatch`]es
    /// without running Alg. 1; it is not schedulable — the interpreter
    /// rejects its zeroed engine geometry.
    pub fn from_partitioned(part: &Partitioned) -> Self {
        let c = part.c;
        let weighted = part.weights.is_some();
        let n = part.subgraphs.len();
        let mut weight_off = Vec::new();
        let mut weights = Vec::new();
        if weighted {
            weight_off.reserve(n + 1);
            weight_off.push(0);
        }
        let mut ops = Vec::with_capacity(n);
        let mut op_bits = Vec::with_capacity(n);
        for (k, sg) in part.subgraphs.iter().enumerate() {
            let rows = sg.pattern.active_row_count(c).max(1);
            ops.push(PlanOp {
                sg_idx: k as u32,
                src_start: sg.brow * c as u32,
                dst_start: sg.bcol * c as u32,
                src_block: sg.brow,
                pattern_rank: k as u32,
                rows,
                read_rows: rows,
                slot_start: 0,
                slot_len: 0,
            });
            op_bits.push(sg.pattern.0);
            if weighted {
                weights.extend_from_slice(&part.weights.as_ref().unwrap()[k]);
                weight_off.push(weights.len() as u32);
            }
        }
        let lanes = LaneTable::build(&ops, &[], 0);
        let gather = GatherTable::build(&ops, c, part.num_vertices);
        Self {
            c,
            num_vertices: part.num_vertices,
            num_blocks: part.num_blocks(),
            weighted,
            num_patterns: n as u32,
            static_engines: 0,
            total_engines: 0,
            crossbars_per_engine: 0,
            order: ExecOrder::default(),
            static_assignment: StaticAssignment::default(),
            ops,
            groups: vec![0, n as u32],
            slot_pool: Vec::new(),
            lanes,
            gather,
            static_config: Vec::new(),
            rank_pattern: part.subgraphs.iter().map(|s| s.pattern).collect(),
            op_bits,
            weight_off,
            weights,
            out_degrees: out_degrees(part),
        }
    }

    /// Recompile only the static-slot section against a new config table
    /// (same ranking — same graph). The DSE static-split sweep calls this
    /// per candidate N instead of recompiling the whole plan: op records,
    /// the gather table, and weights are split-independent and preserved
    /// verbatim (only the slot pool, static config, and lane table — the
    /// sections the split decides — are rebuilt). Errors (like the
    /// interpreter's own mismatch guard) on a config table from another
    /// ranking or an architecture whose execution order differs from the
    /// one baked into the plan's groups. Returns the
    /// [`SectionRebuild`] cost of morphing the old static configuration
    /// into the new one (what a live accelerator would pay to follow the
    /// move).
    pub fn rebuild_static_slots(
        &mut self,
        ct: &ConfigTable,
        arch: &ArchConfig,
    ) -> anyhow::Result<SectionRebuild> {
        anyhow::ensure!(
            ct.len() as u32 == self.num_patterns,
            "static-slot rebuild requires the plan's own pattern ranking \
             ({} patterns, config table has {})",
            self.num_patterns,
            ct.len()
        );
        anyhow::ensure!(
            arch.order == self.order,
            "static-slot rebuild cannot change the execution order \
             (plan {:?}, requested {:?})",
            self.order,
            arch.order
        );
        // The slot section must actually encode the layout `arch` asks
        // for, or `matches()` would later vouch for a layout the caller
        // never requested.
        anyhow::ensure!(
            ct.assignment == arch.static_assignment
                && ct.num_static_engines == arch.static_engines
                && ct.crossbars_per_engine == arch.crossbars_per_engine,
            "config table ({:?}, N={}, M={}) does not match the requested \
             architecture ({:?}, N={}, M={})",
            ct.assignment,
            ct.num_static_engines,
            ct.crossbars_per_engine,
            arch.static_assignment,
            arch.static_engines,
            arch.crossbars_per_engine
        );
        let (slot_pool, rank_slots, static_config) = slot_sections(ct);
        let rebuild = SectionRebuild::between(&self.static_config, &static_config);
        for op in &mut self.ops {
            let (start, len) = rank_slots[op.pattern_rank as usize];
            op.slot_start = start;
            op.slot_len = len;
        }
        // The lane table is a pure function of the slot section: op homes
        // move with the static split, so it is rebuilt with it.
        self.lanes = LaneTable::build(&self.ops, &slot_pool, arch.total_engines);
        self.slot_pool = slot_pool;
        self.static_config = static_config;
        self.static_engines = arch.static_engines;
        self.total_engines = arch.total_engines;
        self.crossbars_per_engine = arch.crossbars_per_engine;
        self.static_assignment = arch.static_assignment;
        Ok(rebuild)
    }

    /// Does the plan's compiled geometry and schedule shape match
    /// `arch`? The interpreter refuses to run on a mismatch (a plan
    /// compiled for another split would dispatch to engines that don't
    /// exist; one compiled under another execution order or assignment
    /// policy would batch and pin ops the caller didn't ask for).
    pub fn matches(&self, arch: &ArchConfig) -> bool {
        self.c == arch.crossbar_size
            && self.static_engines == arch.static_engines
            && self.total_engines == arch.total_engines
            && self.crossbars_per_engine == arch.crossbars_per_engine
            && self.order == arch.order
            && self.static_assignment == arch.static_assignment
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len() - 1
    }

    /// Bounds of group `g` in `ops`.
    #[inline]
    pub fn group_bounds(&self, g: usize) -> (usize, usize) {
        (self.groups[g] as usize, self.groups[g + 1] as usize)
    }

    /// Pre-resolved static slot candidates of `op` (empty = dynamic).
    #[inline]
    pub fn slots_of(&self, op: &PlanOp) -> &[EngineSlot] {
        &self.slot_pool[op.slot_range()]
    }

    /// Precomputed lane partitioning (batch-parallel execution).
    #[inline]
    pub fn lanes(&self) -> &LaneTable {
        &self.lanes
    }

    /// Precomputed snapshot→`xs` gather table (see [`GatherTable`]).
    #[inline]
    pub fn gather(&self) -> &GatherTable {
        &self.gather
    }

    /// One-time static engine configuration (Alg. 2 ll. 6–8).
    pub fn static_config(&self) -> &[(EngineSlot, Pattern)] {
        &self.static_config
    }

    /// Pattern for a rank — the only place the dynamic path ever needs
    /// the actual `Pattern` (to program a crossbar).
    #[inline]
    pub fn pattern_of_rank(&self, rank: u32) -> Pattern {
        self.rank_pattern[rank as usize]
    }

    /// Out-degree per vertex (built once at compile time).
    #[inline]
    pub fn out_degrees(&self) -> &[u32] {
        &self.out_degrees
    }

    /// Select `op_ids` (plan op indices) as one executor batch.
    #[inline]
    pub fn batch<'a>(&'a self, op_ids: &'a [u32]) -> StepBatch<'a> {
        StepBatch { plan: self, op_ids }
    }

    /// Serialize the plan into the on-disk artifact format
    /// (`session::store`): explicit little-endian framing of the op
    /// records, groups, slot pool, static config, interned pattern
    /// table, executor operands and out-degrees. The lane and gather
    /// tables are **not** persisted — they are pure functions of the op
    /// records and are rebuilt by [`decode_from`](Self::decode_from)
    /// (derived state is never trusted from a file), so a decoded plan
    /// is still field-for-field equal to the encoded one and
    /// bit-identical in behaviour under every execution mechanism.
    pub(crate) fn encode_into(&self, w: &mut Writer) {
        w.put_u32(self.c as u32);
        w.put_u32(self.num_vertices);
        w.put_u32(self.num_blocks);
        w.put_u8(self.weighted as u8);
        w.put_u32(self.num_patterns);
        w.put_u32(self.static_engines);
        w.put_u32(self.total_engines);
        w.put_u32(self.crossbars_per_engine);
        w.put_u8(self.order.to_code());
        w.put_u8(self.static_assignment.to_code());
        w.put_u64(self.ops.len() as u64);
        for op in &self.ops {
            w.put_u32(op.sg_idx);
            w.put_u32(op.src_start);
            w.put_u32(op.dst_start);
            w.put_u32(op.src_block);
            w.put_u32(op.pattern_rank);
            w.put_u32(op.rows);
            w.put_u32(op.read_rows);
            w.put_u32(op.slot_start);
            w.put_u32(op.slot_len);
        }
        w.put_u32s(&self.groups);
        w.put_u64(self.slot_pool.len() as u64);
        for s in &self.slot_pool {
            w.put_u32(s.engine);
            w.put_u32(s.crossbar);
        }
        w.put_u64(self.static_config.len() as u64);
        for (slot, pattern) in &self.static_config {
            w.put_u32(slot.engine);
            w.put_u32(slot.crossbar);
            w.put_u64(pattern.0);
        }
        w.put_u64(self.rank_pattern.len() as u64);
        for p in &self.rank_pattern {
            w.put_u64(p.0);
        }
        w.put_u64s(&self.op_bits);
        w.put_u32s(&self.weight_off);
        w.put_f32s(&self.weights);
        w.put_u32s(&self.out_degrees);
    }

    /// Decode a plan and validate every cross-section invariant the
    /// interpreter and executors index by, so a logically-inconsistent
    /// file (wrong schema, hand-edited bytes that still checksum) yields
    /// a typed error here instead of a panic in the superstep hot loop.
    pub(crate) fn decode_from(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        let c = r.u32()? as usize;
        // Checked before anything derives from it (table capacities,
        // gather spans): C bounds every per-op shape.
        if !(1..=crate::pattern::pattern::MAX_C).contains(&c) {
            return Err(CodecError::Invalid("crossbar size out of range"));
        }
        let num_vertices = r.u32()?;
        let num_blocks = r.u32()?;
        let weighted = r.u8()? != 0;
        let num_patterns = r.u32()?;
        let static_engines = r.u32()?;
        let total_engines = r.u32()?;
        let crossbars_per_engine = r.u32()?;
        let order = ExecOrder::from_code(r.u8()?)
            .ok_or(CodecError::Invalid("unknown execution-order code"))?;
        let static_assignment = StaticAssignment::from_code(r.u8()?)
            .ok_or(CodecError::Invalid("unknown static-assignment code"))?;
        let n_ops = r.prefixed_count(36)?;
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            ops.push(PlanOp {
                sg_idx: r.u32()?,
                src_start: r.u32()?,
                dst_start: r.u32()?,
                src_block: r.u32()?,
                pattern_rank: r.u32()?,
                rows: r.u32()?,
                read_rows: r.u32()?,
                slot_start: r.u32()?,
                slot_len: r.u32()?,
            });
        }
        let groups = r.u32s()?;
        let n_slots = r.prefixed_count(8)?;
        let mut slot_pool = Vec::with_capacity(n_slots);
        for _ in 0..n_slots {
            slot_pool.push(EngineSlot { engine: r.u32()?, crossbar: r.u32()? });
        }
        // Engine counts size per-engine vectors eagerly (the lane table
        // below, the scheduler's engine array at run time). No real
        // architecture is within orders of magnitude of this cap; a
        // corrupt count must not become a multi-GiB allocation.
        const MAX_DECODE_ENGINES: u32 = 1 << 20;
        if total_engines > MAX_DECODE_ENGINES {
            return Err(CodecError::Invalid("engine count implausibly large"));
        }
        // The lane and gather tables are derived state: never trusted
        // from the file, always rebuilt from the decoded op records (the
        // same rule the pattern-table hash indices follow). The builders
        // index the slot pool and per-engine vectors, so their inputs
        // are bounds-checked first.
        for op in &ops {
            if (op.slot_start as usize + op.slot_len as usize) > slot_pool.len() {
                return Err(CodecError::Invalid("op slot range out of pool"));
            }
        }
        if !slot_pool
            .iter()
            .all(|s| s.engine < total_engines && s.crossbar < crossbars_per_engine.max(1))
        {
            return Err(CodecError::Invalid("engine slot out of the plan's geometry"));
        }
        let lanes = LaneTable::build(&ops, &slot_pool, total_engines);
        let gather = GatherTable::build(&ops, c, num_vertices);
        let n_cfg = r.prefixed_count(16)?;
        let mut static_config = Vec::with_capacity(n_cfg);
        for _ in 0..n_cfg {
            static_config.push((
                EngineSlot { engine: r.u32()?, crossbar: r.u32()? },
                Pattern(r.u64()?),
            ));
        }
        let rank_pattern: Vec<Pattern> = r.u64s()?.into_iter().map(Pattern).collect();
        let op_bits = r.u64s()?;
        let weight_off = r.u32s()?;
        let weights = r.f32s()?;
        let out_degrees = r.u32s()?;

        let plan = Self {
            c,
            num_vertices,
            num_blocks,
            weighted,
            num_patterns,
            static_engines,
            total_engines,
            crossbars_per_engine,
            order,
            static_assignment,
            ops,
            groups,
            slot_pool,
            lanes,
            gather,
            static_config,
            rank_pattern,
            op_bits,
            weight_off,
            weights,
            out_degrees,
        };
        plan.validate_decoded()?;
        Ok(plan)
    }

    /// Structural invariants every interpreter/executor access relies on
    /// beyond what [`decode_from`](Self::decode_from) already checked
    /// before rebuilding the derived tables (crossbar size, slot ranges,
    /// slot-pool geometry).
    fn validate_decoded(&self) -> Result<(), CodecError> {
        let n = self.ops.len();
        // The frontier bitmap is num_blocks long and reduce/apply indexes
        // `bitmap[v / c]` for every vertex without a hot-loop bounds test.
        if self.num_blocks != self.num_vertices.div_ceil(self.c as u32) {
            return Err(CodecError::Invalid("block count inconsistent with vertices"));
        }
        if self.groups.first() != Some(&0)
            || self.groups.last().copied() != Some(n as u32)
            || self.groups.windows(2).any(|w| w[0] > w[1])
        {
            return Err(CodecError::Invalid("group bounds not a monotone cover of ops"));
        }
        if self.rank_pattern.len() as u32 != self.num_patterns {
            return Err(CodecError::Invalid("pattern table length != num_patterns"));
        }
        if self.op_bits.len() != n {
            return Err(CodecError::Invalid("per-op section lengths diverge"));
        }
        if self.weighted {
            if self.weight_off.len() != n + 1
                || self.weight_off.first().copied().unwrap_or(1) != 0
                || self.weight_off.last().copied().unwrap_or(1) as usize != self.weights.len()
                || self.weight_off.windows(2).any(|w| w[0] > w[1])
            {
                return Err(CodecError::Invalid("weight offsets inconsistent with ops"));
            }
        } else if !self.weight_off.is_empty() || !self.weights.is_empty() {
            return Err(CodecError::Invalid("unweighted plan carries weight data"));
        }
        let cells = self.c * self.c;
        for (k, op) in self.ops.iter().enumerate() {
            if op.pattern_rank >= self.num_patterns {
                return Err(CodecError::Invalid("op pattern rank out of table"));
            }
            if op.src_block >= self.num_blocks {
                return Err(CodecError::Invalid("op source block out of bitmap"));
            }
            // Executors index `x[bit / c]` / `out[bit]` straight off the
            // packed bits; a bit beyond the C×C window is a panic, not
            // an edge. (C ≤ 8 was checked at decode, so cells ≤ 64.)
            if cells < 64 && self.op_bits[k] >> cells != 0 {
                return Err(CodecError::Invalid("op bits outside the C×C window"));
            }
            // The weighted kernel walks one weight per set bit.
            if self.weighted
                && self.weight_off[k + 1] - self.weight_off[k] != self.op_bits[k].count_ones()
            {
                return Err(CodecError::Invalid("op weight span != pattern edge count"));
            }
        }
        // Static-config slots feed `engines[e].configure(m, ..)` at init.
        let slot_ok = |s: &EngineSlot| {
            s.engine < self.total_engines && s.crossbar < self.crossbars_per_engine.max(1)
        };
        if !self.static_config.iter().all(|(s, _)| slot_ok(s)) {
            return Err(CodecError::Invalid("static config slot out of the plan's geometry"));
        }
        // Patterns from both tables are programmed into C×C crossbars
        // (`Crossbar::configure` walks set bits into a cells-long wear
        // vector with only a debug_assert) — same window rule as op_bits.
        if cells < 64
            && (self.rank_pattern.iter().any(|p| p.0 >> cells != 0)
                || self.static_config.iter().any(|(_, p)| p.0 >> cells != 0))
        {
            return Err(CodecError::Invalid("table pattern outside the C×C window"));
        }
        if self.out_degrees.len() != self.num_vertices as usize {
            return Err(CodecError::Invalid("out-degree table length != num_vertices"));
        }
        Ok(())
    }
}

/// Out-degree per vertex, reconstructed from the partitioning (the ST is
/// the only main-memory representation at runtime).
fn out_degrees(part: &Partitioned) -> Vec<u32> {
    let c = part.c;
    let mut deg = vec![0u32; part.num_vertices as usize];
    for sg in &part.subgraphs {
        let base = sg.brow as usize * c;
        let mut bits = sg.pattern.0;
        while bits != 0 {
            let bit = bits.trailing_zeros() as usize;
            let v = base + bit / c;
            if v < deg.len() {
                deg[v] += 1;
            }
            bits &= bits - 1;
        }
    }
    deg
}

/// A selected slice of plan ops handed to a
/// [`StepExecutor`](crate::sched::StepExecutor): the executor reads plan-owned operands
/// (packed bits, weight slices, dense matrices) through positional
/// accessors instead of rebuilding shapes from a `Partitioned` per call.
#[derive(Debug, Clone, Copy)]
pub struct StepBatch<'a> {
    plan: &'a ExecutionPlan,
    op_ids: &'a [u32],
}

impl<'a> StepBatch<'a> {
    /// Crossbar size (lane width of `xs`/`out`).
    #[inline]
    pub fn c(&self) -> usize {
        self.plan.c
    }

    /// Number of selected ops.
    #[inline]
    pub fn len(&self) -> usize {
        self.op_ids.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.op_ids.is_empty()
    }

    /// Whether the plan carries edge weights (SSSP operands).
    #[inline]
    pub fn weighted(&self) -> bool {
        self.plan.weighted
    }

    /// Packed pattern bits of the k-th selected op.
    #[inline]
    pub fn bits(&self, k: usize) -> u64 {
        self.plan.op_bits[self.op_ids[k] as usize]
    }

    /// Edge weights of the k-th selected op, in bit (cell) order.
    /// Panics when the plan is unweighted — check [`weighted`](Self::weighted) first.
    #[inline]
    pub fn weights_of(&self, k: usize) -> &'a [f32] {
        let op = self.op_ids[k] as usize;
        &self.plan.weights[self.plan.weight_off[op] as usize..self.plan.weight_off[op + 1] as usize]
    }

    /// Write the k-th selected op's dense C×C weight matrix into `out`
    /// (which must be zeroed, length C²) straight from the plan-owned
    /// packed bits/weights — the PJRT packing path, with memory bounded
    /// by the dispatch chunk rather than the graph.
    #[inline]
    pub fn dense_into(&self, k: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.plan.c * self.plan.c);
        let op = self.op_ids[k] as usize;
        let mut bits = self.plan.op_bits[op];
        if self.plan.weighted {
            let w = self.weights_of(k);
            let mut nth = 0usize;
            while bits != 0 {
                out[bits.trailing_zeros() as usize] = w[nth];
                bits &= bits - 1;
                nth += 1;
            }
        } else {
            while bits != 0 {
                out[bits.trailing_zeros() as usize] = 1.0;
                bits &= bits - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::{Coo, Edge};
    use crate::pattern::extract::partition;
    use crate::pattern::rank::PatternRanking;
    use crate::pattern::tables::ExecOrder;

    fn setup(weighted: bool) -> (Partitioned, ConfigTable, SubgraphTable, ArchConfig) {
        let g = Coo::from_edges(
            8,
            vec![
                Edge::weighted(0, 1, 2.0),
                Edge::weighted(2, 3, 3.0),
                Edge::weighted(4, 5, 4.0),
                Edge::weighted(7, 6, 5.0),
                Edge::weighted(0, 5, 6.0),
                Edge::weighted(1, 4, 7.0),
            ],
        );
        let arch = ArchConfig {
            crossbar_size: 2,
            total_engines: 4,
            static_engines: 2,
            ..ArchConfig::default()
        };
        let part = partition(&g, 2, weighted);
        let ranking = PatternRanking::from_partitioned(&part);
        let ct = ConfigTable::build(&ranking, 2, 2, 1, 2, arch.static_assignment);
        let st = SubgraphTable::build(&part, &ranking, ExecOrder::ColumnMajor);
        (part, ct, st, arch)
    }

    #[test]
    fn plan_ops_mirror_subgraph_table() {
        let (part, ct, st, arch) = setup(false);
        let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        assert_eq!(plan.num_ops(), st.len());
        assert_eq!(plan.groups, st.groups);
        for (op, e) in plan.ops.iter().zip(&st.entries) {
            assert_eq!(op.sg_idx, e.sg_idx);
            assert_eq!(op.src_start, e.src_start);
            assert_eq!(op.dst_start, e.dst_start);
            assert_eq!(op.pattern_rank, e.pattern_rank);
            assert_eq!(op.src_block, e.src_start / 2);
            let entry = ct.entry_at(e.pattern_rank);
            assert_eq!(op.is_static(), entry.is_static());
            assert_eq!(plan.slots_of(op), &entry.slots[..]);
            assert_eq!(op.rows, entry.active_rows.max(1));
            let want_read = if entry.row_addr.is_some() { 1 } else { op.rows };
            assert_eq!(op.read_rows, want_read);
        }
    }

    #[test]
    fn static_config_matches_ct_assignments() {
        let (part, ct, st, arch) = setup(false);
        let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        let want: Vec<_> = ct.static_assignments().map(|(e, s)| (s, e.pattern)).collect();
        assert_eq!(plan.static_config(), &want[..]);
        assert!(plan.matches(&arch));
    }

    #[test]
    fn rebuild_static_slots_changes_only_the_slot_section() {
        let (part, ct, st, arch) = setup(false);
        let mut plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        let before: Vec<_> = plan.ops.iter().map(|o| (o.sg_idx, o.rows, o.read_rows)).collect();

        let ranking = PatternRanking::from_partitioned(&part);
        let arch0 = ArchConfig { static_engines: 0, ..arch.clone() };
        let ct0 = ConfigTable::build(&ranking, 2, 0, 1, 4, arch0.static_assignment);
        plan.rebuild_static_slots(&ct0, &arch0).unwrap();
        assert!(plan.matches(&arch0));
        assert!(plan.static_config().is_empty());
        assert!(plan.ops.iter().all(|o| !o.is_static()));
        let after: Vec<_> = plan.ops.iter().map(|o| (o.sg_idx, o.rows, o.read_rows)).collect();
        assert_eq!(before, after, "non-slot op fields must be untouched");

        // A rebuild that would change the baked-in execution order (or
        // use a foreign ranking) is rejected, not silently applied.
        let rm = ArchConfig { order: ExecOrder::RowMajor, ..arch0 };
        assert!(plan.rebuild_static_slots(&ct0, &rm).is_err());
    }

    #[test]
    fn rebuild_reports_rehomes_as_single_writes() {
        // setup() yields static_config [((e0,x0), P_a), ((e1,x0), P_b)]
        // under the 2-static-engine split. Folding both statics onto one
        // engine with two crossbars re-homes rank 1 from (1,0) to (0,1):
        // exactly ONE crossbar write (programming the new home), never
        // zero and never two — the vacated crossbar is abandoned in
        // place, not erased.
        let (part, ct, st, arch) = setup(false);
        let mut plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        assert_eq!(plan.static_config().len(), 2);
        let rank1_pattern = plan.static_config()[1].1;

        // Rebuilding against the identical layout is a no-op: no writes.
        let same = plan.rebuild_static_slots(&ct, &arch).unwrap();
        assert_eq!(same, SectionRebuild::default());
        assert_eq!(same.event_counts(), EventCounts::default());

        let ranking = PatternRanking::from_partitioned(&part);
        let arch2 = ArchConfig {
            static_engines: 1,
            crossbars_per_engine: 2,
            ..arch.clone()
        };
        // Same dynamic capacity (2 slots) so the apportionment — and
        // therefore which ranks are static — is unchanged; only homes move.
        let ct2 = ConfigTable::build(&ranking, 2, 1, 2, 2, arch2.static_assignment);
        let moved = plan.rebuild_static_slots(&ct2, &arch2).unwrap();
        assert_eq!(moved.crossbar_writes, 1, "one re-home = one write");
        assert_eq!(moved.write_bits, rank1_pattern.nnz() as u64);
        let ev = moved.event_counts();
        assert_eq!(ev.reconfigs, 1);
        assert_eq!(ev.sram_accesses, 2); // row read + write per crossbar write
        assert_eq!(ev.write_bits, moved.write_bits);

        // Moving back is symmetric: (1,0) is empty after the fold, so
        // re-homing rank 1 there is again exactly one write.
        let back = plan.rebuild_static_slots(&ct, &arch).unwrap();
        assert_eq!(back.crossbar_writes, 1);
        assert_eq!(back.write_bits, rank1_pattern.nnz() as u64);
    }

    #[test]
    fn lane_table_homes_single_replica_static_ops() {
        let (part, ct, st, arch) = setup(false);
        let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        let lanes = plan.lanes();
        assert_eq!(lanes.len(), plan.num_ops());
        let mut fixed_seen = vec![0u32; arch.total_engines as usize];
        for (k, op) in plan.ops.iter().enumerate() {
            let slots = plan.slots_of(op);
            match lanes.home_of(k) {
                Some(e) => {
                    assert_eq!(slots.len(), 1, "op {k}: home implies one replica");
                    assert_eq!(e, slots[0].engine, "op {k}: wrong home engine");
                    fixed_seen[e as usize] += 1;
                }
                None => assert_ne!(slots.len(), 1, "op {k}: single replica left unhomed"),
            }
        }
        for (e, &n) in fixed_seen.iter().enumerate() {
            assert_eq!(n, lanes.fixed_ops_on(e as u32), "engine {e} capacity");
        }
        assert_eq!(
            lanes.fixed_ops() + lanes.multi_replica_ops + lanes.dynamic_path_ops,
            plan.num_ops() as u32
        );
    }

    #[test]
    fn gather_table_lists_clipped_contiguous_sources() {
        let (part, ct, st, arch) = setup(false);
        let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        let gather = plan.gather();
        assert_eq!(gather.len(), plan.num_ops());
        for (k, op) in plan.ops.iter().enumerate() {
            let (src, pad) = gather.sources_of(k, plan.c);
            assert_eq!(src.len() + pad, plan.c, "op {k}: always C wordlines");
            // Exactly the in-range wordlines, in wordline order.
            let want: Vec<u32> = (0..plan.c as u32)
                .map(|i| op.src_start + i)
                .filter(|&v| v < plan.num_vertices)
                .collect();
            assert_eq!(src, &want[..], "op {k}: clipped source range");
        }
    }

    #[test]
    fn rebuild_static_slots_preserves_the_gather_table() {
        let (part, ct, st, arch) = setup(false);
        let mut plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        let before = plan.gather().clone();
        let ranking = PatternRanking::from_partitioned(&part);
        let arch0 = ArchConfig { static_engines: 0, ..arch.clone() };
        let ct0 = ConfigTable::build(&ranking, 2, 0, 1, 4, arch0.static_assignment);
        plan.rebuild_static_slots(&ct0, &arch0).unwrap();
        assert_eq!(plan.gather(), &before, "gather is split-independent");
    }

    #[test]
    fn rebuild_static_slots_rebuilds_the_lane_table() {
        let (part, ct, st, arch) = setup(false);
        let mut plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        assert!(plan.lanes().fixed_ops() > 0, "setup has static slots");

        // All-dynamic rebuild: every op loses its compile-time home.
        let ranking = PatternRanking::from_partitioned(&part);
        let arch0 = ArchConfig { static_engines: 0, ..arch.clone() };
        let ct0 = ConfigTable::build(&ranking, 2, 0, 1, 4, arch0.static_assignment);
        plan.rebuild_static_slots(&ct0, &arch0).unwrap();
        let lanes = plan.lanes();
        assert_eq!(lanes.fixed_ops(), 0);
        assert_eq!(lanes.dynamic_path_ops, plan.num_ops() as u32);
        assert!((0..plan.num_ops()).all(|k| lanes.home_of(k).is_none()));

        // Restoring the original split restores the original lane table.
        plan.rebuild_static_slots(&ct, &arch).unwrap();
        let fresh = ExecutionPlan::build(&part, &ct, &st, &arch);
        assert_eq!(plan.lanes(), fresh.lanes());
    }

    #[test]
    fn batch_exposes_bits_weights_and_dense() {
        let (part, ct, st, arch) = setup(true);
        let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        let ids: Vec<u32> = (0..plan.num_ops() as u32).collect();
        let batch = plan.batch(&ids);
        assert!(batch.weighted());
        let mut got = vec![0f32; 4];
        let mut want = vec![0f32; 4];
        for k in 0..batch.len() {
            let op = &plan.ops[k];
            let sg = &part.subgraphs[op.sg_idx as usize];
            assert_eq!(batch.bits(k), sg.pattern.0);
            assert_eq!(batch.weights_of(k).len(), sg.pattern.nnz() as usize);
            got.iter_mut().for_each(|x| *x = 0.0);
            want.iter_mut().for_each(|x| *x = 0.0);
            batch.dense_into(k, &mut got);
            part.dense_weights_into(op.sg_idx as usize, &mut want);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn from_partitioned_is_identity_over_subgraphs() {
        let (part, _, _, _) = setup(true);
        let plan = ExecutionPlan::from_partitioned(&part);
        assert_eq!(plan.num_ops(), part.num_subgraphs());
        assert_eq!(plan.num_groups(), 1);
        for (k, (op, sg)) in plan.ops.iter().zip(&part.subgraphs).enumerate() {
            assert_eq!(op.sg_idx as usize, k);
            assert_eq!(op.src_start, sg.brow * 2);
            assert!(!op.is_static());
        }
        // Not schedulable: zeroed geometry never matches a valid arch.
        assert!(!plan.matches(&ArchConfig::default()));
    }

    #[test]
    fn out_degrees_count_edges_per_source() {
        let (part, ct, st, arch) = setup(false);
        let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        let deg = plan.out_degrees();
        assert_eq!(deg.len(), 8);
        assert_eq!(deg[0], 2); // edges (0,1) and (0,5)
        assert_eq!(deg[7], 1); // edge (7,6)
        assert_eq!(deg.iter().sum::<u32>(), 6);
    }

    #[test]
    fn matches_rejects_differing_order_and_assignment() {
        let (part, ct, st, arch) = setup(false);
        let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        assert!(plan.matches(&arch));
        let other_order = ArchConfig { order: ExecOrder::RowMajor, ..arch.clone() };
        assert!(!plan.matches(&other_order), "order is baked into the groups");
        let other_assign = ArchConfig {
            static_assignment: crate::pattern::tables::StaticAssignment::TopK,
            ..arch.clone()
        };
        assert!(!plan.matches(&other_assign), "assignment shapes the slot section");
    }

    #[test]
    fn encode_decode_is_field_identical() {
        for weighted in [false, true] {
            let (part, ct, st, arch) = setup(weighted);
            let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
            let mut w = Writer::new();
            plan.encode_into(&mut w);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            let decoded = ExecutionPlan::decode_from(&mut r).unwrap();
            r.done().unwrap();
            assert_eq!(plan, decoded, "weighted={weighted}");
            assert!(decoded.matches(&arch));
        }
    }

    #[test]
    fn decode_rejects_inconsistent_sections() {
        let (part, ct, st, arch) = setup(false);
        let mut plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        // Point an op past the slot pool: still well-framed bytes, but an
        // index the interpreter would chase — must be a typed error.
        plan.ops[0].slot_start = plan.slot_pool.len() as u32;
        plan.ops[0].slot_len = 2;
        let mut w = Writer::new();
        plan.encode_into(&mut w);
        let bytes = w.into_bytes();
        let err = ExecutionPlan::decode_from(&mut Reader::new(&bytes)).unwrap_err();
        assert!(matches!(err, CodecError::Invalid(_)), "{err}");
        // Truncation anywhere is typed too, never a panic.
        for cut in [0, 1, bytes.len() / 2, bytes.len() - 1] {
            assert!(ExecutionPlan::decode_from(&mut Reader::new(&bytes[..cut])).is_err());
        }
    }

    #[test]
    fn empty_graph_plan() {
        let part = partition(&Coo::from_edges(4, vec![]), 2, false);
        let ranking = PatternRanking::from_partitioned(&part);
        let arch = ArchConfig { crossbar_size: 2, ..ArchConfig::default() };
        let ct = ConfigTable::build(&ranking, 2, 16, 1, 16, arch.static_assignment);
        let st = SubgraphTable::build(&part, &ranking, ExecOrder::ColumnMajor);
        let plan = ExecutionPlan::build(&part, &ct, &st, &arch);
        assert_eq!(plan.num_ops(), 0);
        assert_eq!(plan.num_groups(), 1);
        assert_eq!(plan.group_bounds(0), (0, 0));
    }
}
