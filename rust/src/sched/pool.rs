//! `sched::pool` — a persistent, channel-fed worker pool for the
//! batch-parallel scheduler.
//!
//! The paper's whole premise is eliminating per-op reconfiguration cost;
//! the simulator owes its own hot loop the same discipline. PR 3's lane
//! replay and numeric chunking paid a `std::thread::scope` spawn/join
//! round-trip on *every superstep* (twice). This pool is spawned **once**
//! — by the [`Session`](crate::session::Session) that owns it, or
//! transiently per run by the compat wrapper
//! [`run_parallel`](super::par::run_parallel) — and fed work over
//! per-worker mpsc channels, so the steady-state superstep performs zero
//! thread spawns and zero heap allocation on the pool's side.
//!
//! # Ownership model
//!
//! * One pool per configured `parallelism`: the `Session` lazily spawns
//!   `WorkerPool::new(threads)` on the first parallel job and reuses it
//!   for every subsequent run; dropping the pool (or the session) closes
//!   the task channels and joins every worker.
//! * Each worker owns long-lived scratch: its cached
//!   [`StepExecutor::fork`] (installed once per backend, not re-forked
//!   every superstep) and whatever buffers ride the task messages.
//! * Reusable buffers are double-buffered through the channels: the
//!   caller moves lane/output buffers into a task, the worker fills them,
//!   and the reply moves them back — capacity is never dropped.
//!
//! # Determinism contract
//!
//! The pool is a pure *mechanism*: every scheduling decision is already
//! resolved by the sequential dispatch pass in [`super::par`], tasks are
//! routed to workers by lane index, and replies are collected in worker
//! index order — the same lane-then-engine merge order the scoped
//! baseline and the sequential interpreter use. Which OS thread replays a
//! lane can therefore never affect a `RunResult` bit. Any new pool
//! feature must keep both properties: decisions stay in the dispatch
//! pass, merges stay index-ordered.
//!
//! # Safety
//!
//! Tasks borrow run-local state (the plan, cost params, record queues,
//! the gathered `xs`) across threads through lifetime-erased pointers.
//! Every public method that submits tasks **blocks until all replies for
//! those tasks are received before returning**, so the borrowed data
//! strictly outlives worker access, and workers never retain a pointer
//! past the task that carried it. The unsafety is fully contained in this
//! module; the public API is safe.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Weak};
use std::thread::{JoinHandle, ThreadId};

use anyhow::Result;

use std::collections::HashMap;

use crate::cost::CostParams;
use crate::engine::GraphEngine;
use crate::graph::coo::Edge;
use crate::pattern::extract::{bucket_edges, Partitioned, Subgraph, WindowMap};
use crate::pattern::rank::count_patterns;
use crate::pattern::tables::{ConfigTable, SubgraphTable};
use crate::pattern::Pattern;

use super::executor::StepExecutor;
use super::par::{replay_engine, LaneRecord};
use super::plan::{EmittedOps, ExecutionPlan};

/// One lane entry in flight: engine index, the engine itself, and the
/// busy time its replay produced (filled in by the worker).
pub(crate) type LaneSlot = (usize, GraphEngine, f64);

/// Lifetime-erased shared reference. Safe to send because every pool
/// method joins on its replies before the underlying borrow can end (see
/// the module-level safety notes).
struct SendConstPtr<T: ?Sized>(*const T);
unsafe impl<T: ?Sized + Sync> Send for SendConstPtr<T> {}

enum Task {
    /// Replay the lane's engines against the shared record queues.
    Replay {
        lane: Vec<LaneSlot>,
        records: SendConstPtr<[Vec<LaneRecord>]>,
        plan: SendConstPtr<ExecutionPlan>,
        params: SendConstPtr<CostParams>,
        lat_mvm: f64,
    },
    /// Evaluate one numeric batch chunk on the worker's cached fork.
    /// `lanes > 1` selects the batched (`execute_multi`) surface: `xs`
    /// and `out` are op-major lane-interleaved, one C-vector per
    /// `(op, lane)` pair; `lanes == 1` is the plain solo call.
    Numeric {
        kind: crate::algo::traits::StepKind,
        ops: SendConstPtr<[u32]>,
        xs: SendConstPtr<[f32]>,
        plan: SendConstPtr<ExecutionPlan>,
        lanes: usize,
        out: Vec<f32>,
    },
    /// Cache a forked executor for subsequent `Numeric` tasks (replaces
    /// any previous fork). No reply; channel FIFO ordering guarantees the
    /// fork is installed before any numeric task submitted after it.
    InstallFork(Box<dyn StepExecutor + Send>),
    /// Report the worker's index and OS thread id (test/diagnostic hook).
    Probe,
    /// Cold-preprocess phase ①: bucket a contiguous edge range into a
    /// per-chunk window map.
    Bucket {
        edges: SendConstPtr<[Edge]>,
        c: usize,
        weighted: bool,
    },
    /// Cold-preprocess phase ②: count pattern occurrences over a
    /// contiguous subgraph range.
    Count { subgraphs: SendConstPtr<[Subgraph]> },
    /// Cold-preprocess phase ③: emit plan sections for a contiguous
    /// subgraph-table entry range.
    Emit {
        part: SendConstPtr<Partitioned>,
        ct: SendConstPtr<ConfigTable>,
        st: SendConstPtr<SubgraphTable>,
        rank_slots: SendConstPtr<[(u32, u32)]>,
        entries: std::ops::Range<usize>,
        weighted: bool,
    },
}

enum Reply {
    Replay(Vec<LaneSlot>),
    Numeric { out: Vec<f32>, result: Result<()> },
    Probe(ThreadId),
    Windows(WindowMap),
    Counts(HashMap<Pattern, u32>),
    Emitted(EmittedOps),
}

fn worker_loop(rx: Receiver<Task>, tx: Sender<Reply>, _alive: Arc<()>) {
    let mut fork: Option<Box<dyn StepExecutor + Send>> = None;
    while let Ok(task) = rx.recv() {
        let reply = match task {
            Task::InstallFork(exec) => {
                fork = Some(exec);
                continue;
            }
            Task::Replay { mut lane, records, plan, params, lat_mvm } => {
                // SAFETY: the submitting call blocks on this reply before
                // the borrowed dispatch state can move or drop, and no
                // pointer outlives this match arm.
                let (records, plan, params) =
                    unsafe { (&*records.0, &*plan.0, &*params.0) };
                for (e, eng, busy) in lane.iter_mut() {
                    replay_engine(eng, &records[*e], plan, params, lat_mvm);
                    let (b, _) = eng.end_iteration();
                    *busy = b;
                }
                Reply::Replay(lane)
            }
            Task::Numeric { kind, ops, xs, plan, lanes, mut out } => {
                // SAFETY: as above.
                let (ops, xs, plan) = unsafe { (&*ops.0, &*xs.0, &*plan.0) };
                let result = match fork.as_mut() {
                    Some(exec) if lanes > 1 => {
                        exec.execute_multi(kind, plan.batch(ops), lanes, xs, &mut out)
                    }
                    Some(exec) => exec.execute(kind, plan.batch(ops), xs, &mut out),
                    None => Err(anyhow::anyhow!(
                        "pool worker received a numeric chunk without a \
                         cached executor fork"
                    )),
                };
                Reply::Numeric { out, result }
            }
            Task::Probe => Reply::Probe(std::thread::current().id()),
            Task::Bucket { edges, c, weighted } => {
                // SAFETY: as above.
                let edges = unsafe { &*edges.0 };
                let mut map = WindowMap::default();
                bucket_edges(edges, c, weighted, &mut map);
                Reply::Windows(map)
            }
            Task::Count { subgraphs } => {
                // SAFETY: as above.
                Reply::Counts(count_patterns(unsafe { &*subgraphs.0 }))
            }
            Task::Emit { part, ct, st, rank_slots, entries, weighted } => {
                // SAFETY: as above.
                let (part, ct, st, rank_slots) =
                    unsafe { (&*part.0, &*ct.0, &*st.0, &*rank_slots.0) };
                Reply::Emitted(ExecutionPlan::emit_entry_range(
                    part, ct, st, rank_slots, entries, weighted,
                ))
            }
        };
        if tx.send(reply).is_err() {
            break; // pool dropped mid-reply; exit quietly
        }
    }
}

/// Persistent worker pool — see the module docs for the ownership model
/// and the determinism contract.
pub struct WorkerPool {
    tx: Vec<Sender<Task>>,
    rx: Vec<Receiver<Reply>>,
    handles: Vec<JoinHandle<()>>,
    /// Backend name whose forks the workers currently cache.
    fork_backend: Option<&'static str>,
    /// Each worker thread holds a strong clone for its lifetime; the
    /// pool itself keeps only this `Weak`, so `liveness()` truly tracks
    /// worker threads (it stops upgrading once every worker has exited,
    /// even if the pool value still exists) — the "no leaked threads"
    /// test hook.
    alive: Weak<()>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.tx.len())
            .field("fork_backend", &self.fork_backend)
            .finish()
    }
}

impl WorkerPool {
    /// Spawn `workers` (min 1) persistent lane workers.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let alive = Arc::new(());
        let mut tx = Vec::with_capacity(workers);
        let mut rx = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (task_tx, task_rx) = channel::<Task>();
            let (reply_tx, reply_rx) = channel::<Reply>();
            let token = Arc::clone(&alive);
            let handle = std::thread::Builder::new()
                .name(format!("sched-pool-{i}"))
                .spawn(move || worker_loop(task_rx, reply_tx, token))
                .expect("spawn pool worker");
            tx.push(task_tx);
            rx.push(reply_rx);
            handles.push(handle);
        }
        // Keep only a Weak: the workers' clones are the strong refs.
        let alive = Arc::downgrade(&alive);
        Self { tx, rx, handles, fork_backend: None, alive }
    }

    /// Number of persistent workers (== maximum lane count).
    pub fn workers(&self) -> usize {
        self.tx.len()
    }

    /// A `Weak` that upgrades iff at least one worker thread is still
    /// alive — worker exits (even early, via panic) are observable, and
    /// after the pool drops (joining its workers) it never upgrades
    /// again.
    pub fn liveness(&self) -> Weak<()> {
        self.alive.clone()
    }

    /// OS thread ids of the workers, in worker-index order. Stable for
    /// the pool's whole lifetime — the unit test for "zero per-superstep
    /// thread spawns" asserts this set is identical before and after
    /// full pooled runs.
    pub fn worker_ids(&mut self) -> Vec<ThreadId> {
        for tx in &self.tx {
            tx.send(Task::Probe).expect("pool worker exited");
        }
        self.rx
            .iter()
            .map(|rx| match rx.recv().expect("pool worker panicked") {
                Reply::Probe(id) => id,
                _ => unreachable!("probe reply"),
            })
            .collect()
    }

    /// Ensure every worker caches a fork of `executor`'s backend; returns
    /// whether the backend supports forking (`false` keeps the numeric
    /// phase sequential, exactly like the scoped baseline). Idempotent
    /// per backend name — forks survive across supersteps *and* runs,
    /// which is sound because `StepExecutor::fork` promises pure,
    /// position-independent numerics.
    pub(crate) fn ensure_forks(&mut self, executor: &dyn StepExecutor) -> bool {
        if self.fork_backend == Some(executor.name()) {
            return true;
        }
        let mut forks = Vec::with_capacity(self.workers());
        for _ in 0..self.workers() {
            match executor.fork() {
                Some(f) => forks.push(f),
                None => return false,
            }
        }
        for (tx, f) in self.tx.iter().zip(forks) {
            tx.send(Task::InstallFork(f)).expect("pool worker exited");
        }
        self.fork_backend = Some(executor.name());
        true
    }

    /// Phase 2 on the pool: lane `i` replays on worker `i`; blocks until
    /// every lane is back (filled with per-engine busy times). Lane
    /// buffers are moved out and back — capacity survives.
    ///
    /// Panic safety: the method never unwinds while a live worker still
    /// holds a task pointer — every submitted task is drained first (a
    /// worker either replies, having released its pointers, or has died,
    /// holding none), *then* a worker failure panics the caller. This is
    /// what keeps the lifetime erasure sound; `std::thread::scope` gave
    /// the scoped baseline the same property via join-on-panic.
    pub(crate) fn replay(
        &mut self,
        lanes: &mut [Vec<LaneSlot>],
        records: &[Vec<LaneRecord>],
        plan: &ExecutionPlan,
        params: &CostParams,
        lat_mvm: f64,
    ) {
        // Hard-checked before any task is in flight: an out-of-bounds
        // panic mid-submission would unwind with pointers outstanding.
        assert!(lanes.len() <= self.workers(), "more lanes than workers");
        let mut sent = 0usize;
        let mut failed = false;
        for (w, lane) in lanes.iter_mut().enumerate() {
            let task = Task::Replay {
                lane: std::mem::take(lane),
                records: SendConstPtr(records as *const _),
                plan: SendConstPtr(plan as *const _),
                params: SendConstPtr(params as *const _),
                lat_mvm,
            };
            if self.tx[w].send(task).is_err() {
                failed = true;
                break;
            }
            sent += 1;
        }
        // Collect in worker order — the deterministic lane-order merge.
        for (w, lane) in lanes.iter_mut().enumerate().take(sent) {
            match self.rx[w].recv() {
                Ok(Reply::Replay(l)) => *lane = l,
                Ok(_) => unreachable!("replay reply"),
                Err(_) => failed = true,
            }
        }
        assert!(!failed, "pool worker panicked");
    }

    /// Phase 3 on the pool: chunk `i` of the numeric batch evaluates on
    /// worker `i`'s cached fork; outputs concatenate into `cand` in chunk
    /// order (bit-identical to one sequential call — each op's output
    /// lanes are an independent pure function of its operands). `bufs`
    /// cycle through the channels so the steady state allocates nothing.
    /// With `lanes > 1`, `xs` is op-major lane-interleaved and each op
    /// chunk carries `chunk * lanes` C-vectors — chunk boundaries sit on
    /// op boundaries, so every lane's chunking matches its solo run. The
    /// caller must have succeeded with [`ensure_forks`](Self::ensure_forks).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_chunks(
        &mut self,
        kind: crate::algo::traits::StepKind,
        plan: &ExecutionPlan,
        sup_ops: &[u32],
        lanes: usize,
        xs: &[f32],
        chunk: usize,
        bufs: &mut [Vec<f32>],
        cand: &mut Vec<f32>,
    ) -> Result<()> {
        let c = plan.c;
        let n_chunks = sup_ops.len().div_ceil(chunk);
        // Hard-checked before any task is in flight (see `replay`).
        assert!(
            n_chunks <= self.workers() && n_chunks <= bufs.len(),
            "more chunks than workers/buffers"
        );
        assert!(lanes >= 1, "execute_chunks requires at least one lane");
        // Prepare `cand` BEFORE any task is in flight: `reserve` can
        // panic (capacity overflow), and no unwind may happen while
        // workers hold task pointers.
        cand.clear();
        cand.reserve(sup_ops.len() * lanes * c);
        let mut sent = 0usize;
        let mut failed = false;
        for (w, (ops_chunk, xs_chunk)) in
            sup_ops.chunks(chunk).zip(xs.chunks(chunk * lanes * c)).enumerate()
        {
            let task = Task::Numeric {
                kind,
                ops: SendConstPtr(ops_chunk as *const _),
                xs: SendConstPtr(xs_chunk as *const _),
                plan: SendConstPtr(plan as *const _),
                lanes,
                out: std::mem::take(&mut bufs[w]),
            };
            if self.tx[w].send(task).is_err() {
                failed = true;
                break;
            }
            sent += 1;
        }
        let mut first_err = None;
        // Drain every submitted chunk first — workers release their task
        // pointers as they reply, and nothing in this loop can unwind
        // (see `replay` on why that is load-bearing).
        for (w, buf) in bufs.iter_mut().enumerate().take(sent) {
            match self.rx[w].recv() {
                Ok(Reply::Numeric { out, result }) => {
                    if let Err(e) = result {
                        first_err.get_or_insert(e);
                    }
                    *buf = out; // buffer returns to the caller's scratch
                }
                Ok(_) => unreachable!("numeric reply"),
                Err(_) => failed = true,
            }
        }
        // All tasks are accounted for; failures may surface now.
        assert!(!failed, "pool worker panicked");
        if let Some(e) = first_err {
            return Err(e);
        }
        // Concatenate in chunk order — exactly like one sequential call.
        for buf in bufs.iter().take(sent) {
            cand.extend_from_slice(buf);
        }
        Ok(())
    }

    /// Cold-preprocess phase ① on the pool: chunk `i` buckets on worker
    /// `i`; per-chunk window maps return in chunk order. The caller's
    /// merge is chunk-ordered and (structurally) chunk-invariant — see
    /// `pattern::extract`. Panic safety mirrors [`replay`](Self::replay):
    /// every submitted task drains before any failure surfaces.
    pub(crate) fn bucket_chunks(
        &mut self,
        chunks: &[&[Edge]],
        c: usize,
        weighted: bool,
    ) -> Vec<WindowMap> {
        // Hard-checked (and allocated) before any task is in flight.
        assert!(chunks.len() <= self.workers(), "more chunks than workers");
        let mut out = Vec::with_capacity(chunks.len());
        let mut sent = 0usize;
        let mut failed = false;
        for (w, edges) in chunks.iter().enumerate() {
            let task = Task::Bucket { edges: SendConstPtr(*edges as *const _), c, weighted };
            if self.tx[w].send(task).is_err() {
                failed = true;
                break;
            }
            sent += 1;
        }
        for w in 0..sent {
            match self.rx[w].recv() {
                Ok(Reply::Windows(m)) => out.push(m),
                Ok(_) => unreachable!("bucket reply"),
                Err(_) => failed = true,
            }
        }
        assert!(!failed, "pool worker panicked");
        out
    }

    /// Cold-preprocess phase ② on the pool: subgraph range `i` counts on
    /// worker `i`; per-chunk pattern counts return in chunk order for a
    /// `merge_counts` fold. Panic safety as in [`replay`](Self::replay).
    pub(crate) fn count_chunks(&mut self, chunks: &[&[Subgraph]]) -> Vec<HashMap<Pattern, u32>> {
        assert!(chunks.len() <= self.workers(), "more chunks than workers");
        let mut out = Vec::with_capacity(chunks.len());
        let mut sent = 0usize;
        let mut failed = false;
        for (w, subgraphs) in chunks.iter().enumerate() {
            let task = Task::Count { subgraphs: SendConstPtr(*subgraphs as *const _) };
            if self.tx[w].send(task).is_err() {
                failed = true;
                break;
            }
            sent += 1;
        }
        for w in 0..sent {
            match self.rx[w].recv() {
                Ok(Reply::Counts(m)) => out.push(m),
                Ok(_) => unreachable!("count reply"),
                Err(_) => failed = true,
            }
        }
        assert!(!failed, "pool worker panicked");
        out
    }

    /// Cold-preprocess phase ③ on the pool: entry range `i` emits on
    /// worker `i`; emitted sections return in range order for the
    /// plan's concatenation. Panic safety as in [`replay`](Self::replay).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn emit_ranges(
        &mut self,
        part: &Partitioned,
        ct: &ConfigTable,
        st: &SubgraphTable,
        rank_slots: &[(u32, u32)],
        ranges: &[std::ops::Range<usize>],
        weighted: bool,
    ) -> Vec<EmittedOps> {
        assert!(ranges.len() <= self.workers(), "more ranges than workers");
        let mut out = Vec::with_capacity(ranges.len());
        let mut sent = 0usize;
        let mut failed = false;
        for (w, entries) in ranges.iter().enumerate() {
            let task = Task::Emit {
                part: SendConstPtr(part as *const _),
                ct: SendConstPtr(ct as *const _),
                st: SendConstPtr(st as *const _),
                rank_slots: SendConstPtr(rank_slots as *const _),
                entries: entries.clone(),
                weighted,
            };
            if self.tx[w].send(task).is_err() {
                failed = true;
                break;
            }
            sent += 1;
        }
        for w in 0..sent {
            match self.rx[w].recv() {
                Ok(Reply::Emitted(e)) => out.push(e),
                Ok(_) => unreachable!("emit reply"),
                Err(_) => failed = true,
            }
        }
        assert!(!failed, "pool worker panicked");
        out
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.clear(); // close task channels; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::config::ArchConfig;
    use crate::algo::traits::StepKind;
    use crate::algo::Bfs;
    use crate::cost::CostParams;
    use crate::graph::datasets::Dataset;
    use crate::pattern::extract::partition;
    use crate::sched::executor::NativeExecutor;
    use crate::sched::par::run_parallel_pooled;
    use crate::sched::Scheduler;

    /// Fork-less test executor: the pool must report `false` and leave
    /// the numeric phase to the caller.
    struct NoFork;
    impl StepExecutor for NoFork {
        fn name(&self) -> &'static str {
            "nofork"
        }
        fn execute(
            &mut self,
            _kind: StepKind,
            _batch: crate::sched::plan::StepBatch<'_>,
            _xs: &[f32],
            _out: &mut Vec<f32>,
        ) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(4);
        let token = pool.liveness();
        assert!(token.upgrade().is_some(), "workers alive while pool lives");
        drop(pool);
        assert!(token.upgrade().is_none(), "drop must join every worker");
    }

    #[test]
    fn worker_ids_are_stable_across_full_runs() {
        // The zero-per-superstep-spawn lockdown: the same OS threads must
        // serve every superstep of every run on this pool.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let params = CostParams::default();
        let acc = crate::accel::Accelerator::new(config.clone(), params.clone());
        let pre = acc.preprocess(&g, false).unwrap();

        let mut pool = WorkerPool::new(4);
        let before = pool.worker_ids();
        assert_eq!(before.len(), 4);
        let unique: std::collections::HashSet<_> = before.iter().collect();
        assert_eq!(unique.len(), 4, "worker threads are distinct");

        let seq = Scheduler::new(&config, &params, &pre.plan)
            .run(&Bfs::new(0), &mut NativeExecutor)
            .unwrap();
        for _ in 0..2 {
            let run = run_parallel_pooled(
                &config,
                &params,
                &pre.plan,
                &Bfs::new(0),
                &mut NativeExecutor,
                &mut pool,
            )
            .unwrap();
            assert_eq!(run.values, seq.values);
            assert_eq!(run.exec_time_ns, seq.exec_time_ns);
        }
        assert_eq!(pool.worker_ids(), before, "runs must not spawn threads");
    }

    #[test]
    fn ensure_forks_is_idempotent_and_backend_aware() {
        let mut pool = WorkerPool::new(2);
        assert!(pool.ensure_forks(&NativeExecutor));
        assert!(pool.ensure_forks(&NativeExecutor), "cached forks reused");
        assert!(!pool.ensure_forks(&NoFork), "fork-less backend stays sequential");
        // The failed attempt must not clobber the cached native forks.
        assert!(pool.ensure_forks(&NativeExecutor));
    }

    #[test]
    fn execute_chunks_matches_one_sequential_call() {
        let g = Dataset::Tiny.load().unwrap();
        let part = partition(&g, 4, false);
        let plan = ExecutionPlan::from_partitioned(&part);
        let n = plan.num_ops();
        let ids: Vec<u32> = (0..n as u32).collect();
        let xs: Vec<f32> = (0..n * 4).map(|i| (i % 7) as f32).collect();

        let mut want = Vec::new();
        NativeExecutor
            .execute(StepKind::PageRank, plan.batch(&ids), &xs, &mut want)
            .unwrap();

        let mut pool = WorkerPool::new(3);
        assert!(pool.ensure_forks(&NativeExecutor));
        let mut bufs = vec![Vec::new(); 3];
        let mut got = Vec::new();
        let chunk = n.div_ceil(3);
        pool.execute_chunks(StepKind::PageRank, &plan, &ids, 1, &xs, chunk, &mut bufs, &mut got)
            .unwrap();
        assert_eq!(got, want, "chunked == sequential, bit for bit");
        // Buffers came back with retained capacity for the next call.
        assert!(bufs.iter().take(n.div_ceil(chunk)).all(|b| b.capacity() > 0));
    }

    #[test]
    fn execute_chunks_multi_lane_matches_per_lane_sequential_calls() {
        let g = Dataset::Tiny.load().unwrap();
        let part = partition(&g, 4, false);
        let plan = ExecutionPlan::from_partitioned(&part);
        let n = plan.num_ops();
        let ids: Vec<u32> = (0..n as u32).collect();
        let c = 4;
        let lanes = 3;
        // Per-lane solo inputs, then the op-major lane-interleaved image.
        let lane_xs: Vec<Vec<f32>> = (0..lanes)
            .map(|l| (0..n * c).map(|i| ((i + l * 11) % 7) as f32).collect())
            .collect();
        let mut xs = vec![0.0f32; n * lanes * c];
        for (l, lx) in lane_xs.iter().enumerate() {
            for k in 0..n {
                xs[(k * lanes + l) * c..(k * lanes + l + 1) * c]
                    .copy_from_slice(&lx[k * c..(k + 1) * c]);
            }
        }

        let mut pool = WorkerPool::new(3);
        assert!(pool.ensure_forks(&NativeExecutor));
        let mut bufs = vec![Vec::new(); 3];
        let mut got = Vec::new();
        let chunk = n.div_ceil(3);
        pool.execute_chunks(
            StepKind::PageRank,
            &plan,
            &ids,
            lanes,
            &xs,
            chunk,
            &mut bufs,
            &mut got,
        )
        .unwrap();
        assert_eq!(got.len(), n * lanes * c);
        for (l, lx) in lane_xs.iter().enumerate() {
            let mut want = Vec::new();
            NativeExecutor.execute(StepKind::PageRank, plan.batch(&ids), lx, &mut want).unwrap();
            for k in 0..n {
                assert_eq!(
                    got[(k * lanes + l) * c..(k * lanes + l + 1) * c],
                    want[k * c..(k + 1) * c],
                    "lane {l} op {k}",
                );
            }
        }
    }
}
