//! Dynamic-engine replacement policies (Alg. 2 `FindGE`).
//!
//! When a subgraph's pattern is not pinned to a static engine, the
//! scheduler first checks whether any dynamic crossbar *already* holds
//! the pattern (a dynamic hit — no write needed); otherwise the policy
//! selects a victim slot (engine, crossbar) to reconfigure.

use crate::accel::config::PolicyKind;
use crate::util::SplitMix64;

/// A dynamic crossbar slot: (engine index, crossbar index) — engine
/// indices are global (dynamic engines occupy `n_static..total`).
pub type Slot = (usize, usize);

pub trait ReplacementPolicy: Send {
    fn name(&self) -> &'static str;
    /// Choose a victim slot for a pattern miss. `retired[k]` marks slots
    /// that must not be used (wear-out, §IV.D). Returns `None` when every
    /// slot is retired.
    fn pick(&mut self, retired: &[bool]) -> Option<usize>;
    /// Record a use of slot `k` (hit or post-reconfig use).
    fn touch(&mut self, k: usize);
    /// Number of slots managed.
    fn num_slots(&self) -> usize;
}

/// Least-recently-used over dynamic slots.
pub struct Lru {
    stamp: Vec<u64>,
    clock: u64,
}

impl Lru {
    pub fn new(slots: usize) -> Self {
        Self { stamp: vec![0; slots], clock: 0 }
    }
}

impl ReplacementPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn pick(&mut self, retired: &[bool]) -> Option<usize> {
        (0..self.stamp.len())
            .filter(|&k| !retired[k])
            .min_by_key(|&k| self.stamp[k])
    }

    fn touch(&mut self, k: usize) {
        self.clock += 1;
        self.stamp[k] = self.clock;
    }

    fn num_slots(&self) -> usize {
        self.stamp.len()
    }
}

/// Round-robin cursor over dynamic slots.
pub struct RoundRobin {
    cursor: usize,
    slots: usize,
}

impl RoundRobin {
    pub fn new(slots: usize) -> Self {
        Self { cursor: 0, slots }
    }
}

impl ReplacementPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, retired: &[bool]) -> Option<usize> {
        for _ in 0..self.slots {
            let k = self.cursor;
            self.cursor = (self.cursor + 1) % self.slots.max(1);
            if !retired[k] {
                return Some(k);
            }
        }
        None
    }

    fn touch(&mut self, _k: usize) {}

    fn num_slots(&self) -> usize {
        self.slots
    }
}

/// Least-frequently-used over dynamic slots.
pub struct Lfu {
    freq: Vec<u64>,
}

impl Lfu {
    pub fn new(slots: usize) -> Self {
        Self { freq: vec![0; slots] }
    }
}

impl ReplacementPolicy for Lfu {
    fn name(&self) -> &'static str {
        "lfu"
    }

    fn pick(&mut self, retired: &[bool]) -> Option<usize> {
        (0..self.freq.len())
            .filter(|&k| !retired[k])
            .min_by_key(|&k| self.freq[k])
    }

    fn touch(&mut self, k: usize) {
        self.freq[k] += 1;
    }

    fn num_slots(&self) -> usize {
        self.freq.len()
    }
}

/// Uniform-random victim (deterministic seed — reproducible runs).
pub struct Random {
    rng: SplitMix64,
    slots: usize,
}

impl Random {
    pub fn new(slots: usize, seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), slots }
    }
}

impl ReplacementPolicy for Random {
    fn name(&self) -> &'static str {
        "random"
    }

    fn pick(&mut self, retired: &[bool]) -> Option<usize> {
        if retired.iter().all(|&r| r) || self.slots == 0 {
            return None;
        }
        loop {
            let k = self.rng.next_index(self.slots);
            if !retired[k] {
                return Some(k);
            }
        }
    }

    fn touch(&mut self, _k: usize) {}

    fn num_slots(&self) -> usize {
        self.slots
    }
}

/// Factory from the config enum.
pub fn build_policy(kind: PolicyKind, slots: usize) -> Box<dyn ReplacementPolicy> {
    match kind {
        PolicyKind::Lru => Box::new(Lru::new(slots)),
        PolicyKind::RoundRobin => Box::new(RoundRobin::new(slots)),
        PolicyKind::Lfu => Box::new(Lfu::new(slots)),
        PolicyKind::Random => Box::new(Random::new(slots, 0xD15C)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recent() {
        let mut p = Lru::new(3);
        let retired = vec![false; 3];
        p.touch(0);
        p.touch(1);
        p.touch(2);
        p.touch(0);
        assert_eq!(p.pick(&retired), Some(1));
    }

    #[test]
    fn lru_skips_retired() {
        let mut p = Lru::new(2);
        p.touch(0);
        assert_eq!(p.pick(&[false, true]), Some(0));
        assert_eq!(p.pick(&[true, true]), None);
    }

    #[test]
    fn round_robin_cycles() {
        let mut p = RoundRobin::new(3);
        let retired = vec![false; 3];
        assert_eq!(p.pick(&retired), Some(0));
        assert_eq!(p.pick(&retired), Some(1));
        assert_eq!(p.pick(&retired), Some(2));
        assert_eq!(p.pick(&retired), Some(0));
    }

    #[test]
    fn lfu_prefers_cold_slot() {
        let mut p = Lfu::new(3);
        let retired = vec![false; 3];
        p.touch(0);
        p.touch(0);
        p.touch(2);
        assert_eq!(p.pick(&retired), Some(1));
    }

    #[test]
    fn random_is_deterministic_and_respects_retired() {
        let mut a = Random::new(4, 1);
        let mut b = Random::new(4, 1);
        let retired = vec![false, true, false, true];
        for _ in 0..20 {
            let ka = a.pick(&retired).unwrap();
            assert_eq!(Some(ka), b.pick(&retired));
            assert!(ka == 0 || ka == 2);
        }
    }

    #[test]
    fn factory_builds_all_kinds() {
        for kind in [
            PolicyKind::Lru,
            PolicyKind::RoundRobin,
            PolicyKind::Lfu,
            PolicyKind::Random,
        ] {
            let p = build_policy(kind, 4);
            assert_eq!(p.num_slots(), 4);
        }
    }
}
