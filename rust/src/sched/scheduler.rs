//! Graph processing & scheduling — paper Algorithm 2, as a thin
//! interpreter over a compiled [`ExecutionPlan`].
//!
//! Static engines are configured once at initialization; subgraphs are
//! then processed in batches that share destination (column-major) or
//! source (row-major) vertices. Within a batch, engines operate in
//! parallel; operations queued on the same engine serialize. Subgraphs
//! whose pattern is pinned to a static engine transfer only vertex data;
//! the rest go to a dynamic engine picked by the replacement policy
//! (reconfiguring it unless it already holds the pattern).
//!
//! All per-op decisions (static slot candidates, read-row counts, pattern
//! ranks, gather bases) are data in the plan — compiled once per
//! `(graph, architecture)` by [`ExecutionPlan::build`] and cached with the
//! preprocessed artifact. The interpreter holds only mutable runtime
//! state: engine busy-times, the rank-keyed dynamic directory, the
//! frontier bitmap masking plan groups, and wear. The superstep hot loop
//! performs no `HashMap<Pattern, _>` lookups and no `SubgraphTable`
//! rescans.
//!
//! The scheduler is the paper's timing/energy model; numeric edge-compute
//! values flow through a [`StepExecutor`] (native mirror or AOT/PJRT
//! artifact) with synchronous (Jacobi) superstep semantics.

use anyhow::Result;

use crate::accel::activity::ActivityTrace;
use crate::accel::config::ArchConfig;
use crate::algo::traits::{Semiring, StepKind, VertexProgram, INF};
use crate::cost::{CostParams, EventCounts};
use crate::engine::{EngineKind, GraphEngine};

use super::executor::StepExecutor;
use super::plan::ExecutionPlan;
use super::replacement::{build_policy, ReplacementPolicy};

/// Sentinel for "no rank / no slot" in the dense dynamic directory.
pub(crate) const NONE: u32 = u32::MAX;

/// Dynamic slot index -> (engine index, crossbar index). Dynamic slots
/// spread over engines first so consecutive misses land on distinct
/// engines. Shared by the sequential interpreter and the batch-parallel
/// dispatcher (`sched::par`) so their slot geometry can never drift.
#[inline]
pub(crate) fn slot_pos(config: &ArchConfig, k: usize) -> (usize, usize) {
    let n_dyn = config.dynamic_engines() as usize;
    (config.static_engines as usize + k % n_dyn, k / n_dyn)
}

/// Per-engine summary for reports and the lifetime analysis.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineSummary {
    pub id: u32,
    pub is_static: bool,
    pub read_bits: u64,
    pub write_bits: u64,
    pub mvm_ops: u64,
    pub reconfigs: u64,
    pub max_cell_writes: u32,
}

impl EngineSummary {
    /// Snapshot an engine after a run (sequential, oracle, and lane
    /// replay all reassemble engines into summaries through this).
    pub fn of(e: &GraphEngine) -> Self {
        Self {
            id: e.id,
            is_static: e.kind == EngineKind::Static,
            read_bits: e.counts.read_bits,
            write_bits: e.counts.write_bits,
            mvm_ops: e.counts.mvm_ops,
            reconfigs: e.counts.reconfigs,
            max_cell_writes: e.max_cell_writes(),
        }
    }
}

/// Outcome of one accelerator run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Final vertex values (levels / distances / ranks / labels).
    pub values: Vec<f32>,
    /// Runtime hardware events (excludes initialization).
    pub counts: EventCounts,
    /// Initialization events (static-engine configuration).
    pub init_counts: EventCounts,
    /// Modeled execution time (ns), initialization excluded.
    pub exec_time_ns: f64,
    /// Initialization time (ns).
    pub init_time_ns: f64,
    /// Algorithm supersteps executed.
    pub supersteps: usize,
    /// Scheduler iterations (processed batches).
    pub iterations: u64,
    /// Subgraph ops served by static engines.
    pub static_ops: u64,
    /// Subgraph ops served by dynamic engines.
    pub dynamic_ops: u64,
    /// Dynamic ops that hit an already-configured crossbar (no write).
    pub dynamic_hits: u64,
    /// Max per-cell write count over dynamic crossbars (lifetime `w`).
    pub max_dynamic_cell_writes: u32,
    pub engines: Vec<EngineSummary>,
    /// Per-iteration activity (Fig. 5), if tracing was enabled.
    pub activity: Option<ActivityTrace>,
}

impl RunResult {
    /// Fraction of subgraph ops served without any ReRAM write risk.
    pub fn static_hit_rate(&self) -> f64 {
        let total = self.static_ops + self.dynamic_ops;
        if total == 0 {
            0.0
        } else {
            self.static_ops as f64 / total as f64
        }
    }

    /// Total events including initialization.
    pub fn total_counts(&self) -> EventCounts {
        let mut c = self.counts;
        c.add(&self.init_counts);
        c
    }
}

/// Gather one superstep's wordline inputs: a C-vector per selected op,
/// snapshot source values mapped through `VertexProgram::source_value`,
/// identity-padded past the vertex count. An indexed copy through the
/// plan's precompiled [`GatherTable`](super::plan::GatherTable) — no
/// per-wordline bounds test in the hot loop. Shared by the sequential
/// interpreter and `sched::par` so the numeric operands can never drift
/// between them (the oracle keeps its own copy by design).
pub(crate) fn gather_sources(
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    kind: StepKind,
    snapshot: &[f32],
    outdeg: &[u32],
    sup_ops: &[u32],
    xs: &mut Vec<f32>,
) {
    let c = plan.c;
    let gather = plan.gather();
    let id = super::executor::identity(kind);
    xs.clear();
    xs.reserve(sup_ops.len() * c);
    for &op in sup_ops {
        let (src, pad) = gather.sources_of(op as usize, c);
        for &v in src {
            xs.push(program.source_value(snapshot[v as usize], outdeg[v as usize]));
        }
        for _ in 0..pad {
            xs.push(id);
        }
    }
}

/// Reduce & apply one superstep's candidates (engine-ALU model; events
/// are already accounted): min-plus programs apply per destination lane
/// and rebuild the frontier bitmap, sum-product programs accumulate into
/// `acc`. Returns whether anything changed. Shared by the sequential
/// interpreter and `sched::par` — same caveat as [`gather_sources`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn reduce_apply(
    plan: &ExecutionPlan,
    program: &dyn VertexProgram,
    semiring: Semiring,
    sup_ops: &[u32],
    cand: &[f32],
    values: &mut [f32],
    acc: &mut [f32],
    active_block: &mut Vec<bool>,
    next_active_block: &mut Vec<bool>,
) -> bool {
    let c = plan.c;
    let n = values.len();
    let mut any_changed = false;
    match semiring {
        Semiring::MinPlus => {
            next_active_block.iter_mut().for_each(|b| *b = false);
            for (k, &op) in sup_ops.iter().enumerate() {
                let dst_start = plan.ops[op as usize].dst_start as usize;
                for j in 0..c {
                    let v = dst_start + j;
                    if v >= n {
                        break;
                    }
                    let old = values[v];
                    let new = program.apply(old, cand[k * c + j]);
                    if program.changed(old, new) {
                        values[v] = new;
                        next_active_block[v / c] = true;
                        any_changed = true;
                    }
                }
            }
            std::mem::swap(active_block, next_active_block);
        }
        Semiring::SumProd => {
            for (k, &op) in sup_ops.iter().enumerate() {
                let dst_start = plan.ops[op as usize].dst_start as usize;
                for j in 0..c {
                    let v = dst_start + j;
                    if v >= n {
                        break;
                    }
                    acc[v] += cand[k * c + j];
                }
            }
            any_changed = true;
        }
    }
    any_changed
}

/// Algorithm 2 interpreter over a compiled execution plan.
pub struct Scheduler<'a> {
    pub config: &'a ArchConfig,
    pub params: &'a CostParams,
    pub plan: &'a ExecutionPlan,
}

impl<'a> Scheduler<'a> {
    pub fn new(config: &'a ArchConfig, params: &'a CostParams, plan: &'a ExecutionPlan) -> Self {
        Self { config, params, plan }
    }

    /// See the module-level [`slot_pos`].
    #[inline]
    fn slot_pos(&self, k: usize) -> (usize, usize) {
        slot_pos(self.config, k)
    }

    /// Run `program` to convergence, computing values via `executor`.
    pub fn run(
        &self,
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
    ) -> Result<RunResult> {
        self.config.validate()?;
        anyhow::ensure!(
            self.plan.matches(self.config),
            "execution plan was compiled for a different architecture \
             (plan C={} N={} T={} M={})",
            self.plan.c,
            self.plan.static_engines,
            self.plan.total_engines,
            self.plan.crossbars_per_engine
        );
        if program.needs_weights() {
            anyhow::ensure!(
                self.plan.weighted,
                "{} requires weighted partitioning",
                program.name()
            );
        }
        let plan = self.plan;
        let c = plan.c;
        let n = plan.num_vertices as usize;
        let num_blocks = plan.num_blocks as usize;
        let n_static = self.config.static_engines;
        let n_total = self.config.total_engines;
        let m = self.config.crossbars_per_engine as usize;

        // --- engines + policy + rank-keyed dynamic-content directory ---
        let mut engines: Vec<GraphEngine> = (0..n_total)
            .map(|i| {
                let kind = if i < n_static { EngineKind::Static } else { EngineKind::Dynamic };
                GraphEngine::new(i, kind, c, m as u32)
            })
            .collect();
        let n_dyn_slots = self.config.dynamic_engines() as usize * m;
        let mut policy: Box<dyn ReplacementPolicy> =
            build_policy(self.config.policy, n_dyn_slots);
        // rank -> dynamic slot currently holding it (dense, no hashing).
        let mut dyn_dir: Vec<u32> = vec![NONE; plan.num_patterns as usize];
        // dynamic slot -> rank it holds.
        let mut slot_rank: Vec<u32> = vec![NONE; n_dyn_slots];
        let mut retired: Vec<bool> = vec![false; n_dyn_slots];

        // --- initialization: configure static engines (Alg. 2 l. 6–8) ---
        for &(slot, pattern) in plan.static_config() {
            engines[slot.engine as usize].configure(slot.crossbar as usize, pattern, self.params);
        }
        let mut init_counts = EventCounts::default();
        let mut init_time_ns = 0f64;
        for e in engines.iter_mut() {
            init_counts.add(&e.counts);
            let (busy, _) = e.end_iteration();
            init_time_ns = init_time_ns.max(busy);
        }
        let counts_baseline = init_counts;

        // --- vertex state ---
        let mut values = program.init(plan.num_vertices);
        anyhow::ensure!(values.len() == n, "program init length mismatch");
        let mut snapshot = values.clone();
        let semiring = program.semiring();
        let mut acc = match semiring {
            Semiring::SumProd => vec![0f32; n],
            Semiring::MinPlus => Vec::new(),
        };
        let outdeg = plan.out_degrees();

        // Frontier at block-row granularity, masking plan groups.
        let all_blocks = program.processes_all_blocks();
        let mut active_block = vec![false; num_blocks];
        let mut next_active_block = vec![false; num_blocks];
        if !all_blocks {
            for (v, &val) in values.iter().enumerate() {
                if val < INF {
                    active_block[v / c] = true;
                }
            }
        }

        // --- tracing ---
        let mut trace = self
            .config
            .trace_activity
            .then(|| ActivityTrace::new(n_total as usize));
        let mut prev_reads = vec![0u64; n_total as usize];
        let mut prev_writes = vec![0u64; n_total as usize];
        if trace.is_some() {
            for (i, e) in engines.iter().enumerate() {
                prev_reads[i] = e.counts.read_bits;
                prev_writes[i] = e.counts.write_bits;
            }
        }

        // --- main loop ---
        let kind = program.step_kind();
        let mut exec_time_ns = 0f64;
        // System-level events not attributable to one engine: ST entries
        // and vertex data stream from main memory in 64 B bursts (16
        // four-byte ST records / 4-lane vertex vectors per burst).
        let mut sys_counts = EventCounts::default();
        let mut iterations = 0u64;
        let mut static_ops = 0u64;
        let mut dynamic_ops = 0u64;
        let mut dynamic_hits = 0u64;
        let mut supersteps = 0usize;

        // Reused per-superstep buffers (no allocation in the hot loop).
        let mut sup_ops: Vec<u32> = Vec::new();
        let mut xs: Vec<f32> = Vec::new();
        let mut cand: Vec<f32> = Vec::new();

        // Per-op latency depends only on params and C — compute once.
        let lat_mvm = crate::cost::timing::mvm_latency_ns(self.params, c as u32, c as u32)
            + crate::cost::timing::reduce_latency_ns(self.params, c as u32);

        for superstep in 0..program.max_supersteps() {
            snapshot.copy_from_slice(&values);
            sup_ops.clear();

            for g in 0..plan.num_groups() {
                let (start, end) = plan.group_bounds(g);
                let mut ops_in_group = 0u64;
                for (off, op) in plan.ops[start..end].iter().enumerate() {
                    if !all_blocks && !active_block[op.src_block as usize] {
                        continue;
                    }
                    ops_in_group += 1;
                    if op.is_static() {
                        // Static hit: vertex data only, no configuration.
                        // Among the pattern's replicas, queue on the
                        // least-busy engine (load balancing, §III.B).
                        let slots = plan.slots_of(op);
                        let slot = if slots.len() == 1 {
                            slots[0]
                        } else {
                            *slots
                                .iter()
                                .min_by(|a, b| {
                                    engines[a.engine as usize]
                                        .busy_ns
                                        .total_cmp(&engines[b.engine as usize].busy_ns)
                                })
                                .expect("static op has a slot")
                        };
                        engines[slot.engine as usize].mvm_precomputed(
                            slot.crossbar as usize,
                            op.read_rows as u64,
                            lat_mvm,
                        );
                        static_ops += 1;
                    } else {
                        // Dynamic path (Alg. 2 l. 13–15). Alg. 2
                        // reconfigures unconditionally; content-aware
                        // reuse is the opt-in extension (config flag).
                        let rank = op.pattern_rank as usize;
                        let hit = if self.config.dynamic_reuse {
                            let k = dyn_dir[rank];
                            (k != NONE && !retired[k as usize]).then_some(k as usize)
                        } else {
                            None
                        };
                        let k = match hit {
                            Some(k) => {
                                dynamic_hits += 1;
                                k
                            }
                            None => {
                                let pattern = plan.pattern_of_rank(op.pattern_rank);
                                // Retire-then-repick: a crossbar whose
                                // configuration write crosses the
                                // endurance budget is retired on the spot
                                // and must never serve the triggering MVM;
                                // the op repicks until a healthy slot
                                // holds the pattern.
                                loop {
                                    let k = policy.pick(&retired).ok_or_else(|| {
                                        anyhow::anyhow!(
                                            "all dynamic crossbars retired (wear-out)"
                                        )
                                    })?;
                                    let (ei, cb) = self.slot_pos(k);
                                    let old = slot_rank[k];
                                    if old != NONE {
                                        dyn_dir[old as usize] = NONE;
                                        slot_rank[k] = NONE;
                                    }
                                    engines[ei].configure(cb, pattern, self.params);
                                    if engines[ei].crossbars[cb]
                                        .worn_out(self.params.endurance_cycles)
                                    {
                                        retired[k] = true;
                                        continue;
                                    }
                                    slot_rank[k] = rank as u32;
                                    dyn_dir[rank] = k as u32;
                                    break k;
                                }
                            }
                        };
                        let (ei, cb) = self.slot_pos(k);
                        engines[ei].mvm_precomputed(cb, op.rows as u64, lat_mvm);
                        policy.touch(k);
                        dynamic_ops += 1;
                    }
                    sup_ops.push((start + off) as u32);
                }
                if ops_in_group == 0 {
                    continue;
                }
                iterations += 1;
                // ST stream-in + vertex data in/out, 64 B bursts.
                sys_counts.main_mem_accesses += 2 * ops_in_group.div_ceil(16);
                if let Some(t) = trace.as_mut() {
                    t.push_iteration(engines.iter().enumerate().map(|(i, e)| {
                        let dr = (e.counts.read_bits - prev_reads[i]) as u32;
                        let dw = (e.counts.write_bits - prev_writes[i]) as u32;
                        prev_reads[i] = e.counts.read_bits;
                        prev_writes[i] = e.counts.write_bits;
                        (dr, dw)
                    }));
                }
            }

            // Superstep timing: engines run their queues in parallel
            // (Alg. 2 `parallelforeach`); the FIFO input/output buffers
            // pipeline consecutive batches (Fig. 4), so the superstep
            // latency is the longest per-engine queue, not a barrier per
            // destination group.
            let mut max_busy = 0f64;
            for e in engines.iter_mut() {
                let (busy, _) = e.end_iteration();
                max_busy = max_busy.max(busy);
            }
            exec_time_ns += max_busy;

            if sup_ops.is_empty() {
                break;
            }

            // --- numeric phase: edge compute through the executor ---
            gather_sources(plan, program, kind, &snapshot, outdeg, &sup_ops, &mut xs);
            executor.execute(kind, plan.batch(&sup_ops), &xs, &mut cand)?;

            // --- reduce & apply (engine ALU, modeled events already) ---
            let any_changed = reduce_apply(
                plan,
                program,
                semiring,
                &sup_ops,
                &cand,
                &mut values,
                &mut acc,
                &mut active_block,
                &mut next_active_block,
            );

            supersteps = superstep + 1;
            if !program.post_superstep(superstep, &mut values, &mut acc, any_changed) {
                break;
            }
        }

        // --- final accounting ---
        let mut counts = sys_counts;
        let mut summaries = Vec::with_capacity(engines.len());
        let mut max_dyn_writes = 0u32;
        for e in &engines {
            counts.add(&e.counts);
            if e.kind == EngineKind::Dynamic {
                max_dyn_writes = max_dyn_writes.max(e.max_cell_writes());
            }
            summaries.push(EngineSummary::of(e));
        }
        // Runtime counts exclude initialization.
        counts.subtract(&counts_baseline);

        Ok(RunResult {
            values,
            counts,
            init_counts,
            exec_time_ns,
            init_time_ns,
            supersteps,
            iterations,
            static_ops,
            dynamic_ops,
            dynamic_hits,
            max_dynamic_cell_writes: max_dyn_writes,
            engines: summaries,
            activity: trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Bfs, PageRank, Sssp, Wcc};
    use crate::graph::datasets::Dataset;
    use crate::graph::Csr;
    use crate::pattern::extract::partition;
    use crate::pattern::rank::PatternRanking;
    use crate::pattern::tables::{ConfigTable, ExecOrder, SubgraphTable};
    use crate::sched::executor::NativeExecutor;

    fn run_with_params(
        g: &crate::graph::Coo,
        config: &ArchConfig,
        params: &CostParams,
        program: &dyn VertexProgram,
    ) -> Result<RunResult> {
        let part = partition(g, config.crossbar_size, program.needs_weights());
        let ranking = PatternRanking::from_partitioned(&part);
        let ct = ConfigTable::build(
            &ranking,
            config.crossbar_size,
            config.static_engines,
            config.crossbars_per_engine,
            config.dynamic_engines() * config.crossbars_per_engine,
            config.static_assignment,
        );
        let st = SubgraphTable::build(&part, &ranking, config.order);
        let plan = ExecutionPlan::build(&part, &ct, &st, config);
        let sched = Scheduler::new(config, params, &plan);
        sched.run(program, &mut NativeExecutor)
    }

    fn run_on(
        g: &crate::graph::Coo,
        config: &ArchConfig,
        program: &dyn VertexProgram,
    ) -> RunResult {
        run_with_params(g, config, &CostParams::default(), program).unwrap()
    }

    #[test]
    fn bfs_matches_reference_on_tiny() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let res = run_on(&g, &config, &Bfs::new(0));
        let want = crate::algo::reference::bfs_levels(&Csr::from_coo(&g), 0);
        assert_eq!(res.values.len(), want.len());
        for (v, (got, want)) in res.values.iter().zip(&want).enumerate() {
            assert!(
                (got - want).abs() < 1e-3 || (*got >= INF && *want >= INF),
                "vertex {v}: got {got} want {want}"
            );
        }
        assert!(res.supersteps > 1);
        assert!(res.counts.mvm_ops > 0);
    }

    #[test]
    fn sssp_matches_reference_on_tiny() {
        let g = Dataset::Tiny.load_weighted(1.0).unwrap();
        let config = ArchConfig::default();
        let res = run_on(&g, &config, &Sssp::new(3));
        let want = crate::algo::reference::sssp_distances(&Csr::from_coo(&g), 3);
        for (got, want) in res.values.iter().zip(&want) {
            assert!(
                (got - want).abs() < 1e-2 || (*got >= INF && *want >= INF),
                "got {got} want {want}"
            );
        }
    }

    #[test]
    fn pagerank_matches_reference_on_tiny() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let pr = PageRank::new(0.85, 10);
        let res = run_on(&g, &config, &pr);
        let want = crate::algo::reference::pagerank(&Csr::from_coo(&g), 0.85, 10);
        for (got, want) in res.values.iter().zip(&want) {
            assert!((got - want).abs() < 1e-4, "got {got} want {want}");
        }
        assert_eq!(res.supersteps, 10);
    }

    #[test]
    fn wcc_matches_reference_on_tiny() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let res = run_on(&g, &config, &Wcc);
        let want = crate::algo::reference::wcc_labels(&Csr::from_coo(&g));
        for (got, want) in res.values.iter().zip(&want) {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn static_engines_attract_most_ops() {
        // The paper's core claim: with 16 static engines most subgraphs
        // are served without writes.
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let res = run_on(&g, &config, &Bfs::new(0));
        assert!(
            res.static_hit_rate() > 0.5,
            "static hit rate {:.2}",
            res.static_hit_rate()
        );
    }

    #[test]
    fn zero_static_engines_write_more() {
        let g = Dataset::Tiny.load().unwrap();
        let mut cfg0 = ArchConfig::default();
        cfg0.static_engines = 0;
        let mut cfg16 = ArchConfig::default();
        cfg16.static_engines = 16;
        let r0 = run_on(&g, &cfg0, &Bfs::new(0));
        let r16 = run_on(&g, &cfg16, &Bfs::new(0));
        assert!(r0.counts.write_bits > 2 * r16.counts.write_bits);
        assert!(r0.exec_time_ns > r16.exec_time_ns);
        // Same numeric result regardless of engine allocation.
        assert_eq!(r0.values, r16.values);
    }

    #[test]
    fn static_engines_never_written_at_runtime() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let res = run_on(&g, &config, &Bfs::new(0));
        for e in res.engines.iter().filter(|e| e.is_static) {
            // Exactly the init writes, no runtime reconfiguration: the
            // engine summary includes init, so write_bits equals the
            // pattern's nnz (written once) and max one write per cell.
            assert!(e.max_cell_writes <= 1, "static engine rewritten");
        }
    }

    #[test]
    fn activity_trace_when_enabled() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::fig5();
        let res = run_on(&g, &config, &Bfs::new(0));
        let t = res.activity.expect("tracing enabled");
        assert_eq!(t.num_engines, 6);
        assert!(t.num_iterations() > 0);
        assert_eq!(res.iterations, t.num_iterations() as u64);
    }

    #[test]
    fn row_major_order_also_converges() {
        let g = Dataset::Tiny.load().unwrap();
        let mut config = ArchConfig::default();
        config.order = ExecOrder::RowMajor;
        let res = run_on(&g, &config, &Bfs::new(0));
        let want = crate::algo::reference::bfs_levels(&Csr::from_coo(&g), 0);
        for (got, want) in res.values.iter().zip(&want) {
            assert!((got - want).abs() < 1e-3 || (*got >= INF && *want >= INF));
        }
    }

    #[test]
    fn unreachable_source_terminates_quickly() {
        // Source with no out-edges: one superstep, nothing explodes.
        let g = crate::graph::Coo::from_edges(
            8,
            vec![crate::graph::coo::Edge::new(1, 2)],
        );
        let config = ArchConfig::default();
        let res = run_on(&g, &config, &Bfs::new(7));
        assert!(res.supersteps <= 1);
        assert_eq!(res.values[7], 0.0);
    }

    #[test]
    fn plan_for_wrong_architecture_is_rejected() {
        let g = Dataset::Tiny.load().unwrap();
        let config = ArchConfig::default();
        let part = partition(&g, config.crossbar_size, false);
        let ranking = PatternRanking::from_partitioned(&part);
        let ct = ConfigTable::build(&ranking, 4, 16, 1, 16, config.static_assignment);
        let st = SubgraphTable::build(&part, &ranking, config.order);
        let plan = ExecutionPlan::build(&part, &ct, &st, &config);
        let other = ArchConfig { static_engines: 8, ..config };
        let sched = Scheduler::new(&other, &CostParams::default(), &plan);
        let err = sched.run(&Bfs::new(0), &mut NativeExecutor).unwrap_err();
        assert!(err.to_string().contains("different architecture"), "{err}");
    }

    #[test]
    fn worn_out_slot_never_serves_the_triggering_op() {
        // One dynamic slot with endurance 1: the very first dynamic
        // configure crosses the budget, so retire-then-repick must fail
        // the run (nothing left to repick) instead of serving the MVM on
        // the just-retired crossbar as the seed scheduler did.
        let g = crate::graph::Coo::from_edges(
            4,
            vec![crate::graph::coo::Edge::new(0, 1)],
        );
        let config = ArchConfig {
            crossbar_size: 2,
            total_engines: 1,
            static_engines: 0,
            ..ArchConfig::default()
        };
        let params = CostParams { endurance_cycles: 1.0, ..CostParams::default() };
        let err = run_with_params(&g, &config, &params, &Bfs::new(0)).unwrap_err();
        assert!(
            err.to_string().contains("retired"),
            "expected wear-out error, got {err}"
        );
    }

    #[test]
    fn healthy_slot_below_endurance_still_serves() {
        // Same setup but endurance 2: one configure writes one cell once,
        // staying under the budget — the op is served normally.
        let g = crate::graph::Coo::from_edges(
            4,
            vec![crate::graph::coo::Edge::new(0, 1)],
        );
        let config = ArchConfig {
            crossbar_size: 2,
            total_engines: 1,
            static_engines: 0,
            ..ArchConfig::default()
        };
        let params = CostParams { endurance_cycles: 2.0, ..CostParams::default() };
        let res = run_with_params(&g, &config, &params, &Bfs::new(0)).unwrap();
        assert!(res.dynamic_ops >= 1);
        assert_eq!(res.values[1], 1.0);
    }
}
