//! `ArtifactStore` — the shared preprocessed-artifact cache.
//!
//! Promoted from the serve loop's ad-hoc `PreCache` so that CLI,
//! coordinator, and DSE callers all reuse one set of Alg.-1 outputs: the
//! paper's static engines only avoid crossbar reconfiguration if every
//! entry point runs against the same preprocessed tables.
//!
//! A cached [`Preprocessed`] carries its compiled
//! [`ExecutionPlan`](crate::sched::ExecutionPlan), so the schedule is
//! compiled exactly once per `(dataset, scale, weighted, arch)` key — the
//! arch signature includes the execution order and the static split —
//! and every serve worker and repeat job interprets the *same plan
//! instance* (asserted by the coordinator integration tests).
//!
//! Exactly-once semantics per key: concurrent requesters of the *same*
//! key block on a per-key slot while the first one preprocesses;
//! different keys build in parallel.
//!
//! **Two-tier**: a store built with [`ArtifactStore::with_dir`] backs the
//! in-memory `Arc` map with an on-disk [`DiskStore`](super::DiskStore) of
//! serialized artifacts. Lookup order is memory → disk → recompute; a
//! disk hit deserializes the compiled plan instead of rebuilding it
//! (zero plan compilations on a warm start), a recompute persists its
//! result for the next process. Any disk-tier failure — truncation, bit
//! rot, version or architecture mismatch — is a typed
//! [`StoreError`](super::StoreError) handled by falling back to
//! recompute; a corrupt file is deleted and rewritten, never served.
//!
//! **Streaming mutation**: [`ArtifactStore::patch`] applies an edge
//! [`DeltaBatch`](crate::graph::DeltaBatch) to a cached artifact in
//! place — only the batch's dirty adjacency windows are re-derived, the
//! plan is section-patched rather than recompiled, and the disk tier is
//! republished under a bumped [`DeltaProvenance`] stamp. The patched
//! artifact is bit-identical to a cold recompile of the mutated graph
//! (the delta property suite's central assertion).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::accel::{Accelerator, ArchConfig, Preprocessed, PreprocessTiming};
use crate::coordinator::metrics::PreprocessPhases;
use crate::graph::datasets::Dataset;
use crate::graph::{Coo, DeltaBatch};
use crate::pattern::tables::{ExecOrder, StaticAssignment};
use crate::sched::{patch_preprocessed, PatchStats};
use crate::util::codec::{CodecError, Reader, Writer};

use super::store::{DeltaProvenance, DiskStore, StoreError};

/// A cold-compile strategy injected by the caller (graph + weighted in,
/// artifact + phase timing out). The session passes one that checks a
/// pooled worker set out of its free list and runs
/// [`Accelerator::preprocess_timed`] over it, so cold misses — including
/// the `repro artifacts warm` CLI and delta-log replay — compile in
/// parallel without the store knowing anything about thread pools.
pub type CompileFn<'a> =
    dyn Fn(&Accelerator, &Coo, bool) -> Result<(Preprocessed, PreprocessTiming)> + 'a;

/// The architecture parameters an Alg.-1 output depends on: partition
/// (crossbar size), config table (engine counts, assignment), subgraph
/// table (execution order). Stored in full — no lossy hashing — so two
/// sessions sharing one store can never serve each other artifacts
/// built for a different architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ArchSig {
    crossbar_size: usize,
    total_engines: u32,
    static_engines: u32,
    crossbars_per_engine: u32,
    order: ExecOrder,
    static_assignment: StaticAssignment,
}

impl ArchSig {
    fn of(arch: &ArchConfig) -> Self {
        Self {
            crossbar_size: arch.crossbar_size,
            total_engines: arch.total_engines,
            static_engines: arch.static_engines,
            crossbars_per_engine: arch.crossbars_per_engine,
            order: arch.order,
            static_assignment: arch.static_assignment,
        }
    }
}

/// Cache key: dataset identity, scale (fixed-point, microunits — f64 is
/// not `Eq`), whether edge weights were kept by partitioning, and the
/// preprocessing-relevant architecture parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub dataset: Dataset,
    scale_micro: u64,
    pub weighted: bool,
    arch: ArchSig,
    /// Shard stamp: which block-row shard of a `shard_count`-way split
    /// this artifact compiles. The default (`0` of `1`) is the unsharded
    /// artifact, so single-shard sessions keep their historical keys —
    /// and their already-published `.rpa` files — byte-for-byte.
    shard_id: u32,
    shard_count: u32,
}

/// The fixed-point (microunit) image of a scale factor — the form in
/// which scale participates in key identity. Shared with the session's
/// delta log and the serve queue's `CoalesceKey` so "same scale" means
/// the same thing in every map that keys on it.
pub(crate) fn scale_micro(scale: f64) -> u64 {
    // .max(1): a denormal-small scale must stay a loadable key.
    ((scale * 1e6).round() as u64).max(1)
}

impl ArtifactKey {
    pub fn new(dataset: Dataset, scale: f64, weighted: bool, arch: &ArchConfig) -> Self {
        Self {
            dataset,
            scale_micro: scale_micro(scale),
            weighted,
            arch: ArchSig::of(arch),
            shard_id: 0,
            shard_count: 1,
        }
    }

    /// Stamp this key as shard `shard_id` of a `shard_count`-way
    /// block-row split. `with_shard(0, 1)` is the identity — a 1-shard
    /// key equals (and hashes/fingerprints as) the unsharded key.
    pub fn with_shard(mut self, shard_id: u32, shard_count: u32) -> Self {
        assert!(shard_count >= 1 && shard_id < shard_count, "shard id out of range");
        self.shard_id = shard_id;
        self.shard_count = shard_count;
        self
    }

    pub fn shard_id(&self) -> u32 {
        self.shard_id
    }

    pub fn shard_count(&self) -> u32 {
        self.shard_count
    }

    pub fn scale(&self) -> f64 {
        self.scale_micro as f64 * 1e-6
    }

    /// Serialize the full key — dataset identity, fixed-point scale,
    /// weighted flag, and every arch-signature field — into the on-disk
    /// artifact header (`session::store`). The stored bytes are compared
    /// against the requested key on load, so an `ArchConfig` mismatch is
    /// a typed error even behind a colliding or copied filename.
    pub(crate) fn encode_into(&self, w: &mut Writer) {
        w.put_str(self.dataset.spec().short);
        w.put_u64(self.scale_micro);
        w.put_u8(self.weighted as u8);
        w.put_u32(self.arch.crossbar_size as u32);
        w.put_u32(self.arch.total_engines);
        w.put_u32(self.arch.static_engines);
        w.put_u32(self.arch.crossbars_per_engine);
        w.put_u8(self.arch.order.to_code());
        w.put_u8(self.arch.static_assignment.to_code());
        w.put_u32(self.shard_id);
        w.put_u32(self.shard_count);
    }

    pub(crate) fn decode_from(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        let short = r.str()?;
        let dataset = Dataset::from_short(&short)
            .ok_or(CodecError::Invalid("unknown dataset short name"))?;
        let scale_micro = r.u64()?;
        let weighted = r.u8()? != 0;
        let arch = ArchSig {
            crossbar_size: r.u32()? as usize,
            total_engines: r.u32()?,
            static_engines: r.u32()?,
            crossbars_per_engine: r.u32()?,
            order: ExecOrder::from_code(r.u8()?)
                .ok_or(CodecError::Invalid("unknown execution-order code"))?,
            static_assignment: StaticAssignment::from_code(r.u8()?)
                .ok_or(CodecError::Invalid("unknown static-assignment code"))?,
        };
        let shard_id = r.u32()?;
        let shard_count = r.u32()?;
        if shard_count == 0 || shard_id >= shard_count {
            return Err(CodecError::Invalid("shard id out of range"));
        }
        Ok(Self { dataset, scale_micro, weighted, arch, shard_id, shard_count })
    }

    /// Stable 64-bit content address over the encoded key bytes — the
    /// on-disk filename component. Deliberately *not* `std::hash::Hash`
    /// (whose layout is an implementation detail): this value is part of
    /// the persistent format.
    pub fn fingerprint(&self) -> u64 {
        let mut w = Writer::new();
        self.encode_into(&mut w);
        crate::util::codec::fnv1a64(w.as_bytes())
    }

    /// One-line human-readable identity (the `repro artifacts ls` view).
    pub fn summary(&self) -> String {
        format!(
            "{} scale {:.3} {} | C={} T={} N={} M={} {:?} {:?} | shard {}/{}",
            self.dataset.spec().short,
            self.scale(),
            if self.weighted { "weighted" } else { "unweighted" },
            self.arch.crossbar_size,
            self.arch.total_engines,
            self.arch.static_engines,
            self.arch.crossbars_per_engine,
            self.arch.order,
            self.arch.static_assignment,
            self.shard_id,
            self.shard_count,
        )
    }
}

#[derive(Debug, Default)]
struct Slot {
    /// The artifact plus its accumulated delta provenance (zeroed for a
    /// cold compile, carried across the disk tier for a patched entry)
    /// and the phase timing of the cold compile that produced it
    /// (carried verbatim across patches and disk round trips).
    pre: Mutex<Option<(Arc<Preprocessed>, DeltaProvenance, PreprocessTiming)>>,
}

/// Counters for cache behaviour (`misses` == preprocessing runs — a
/// disk hit is *not* a miss, because nothing was compiled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArtifactStats {
    /// In-memory hits (the artifact `Arc` was already resident).
    pub hits: u64,
    /// Full misses: Alg. 1 + plan compilation actually ran. On a
    /// two-tier store this stays 0 for every key already on disk — the
    /// warm-start acceptance criterion.
    pub misses: u64,
    pub entries: usize,
    /// Requests that found their key's build already in flight (or its
    /// slot otherwise contended) and blocked for the shared result
    /// instead of starting a second preprocess. Always `<= hits + misses
    /// + disk_hits`; under an N-thread stampede on one cold key, up to
    /// N−1 requests coalesce behind the single builder.
    pub coalesced: u64,
    /// Memory misses satisfied by deserializing an on-disk artifact
    /// (no recompute). Always 0 on a memory-only store.
    pub disk_hits: u64,
    /// Memory misses that probed the disk tier and found nothing usable
    /// (absent, stale, or corrupt file) and fell through to recompute.
    pub disk_misses: u64,
    /// Artifacts this store persisted to disk (another store winning the
    /// publish race does not count — writes are exactly-once per key
    /// across every store sharing the directory on any filesystem with
    /// hard links; on the rare mount without them, racing writers of
    /// identical bytes may each count one — see [`DiskStore::save`]).
    pub writes: u64,
}

/// Concurrent map from [`ArtifactKey`] to preprocessed artifacts,
/// optionally backed by an on-disk [`DiskStore`] tier.
#[derive(Debug, Default)]
pub struct ArtifactStore {
    slots: Mutex<HashMap<ArtifactKey, Arc<Slot>>>,
    /// Persistent tier; `None` = memory-only (the historical behaviour).
    disk: Option<DiskStore>,
    /// Bumped by [`clear`](Self::clear) *before* it starts deleting, so
    /// an in-flight recompute (whose disk publish runs outside the slot
    /// lock) can tell its artifact was cleared out from under it and
    /// must not re-persist it.
    clear_gen: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    disk_hits: AtomicU64,
    disk_misses: AtomicU64,
    writes: AtomicU64,
    /// Phase-split wall time of every cold compile this store ran — the
    /// single source of truth `Service::snapshot` and the `artifacts
    /// warm` CLI read. Disk hits and patches record nothing here:
    /// `compiles` counts actual preprocess runs, exactly like `misses`.
    phases: Mutex<PreprocessPhases>,
}

impl ArtifactStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// A two-tier store over `dir` (created if needed): memory misses
    /// probe the directory for a serialized artifact before recomputing,
    /// and recomputes persist their result. Any number of stores — in
    /// this process or others — may share one directory; on-disk writes
    /// are exactly-once per key across all of them.
    pub fn with_dir(dir: impl Into<std::path::PathBuf>) -> Result<Self> {
        let disk = DiskStore::open(dir)?;
        Ok(Self { disk: Some(disk), ..Self::default() })
    }

    /// The on-disk tier's directory, if this store has one.
    pub fn disk_dir(&self) -> Option<&std::path::Path> {
        self.disk.as_ref().map(|d| d.dir())
    }

    /// Return the cached artifact for `key`, or load the dataset and run
    /// Alg. 1 under `acc` exactly once. A failed build leaves the slot
    /// empty so the next caller retries.
    pub fn get_or_preprocess(
        &self,
        key: ArtifactKey,
        acc: &Accelerator,
    ) -> Result<Arc<Preprocessed>> {
        self.build(key, acc, None, None)
    }

    /// Like [`get_or_preprocess`](Self::get_or_preprocess) but builds
    /// from a graph the caller already loaded (must be `key`'s graph),
    /// avoiding a second dataset load on a cache miss.
    pub fn get_or_preprocess_from(
        &self,
        key: ArtifactKey,
        acc: &Accelerator,
        graph: &Coo,
    ) -> Result<Arc<Preprocessed>> {
        self.build(key, acc, Some(graph), None)
    }

    /// The fully general entry point: optional pre-loaded graph, and an
    /// optional [`CompileFn`] that replaces the sequential
    /// `acc.preprocess` on a full miss (the session's pooled parallel
    /// compile). Cache semantics are identical on every path — the
    /// strategy only changes *how* a miss compiles, never what it
    /// produces (parallel preprocess is whole-struct-equal to
    /// sequential; see `rust/tests/preprocess_par.rs`).
    pub fn get_or_preprocess_with(
        &self,
        key: ArtifactKey,
        acc: &Accelerator,
        graph: Option<&Coo>,
        compile: &CompileFn<'_>,
    ) -> Result<Arc<Preprocessed>> {
        self.build(key, acc, graph, Some(compile))
    }

    /// Phase timing accumulated over this store's cold compiles.
    pub fn preprocess_phases(&self) -> PreprocessPhases {
        *self.phases.lock().unwrap()
    }

    fn build(
        &self,
        key: ArtifactKey,
        acc: &Accelerator,
        graph: Option<&Coo>,
        compile: Option<&CompileFn<'_>>,
    ) -> Result<Arc<Preprocessed>> {
        let slot = {
            let mut slots = self.slots.lock().unwrap();
            Arc::clone(slots.entry(key).or_default())
        };
        // A contended per-key lock means another caller holds the slot —
        // almost always the in-flight first build; waiting here is what
        // coalesces the stampede into exactly one preprocess.
        let mut cell = match slot.pre.try_lock() {
            Ok(cell) => cell,
            Err(std::sync::TryLockError::WouldBlock) => {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                slot.pre.lock().unwrap()
            }
            // Same failure mode as the plain `.lock().unwrap()` before:
            // a poisoned slot (builder panicked) is unrecoverable.
            Err(e @ std::sync::TryLockError::Poisoned(_)) => {
                panic!("artifact slot poisoned: {e}")
            }
        };
        if let Some((p, ..)) = cell.as_ref() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(p));
        }
        // Disk tier: a serialized artifact skips the dataset load, Alg. 1
        // *and* plan compilation. Every failure mode is typed and falls
        // through to recompute — a corrupt file is removed (and rewritten
        // below), never served.
        if let Some(disk) = &self.disk {
            match disk.load_with(&key, &acc.config) {
                Ok((pre, prov, timing)) => {
                    self.disk_hits.fetch_add(1, Ordering::Relaxed);
                    let p = Arc::new(pre);
                    *cell = Some((Arc::clone(&p), prov, timing));
                    return Ok(p);
                }
                // Nothing there, or a *transient* I/O failure (fd
                // exhaustion, momentary permissions): recompute, but
                // leave the file alone — it may be perfectly valid.
                Err(StoreError::Missing) | Err(StoreError::Io(_)) => {
                    self.disk_misses.fetch_add(1, Ordering::Relaxed);
                }
                // Structurally bad for this binary and this key
                // (corrupt, stale version, foreign key): delete so the
                // recompute below can republish a good file.
                Err(_) => {
                    self.disk_misses.fetch_add(1, Ordering::Relaxed);
                    disk.remove(&key);
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let generation = self.clear_gen.load(Ordering::Acquire);
        let loaded;
        let g = match graph {
            Some(g) => g,
            None => {
                loaded = if key.weighted {
                    key.dataset.load_weighted(key.scale())?
                } else {
                    key.dataset.load_scaled(key.scale())?
                };
                &loaded
            }
        };
        let (pre, timing) = match compile {
            Some(f) => f(acc, g, key.weighted)?,
            None => acc.preprocess_timed(g, key.weighted, None)?,
        };
        let p = Arc::new(pre);
        self.phases.lock().unwrap().record(&timing);
        *cell = Some((Arc::clone(&p), DeltaProvenance::default(), timing));
        // Release the per-key slot before serializing to disk: coalesced
        // waiters only need the in-memory Arc, which is ready now — they
        // must not stall behind a multi-MB file write. The on-disk
        // publish is exactly-once on its own (temp-file + hard-link), so
        // it needs no lock.
        drop(cell);
        if let Some(disk) = &self.disk {
            // Persist for the next process. A lost publish race or an
            // unwritable directory degrades to memory-only caching — the
            // job itself must not fail on it. If `clear()` ran at any
            // point since this build started (checked again *after* the
            // publish, so a clear overlapping the file write is caught
            // too), honor it: un-publish rather than resurrect an
            // artifact the caller just wiped.
            if self.clear_gen.load(Ordering::Acquire) == generation {
                if let Ok(true) = disk.save_with(&key, &p, &DeltaProvenance::default(), &timing) {
                    if self.clear_gen.load(Ordering::Acquire) == generation {
                        self.writes.fetch_add(1, Ordering::Relaxed);
                    } else {
                        disk.remove(&key);
                    }
                }
            }
        }
        Ok(p)
    }

    /// Apply a validated [`DeltaBatch`] to the cached artifact for
    /// `key`, patching it **in place** (dirty adjacency windows only —
    /// never a whole-plan recompile; see
    /// [`patch_preprocessed`](crate::sched::patch_preprocessed)).
    ///
    /// Lookup order mirrors [`build`](Self::get_or_preprocess): a
    /// memory-resident artifact is patched directly; otherwise a
    /// disk-tier artifact is deserialized, patched, and promoted to
    /// memory. A key cached in *neither* tier returns `Ok(None)` — there
    /// is nothing to invalidate, and the next `get_or_preprocess`
    /// compiles against the already-mutated graph, so patching it here
    /// would only duplicate work.
    ///
    /// On success the on-disk entry (if any) is republished with the
    /// patched payload and accumulated [`DeltaProvenance`]; on any
    /// failure both tiers keep serving the pre-batch artifact untouched.
    pub fn patch(
        &self,
        key: ArtifactKey,
        arch: &ArchConfig,
        batch: &DeltaBatch,
    ) -> Result<Option<PatchStats>> {
        let slot = {
            let mut slots = self.slots.lock().unwrap();
            Arc::clone(slots.entry(key).or_default())
        };
        let mut cell = match slot.pre.try_lock() {
            Ok(cell) => cell,
            Err(std::sync::TryLockError::WouldBlock) => {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                slot.pre.lock().unwrap()
            }
            Err(e @ std::sync::TryLockError::Poisoned(_)) => {
                panic!("artifact slot poisoned: {e}")
            }
        };
        let generation = self.clear_gen.load(Ordering::Acquire);
        // Non-destructive read: the cached value stays in place until the
        // patched replacement is ready, so a failed patch leaves every
        // tier serving the pre-batch artifact.
        let (mut pre, mut prov, timing) = match cell.as_ref() {
            Some((p, prov, timing)) => ((**p).clone(), *prov, *timing),
            None => match &self.disk {
                Some(disk) => match disk.load_with(&key, arch) {
                    Ok((pre, prov, timing)) => {
                        self.disk_hits.fetch_add(1, Ordering::Relaxed);
                        (pre, prov, timing)
                    }
                    Err(StoreError::Missing) | Err(StoreError::Io(_)) => {
                        self.disk_misses.fetch_add(1, Ordering::Relaxed);
                        return Ok(None);
                    }
                    Err(_) => {
                        self.disk_misses.fetch_add(1, Ordering::Relaxed);
                        disk.remove(&key);
                        return Ok(None);
                    }
                },
                None => return Ok(None),
            },
        };
        let stats = patch_preprocessed(&mut pre, batch, arch)?;
        prov.batches += 1;
        prov.dirty_partitions += u64::from(stats.dirty_partitions);
        prov.patched_ops += u64::from(stats.patched_ops);
        let p = Arc::new(pre);
        *cell = Some((Arc::clone(&p), prov, timing));
        drop(cell);
        // Republish the patched generation of this key: the stale file
        // must go first, because `save_with` is once-only per existing
        // target. Same clear()-race discipline as `build`'s publish.
        if let Some(disk) = &self.disk {
            if self.clear_gen.load(Ordering::Acquire) == generation {
                disk.remove(&key);
                if let Ok(true) = disk.save_with(&key, &p, &prov, &timing) {
                    if self.clear_gen.load(Ordering::Acquire) == generation {
                        self.writes.fetch_add(1, Ordering::Relaxed);
                    } else {
                        disk.remove(&key);
                    }
                }
            }
        }
        Ok(Some(stats))
    }

    /// Drop every cached **sharded** variant of `base` from both tiers
    /// (shard stamps ignored in the match; `base` itself — the
    /// unsharded key — is left alone). The streaming-mutation path
    /// calls this instead of patching per-shard plans in place: the
    /// patch kernel stays single-plan, and because the session's delta
    /// log routes the next sharded compile to the mutated graph — and
    /// the determinism contract makes that recompile bit-identical to
    /// a patch — invalidate-to-recompile is an equivalent, simpler
    /// policy. Returns the number of distinct shard keys dropped.
    pub fn invalidate_sharded(&self, base: ArtifactKey) -> u32 {
        let base = base.with_shard(0, 1);
        let matches = |k: &ArtifactKey| k.shard_count() > 1 && k.with_shard(0, 1) == base;
        let mut stale: std::collections::HashSet<ArtifactKey> = {
            let mut slots = self.slots.lock().unwrap();
            let keys: Vec<ArtifactKey> = slots.keys().filter(|k| matches(k)).copied().collect();
            for k in &keys {
                slots.remove(k);
            }
            keys.into_iter().collect()
        };
        // Disk files can outlive the memory tier (written by an earlier
        // process), so sweep the directory by embedded key too.
        if let Some(disk) = &self.disk {
            for path in disk.entries() {
                if let Ok(key) = DiskStore::embedded_key(&path) {
                    if matches(&key) && disk.remove(&key) {
                        stale.insert(key);
                    }
                }
            }
        }
        stale.len() as u32
    }

    /// Peek without building (does not count as a hit).
    pub fn get(&self, key: &ArtifactKey) -> Option<Arc<Preprocessed>> {
        let slot = self.slots.lock().unwrap().get(key).cloned()?;
        let cell = slot.pre.lock().unwrap();
        cell.as_ref().map(|(p, ..)| Arc::clone(p))
    }

    pub fn stats(&self) -> ArtifactStats {
        let slots = self.slots.lock().unwrap();
        let entries = slots
            .values()
            .filter(|s| s.pre.lock().unwrap().is_some())
            .count();
        ArtifactStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
            coalesced: self.coalesced.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            disk_misses: self.disk_misses.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
        }
    }

    /// Drop every cached artifact — **both tiers**: the in-memory map
    /// and, on a two-tier store, every artifact file in the directory
    /// (including orphans from older format versions). Counters keep
    /// accumulating.
    pub fn clear(&self) {
        // Before deleting anything: any recompute still in flight must
        // see the bump and refrain from re-persisting its artifact.
        self.clear_gen.fetch_add(1, Ordering::AcqRel);
        self.slots.lock().unwrap().clear();
        if let Some(disk) = &self.disk {
            disk.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Accelerator;

    fn key(scale: f64, weighted: bool) -> ArtifactKey {
        ArtifactKey::new(Dataset::Tiny, scale, weighted, &ArchConfig::default())
    }

    #[test]
    fn key_is_fixed_point_in_scale() {
        let a = key(1.0, false);
        let b = key(1.0 - 1e-9, false);
        assert_eq!(a, b);
        assert_eq!(a.scale(), 1.0);
        assert_ne!(a, key(0.5, false));
        assert_ne!(a, key(1.0, true));
    }

    #[test]
    fn different_architectures_do_not_collide() {
        let a = key(1.0, false);
        let arch8 = ArchConfig { crossbar_size: 8, ..ArchConfig::default() };
        assert_ne!(a, ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch8));
        let n0 = ArchConfig { static_engines: 0, ..ArchConfig::default() };
        assert_ne!(a, ArtifactKey::new(Dataset::Tiny, 1.0, false, &n0));
    }

    #[test]
    fn same_key_preprocesses_once() {
        let store = ArtifactStore::new();
        let acc = Accelerator::with_defaults();
        let a = store.get_or_preprocess(key(1.0, false), &acc).unwrap();
        let b = store.get_or_preprocess(key(1.0, false), &acc).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        // Exactly one compile recorded phase timing; the hit recorded
        // nothing (compiles mirrors misses by construction).
        let ph = store.preprocess_phases();
        assert_eq!(ph.compiles, 1);
        assert!(ph.total.max_ns > 0);
    }

    #[test]
    fn distinct_keys_build_separately() {
        let store = ArtifactStore::new();
        let acc = Accelerator::with_defaults();
        store.get_or_preprocess(key(1.0, false), &acc).unwrap();
        store.get_or_preprocess(key(0.5, false), &acc).unwrap();
        store.get_or_preprocess(key(1.0, true), &acc).unwrap();
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 3, 3));
    }

    #[test]
    fn fingerprint_is_stable_and_key_sensitive() {
        let a = key(1.0, false);
        assert_eq!(a.fingerprint(), key(1.0, false).fingerprint());
        assert_ne!(a.fingerprint(), key(0.5, false).fingerprint());
        assert_ne!(a.fingerprint(), key(1.0, true).fingerprint());
        let arch8 = ArchConfig { crossbar_size: 8, ..ArchConfig::default() };
        assert_ne!(
            a.fingerprint(),
            ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch8).fingerprint()
        );
    }

    #[test]
    fn key_encoding_roundtrips() {
        let arch = ArchConfig { static_engines: 3, ..ArchConfig::default() };
        let k = ArtifactKey::new(Dataset::WikiVote, 0.25, true, &arch).with_shard(2, 4);
        let mut w = Writer::new();
        k.encode_into(&mut w);
        let bytes = w.into_bytes();
        let got = ArtifactKey::decode_from(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(k, got);
        assert_eq!((got.shard_id(), got.shard_count()), (2, 4));
    }

    #[test]
    fn shard_stamp_is_part_of_key_identity_and_defaults_to_unsharded() {
        let a = key(1.0, false);
        // The 1-shard stamp is the identity: same key, same fingerprint,
        // so single-shard sessions keep serving their historical files.
        assert_eq!(a, a.with_shard(0, 1));
        assert_eq!(a.fingerprint(), a.with_shard(0, 1).fingerprint());
        // Any real shard stamp is a distinct artifact.
        assert_ne!(a, a.with_shard(0, 2));
        assert_ne!(a.with_shard(0, 2), a.with_shard(1, 2));
        assert_ne!(a.fingerprint(), a.with_shard(0, 2).fingerprint());
        assert_ne!(
            a.with_shard(0, 2).fingerprint(),
            a.with_shard(1, 2).fingerprint()
        );
        assert!(a.summary().contains("shard 0/1"));
        assert!(a.with_shard(1, 4).summary().contains("shard 1/4"));
    }

    #[test]
    fn two_tier_store_round_trips_through_disk() {
        let dir =
            std::env::temp_dir().join(format!("repro-artifact-two-tier-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let acc = Accelerator::with_defaults();
        let k = key(1.0, false);

        let first = ArtifactStore::with_dir(&dir).unwrap();
        let a = first.get_or_preprocess(k, &acc).unwrap();
        let s = first.stats();
        assert_eq!((s.misses, s.disk_hits, s.disk_misses, s.writes), (1, 0, 1, 1));

        // A fresh store over the same directory warm-starts: zero
        // compilations, one disk hit, and the identical artifact.
        let second = ArtifactStore::with_dir(&dir).unwrap();
        let b = second.get_or_preprocess(k, &acc).unwrap();
        let s = second.stats();
        assert_eq!((s.misses, s.disk_hits, s.writes), (0, 1, 0));
        assert_eq!(*a, *b);
        assert_eq!(second.preprocess_phases().compiles, 0, "disk hit compiled nothing");

        // clear() empties both tiers: the next fresh store recomputes.
        second.clear();
        let third = ArtifactStore::with_dir(&dir).unwrap();
        third.get_or_preprocess(k, &acc).unwrap();
        assert_eq!(third.stats().misses, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn patch_rewrites_cached_artifact_and_skips_absent_keys() {
        let store = ArtifactStore::new();
        let acc = Accelerator::with_defaults();
        let k = key(1.0, false);

        // Nothing cached yet: a patch has nothing to invalidate.
        let g = Dataset::Tiny.load().unwrap();
        let e = g.edges[0];
        let batch = DeltaBatch::new(
            g.num_vertices,
            vec![crate::graph::EdgeDelta::remove(e.src, e.dst)],
        )
        .unwrap();
        assert!(store.patch(k, &acc.config, &batch).unwrap().is_none());

        // Cached: the patched artifact must equal a cold recompile of
        // the mutated graph, served from memory without a new miss.
        store.get_or_preprocess(k, &acc).unwrap();
        let stats = store.patch(k, &acc.config, &batch).unwrap().unwrap();
        assert_eq!(stats.removes, 1);
        let cold = acc.preprocess(&batch.apply_to_coo(&g).unwrap(), false).unwrap();
        assert_eq!(*store.get(&k).unwrap(), cold);
        assert_eq!(store.stats().misses, 1, "patch never recompiles");
    }

    #[test]
    fn concurrent_same_key_builds_exactly_once() {
        let store = Arc::new(ArtifactStore::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let store = Arc::clone(&store);
                std::thread::spawn(move || {
                    store
                        .get_or_preprocess(key(1.0, false), &Accelerator::with_defaults())
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = store.stats();
        assert_eq!(s.misses, 1, "preprocessing must run exactly once");
        assert_eq!(s.hits, 7);
    }
}
