//! `ArtifactStore` — the shared preprocessed-artifact cache.
//!
//! Promoted from the serve loop's ad-hoc `PreCache` so that CLI,
//! coordinator, and DSE callers all reuse one set of Alg.-1 outputs: the
//! paper's static engines only avoid crossbar reconfiguration if every
//! entry point runs against the same preprocessed tables.
//!
//! A cached [`Preprocessed`] carries its compiled
//! [`ExecutionPlan`](crate::sched::ExecutionPlan), so the schedule is
//! compiled exactly once per `(dataset, scale, weighted, arch)` key — the
//! arch signature includes the execution order and the static split —
//! and every serve worker and repeat job interprets the *same plan
//! instance* (asserted by the coordinator integration tests).
//!
//! Exactly-once semantics per key: concurrent requesters of the *same*
//! key block on a per-key slot while the first one preprocesses;
//! different keys build in parallel.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::accel::{Accelerator, ArchConfig, Preprocessed};
use crate::graph::datasets::Dataset;
use crate::pattern::tables::{ExecOrder, StaticAssignment};

/// The architecture parameters an Alg.-1 output depends on: partition
/// (crossbar size), config table (engine counts, assignment), subgraph
/// table (execution order). Stored in full — no lossy hashing — so two
/// sessions sharing one store can never serve each other artifacts
/// built for a different architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ArchSig {
    crossbar_size: usize,
    total_engines: u32,
    static_engines: u32,
    crossbars_per_engine: u32,
    order: ExecOrder,
    static_assignment: StaticAssignment,
}

impl ArchSig {
    fn of(arch: &ArchConfig) -> Self {
        Self {
            crossbar_size: arch.crossbar_size,
            total_engines: arch.total_engines,
            static_engines: arch.static_engines,
            crossbars_per_engine: arch.crossbars_per_engine,
            order: arch.order,
            static_assignment: arch.static_assignment,
        }
    }
}

/// Cache key: dataset identity, scale (fixed-point, microunits — f64 is
/// not `Eq`), whether edge weights were kept by partitioning, and the
/// preprocessing-relevant architecture parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub dataset: Dataset,
    scale_micro: u64,
    pub weighted: bool,
    arch: ArchSig,
}

impl ArtifactKey {
    pub fn new(dataset: Dataset, scale: f64, weighted: bool, arch: &ArchConfig) -> Self {
        // .max(1): a denormal-small scale must stay a loadable key.
        let scale_micro = ((scale * 1e6).round() as u64).max(1);
        Self { dataset, scale_micro, weighted, arch: ArchSig::of(arch) }
    }

    pub fn scale(&self) -> f64 {
        self.scale_micro as f64 * 1e-6
    }
}

#[derive(Debug, Default)]
struct Slot {
    pre: Mutex<Option<Arc<Preprocessed>>>,
}

/// Counters for cache behaviour (`misses` == preprocessing runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArtifactStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// Requests that found their key's build already in flight (or its
    /// slot otherwise contended) and blocked for the shared result
    /// instead of starting a second preprocess. Always `<= hits + misses`;
    /// under an N-thread stampede on one cold key, up to N−1 requests
    /// coalesce behind the single builder.
    pub coalesced: u64,
}

/// Concurrent map from [`ArtifactKey`] to preprocessed artifacts.
#[derive(Debug, Default)]
pub struct ArtifactStore {
    slots: Mutex<HashMap<ArtifactKey, Arc<Slot>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
}

impl ArtifactStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Return the cached artifact for `key`, or load the dataset and run
    /// Alg. 1 under `acc` exactly once. A failed build leaves the slot
    /// empty so the next caller retries.
    pub fn get_or_preprocess(
        &self,
        key: ArtifactKey,
        acc: &Accelerator,
    ) -> Result<Arc<Preprocessed>> {
        self.build(key, acc, None)
    }

    /// Like [`get_or_preprocess`](Self::get_or_preprocess) but builds
    /// from a graph the caller already loaded (must be `key`'s graph),
    /// avoiding a second dataset load on a cache miss.
    pub fn get_or_preprocess_from(
        &self,
        key: ArtifactKey,
        acc: &Accelerator,
        graph: &crate::graph::Coo,
    ) -> Result<Arc<Preprocessed>> {
        self.build(key, acc, Some(graph))
    }

    fn build(
        &self,
        key: ArtifactKey,
        acc: &Accelerator,
        graph: Option<&crate::graph::Coo>,
    ) -> Result<Arc<Preprocessed>> {
        let slot = {
            let mut slots = self.slots.lock().unwrap();
            Arc::clone(slots.entry(key).or_default())
        };
        // A contended per-key lock means another caller holds the slot —
        // almost always the in-flight first build; waiting here is what
        // coalesces the stampede into exactly one preprocess.
        let mut cell = match slot.pre.try_lock() {
            Ok(cell) => cell,
            Err(std::sync::TryLockError::WouldBlock) => {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                slot.pre.lock().unwrap()
            }
            // Same failure mode as the plain `.lock().unwrap()` before:
            // a poisoned slot (builder panicked) is unrecoverable.
            Err(e @ std::sync::TryLockError::Poisoned(_)) => {
                panic!("artifact slot poisoned: {e}")
            }
        };
        if let Some(p) = cell.as_ref() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(p));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let loaded;
        let g = match graph {
            Some(g) => g,
            None => {
                loaded = if key.weighted {
                    key.dataset.load_weighted(key.scale())?
                } else {
                    key.dataset.load_scaled(key.scale())?
                };
                &loaded
            }
        };
        let p = Arc::new(acc.preprocess(g, key.weighted)?);
        *cell = Some(Arc::clone(&p));
        Ok(p)
    }

    /// Peek without building (does not count as a hit).
    pub fn get(&self, key: &ArtifactKey) -> Option<Arc<Preprocessed>> {
        let slot = self.slots.lock().unwrap().get(key).cloned()?;
        let cell = slot.pre.lock().unwrap();
        cell.clone()
    }

    pub fn stats(&self) -> ArtifactStats {
        let slots = self.slots.lock().unwrap();
        let entries = slots
            .values()
            .filter(|s| s.pre.lock().unwrap().is_some())
            .count();
        ArtifactStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
            coalesced: self.coalesced.load(Ordering::Relaxed),
        }
    }

    /// Drop every cached artifact (counters keep accumulating).
    pub fn clear(&self) {
        self.slots.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Accelerator;

    fn key(scale: f64, weighted: bool) -> ArtifactKey {
        ArtifactKey::new(Dataset::Tiny, scale, weighted, &ArchConfig::default())
    }

    #[test]
    fn key_is_fixed_point_in_scale() {
        let a = key(1.0, false);
        let b = key(1.0 - 1e-9, false);
        assert_eq!(a, b);
        assert_eq!(a.scale(), 1.0);
        assert_ne!(a, key(0.5, false));
        assert_ne!(a, key(1.0, true));
    }

    #[test]
    fn different_architectures_do_not_collide() {
        let a = key(1.0, false);
        let arch8 = ArchConfig { crossbar_size: 8, ..ArchConfig::default() };
        assert_ne!(a, ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch8));
        let n0 = ArchConfig { static_engines: 0, ..ArchConfig::default() };
        assert_ne!(a, ArtifactKey::new(Dataset::Tiny, 1.0, false, &n0));
    }

    #[test]
    fn same_key_preprocesses_once() {
        let store = ArtifactStore::new();
        let acc = Accelerator::with_defaults();
        let a = store.get_or_preprocess(key(1.0, false), &acc).unwrap();
        let b = store.get_or_preprocess(key(1.0, false), &acc).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn distinct_keys_build_separately() {
        let store = ArtifactStore::new();
        let acc = Accelerator::with_defaults();
        store.get_or_preprocess(key(1.0, false), &acc).unwrap();
        store.get_or_preprocess(key(0.5, false), &acc).unwrap();
        store.get_or_preprocess(key(1.0, true), &acc).unwrap();
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 3, 3));
    }

    #[test]
    fn concurrent_same_key_builds_exactly_once() {
        let store = Arc::new(ArtifactStore::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let store = Arc::clone(&store);
                std::thread::spawn(move || {
                    store
                        .get_or_preprocess(key(1.0, false), &Accelerator::with_defaults())
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = store.stats();
        assert_eq!(s.misses, 1, "preprocessing must run exactly once");
        assert_eq!(s.hits, 7);
    }
}
