//! `JobSpec` — the open job description shared by every entry point.
//!
//! Replaced the closed `coordinator::Job` enum (whose per-algorithm
//! variants forced duplicated match arms into `main.rs` and the serve
//! workers; the enum and its `From<Job>` shim were removed once every
//! caller migrated): *what* to run is an [`AlgorithmId`] looked up in
//! the session's registry, and per-algorithm knobs ride in one open
//! [`AlgoParams`] bag.

use anyhow::Result;

use crate::algo::registry::{AlgoParams, AlgorithmId};
use crate::graph::datasets::Dataset;

/// A graph-processing request: which input, at which scale, through which
/// registered algorithm, with which parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub dataset: Dataset,
    /// Dataset scale factor in (0, 1] (see `Dataset::load_scaled`).
    pub scale: f64,
    pub algorithm: AlgorithmId,
    pub params: AlgoParams,
    /// Per-job override of the session's superstep execution-lane count
    /// (`None` = session default; `Some(0)` = one lane per hardware
    /// thread). Purely a throughput knob — results are bit-identical for
    /// every setting.
    pub parallelism: Option<usize>,
}

impl JobSpec {
    /// A job at full dataset scale with default parameters.
    pub fn new(dataset: Dataset, algorithm: impl Into<AlgorithmId>) -> Self {
        Self {
            dataset,
            scale: 1.0,
            algorithm: algorithm.into(),
            params: AlgoParams::default(),
            parallelism: None,
        }
    }

    pub fn with_scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    pub fn with_source(mut self, source: u32) -> Self {
        self.params.source = source;
        self
    }

    pub fn with_iterations(mut self, iterations: usize) -> Self {
        self.params.iterations = iterations;
        self
    }

    pub fn with_damping(mut self, damping: f32) -> Self {
        self.params.damping = damping;
        self
    }

    pub fn with_params(mut self, params: AlgoParams) -> Self {
        self.params = params;
        self
    }

    /// Override the session's execution-lane count for this job alone.
    pub fn with_parallelism(mut self, threads: usize) -> Self {
        self.parallelism = Some(threads);
        self
    }

    /// Spec-level validation (algorithm existence and parameter checks
    /// happen against the session's registry at run time).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.scale > 0.0 && self.scale <= 1.0 && self.scale.is_finite(),
            "scale must be in (0, 1], got {}",
            self.scale
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_style_construction() {
        let s = JobSpec::new(Dataset::Tiny, "BFS").with_scale(0.5).with_source(3);
        assert_eq!(s.algorithm.as_str(), "bfs");
        assert_eq!(s.scale, 0.5);
        assert_eq!(s.params.source, 3);
        assert_eq!(s.parallelism, None);
        assert!(s.validate().is_ok());
        assert_eq!(s.with_parallelism(4).parallelism, Some(4));
    }

    #[test]
    fn rejects_bad_scale() {
        assert!(JobSpec::new(Dataset::Tiny, "bfs").with_scale(0.0).validate().is_err());
        assert!(JobSpec::new(Dataset::Tiny, "bfs").with_scale(1.5).validate().is_err());
        assert!(JobSpec::new(Dataset::Tiny, "bfs").with_scale(f64::NAN).validate().is_err());
    }
}
