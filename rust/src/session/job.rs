//! `JobSpec` — the open job description shared by every entry point.
//!
//! Replaced the closed `coordinator::Job` enum (whose per-algorithm
//! variants forced duplicated match arms into `main.rs` and the serve
//! workers; the enum and its `From<Job>` shim were removed once every
//! caller migrated): *what* to run is an [`AlgorithmId`] looked up in
//! the session's registry, and per-algorithm knobs ride in one open
//! [`AlgoParams`] bag.

use std::time::Duration;

use anyhow::Result;

use crate::algo::registry::{AlgoParams, AlgorithmId};
use crate::graph::datasets::Dataset;

use super::artifact::scale_micro;

/// A graph-processing request: which input, at which scale, through which
/// registered algorithm, with which parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub dataset: Dataset,
    /// Dataset scale factor in (0, 1] (see `Dataset::load_scaled`).
    pub scale: f64,
    pub algorithm: AlgorithmId,
    pub params: AlgoParams,
    /// Per-job override of the session's superstep execution-lane count
    /// (`None` = session default; `Some(0)` = one lane per hardware
    /// thread). Purely a throughput knob — results are bit-identical for
    /// every setting.
    pub parallelism: Option<usize>,
    /// Per-job override of the session's shard count (`None` = session
    /// default). A scheduling knob exactly like `parallelism`: the shard
    /// merge determinism invariant guarantees bit-identical results for
    /// every shard count, so it never enters [`CoalesceKey`].
    pub shards: Option<u32>,
    /// Dequeue priority: higher runs first within the serve queue
    /// (default 0; ties break earliest-deadline, then FIFO). Scheduling
    /// only — never part of the result or the coalesce identity.
    pub priority: i8,
    /// Optional latency budget, measured from `Service::submit`. A job
    /// still queued when its deadline passes is load-shed at dequeue
    /// (typed `JobError::DeadlineExceeded`) instead of wasting an
    /// executor on an answer nobody is waiting for. `None` = run
    /// whenever.
    pub deadline: Option<Duration>,
}

/// The result-identity of a [`JobSpec`]: two specs with equal keys are
/// guaranteed — by the determinism contract (see ROADMAP standing
/// invariants) — to produce bit-identical `SimReport`s, so the serve
/// queue lets them share one execution (request coalescing).
///
/// Deliberately *excludes* `parallelism` and `shards` (pure throughput
/// knobs — results are bit-identical for every lane count and every
/// shard count), `priority`, and `deadline` (scheduling inputs, not
/// result inputs). Scale enters in
/// the same fixed-point microunit image the `ArtifactKey` uses, so
/// "same scale" means the same thing at both cache levels.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CoalesceKey {
    dataset: Dataset,
    scale_micro: u64,
    algorithm: AlgorithmId,
    source: u32,
    iterations: usize,
    damping_bits: u32,
}

/// The batch-compatibility identity of a [`JobSpec`]: two specs with
/// equal keys share one execution artifact — the same `(dataset,
/// scale-microunits, algorithm kind, weighted)` preprocessing output and
/// compiled plan — and identical result-determining parameters except
/// the source vertex, so a serve worker can run them as one multi-source
/// batch through the batch-aware executor surface
/// (`sched::run_parallel_pooled_batch`).
///
/// Batch compatibility is a **scheduling** decision, exactly like
/// `parallelism` and `shards`: it decides *when* jobs run together,
/// never *what* a job returns (every batched job's `RunResult` is
/// bit-identical to its solo run — see the ROADMAP batch-formation
/// invariant). It therefore must never feed back into
/// [`CoalesceKey`], which is pure result identity: specs that batch
/// together still answer with *different* per-source results, while
/// specs that coalesce share one result. The two keys are kept as
/// separate types so the compiler enforces the distinction.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    dataset: Dataset,
    scale_micro: u64,
    algorithm: AlgorithmId,
    iterations: usize,
    damping_bits: u32,
}

impl JobSpec {
    /// A job at full dataset scale with default parameters.
    pub fn new(dataset: Dataset, algorithm: impl Into<AlgorithmId>) -> Self {
        Self {
            dataset,
            scale: 1.0,
            algorithm: algorithm.into(),
            params: AlgoParams::default(),
            parallelism: None,
            shards: None,
            priority: 0,
            deadline: None,
        }
    }

    pub fn with_scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    pub fn with_source(mut self, source: u32) -> Self {
        self.params.source = source;
        self
    }

    pub fn with_iterations(mut self, iterations: usize) -> Self {
        self.params.iterations = iterations;
        self
    }

    pub fn with_damping(mut self, damping: f32) -> Self {
        self.params.damping = damping;
        self
    }

    pub fn with_params(mut self, params: AlgoParams) -> Self {
        self.params = params;
        self
    }

    /// Override the session's execution-lane count for this job alone.
    pub fn with_parallelism(mut self, threads: usize) -> Self {
        self.parallelism = Some(threads);
        self
    }

    /// Override the session's shard count for this job alone (must be
    /// >= 1). A scheduling knob — shard count never changes a result
    /// byte.
    pub fn with_shards(mut self, shards: u32) -> Self {
        self.shards = Some(shards);
        self
    }

    /// Dequeue priority (higher first; default 0).
    pub fn with_priority(mut self, priority: i8) -> Self {
        self.priority = priority;
        self
    }

    /// Latency budget measured from submission; expired jobs are shed.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// The result-identity this spec coalesces under (see
    /// [`CoalesceKey`]).
    pub fn coalesce_key(&self) -> CoalesceKey {
        CoalesceKey {
            dataset: self.dataset,
            scale_micro: scale_micro(self.scale),
            algorithm: self.algorithm.clone(),
            source: self.params.source,
            iterations: self.params.iterations,
            // f32 is not Hash/Eq; the bit image is (NaN damping never
            // coalesces with anything but the same NaN bits — fine).
            damping_bits: self.params.damping.to_bits(),
        }
    }

    /// The batch-compatibility identity of this spec (see [`BatchKey`]):
    /// [`coalesce_key`](Self::coalesce_key) minus the source vertex.
    /// Scheduling only — this key never influences coalescing.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            dataset: self.dataset,
            scale_micro: scale_micro(self.scale),
            algorithm: self.algorithm.clone(),
            iterations: self.params.iterations,
            damping_bits: self.params.damping.to_bits(),
        }
    }

    /// Spec-level validation (algorithm existence and parameter checks
    /// happen against the session's registry at run time).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.scale > 0.0 && self.scale <= 1.0 && self.scale.is_finite(),
            "scale must be in (0, 1], got {}",
            self.scale
        );
        anyhow::ensure!(self.shards != Some(0), "shard count must be >= 1");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_style_construction() {
        let s = JobSpec::new(Dataset::Tiny, "BFS").with_scale(0.5).with_source(3);
        assert_eq!(s.algorithm.as_str(), "bfs");
        assert_eq!(s.scale, 0.5);
        assert_eq!(s.params.source, 3);
        assert_eq!(s.parallelism, None);
        assert_eq!(s.priority, 0);
        assert_eq!(s.deadline, None);
        assert!(s.validate().is_ok());
        assert_eq!(s.shards, None);
        assert_eq!(s.clone().with_shards(2).shards, Some(2));
        assert!(s.clone().with_shards(0).validate().is_err());
        assert_eq!(s.clone().with_parallelism(4).parallelism, Some(4));
        assert_eq!(s.clone().with_priority(7).priority, 7);
        assert_eq!(
            s.with_deadline(Duration::from_millis(5)).deadline,
            Some(Duration::from_millis(5))
        );
    }

    #[test]
    fn rejects_bad_scale() {
        assert!(JobSpec::new(Dataset::Tiny, "bfs").with_scale(0.0).validate().is_err());
        assert!(JobSpec::new(Dataset::Tiny, "bfs").with_scale(1.5).validate().is_err());
        assert!(JobSpec::new(Dataset::Tiny, "bfs").with_scale(f64::NAN).validate().is_err());
    }

    #[test]
    fn coalesce_key_tracks_result_identity_only() {
        let base = || JobSpec::new(Dataset::Tiny, "bfs").with_source(3);
        assert_eq!(base().coalesce_key(), base().coalesce_key());
        // Scheduling knobs don't change the key...
        assert_eq!(
            base().coalesce_key(),
            base()
                .with_parallelism(8)
                .with_shards(4)
                .with_priority(5)
                .with_deadline(Duration::from_secs(1))
                .coalesce_key()
        );
        // ...result-determining inputs do.
        assert_ne!(base().coalesce_key(), base().with_source(4).coalesce_key());
        assert_ne!(base().coalesce_key(), base().with_scale(0.5).coalesce_key());
        assert_ne!(base().coalesce_key(), base().with_iterations(9).coalesce_key());
        assert_ne!(base().coalesce_key(), base().with_damping(0.9).coalesce_key());
        assert_ne!(
            base().coalesce_key(),
            JobSpec::new(Dataset::Tiny, "sssp").with_source(3).coalesce_key()
        );
    }

    #[test]
    fn batch_key_groups_compatible_sources_and_never_drives_coalescing() {
        let base = || JobSpec::new(Dataset::Tiny, "bfs").with_source(3);
        // Different sources batch together...
        assert_eq!(base().batch_key(), base().with_source(4).batch_key());
        // ...but never coalesce: batch compatibility must not leak into
        // result identity.
        assert_ne!(base().coalesce_key(), base().with_source(4).coalesce_key());
        // Scheduling knobs don't change the batch key either.
        assert_eq!(
            base().batch_key(),
            base()
                .with_parallelism(8)
                .with_shards(4)
                .with_priority(5)
                .with_deadline(Duration::from_secs(1))
                .batch_key()
        );
        // Result-determining params other than the source split batches:
        // they select different execution artifacts or numeric programs.
        assert_ne!(base().batch_key(), base().with_scale(0.5).batch_key());
        assert_ne!(base().batch_key(), base().with_iterations(9).batch_key());
        assert_ne!(base().batch_key(), base().with_damping(0.9).batch_key());
        assert_ne!(
            base().batch_key(),
            JobSpec::new(Dataset::Tiny, "sssp").with_source(3).batch_key()
        );
    }
}
