//! The `Session` facade — the one way to construct the accelerator
//! pipeline.
//!
//! Every entry point (the CLI `run`/`dse` commands, the serving
//! coordinator, examples, benches) builds a [`Session`] and goes through
//! it; none of them hand-wire `Accelerator` + executor anymore. A session
//! bundles:
//!
//! * the architecture model ([`ArchConfig`]) and cost model ([`CostParams`]),
//! * a [`Backend`] selection — the pure-rust [`NativeExecutor`] mirror or
//!   the AOT/PJRT production datapath,
//! * an [`AlgorithmRegistry`] of pluggable vertex programs, and
//! * a shared [`ArtifactStore`] so preprocessing (Alg. 1) runs once per
//!   `(dataset, scale, weighted, arch)` key no matter how many callers
//!   or worker threads submit jobs.
//!
//! # The two-tier artifact cache
//!
//! The [`ArtifactStore`] is **two-tier** when the session is built with
//! [`SessionBuilder::artifact_dir`] (CLI `--artifact-dir`): tier 1 is the
//! in-memory `Arc` map (exactly-once compilation per key per process),
//! tier 2 an on-disk directory of versioned, checksummed serialized
//! [`Preprocessed`] artifacts ([`DiskStore`]) — partitioning, pattern
//! tables, *and the compiled `ExecutionPlan`*. Lookup is memory → disk →
//! recompute(+persist), so a restarted process (e.g. a redeployed serve
//! fleet) warm-starts with **zero plan compilations** for every key it
//! has seen before, the software analogue of the paper's
//! write-once-then-reuse static crossbars. Loaded plans are
//! byte-validated and bit-identical in behaviour to freshly compiled
//! ones (locked down by `rust/tests/artifact_io.rs`); any stale, corrupt
//! or mismatched file is a typed [`StoreError`] that falls back to
//! recompute. Pre-bake and inspect directories with the
//! `repro artifacts warm|ls` subcommands.
//!
//! # Streaming mutation
//!
//! [`Session::apply_delta`] is the write path of the streaming-ingest
//! subsystem: a validated [`DeltaBatch`](crate::graph::DeltaBatch) of
//! edge mutations (add / remove / reweight) is applied to the session's
//! view of a `(dataset, scale)` pair. Cached artifacts — both tiers,
//! weighted and unweighted — are **patched in place**: only the batch's
//! dirty adjacency windows are re-derived and the compiled plan is
//! section-patched, never recompiled
//! ([`sched::patch`](crate::sched::patch)); the on-disk copy is
//! republished under an accumulated [`DeltaProvenance`] stamp. The batch
//! is then appended to the session's delta log, so any key *not* cached
//! at patch time (skipped, not cold-compiled) is compiled against the
//! mutated graph on its next request. Determinism contract: a patched
//! artifact is bit-identical to a cold recompile of the mutated graph —
//! run results cannot depend on *how* the plan was produced (locked
//! down by `rust/tests/delta.rs` across algorithms, schedulers, and
//! thread counts).
//!
//! # Example
//!
//! ```no_run
//! use repro::graph::datasets::Dataset;
//! use repro::session::{Backend, JobSpec, Session};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = Session::builder().backend(Backend::Native).build()?;
//! let report = session.run(&JobSpec::new(Dataset::Tiny, "bfs").with_source(0))?;
//! println!("{}: {} supersteps, {:.3e} J", report.algorithm, report.supersteps,
//!          report.energy_j());
//!
//! // Algorithms are registry entries, not match arms: the same spec shape
//! // drives any registered program.
//! let pr = JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(10);
//! let _report = session.run(&pr)?;
//! # Ok(()) }
//! ```

mod artifact;
mod job;
mod store;

pub use artifact::{ArtifactKey, ArtifactStats, ArtifactStore, CompileFn};
pub use job::{BatchKey, CoalesceKey, JobSpec};
pub use store::{DeltaProvenance, DiskStore, StoreError, FORMAT_VERSION, SCHEMA_VERSION};

pub use crate::algo::registry::{AlgoParams, AlgorithmId, AlgorithmRegistry, BoxedProgram};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::accel::{Accelerator, ArchConfig, Preprocessed, PreprocessTiming, SimReport};
use crate::algo::traits::VertexProgram;
use crate::coordinator::metrics::PreprocessPhases;
use crate::cost::CostParams;
use crate::dse::SweepPoint;
use crate::graph::datasets::Dataset;
use crate::graph::{Coo, DeltaBatch};
use crate::sched::executor::NativeExecutor;
use crate::sched::{resolve_threads, PatchStats, StepExecutor, WorkerPool};

/// Upper bound on idle pools parked in a session's free list: enough
/// that a typical serve deployment (workers ≤ 8) keeps one spawn-once
/// pool per concurrent job, while a one-off concurrency burst beyond it
/// can't hold worker threads for the session's whole lifetime.
const MAX_FREE_POOLS: usize = 8;

/// What one [`Session::apply_delta`] call did across the session's
/// cached artifacts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeltaReport {
    /// Mutations in the batch after canonicalization (last-wins dedup).
    pub deltas: usize,
    /// Cached artifacts (memory or disk tier) patched in place — each
    /// one a whole-plan recompile avoided.
    pub patched_artifacts: u32,
    /// Artifact keys not patched in place: keys with nothing cached in
    /// either tier, plus shard-stamped variants dropped from the cache
    /// (sharded plans invalidate-to-recompile rather than patch) —
    /// either way the next request builds from the mutated graph.
    pub skipped_keys: u32,
    /// Patch work accumulated across the patched artifacts.
    pub stats: PatchStats,
}

/// Which numeric edge-compute datapath a session drives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust mirror of the L1/L2 kernels.
    Native,
    /// AOT-lowered HLO artifacts on the PJRT CPU client, loaded from the
    /// given artifact directory.
    Pjrt(PathBuf),
}

impl Backend {
    /// PJRT against the default artifact directory
    /// (`$REPRO_ARTIFACTS` or `./artifacts`).
    pub fn pjrt_default() -> Self {
        Backend::Pjrt(crate::runtime::default_artifact_dir())
    }

    /// Parse a CLI selector (`native` | `pjrt`).
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::pjrt_default()),
            other => anyhow::bail!("unknown backend {other:?} (native|pjrt)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Eager availability check, run at session build time so a
    /// misconfigured backend fails loudly up front — a PJRT session never
    /// silently falls back to the native executor.
    pub fn validate(&self) -> Result<()> {
        match self {
            Backend::Native => Ok(()),
            Backend::Pjrt(dir) => {
                anyhow::ensure!(
                    cfg!(feature = "pjrt"),
                    "backend pjrt selected but this binary was built without the \
                     `pjrt` feature (rebuild with `--features pjrt`)"
                );
                let manifest = dir.join("manifest.tsv");
                anyhow::ensure!(
                    manifest.exists(),
                    "backend pjrt selected but no artifact manifest at {} \
                     (run `make artifacts`); refusing to fall back to native",
                    manifest.display()
                );
                Ok(())
            }
        }
    }
}

/// Builder for [`Session`]. Defaults: paper §IV.A architecture, default
/// cost table, native backend, builtin algorithms, fresh artifact store.
#[derive(Debug)]
pub struct SessionBuilder {
    arch: ArchConfig,
    params: CostParams,
    backend: Backend,
    registry: Option<AlgorithmRegistry>,
    artifacts: Option<Arc<ArtifactStore>>,
    artifact_dir: Option<PathBuf>,
    parallelism: usize,
    preprocess_parallelism: Option<usize>,
    shards: u32,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self {
            arch: ArchConfig::default(),
            params: CostParams::default(),
            backend: Backend::Native,
            registry: None,
            artifacts: None,
            artifact_dir: None,
            parallelism: 1,
            preprocess_parallelism: None,
            shards: 1,
        }
    }
}

impl SessionBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn arch(mut self, arch: ArchConfig) -> Self {
        self.arch = arch;
        self
    }

    pub fn cost_params(mut self, params: CostParams) -> Self {
        self.params = params;
        self
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Replace the algorithm registry (default: the four builtins).
    pub fn registry(mut self, registry: AlgorithmRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Share an existing artifact store across sessions instead of
    /// starting one fresh. Safe across differing architectures: the
    /// cache key includes the preprocessing-relevant arch parameters.
    /// Mutually exclusive with [`artifact_dir`](Self::artifact_dir) —
    /// give the shared store its own directory instead.
    pub fn artifacts(mut self, store: Arc<ArtifactStore>) -> Self {
        self.artifacts = Some(store);
        self
    }

    /// Back the session's artifact store with an on-disk directory
    /// (created if needed): preprocessed artifacts — including the
    /// compiled `ExecutionPlan` — are serialized there and reloaded by
    /// later sessions/processes, so a warm start performs zero plan
    /// compilations. The CLI flag `--artifact-dir` and
    /// `ServiceConfig::artifact_dir` route here; pre-bake with
    /// `repro artifacts warm`.
    pub fn artifact_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifact_dir = Some(dir.into());
        self
    }

    /// Batch-parallel execution lanes per superstep (default 1 — the
    /// sequential interpreter; `0` = one lane per hardware thread,
    /// resolved eagerly at build time via
    /// [`resolve_threads`](crate::sched::resolve_threads)). Parallel jobs
    /// run on persistent [`WorkerPool`]s checked out of the session's
    /// free list — spawned once per peak-concurrent job and reused until
    /// the session drops. Results are bit-identical for every setting,
    /// so this is purely a throughput knob; a
    /// [`JobSpec::with_parallelism`] override wins per job (smaller
    /// overrides cap the lanes used, larger ones spawn a bigger pool).
    pub fn parallelism(mut self, threads: usize) -> Self {
        self.parallelism = threads;
        self
    }

    /// Default shard count for every job (default 1 — unsharded; must
    /// be >= 1). With `N > 1` each graph is split into `N` contiguous
    /// block-row shards ([`graph::shard`](crate::graph::shard)), each
    /// compiled to its own artifact under a shard-stamped
    /// [`ArtifactKey`] and run in lockstep through the deterministic
    /// cross-shard exchange
    /// ([`sched::exchange`](crate::sched::exchange)). Purely a
    /// scheduling knob: results are bit-identical for every shard
    /// count; a [`JobSpec::with_shards`] override wins per job. CLI
    /// flag: `--shards`.
    pub fn shards(mut self, shards: u32) -> Self {
        self.shards = shards;
        self
    }

    /// Worker threads for **cold preprocessing** — chunked partitioning,
    /// parallel pattern mining, and plan-section emission all fan out
    /// over the session's pooled workers on a full cache miss (`0` = one
    /// per hardware thread). Default: inherit the job's execution-lane
    /// count ([`parallelism`](Self::parallelism) /
    /// [`JobSpec::with_parallelism`]); the `REPRO_PREPROCESS_THREADS`
    /// environment variable overrides that default when no builder value
    /// is set. Purely a throughput knob: the parallel compile is
    /// whole-struct-equal to the sequential one for every thread count.
    pub fn preprocess_parallelism(mut self, threads: usize) -> Self {
        self.preprocess_parallelism = Some(threads);
        self
    }

    /// Validate everything eagerly and assemble the session.
    pub fn build(self) -> Result<Session> {
        self.arch.validate().context("invalid architecture")?;
        self.backend.validate()?;
        anyhow::ensure!(self.shards >= 1, "session shard count must be >= 1");
        let registry = self.registry.unwrap_or_default();
        anyhow::ensure!(!registry.is_empty(), "algorithm registry is empty");
        let artifacts = match (self.artifacts, self.artifact_dir) {
            (Some(_), Some(_)) => anyhow::bail!(
                "artifacts() and artifact_dir() are mutually exclusive — \
                 open the shared store with ArtifactStore::with_dir instead"
            ),
            (Some(store), None) => store,
            (None, Some(dir)) => Arc::new(
                ArtifactStore::with_dir(&dir)
                    .with_context(|| format!("opening artifact dir {}", dir.display()))?,
            ),
            (None, None) => Arc::default(),
        };
        // Builder override → environment → inherit the job lane count
        // (the `None` arm of `preprocess_threads_for`), resolved eagerly
        // so `0 = auto` never reaches the checkout path.
        let preprocess_parallelism = self
            .preprocess_parallelism
            .or_else(|| {
                std::env::var("REPRO_PREPROCESS_THREADS")
                    .ok()
                    .and_then(|s| s.trim().parse::<usize>().ok())
            })
            .map(resolve_threads);
        Ok(Session {
            arch: self.arch,
            params: self.params,
            backend: self.backend,
            registry: Arc::new(registry),
            artifacts,
            parallelism: resolve_threads(self.parallelism),
            preprocess_parallelism,
            shards: self.shards,
            pools: Mutex::new(Vec::new()),
            delta_log: Mutex::new(HashMap::new()),
        })
    }
}

/// The shared facade over preprocessing, dispatch, and cost reporting.
/// Cheap to share: clone the `Arc<Session>` the coordinator hands out.
#[derive(Debug)]
pub struct Session {
    arch: ArchConfig,
    params: CostParams,
    backend: Backend,
    registry: Arc<AlgorithmRegistry>,
    artifacts: Arc<ArtifactStore>,
    /// Resolved lane count (0-means-auto already applied).
    parallelism: usize,
    /// Cold-preprocess worker count override (builder or
    /// `REPRO_PREPROCESS_THREADS`; resolved, never 0). `None` = inherit
    /// the job's lane count per compile.
    preprocess_parallelism: Option<usize>,
    /// Default shard count (>= 1; a per-job [`JobSpec::with_shards`]
    /// override wins). A scheduling knob — never part of any cache or
    /// coalesce identity except the shard-stamped `ArtifactKey`s the
    /// sharded compile itself publishes under.
    shards: u32,
    /// Free list of persistent lane-worker pools. A parallel job checks
    /// one out (spawning it on first need), runs on it with the lock
    /// *released*, and checks it back in — so N concurrent serve workers
    /// converge on N pools, each spawned once and reused for every later
    /// job, and nobody falls back to per-run spawning under contention.
    /// All pools (and their worker threads) join when the session drops.
    pools: Mutex<Vec<WorkerPool>>,
    /// The streaming-mutation log: every [`DeltaBatch`] applied via
    /// [`apply_delta`](Self::apply_delta), keyed by `(dataset,
    /// fixed-point scale)` — the same microunit image the
    /// [`ArtifactKey`] uses, so "same scale" can never diverge between
    /// the log and the cache. Cache misses for a logged pair fold these
    /// batches into the dataset load before compiling.
    delta_log: Mutex<HashMap<(Dataset, u64), Vec<DeltaBatch>>>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// Paper defaults on the native backend.
    pub fn with_defaults() -> Result<Session> {
        Self::builder().build()
    }

    pub fn arch(&self) -> &ArchConfig {
        &self.arch
    }

    pub fn cost_params(&self) -> &CostParams {
        &self.params
    }

    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    pub fn registry(&self) -> &AlgorithmRegistry {
        &self.registry
    }

    pub fn artifacts(&self) -> &Arc<ArtifactStore> {
        &self.artifacts
    }

    /// The session's default superstep execution-lane count (resolved:
    /// never 0).
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Lanes for one job: the spec's override, else the session default.
    fn threads_for(&self, spec: &JobSpec) -> usize {
        spec.parallelism.map(resolve_threads).unwrap_or(self.parallelism)
    }

    /// The session's default shard count (>= 1).
    pub fn shards(&self) -> u32 {
        self.shards
    }

    /// Shard count for one job: the spec's override, else the session
    /// default.
    fn shards_for(&self, spec: &JobSpec) -> u32 {
        spec.shards.unwrap_or(self.shards).max(1)
    }

    /// Liveness probe of the session's persistent worker pools: `None`
    /// until the first parallel job spawns one; afterwards a `Weak` (of
    /// one idle pool's workers) that stops upgrading once the session —
    /// and so every pool and its worker threads — is gone. The "no
    /// leaked threads" test hook; probe it between jobs, not mid-run
    /// (a checked-out pool is not in the free list).
    pub fn pool_liveness(&self) -> Option<std::sync::Weak<()>> {
        self.pool_list().first().map(|p| p.liveness())
    }

    /// Lock the pool free list, recovering from poisoning (only a
    /// panicked check-in could poison it; the list itself is always
    /// structurally sound).
    fn pool_list(&self) -> std::sync::MutexGuard<'_, Vec<WorkerPool>> {
        self.pools.lock().unwrap_or_else(|p| {
            self.pools.clear_poison();
            p.into_inner()
        })
    }

    /// Check a pool with at least `threads` workers out of the free
    /// list. Too-small pools (from a smaller earlier override) are left
    /// in the list for jobs they still fit — never dropped under the
    /// lock, whose hold time stays O(scan). With a uniform lane count
    /// this spawns exactly once per peak-concurrent job.
    fn checkout_pool(&self, threads: usize) -> WorkerPool {
        let mut free = self.pool_list();
        if let Some(i) = free.iter().position(|p| p.workers() >= threads) {
            return free.swap_remove(i);
        }
        drop(free); // don't hold the lock across the spawn
        WorkerPool::new(threads)
    }

    /// Return a checked-out pool to the bounded free list (shared by the
    /// run dispatch and the pooled cold-compile path). The list is
    /// bounded so a one-off concurrency burst can't park worker threads
    /// forever; an overflow pool drops — joining its workers — outside
    /// the lock.
    fn checkin_pool(&self, pool: WorkerPool) {
        let overflow = {
            let mut free = self.pool_list();
            if free.len() < MAX_FREE_POOLS {
                free.push(pool);
                None
            } else {
                // Full: keep the most capable pools. Evict the smallest
                // parked pool if the incoming one is larger, so a
                // recurring large-override job class converges on a
                // parked pool instead of respawning per job.
                let smallest = free
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, p)| p.workers())
                    .map(|(i, _)| i);
                match smallest {
                    Some(i) if free[i].workers() < pool.workers() => {
                        Some(std::mem::replace(&mut free[i], pool))
                    }
                    _ => Some(pool),
                }
            }
        };
        drop(overflow);
    }

    /// Execute a prepared job on the right scheduler path. Sequential
    /// (and tracing) jobs take the interpreter; parallel jobs check a
    /// persistent pool out of the session free list, run on it with no
    /// lock held (concurrent jobs each get their own pooled workers,
    /// spawned once and reused), and check it back in. Per-job overrides
    /// smaller than a pool just cap the lanes they use.
    fn dispatch(
        &self,
        acc: &Accelerator,
        pre: &Preprocessed,
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
        threads: usize,
    ) -> Result<SimReport> {
        if threads <= 1 || self.arch.trace_activity {
            // Sequential interpreter (also the tracing path — see
            // `sched::par`); no pool involvement.
            return acc.run_threaded(pre, program, executor, 1);
        }
        let mut pool = self.checkout_pool(threads);
        let result = acc.run_pooled_at(pre, program, executor, &mut pool, threads);
        // Check the pool back in even when the job failed — pool workers
        // are job-agnostic. (If the run panicked, the pool unwinds and
        // joins its workers instead.)
        self.checkin_pool(pool);
        result
    }

    /// Sharded counterpart of [`dispatch`](Self::dispatch): one worker
    /// pool per shard checked out of the same free list (`pools[0]`
    /// doubles as the global lane-replay pool). Sequential and tracing
    /// jobs take the transient path — multi-shard tracing is a typed
    /// error raised by the exchange itself, never a silent fallback.
    fn dispatch_sharded(
        &self,
        acc: &Accelerator,
        pres: &[Arc<Preprocessed>],
        program: &dyn VertexProgram,
        executor: &mut dyn StepExecutor,
        threads: usize,
    ) -> Result<SimReport> {
        let shards: Vec<&Preprocessed> = pres.iter().map(|p| &**p).collect();
        if threads <= 1 || self.arch.trace_activity {
            return acc.run_sharded(&shards, program, executor, 1);
        }
        let mut pools: Vec<WorkerPool> =
            (0..shards.len()).map(|_| self.checkout_pool(threads)).collect();
        let result = acc.run_sharded_pooled(&shards, program, executor, &mut pools, threads);
        for pool in pools {
            self.checkin_pool(pool);
        }
        result
    }

    /// The accelerator model this session simulates.
    pub fn accelerator(&self) -> Accelerator {
        Accelerator::new(self.arch.clone(), self.params.clone())
    }

    /// Construct a fresh executor for this session's backend. Serve
    /// workers hold one each so PJRT compiles every artifact once per
    /// worker; `run` builds one per call.
    pub fn executor(&self) -> Result<Box<dyn StepExecutor>> {
        match &self.backend {
            Backend::Native => Ok(Box::new(NativeExecutor)),
            Backend::Pjrt(dir) => pjrt_executor(dir),
        }
    }

    /// Resolve and instantiate the job's program. `needs_weights` comes
    /// from the program itself, so the dataset loader and artifact key
    /// can never disagree with what the scheduler will demand.
    fn program_for(&self, spec: &JobSpec) -> Result<BoxedProgram> {
        spec.validate()?;
        self.registry.resolve(&spec.algorithm)?.instantiate(&spec.params)
    }

    /// Load the job's input graph (weighted iff the algorithm requires
    /// it), with every delta batch this session has applied to the
    /// spec's `(dataset, scale)` folded in.
    pub fn load_graph(&self, spec: &JobSpec) -> Result<Coo> {
        let program = self.program_for(spec)?;
        self.mutated_graph(spec.dataset, spec.scale, program.needs_weights())
    }

    /// The current graph for `(dataset, scale)`: the dataset load with
    /// the session's delta log applied on top, batch by batch, in
    /// arrival order. With an empty log this is exactly the dataset
    /// load.
    fn mutated_graph(&self, dataset: Dataset, scale: f64, weighted: bool) -> Result<Coo> {
        let mut g =
            if weighted { dataset.load_weighted(scale)? } else { dataset.load_scaled(scale)? };
        // Clone the batches out so the lock is not held across the folds.
        let batches = {
            let log = self.delta_log.lock().unwrap();
            log.get(&(dataset, artifact::scale_micro(scale))).cloned().unwrap_or_default()
        };
        for batch in &batches {
            g = batch.apply_to_coo(&g)?;
        }
        Ok(g)
    }

    fn has_mutations(&self, dataset: Dataset, scale: f64) -> bool {
        self.delta_log
            .lock()
            .unwrap()
            .contains_key(&(dataset, artifact::scale_micro(scale)))
    }

    /// Worker threads a cold compile for `spec` fans out over: the
    /// session override (builder / `REPRO_PREPROCESS_THREADS`), else the
    /// job's execution-lane count.
    fn preprocess_threads_for(&self, spec: &JobSpec) -> usize {
        self.preprocess_parallelism.unwrap_or_else(|| self.threads_for(spec))
    }

    /// Compile-or-fetch one key through the shared store. With more than
    /// one preprocess thread, a full-miss compile runs on pooled workers
    /// checked out of the session free list — the same spawn-once pools
    /// the run dispatch uses, never ad-hoc threads — and is
    /// whole-struct-equal to the sequential compile (the
    /// `rust/tests/preprocess_par.rs` contract).
    fn compile_artifact(
        &self,
        key: ArtifactKey,
        graph: Option<&Coo>,
        threads: usize,
    ) -> Result<Arc<Preprocessed>> {
        let acc = self.accelerator();
        if threads <= 1 {
            return match graph {
                Some(g) => self.artifacts.get_or_preprocess_from(key, &acc, g),
                None => self.artifacts.get_or_preprocess(key, &acc),
            };
        }
        self.artifacts
            .get_or_preprocess_with(key, &acc, graph, &|acc, g, weighted| {
                let mut pool = self.checkout_pool(threads);
                let result = acc.preprocess_timed(g, weighted, Some(&mut pool));
                self.checkin_pool(pool);
                result
            })
    }

    /// Compile-or-fetch a whole shard set: shard `s` lives under
    /// `base.with_shard(s, n)` — its own `.rpa` file on the disk tier —
    /// and any shard's full miss runs **one** global sharded compile
    /// ([`Accelerator::preprocess_sharded_timed`]) memoized across the
    /// set, so a cold start compiles each shard exactly once no matter
    /// how many shards miss. Warm starts load per-shard files with zero
    /// compiles, exactly like the unsharded tier-2 path.
    fn compile_sharded_artifacts(
        &self,
        base: ArtifactKey,
        shards: u32,
        graph: Option<&Coo>,
        threads: usize,
    ) -> Result<Vec<Arc<Preprocessed>>> {
        debug_assert!(shards > 1);
        let acc = self.accelerator();
        let compiled: Mutex<Option<Vec<(Preprocessed, PreprocessTiming)>>> = Mutex::new(None);
        let mut out = Vec::with_capacity(shards as usize);
        for s in 0..shards {
            let key = base.with_shard(s, shards);
            let pre =
                self.artifacts.get_or_preprocess_with(key, &acc, graph, &|acc, g, weighted| {
                    let mut cache = compiled.lock().unwrap();
                    if cache.is_none() {
                        let mut pool = (threads > 1).then(|| self.checkout_pool(threads));
                        let result = acc.preprocess_sharded_timed(
                            g,
                            weighted,
                            shards as usize,
                            pool.as_mut(),
                        );
                        if let Some(pool) = pool {
                            self.checkin_pool(pool);
                        }
                        *cache = Some(result?);
                    }
                    Ok(cache.as_ref().expect("memoized sharded compile")[s as usize].clone())
                })?;
            out.push(pre);
        }
        Ok(out)
    }

    /// Route one sharded artifact-set request with the same
    /// mutated-graph discipline as [`artifact_for`](Self::artifact_for);
    /// `shards == 1` is exactly the unsharded single-artifact path (the
    /// unstamped key — cache-compatible with artifacts written before
    /// sharding existed).
    fn sharded_artifacts_for(
        &self,
        spec: &JobSpec,
        weighted: bool,
        shards: u32,
        graph: Option<&Coo>,
    ) -> Result<Vec<Arc<Preprocessed>>> {
        let base = self.key_for(spec, weighted);
        let threads = self.preprocess_threads_for(spec);
        let owned;
        let graph = match graph {
            Some(g) => Some(g),
            None if self.has_mutations(spec.dataset, spec.scale) => {
                owned = self.mutated_graph(spec.dataset, spec.scale, weighted)?;
                Some(&owned)
            }
            None => None,
        };
        if shards <= 1 {
            return Ok(vec![self.compile_artifact(base, graph, threads)?]);
        }
        self.compile_sharded_artifacts(base, shards, graph, threads)
    }

    /// Route one artifact request: a key whose `(dataset, scale)` has
    /// logged mutations must compile (on a full miss) from the mutated
    /// graph, never the pristine dataset load — a patched cache hit and
    /// a post-mutation cold compile must be the same artifact.
    fn artifact_for(&self, spec: &JobSpec, weighted: bool) -> Result<Arc<Preprocessed>> {
        let key = self.key_for(spec, weighted);
        let threads = self.preprocess_threads_for(spec);
        if self.has_mutations(spec.dataset, spec.scale) {
            let g = self.mutated_graph(spec.dataset, spec.scale, weighted)?;
            self.compile_artifact(key, Some(&g), threads)
        } else {
            self.compile_artifact(key, None, threads)
        }
    }

    /// Alg. 1 through the shared [`ArtifactStore`]: preprocesses at most
    /// once per `(dataset, scale, weighted, arch)` key across all
    /// callers.
    pub fn preprocess(&self, spec: &JobSpec) -> Result<Arc<Preprocessed>> {
        let program = self.program_for(spec)?;
        self.artifact_for(spec, program.needs_weights())
    }

    /// Sharded Alg. 1 through the shared store: the job's shard count
    /// (`spec.shards`, else the session default) decides the set; each
    /// shard caches under its own shard-stamped [`ArtifactKey`] — its
    /// own `.rpa` file on the disk tier — so `repro artifacts warm
    /// --shards N` pre-bakes a whole scale-out deployment. One shard is
    /// exactly [`preprocess`](Self::preprocess): the unstamped key,
    /// cache-compatible with artifacts written before sharding existed.
    pub fn preprocess_sharded(&self, spec: &JobSpec) -> Result<Vec<Arc<Preprocessed>>> {
        let program = self.program_for(spec)?;
        self.sharded_artifacts_for(spec, program.needs_weights(), self.shards_for(spec), None)
    }

    /// Apply a batch of streaming edge mutations to the spec's
    /// `(dataset, scale)` pair. The batch is validated against the
    /// current (already-mutated) topology first — a rejected batch has
    /// no effect on any tier or the log. On success every cached
    /// artifact for the pair (weighted and unweighted; the algorithm in
    /// `spec` does not narrow the invalidation) is patched in place via
    /// [`ArtifactStore::patch`], and the batch joins the session's delta
    /// log so uncached keys compile against the mutated graph later.
    pub fn apply_delta(&self, spec: &JobSpec, batch: &DeltaBatch) -> Result<DeltaReport> {
        spec.validate()?;
        // Weighted and unweighted loads share one topology, so one
        // unweighted dry-run validates the batch for both keys.
        let current = self.mutated_graph(spec.dataset, spec.scale, false)?;
        batch.apply_to_coo(&current)?;
        let mut report = DeltaReport { deltas: batch.len(), ..DeltaReport::default() };
        for weighted in [false, true] {
            match self.artifacts.patch(self.key_for(spec, weighted), &self.arch, batch)? {
                Some(stats) => {
                    report.patched_artifacts += 1;
                    report.stats.absorb(&stats);
                }
                None => report.skipped_keys += 1,
            }
            // Shard-stamped variants are invalidated-to-recompile rather
            // than patched: the delta log routes their next compile to
            // the mutated graph, which the determinism contract makes
            // bit-identical to an in-place patch.
            report.skipped_keys += self.artifacts.invalidate_sharded(self.key_for(spec, weighted));
        }
        if !batch.is_empty() {
            self.delta_log
                .lock()
                .unwrap()
                .entry((spec.dataset, artifact::scale_micro(spec.scale)))
                .or_default()
                .push(batch.clone());
        }
        Ok(report)
    }

    /// Like [`preprocess`](Self::preprocess) but from a caller-loaded
    /// graph (must be the spec's dataset/scale), avoiding a second
    /// dataset load on a cache miss.
    pub fn preprocess_on(&self, spec: &JobSpec, graph: &Coo) -> Result<Arc<Preprocessed>> {
        let program = self.program_for(spec)?;
        let key = self.key_for(spec, program.needs_weights());
        self.compile_artifact(key, Some(graph), self.preprocess_threads_for(spec))
    }

    /// Phase-split wall time of every cold compile this session's store
    /// has run (partition / rank / tables / plan, min/mean/max) — what
    /// `repro artifacts warm` prints and `Service::snapshot` surfaces.
    pub fn preprocess_phases(&self) -> PreprocessPhases {
        self.artifacts.preprocess_phases()
    }

    /// Run a job end to end on a fresh backend executor.
    pub fn run(&self, spec: &JobSpec) -> Result<SimReport> {
        let mut exec = self.executor()?;
        self.run_with(spec, exec.as_mut())
    }

    /// Run against a caller-loaded graph (must be the spec's
    /// dataset/scale): skips the second dataset load when the caller
    /// also needs the graph, e.g. the CLI's `--validate` path.
    pub fn run_on(&self, spec: &JobSpec, graph: &Coo) -> Result<SimReport> {
        let program = self.program_for(spec)?;
        let acc = self.accelerator();
        let shards = self.shards_for(spec);
        let mut exec = self.executor()?;
        if shards <= 1 {
            let key = self.key_for(spec, program.needs_weights());
            let pre =
                self.compile_artifact(key, Some(graph), self.preprocess_threads_for(spec))?;
            return self.dispatch(
                &acc,
                &pre,
                program.as_ref(),
                exec.as_mut(),
                self.threads_for(spec),
            );
        }
        let pres =
            self.sharded_artifacts_for(spec, program.needs_weights(), shards, Some(graph))?;
        self.dispatch_sharded(&acc, &pres, program.as_ref(), exec.as_mut(), self.threads_for(spec))
    }

    /// Run a job on a caller-provided executor (the serve workers reuse
    /// one executor across jobs to amortize PJRT compilation).
    pub fn run_with(
        &self,
        spec: &JobSpec,
        executor: &mut dyn StepExecutor,
    ) -> Result<SimReport> {
        let program = self.program_for(spec)?;
        let acc = self.accelerator();
        let shards = self.shards_for(spec);
        if shards <= 1 {
            let pre = self.artifact_for(spec, program.needs_weights())?;
            return self.dispatch(&acc, &pre, program.as_ref(), executor, self.threads_for(spec));
        }
        let pres = self.sharded_artifacts_for(spec, program.needs_weights(), shards, None)?;
        self.dispatch_sharded(&acc, &pres, program.as_ref(), executor, self.threads_for(spec))
    }

    /// Run a batch of **batch-compatible** jobs (equal
    /// [`JobSpec::batch_key`], equal `parallelism`/`shards` overrides —
    /// the serve queue's claim rule) through one lane-interleaved
    /// pipeline pass, sharing the artifact lookup, pool checkout, plan
    /// walk, and crossbar replay across the whole batch. Every returned
    /// report is bit-identical to `run_with` on that spec alone; batches
    /// the pipeline cannot take whole (sharded, tracing, sequential, or
    /// singleton) fall back to solo runs in order, so callers always get
    /// solo-identical results and errors.
    pub fn run_batch_with(
        &self,
        specs: &[JobSpec],
        executor: &mut dyn StepExecutor,
    ) -> Result<Vec<SimReport>> {
        anyhow::ensure!(!specs.is_empty(), "empty job batch");
        let leader = &specs[0];
        // Compatibility is a caller contract, enforced here so the
        // batched and fallback paths reject the same inputs: mixed
        // batch keys would run every job against the leader's artifact.
        for s in &specs[1..] {
            anyhow::ensure!(
                s.batch_key() == leader.batch_key()
                    && s.parallelism == leader.parallelism
                    && s.shards == leader.shards,
                "job batch mixes incompatible specs ({} vs {})",
                s.algorithm.as_str(),
                leader.algorithm.as_str(),
            );
        }
        let threads = self.threads_for(leader);
        if specs.len() == 1
            || self.shards_for(leader) > 1
            || self.arch.trace_activity
            || threads <= 1
        {
            return specs.iter().map(|s| self.run_with(s, executor)).collect();
        }
        let programs: Vec<BoxedProgram> =
            specs.iter().map(|s| self.program_for(s)).collect::<Result<_>>()?;
        let weighted = programs[0].needs_weights();
        let pre = self.artifact_for(leader, weighted)?;
        let acc = self.accelerator();
        let refs: Vec<&dyn VertexProgram> = programs.iter().map(|p| p.as_ref()).collect();
        let mut pool = self.checkout_pool(threads);
        let result = acc.run_batch_pooled_at(&pre, &refs, executor, &mut pool, threads);
        self.checkin_pool(pool);
        result
    }

    /// DSE: best static/dynamic engine split for the job's algorithm on
    /// its dataset (paper Fig. 6 / conclusion). Reuses the session's
    /// cached Alg.-1 output; only the N-dependent pieces — the config
    /// table and the execution plan's static-slot section — are rebuilt
    /// per candidate, on a scratch copy so the shared artifact (and its
    /// compiled plan) stays untouched.
    pub fn dse(
        &self,
        spec: &JobSpec,
        candidates: Option<&[u32]>,
    ) -> Result<(u32, Vec<SweepPoint>)> {
        let program = self.program_for(spec)?;
        let mut scratch = (*self.preprocess(spec)?).clone();
        crate::dse::find_best_static_split_with(
            &mut scratch,
            &self.arch,
            &self.params,
            program.as_ref(),
            candidates,
        )
    }

    fn key_for(&self, spec: &JobSpec, weighted: bool) -> ArtifactKey {
        ArtifactKey::new(spec.dataset, spec.scale, weighted, &self.arch)
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_executor(dir: &std::path::Path) -> Result<Box<dyn StepExecutor>> {
    let rt = crate::runtime::PjrtRuntime::new(dir.to_path_buf())?;
    Ok(Box::new(crate::runtime::PjrtExecutor::new(rt)))
}

/// Unreachable in practice: `Backend::validate` already rejected the
/// PJRT selection at build time in a non-PJRT binary. Kept as a loud
/// guard for sessions constructed through future unchecked paths.
#[cfg(not(feature = "pjrt"))]
fn pjrt_executor(_dir: &std::path::Path) -> Result<Box<dyn StepExecutor>> {
    anyhow::bail!("backend pjrt requires building with `--features pjrt`")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;

    #[test]
    fn default_session_runs_bfs() {
        let session = Session::with_defaults().unwrap();
        let report = session
            .run(&JobSpec::new(Dataset::Tiny, "bfs").with_source(0))
            .unwrap();
        assert_eq!(report.algorithm, "bfs");
        assert!(report.counts.mvm_ops > 0);
    }

    #[test]
    fn invalid_arch_rejected_at_build() {
        let bad = ArchConfig { static_engines: 99, ..ArchConfig::default() };
        assert!(Session::builder().arch(bad).build().is_err());
    }

    #[test]
    fn empty_registry_rejected_at_build() {
        let err = Session::builder()
            .registry(AlgorithmRegistry::empty())
            .build()
            .map(|_| ())
            .unwrap_err();
        assert!(err.to_string().contains("registry"), "{err}");
    }

    #[test]
    fn pjrt_backend_without_artifacts_fails_loudly() {
        let backend = Backend::Pjrt(PathBuf::from("/definitely/not/artifacts"));
        let err = Session::builder().backend(backend).build().map(|_| ()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("PJRT").unwrap().name(), "pjrt");
        assert!(Backend::parse("tpu").is_err());
    }

    #[test]
    fn parallel_session_is_bit_identical_to_sequential() {
        let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(0);
        let seq = Session::with_defaults().unwrap().run(&spec).unwrap();
        let par = Session::builder()
            .parallelism(4)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert_eq!(seq.run.as_ref().unwrap().values, par.run.as_ref().unwrap().values);
        assert_eq!(seq.counts, par.counts);
        assert_eq!(seq.exec_time_ns, par.exec_time_ns);

        // A per-job override wins over the session default — and stays
        // bit-identical too.
        let over = Session::with_defaults()
            .unwrap()
            .run(&spec.clone().with_parallelism(8))
            .unwrap();
        assert_eq!(seq.counts, over.counts);
        assert_eq!(seq.exec_time_ns, over.exec_time_ns);
    }

    #[test]
    fn sharded_session_is_bit_identical_to_unsharded() {
        let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(0);
        let seq = Session::with_defaults().unwrap().run(&spec).unwrap();
        let sharded = Session::builder().shards(3).parallelism(4).build().unwrap();
        let a = sharded.run(&spec).unwrap();
        assert_eq!(seq.run.as_ref().unwrap().values, a.run.as_ref().unwrap().values);
        assert_eq!(seq.counts, a.counts);
        assert_eq!(seq.exec_time_ns, a.exec_time_ns);
        // One cached artifact per shard; a second run recompiles nothing
        // and stays bit-identical.
        assert_eq!(sharded.artifacts().stats().entries, 3);
        let misses = sharded.artifacts().stats().misses;
        let b = sharded.run(&spec).unwrap();
        assert_eq!(sharded.artifacts().stats().misses, misses);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.exec_time_ns, b.exec_time_ns);
        // A per-job shard override wins over the session default — and
        // is a pure scheduling knob too.
        let over = Session::with_defaults()
            .unwrap()
            .run(&spec.clone().with_shards(2).with_parallelism(4))
            .unwrap();
        assert_eq!(seq.counts, over.counts);
        assert_eq!(seq.exec_time_ns, over.exec_time_ns);
        // Zero shards is rejected at build time like any bad config.
        assert!(Session::builder().shards(0).build().is_err());
    }

    #[test]
    fn batched_session_runs_are_bit_identical_to_solo() {
        let session = Session::builder().parallelism(4).build().unwrap();
        let specs: Vec<JobSpec> =
            (0..3).map(|s| JobSpec::new(Dataset::Tiny, "bfs").with_source(s)).collect();
        let mut exec = session.executor().unwrap();
        let batched = session.run_batch_with(&specs, exec.as_mut()).unwrap();
        assert_eq!(batched.len(), specs.len());
        for (spec, b) in specs.iter().zip(&batched) {
            let solo = session.run(spec).unwrap();
            assert_eq!(solo.run.as_ref().unwrap().values, b.run.as_ref().unwrap().values);
            assert_eq!(solo.counts, b.counts);
            assert_eq!(solo.exec_time_ns, b.exec_time_ns);
            assert_eq!(solo.supersteps, b.supersteps);
        }
        // Sequential sessions take the solo fallback and still answer
        // every spec in order.
        let seq = Session::with_defaults().unwrap();
        let mut seq_exec = seq.executor().unwrap();
        let reports = seq.run_batch_with(&specs, seq_exec.as_mut()).unwrap();
        assert_eq!(reports.len(), specs.len());
        assert!(seq.run_batch_with(&[], seq_exec.as_mut()).is_err());
    }

    #[test]
    fn apply_delta_invalidates_sharded_variants() {
        let session = Session::builder().shards(2).build().unwrap();
        let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(0);
        session.run(&spec).unwrap();
        let g = session.load_graph(&spec).unwrap();
        let e = g.edges[0];
        let batch = DeltaBatch::new(
            g.num_vertices,
            vec![crate::graph::EdgeDelta::remove(e.src, e.dst)],
        )
        .unwrap();
        let report = session.apply_delta(&spec, &batch).unwrap();
        // Nothing was patched in place — only shard-stamped keys were
        // cached, and those invalidate-to-recompile: 2 empty base keys
        // plus the 2 dropped shard variants.
        assert_eq!(report.patched_artifacts, 0);
        assert_eq!(report.skipped_keys, 4);
        // The post-delta sharded run compiles from the mutated graph and
        // matches a cold unsharded run on the same graph byte for byte.
        let after = session.run(&spec).unwrap();
        let cold = Session::with_defaults()
            .unwrap()
            .run_on(&spec, &session.load_graph(&spec).unwrap())
            .unwrap();
        assert_eq!(after.run.as_ref().unwrap().values, cold.run.as_ref().unwrap().values);
        assert_eq!(after.counts, cold.counts);
        assert_eq!(after.exec_time_ns, cold.exec_time_ns);
    }

    #[test]
    fn pool_is_lazy_reused_and_joined_on_drop() {
        let session = Session::builder().parallelism(4).build().unwrap();
        assert!(session.pool_liveness().is_none(), "pool spawns lazily");
        let spec = JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(5);
        let a = session.run(&spec).unwrap();
        let token = session
            .pool_liveness()
            .expect("first parallel job spawns the pool");
        assert!(token.upgrade().is_some(), "workers alive with the session");
        // Consecutive runs reuse the pool and stay bit-identical.
        let b = session.run(&spec).unwrap();
        assert_eq!(a.run.as_ref().unwrap().values, b.run.as_ref().unwrap().values);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.exec_time_ns, b.exec_time_ns);
        drop(session);
        assert!(token.upgrade().is_none(), "session drop joins every worker");
    }

    #[test]
    fn pooled_cold_compile_matches_sequential_and_parks_its_pool() {
        let spec = JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(3);
        let seq = Session::with_defaults().unwrap().preprocess(&spec).unwrap();
        let par_session = Session::builder().preprocess_parallelism(4).build().unwrap();
        let par = par_session.preprocess(&spec).unwrap();
        assert_eq!(*seq, *par, "pooled compile must be whole-struct-equal");
        let ph = par_session.preprocess_phases();
        assert_eq!(ph.compiles, 1);
        assert!(ph.total.max_ns > 0);
        // The compile went through the session free list: its pool is
        // parked for reuse, not torn down (no ad-hoc threads).
        assert!(par_session.pool_liveness().is_some(), "compile pool joins the free list");
        // A second, already-cached preprocess records no new compile.
        par_session.preprocess(&spec).unwrap();
        assert_eq!(par_session.preprocess_phases().compiles, 1);
    }

    #[test]
    fn zero_parallelism_resolves_to_hardware_threads_at_build() {
        let session = Session::builder().parallelism(0).build().unwrap();
        assert!(session.parallelism() >= 1, "0 = auto is resolved eagerly");
    }

    #[test]
    fn apply_delta_patches_cache_and_routes_later_runs() {
        let session = Session::with_defaults().unwrap();
        let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(0);
        session.run(&spec).unwrap();

        let g = session.load_graph(&spec).unwrap();
        let e = g.edges[0];
        let batch = DeltaBatch::new(
            g.num_vertices,
            vec![crate::graph::EdgeDelta::remove(e.src, e.dst)],
        )
        .unwrap();
        let report = session.apply_delta(&spec, &batch).unwrap();
        // bfs is unweighted, so only that key was cached; the weighted
        // key had nothing to invalidate.
        assert_eq!((report.patched_artifacts, report.skipped_keys), (1, 1));
        assert_eq!(report.stats.removes, 1);

        // The next run serves the patched artifact (no recompile) and is
        // bit-identical to a fresh session run on the mutated graph.
        let patched = session.run(&spec).unwrap();
        assert_eq!(session.artifacts().stats().misses, 1, "patch avoided a recompile");
        let fresh = Session::with_defaults().unwrap();
        let cold = fresh.run_on(&spec, &session.load_graph(&spec).unwrap()).unwrap();
        assert_eq!(
            patched.run.as_ref().unwrap().values,
            cold.run.as_ref().unwrap().values
        );
        assert_eq!(patched.counts, cold.counts);
        assert_eq!(patched.exec_time_ns, cold.exec_time_ns);
    }

    #[test]
    fn rejected_delta_has_no_effect() {
        let session = Session::with_defaults().unwrap();
        let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(0);
        let before = session.run(&spec).unwrap();
        let g = session.load_graph(&spec).unwrap();
        let e = g.edges[0];
        // Adding an existing edge is rejected up front: no artifact is
        // patched and the log stays empty.
        let bad =
            DeltaBatch::new(g.num_vertices, vec![crate::graph::EdgeDelta::add(e.src, e.dst)])
                .unwrap();
        assert!(session.apply_delta(&spec, &bad).is_err());
        assert!(!session.has_mutations(spec.dataset, spec.scale));
        let after = session.run(&spec).unwrap();
        assert_eq!(before.counts, after.counts);
        assert_eq!(before.exec_time_ns, after.exec_time_ns);
    }

    #[test]
    fn repeated_runs_share_preprocessing() {
        let session = Session::with_defaults().unwrap();
        let spec = JobSpec::new(Dataset::Tiny, "wcc");
        session.run(&spec).unwrap();
        session.run(&spec).unwrap();
        let s = session.artifacts().stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
    }
}
